"""Shared benchmark utilities: timing + CSV emission."""

import time

import numpy as np


def timeit(fn, *args, repeat: int = 3, **kwargs):
    """Median wall time of fn(*args) over `repeat` runs, seconds."""
    times = []
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
