"""Kernel-level microbenchmarks (paper §4.1.2's LUT16 throughput claim and
§3's cache-line model, TPU-adapted).

interpret-mode wall time is NOT a TPU estimate — the structural metrics are
the point here:
  * lut16: bytes streamed per score vs a dense f32 matmul (the paper's 16x
    index-size reduction => 16x fewer HBM bytes on the scan);
  * block_sparse: tiles stored/streamed after cache sorting vs unsorted —
    the exact counter the Eq. 4/5 model predicts (DMA traffic on TPU).
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp
import jax.numpy as jnp

import repro.core.cache_sort as cs
from repro.kernels.block_sparse import dense_to_bcsr
from repro.kernels.ops import lut16_adc
from repro.kernels.ref import lut16_adc_ref

from .common import emit, timeit


def bench_lut16():
    rng = np.random.default_rng(0)
    n, k, l, q = 20000, 32, 16, 16
    d_dense = k * 2
    codes = jnp.asarray(rng.integers(0, l, (n, k)).astype(np.uint8))
    lut = jnp.asarray(rng.normal(size=(q, k, l)).astype(np.float32))

    s_ref, _ = timeit(lambda: lut16_adc_ref(codes, lut).block_until_ready())
    s_ker, _ = timeit(lambda: lut16_adc(codes, lut).block_until_ready())
    # packed 4-bit path (paper's storage; halves the HBM stream again) —
    # through the same ops wrapper the engine's pallas-packed backend uses
    from repro.kernels.ops import pack_codes
    packed = jnp.asarray(pack_codes(np.asarray(codes)))
    s_pk, _ = timeit(lambda: lut16_adc(
        packed, lut, bq=8, bn=512, bk=16, packed=True).block_until_ready())
    # structural: bytes per datapoint scanned
    pq_bytes = k                      # uint8 per subspace
    packed_bytes = packed.shape[1]    # two 4-bit codes per byte
    dense_bytes = d_dense * 4
    emit("lut16_ref_scan", s_ref / (n * q) * 1e6,
         f"bytes_per_point={pq_bytes}")
    emit("lut16_kernel_scan", s_ker / (n * q) * 1e6,
         f"bytes_per_point={pq_bytes};dense_equiv={dense_bytes};"
         f"traffic_reduction={dense_bytes / pq_bytes:.0f}x")
    emit("lut16_kernel_packed4bit", s_pk / (n * q) * 1e6,
         f"bytes_per_point={packed_bytes};dense_equiv={dense_bytes};"
         f"index_bytes={packed.nbytes};unpacked_index_bytes={codes.nbytes};"
         f"traffic_reduction={dense_bytes / packed_bytes:.0f}x")


def bench_block_sparse():
    """Tile counts on the *pruned* head matrix — the object the real pipeline
    builds (HybridIndex eta-prunes before tiling; unpruned dense-ish columns
    are exactly what the paper's hyper-sparse first-pass index removes).
    Tile = 8 rows × 128 lanes (TPU sublane×lane granularity; B=8 in Eq. 4/5
    terms)."""
    from repro.core.pruning import prune_split
    rng = np.random.default_rng(1)
    n, d = 8192, 512
    pj = np.minimum(1.0, cs.power_law_probs(d, 2.0) * 4)
    x = sp.csr_matrix(((rng.random((n, d)) < pj[None, :])
                       * rng.lognormal(0, 1, (n, d))).astype(np.float32))
    pruned = prune_split(x, keep_top=192).index
    dense = pruned.toarray()
    br, bc = 8, 128
    tiles_un, _, _ = dense_to_bcsr(dense, br, bc)
    pi = cs.cache_sort(pruned)
    tiles_so, _, _ = dense_to_bcsr(dense[pi], br, bc)
    total_tiles = (n // br) * (d // bc)
    emit("block_sparse_tiles_unsorted", 0.0,
         f"tiles={tiles_un.shape[0]}/{total_tiles}")
    emit("block_sparse_tiles_cache_sorted", 0.0,
         f"tiles={tiles_so.shape[0]}/{total_tiles};"
         f"dma_reduction={tiles_un.shape[0] / max(tiles_so.shape[0], 1):.2f}x")


def main():
    bench_lut16()
    bench_block_sparse()


if __name__ == "__main__":
    main()
