"""Kernel-level microbenchmarks (paper §4.1.2's LUT16 throughput claim and
§3's cache-line model, TPU-adapted).

interpret-mode wall time is NOT a TPU estimate — the structural metrics are
the point here:
  * lut16: bytes streamed per score vs a dense f32 matmul (the paper's 16x
    index-size reduction => 16x fewer HBM bytes on the scan);
  * block_sparse: tiles stored/streamed after cache sorting vs unsorted —
    the exact counter the Eq. 4/5 model predicts (DMA traffic on TPU).
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp
import jax.numpy as jnp

import repro.core.cache_sort as cs
from repro.kernels.block_sparse import dense_to_bcsr
from repro.kernels.ops import lut16_adc
from repro.kernels.ref import lut16_adc_ref

from .common import emit, timeit


def bench_lut16():
    rng = np.random.default_rng(0)
    n, k, l, q = 20000, 32, 16, 16
    d_dense = k * 2
    codes = jnp.asarray(rng.integers(0, l, (n, k)).astype(np.uint8))
    lut = jnp.asarray(rng.normal(size=(q, k, l)).astype(np.float32))

    s_ref, _ = timeit(lambda: lut16_adc_ref(codes, lut).block_until_ready())
    s_ker, _ = timeit(lambda: lut16_adc(codes, lut).block_until_ready())
    # packed 4-bit path (paper's storage; halves the HBM stream again) —
    # through the same ops wrapper the engine's pallas-packed backend uses
    from repro.kernels.ops import pack_codes
    packed = jnp.asarray(pack_codes(np.asarray(codes)))
    s_pk, _ = timeit(lambda: lut16_adc(
        packed, lut, bq=8, bn=512, bk=16, packed=True).block_until_ready())
    # structural: bytes per datapoint scanned
    pq_bytes = k                      # uint8 per subspace
    packed_bytes = packed.shape[1]    # two 4-bit codes per byte
    dense_bytes = d_dense * 4
    emit("lut16_ref_scan", s_ref / (n * q) * 1e6,
         f"bytes_per_point={pq_bytes}")
    emit("lut16_kernel_scan", s_ker / (n * q) * 1e6,
         f"bytes_per_point={pq_bytes};dense_equiv={dense_bytes};"
         f"traffic_reduction={dense_bytes / pq_bytes:.0f}x")
    emit("lut16_kernel_packed4bit", s_pk / (n * q) * 1e6,
         f"bytes_per_point={packed_bytes};dense_equiv={dense_bytes};"
         f"index_bytes={packed.nbytes};unpacked_index_bytes={codes.nbytes};"
         f"traffic_reduction={dense_bytes / packed_bytes:.0f}x")


def fused_vmem_bytes(bq: int, bn: int, bk: int, *, l: int = 16,
                     packed: bool = False, cbuf: int = 128) -> int:
    """Resident VMEM estimate for one fused scan-and-select grid step
    (DESIGN.md §2.5's budget table): code block + LUT block + accumulator
    scratch + candidate buffers, times 2 for the double-buffered input
    stream Pallas pipelines automatically."""
    lut_bk = 2 * bk if packed else bk
    codes_blk = bn * bk                       # uint8
    lut_blk = bq * lut_bk * l * 4             # f32
    acc = bq * bn * 4                         # f32 scratch
    buf = bq * cbuf * (4 + 4)                 # f32 scores + i32 ids
    return 2 * (codes_blk + lut_blk) + acc + buf


VMEM_BUDGET = 16 * 2 ** 20                    # v5e per-core VMEM


def autotune_fused_blocks(*, n: int = 8192, k: int = 32, l: int = 16,
                          q: int = 8, topk: int = 128,
                          packed: bool = False) -> dict:
    """Sweep the fused kernel's (bq, bn, bk) grid under the VMEM budget and
    time each candidate on a small workload.  Returns the swept candidates
    (with VMEM estimates), the fastest config, and the budget — recorded in
    BENCH_engine.json so the shipped defaults are an audited choice, not a
    guess.  Interpret-mode timings rank relative block overheads only; the
    VMEM feasibility column is hardware-independent."""
    from repro.kernels.lut16 import candidate_buffer_width
    from repro.kernels.ops import lut16_adc_topk
    rng = np.random.default_rng(7)
    codes_np = rng.integers(0, l, (n, k)).astype(np.uint8)
    if packed:
        from repro.kernels.ops import pack_codes
        codes = jnp.asarray(pack_codes(codes_np))
    else:
        codes = jnp.asarray(codes_np)
    lut = jnp.asarray(rng.normal(size=(q, k, l)).astype(np.float32))
    cbuf = candidate_buffer_width(topk)

    candidates = []
    best = None
    for bq in (8,):
        for bn in (128, 256, 512, 1024):
            for bk in (8, 16, 32):
                vmem = fused_vmem_bytes(bq, bn, bk, l=l, packed=packed,
                                        cbuf=cbuf)
                entry = {"bq": bq, "bn": bn, "bk": bk, "vmem_bytes": vmem,
                         "fits": vmem <= VMEM_BUDGET}
                if entry["fits"]:
                    fn = lambda: lut16_adc_topk(
                        codes, lut, topk, bq=bq, bn=bn, bk=bk,
                        packed=packed)[0].block_until_ready()
                    fn()                      # warmup/compile
                    secs, _ = timeit(fn, repeat=3)
                    entry["us"] = secs * 1e6
                    if best is None or entry["us"] < best["us"]:
                        best = entry
                candidates.append(entry)
    return {"workload": {"n": n, "k": k, "l": l, "q": q, "topk": topk,
                         "packed": packed},
            "budget_bytes": VMEM_BUDGET, "candidates": candidates,
            "best": best}


def bench_fused_topk():
    """Fused scan-and-select vs materialize + top_k, unpacked and packed —
    the tentpole A/B.  Off-TPU the wall times are interpret proxies; the
    honest claims are the byte columns (packed stream strictly half) and
    the structural no-materialization assertion (test_kernels)."""
    from repro.kernels.ops import lut16_adc_topk, pack_codes
    rng = np.random.default_rng(2)
    n, k, l, q, topk = 20000, 32, 16, 16, 128
    codes_np = rng.integers(0, l, (n, k)).astype(np.uint8)
    codes = jnp.asarray(codes_np)
    packed = jnp.asarray(pack_codes(codes_np))
    lut = jnp.asarray(rng.normal(size=(q, k, l)).astype(np.float32))

    runs = {
        "fused": lambda: lut16_adc_topk(
            codes, lut, topk, fused=True)[0].block_until_ready(),
        "materialize": lambda: lut16_adc_topk(
            codes, lut, topk, fused=False)[0].block_until_ready(),
        "fused_packed": lambda: lut16_adc_topk(
            packed, lut, topk, packed=True, fused=True)[0].block_until_ready(),
    }
    secs = {}
    for name, fn in runs.items():
        fn()
        secs[name], _ = timeit(fn, repeat=3)
    emit("lut16_fused_topk", secs["fused"] / (n * q) * 1e6,
         f"vs_materialize={secs['materialize'] / secs['fused']:.2f}x")
    emit("lut16_fused_topk_packed", secs["fused_packed"] / (n * q) * 1e6,
         f"bytes_per_point={packed.shape[1]};"
         f"unpacked_bytes_per_point={k};"
         f"vs_unpacked_fused={secs['fused'] / secs['fused_packed']:.2f}x")


def bench_value_forward():
    """SINDI-style value-forward sparse pass-1 vs the gather/scatter-add
    reference on a power-law inverted index."""
    from repro.core.sparse_index import (build_compact_columns,
                                         build_padded_inverted_index,
                                         score_inverted,
                                         sparse_queries_to_padded)
    from repro.kernels.ops import score_inverted_vf
    rng = np.random.default_rng(3)
    n, d, qn = 8192, 2000, 16
    pj = np.minimum(1.0, cs.power_law_probs(d, 2.0) * 4)
    x = sp.csr_matrix(((rng.random((n, d)) < pj[None, :])
                       * rng.lognormal(0, 1, (n, d))).astype(np.float32))
    cols, xc = build_compact_columns(x)
    inv = build_padded_inverted_index(xc)
    qs = sp.csr_matrix(((rng.random((qn, d)) < pj[None, :] * 0.5)
                        * rng.lognormal(0, 1, (qn, d))).astype(np.float32))
    qd, qv = sparse_queries_to_padded(qs, cols, nq_max=128)
    qdj, qvj = jnp.asarray(qd), jnp.asarray(qv)

    ref = lambda: score_inverted(inv, qdj, qvj).block_until_ready()
    vf = lambda: score_inverted_vf(inv, qd, qv).block_until_ready()
    ref(); vf()
    s_ref, _ = timeit(ref, repeat=3)
    s_vf, _ = timeit(vf, repeat=3)
    l_max = int(np.asarray(inv.rows).shape[1])
    emit("sparse_inverted_gather", s_ref / qn * 1e6,
         f"gather_rect={qn}x{qd.shape[1]}x{l_max}")
    emit("sparse_value_forward", s_vf / qn * 1e6,
         f"vs_gather={s_ref / s_vf:.2f}x;includes_host_plan=true")


def bench_block_sparse():
    """Tile counts on the *pruned* head matrix — the object the real pipeline
    builds (HybridIndex eta-prunes before tiling; unpruned dense-ish columns
    are exactly what the paper's hyper-sparse first-pass index removes).
    Tile = 8 rows × 128 lanes (TPU sublane×lane granularity; B=8 in Eq. 4/5
    terms)."""
    from repro.core.pruning import prune_split
    rng = np.random.default_rng(1)
    n, d = 8192, 512
    pj = np.minimum(1.0, cs.power_law_probs(d, 2.0) * 4)
    x = sp.csr_matrix(((rng.random((n, d)) < pj[None, :])
                       * rng.lognormal(0, 1, (n, d))).astype(np.float32))
    pruned = prune_split(x, keep_top=192).index
    dense = pruned.toarray()
    br, bc = 8, 128
    tiles_un, _, _ = dense_to_bcsr(dense, br, bc)
    pi = cs.cache_sort(pruned)
    tiles_so, _, _ = dense_to_bcsr(dense[pi], br, bc)
    total_tiles = (n // br) * (d // bc)
    emit("block_sparse_tiles_unsorted", 0.0,
         f"tiles={tiles_un.shape[0]}/{total_tiles}")
    emit("block_sparse_tiles_cache_sorted", 0.0,
         f"tiles={tiles_so.shape[0]}/{total_tiles};"
         f"dma_reduction={tiles_un.shape[0] / max(tiles_so.shape[0], 1):.2f}x")


def main():
    bench_lut16()
    bench_fused_topk()
    bench_value_forward()
    bench_block_sparse()


if __name__ == "__main__":
    main()
