"""Paper Table 2 analogue: hybrid search on public-dataset-shaped synthetic
data (Netflix: 5e5 x (300 dense + 18k sparse); Movielens: 1.4e5 x (300 +
27k)).  CPU-scaled row counts keep the harness minutes-fast; relative
orderings are the reproduction target (speedup x recall), absolute ms are
this host's.

Reported per method: time per query (ms) and recall@20 — exactly the
paper's table layout.
"""

from __future__ import annotations

import numpy as np

from repro.core import baselines as bl
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.data import make_hybrid_dataset

from .common import emit


def _run_dataset(tag: str, n: int, d_sparse: int, d_dense: int, nnz: float,
                 seed: int):
    ds = make_hybrid_dataset(num_points=n, num_queries=16, d_sparse=d_sparse,
                             d_dense=d_dense, nnz_per_row=nnz, seed=seed)
    q = ds.q_sparse.shape[0]
    true_ids, _ = bl.exact_topk(ds.q_sparse, ds.q_dense, ds.x_sparse,
                                ds.x_dense, 20)

    rows = []
    res = bl.dense_brute_force(ds.q_sparse, ds.q_dense, ds.x_sparse,
                               ds.x_dense, 20)
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))
    res = bl.sparse_brute_force(ds.q_sparse, ds.q_dense, ds.x_sparse,
                                ds.x_dense, 20)
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))
    res = bl.sparse_inverted_index(ds.q_sparse, ds.q_dense, ds.x_sparse,
                                   ds.x_dense, 20)
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))
    # overfetch fractions follow the paper's ratios at its scale
    # (5k/5e5 = 1%, 10k/5e5 = 2%, 20k/5e5 = 4%)
    res = bl.hamming512(ds.q_sparse, ds.q_dense, ds.x_sparse, ds.x_dense, 20,
                        overfetch=max(200, n // 100))
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))
    res = bl.dense_pq_reorder(ds.q_sparse, ds.q_dense, ds.x_sparse,
                              ds.x_dense, 20, overfetch=max(400, n // 50))
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))
    res = bl.sparse_only(ds.q_sparse, ds.q_dense, ds.x_sparse, ds.x_dense, 20)
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))
    res = bl.sparse_only(ds.q_sparse, ds.q_dense, ds.x_sparse, ds.x_dense, 20,
                         overfetch=max(800, n // 25))
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))

    idx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                            HybridIndexParams(keep_top=128, head_dims=64,
                                              kmeans_iters=6))
    import time
    idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=20, beta=5)  # jit warmup
    t0 = time.perf_counter()
    r = idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=20, beta=5)
    hybrid_s = time.perf_counter() - t0
    rows.append(("hybrid_ours", hybrid_s, bl.recall_at_h(r.ids, true_ids)))

    for name, secs, rec in rows:
        emit(f"table2_{tag}_{name}", secs / q * 1e6, f"recall={rec:.3f}")
    return rows


def main():
    # Netflix-shaped (CPU-scaled 5e5 -> 2e4) and Movielens-shaped (1.4e5 -> 1e4)
    _run_dataset("netflix", 20000, 18000, 64, 48, seed=0)
    _run_dataset("movielens", 10000, 27000, 64, 32, seed=1)


if __name__ == "__main__":
    main()
