"""Engine benchmark: host-driven three-pass loop vs the single-jit
ScoringEngine on the kernels_bench-scale workload, plus the packed 4-bit
code backend against unpacked storage.

Emits CSV rows like the other benchmark modules AND writes
``BENCH_engine.json`` (QPS for each path + speedups + index code bytes) so
the perf trajectory of the engine layer is tracked across PRs.  Interpret
mode makes the packed-QPS column a structural proxy off-TPU — the bytes
columns are the hardware-independent claim (paper §4.1.2: the code stream
bounds single-query throughput).
"""

from __future__ import annotations

import dataclasses
import json

import jax.numpy as jnp
import numpy as np

from repro.core import residual as res
from repro.core.engine import (Backend, ScoringEngine,
                               scatter_queries_compact)
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.core.pq import adc_lut, adc_scores_ref
from repro.core.sparse_index import (queries_head_dense, score_head_ref,
                                     score_inverted, sparse_queries_to_padded)
from repro.data import make_hybrid_dataset

from .common import emit, timeit

OUT_JSON = "BENCH_engine.json"


def _host_loop_search(idx: HybridIndex, q_dims_np, q_vals_np, q_dense,
                      h: int, alpha: int, beta: int):
    """The pre-engine HybridIndex.search, verbatim: the host drives one
    dispatch per pass (plus a numpy head-query scatter per call) instead of
    the engine's single fused jit."""
    c1 = min(max(alpha * h, h), idx.num_points)
    c2 = min(max(beta * h, h), c1)
    q_dims, q_vals = jnp.asarray(q_dims_np), jnp.asarray(q_vals_np)

    sparse_scores = score_inverted(idx.inv_index, q_dims, q_vals)
    if idx.head is not None:
        q_head = jnp.asarray(queries_head_dense(
            q_dims_np, q_vals_np, idx.head_dim_ids, idx.head.block.shape[1]))
        head_scores = score_head_ref(idx.head, q_head)
        sparse_scores = sparse_scores + head_scores[:, : idx.num_points]
    lut = adc_lut(q_dense, idx.codebooks)
    approx = sparse_scores + adc_scores_ref(idx.codes, lut)
    s1, ids1 = res.topk_candidates(approx, c1)

    extra_d = res.dense_residual_scores(idx.dense_residual, ids1, q_dense)
    s2, ids2 = res.reorder_pass(s1, ids1, extra_d, c2)

    q_cols = scatter_queries_compact(q_dims, q_vals, idx.cols.num_active)
    extra_s = res.sparse_residual_scores(idx.sparse_residual, ids2, q_cols)
    s3, ids3 = res.reorder_pass(s2, ids2, extra_s, h)
    return np.asarray(s3), np.asarray(ids3)


def main():
    ds = make_hybrid_dataset(num_points=20000, num_queries=32,
                             d_sparse=20000, d_dense=64, nnz_per_row=48,
                             seed=3)
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                            HybridIndexParams(keep_top=96, head_dims=64,
                                              kmeans_iters=6))
    h, alpha, beta = 20, 20, 5
    q_dense = jnp.asarray(ds.q_dense)
    q_dims_np, q_vals_np = sparse_queries_to_padded(
        ds.q_sparse, idx.cols, nq_max=idx.params.nq_max)
    q_dims, q_vals = jnp.asarray(q_dims_np), jnp.asarray(q_vals_np)
    nq = ds.q_sparse.shape[0]

    def run_engine():
        s, i, _ = idx.engine.search(q_dims, q_vals, q_dense,
                                    h=h, alpha=alpha, beta=beta)
        return np.asarray(s), np.asarray(i)

    def run_host():
        return _host_loop_search(idx, q_dims_np, q_vals_np, q_dense,
                                 h, alpha, beta)

    # packed 4-bit backend on the SAME index arrays: codes repacked
    # two-per-byte, engine re-dispatched through Backend.PALLAS_PACKED.
    from repro.core.pq import pack_codes
    arr = idx.engine.arrays
    packed_codes = jnp.asarray(pack_codes(np.asarray(arr.codes)))
    eng_packed = ScoringEngine(
        arrays=dataclasses.replace(arr, codes=packed_codes,
                                   codes_packed=True),
        backend=Backend.PALLAS_PACKED)

    def run_packed():
        s, i, _ = eng_packed.search(q_dims, q_vals, q_dense,
                                    h=h, alpha=alpha, beta=beta)
        return np.asarray(s), np.asarray(i)

    run_engine()  # jit warmup
    run_host()
    run_packed()
    s_eng, _ = timeit(run_engine, repeat=9)
    s_host, _ = timeit(run_host, repeat=9)
    s_pk, _ = timeit(run_packed, repeat=5)

    qps_eng = nq / s_eng
    qps_host = nq / s_host
    qps_pk = nq / s_pk
    bytes_unpacked = int(arr.codes.nbytes)
    bytes_packed = int(packed_codes.nbytes)
    emit("engine_host_loop", s_host / nq * 1e6, f"qps={qps_host:.1f}")
    emit("engine_single_jit", s_eng / nq * 1e6,
         f"qps={qps_eng:.1f};speedup={s_host / s_eng:.2f}x")
    emit("engine_packed4bit", s_pk / nq * 1e6,
         f"qps={qps_pk:.1f};codes_bytes={bytes_packed};"
         f"unpacked_bytes={bytes_unpacked};"
         f"hbm_reduction={bytes_unpacked / bytes_packed:.2f}x")

    with open(OUT_JSON, "w") as f:
        json.dump({"workload": "kernels_bench",
                   "num_points": idx.num_points, "num_queries": nq,
                   "h": h, "alpha": alpha, "beta": beta,
                   "host_loop_qps": qps_host, "engine_qps": qps_eng,
                   "speedup": qps_eng / qps_host,
                   "engine_packed_qps": qps_pk,
                   "packed_vs_unpacked_speedup": qps_pk / qps_eng,
                   "codes_bytes_unpacked": bytes_unpacked,
                   "codes_bytes_packed": bytes_packed}, f, indent=2)


if __name__ == "__main__":
    main()
