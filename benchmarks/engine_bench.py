"""Engine benchmark: host-driven three-pass loop vs the single-jit
ScoringEngine on the kernels_bench-scale workload, plus the packed 4-bit
code backend against unpacked storage.

Emits CSV rows like the other benchmark modules AND writes
``BENCH_engine.json`` (QPS for each path + speedups + index code bytes) so
the perf trajectory of the engine layer is tracked across PRs.  Interpret
mode makes the packed-QPS column a structural proxy off-TPU — the bytes
columns are the hardware-independent claim (paper §4.1.2: the code stream
bounds single-query throughput).
"""

from __future__ import annotations

import dataclasses
import json

import jax.numpy as jnp
import numpy as np

from repro.core import residual as res
from repro.core.engine import (Backend, ScoringEngine,
                               scatter_queries_compact)
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.core.pq import adc_lut, adc_scores_ref
from repro.core.sparse_index import (queries_head_dense, score_head_ref,
                                     score_inverted, sparse_queries_to_padded)
from repro.data import make_hybrid_dataset

from .common import emit, timeit

OUT_JSON = "BENCH_engine.json"


def _host_loop_search(idx: HybridIndex, q_dims_np, q_vals_np, q_dense,
                      h: int, alpha: int, beta: int):
    """The pre-engine HybridIndex.search, verbatim: the host drives one
    dispatch per pass (plus a numpy head-query scatter per call) instead of
    the engine's single fused jit."""
    c1 = min(max(alpha * h, h), idx.num_points)
    c2 = min(max(beta * h, h), c1)
    q_dims, q_vals = jnp.asarray(q_dims_np), jnp.asarray(q_vals_np)

    sparse_scores = score_inverted(idx.inv_index, q_dims, q_vals)
    if idx.head is not None:
        q_head = jnp.asarray(queries_head_dense(
            q_dims_np, q_vals_np, idx.head_dim_ids, idx.head.block.shape[1]))
        head_scores = score_head_ref(idx.head, q_head)
        sparse_scores = sparse_scores + head_scores[:, : idx.num_points]
    lut = adc_lut(q_dense, idx.codebooks)
    approx = sparse_scores + adc_scores_ref(idx.codes, lut)
    s1, ids1 = res.topk_candidates(approx, c1)

    extra_d = res.dense_residual_scores(idx.dense_residual, ids1, q_dense)
    s2, ids2 = res.reorder_pass(s1, ids1, extra_d, c2)

    q_cols = scatter_queries_compact(q_dims, q_vals, idx.cols.num_active)
    extra_s = res.sparse_residual_scores(idx.sparse_residual, ids2, q_cols)
    s3, ids3 = res.reorder_pass(s2, ids2, extra_s, h)
    return np.asarray(s3), np.asarray(ids3)


def main():
    ds = make_hybrid_dataset(num_points=20000, num_queries=32,
                             d_sparse=20000, d_dense=64, nnz_per_row=48,
                             seed=3)
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                            HybridIndexParams(keep_top=96, head_dims=64,
                                              kmeans_iters=6))
    h, alpha, beta = 20, 20, 5
    q_dense = jnp.asarray(ds.q_dense)
    q_dims_np, q_vals_np = sparse_queries_to_padded(
        ds.q_sparse, idx.cols, nq_max=idx.params.nq_max)
    q_dims, q_vals = jnp.asarray(q_dims_np), jnp.asarray(q_vals_np)
    nq = ds.q_sparse.shape[0]

    def run_engine():
        s, i, _ = idx.engine.search(q_dims, q_vals, q_dense,
                                    h=h, alpha=alpha, beta=beta)
        return np.asarray(s), np.asarray(i)

    def run_host():
        return _host_loop_search(idx, q_dims_np, q_vals_np, q_dense,
                                 h, alpha, beta)

    # packed 4-bit backend on the SAME index arrays: codes repacked
    # two-per-byte, engine re-dispatched through Backend.PALLAS_PACKED.
    from repro.core.pq import pack_codes
    arr = idx.engine.arrays
    packed_codes = jnp.asarray(pack_codes(np.asarray(arr.codes)))
    arr_packed = dataclasses.replace(arr, codes=packed_codes,
                                     codes_packed=True)
    # fused-vs-materialize A/B on both Pallas backends: same arrays, the
    # fused flag is the only difference (c1 = alpha*h = 400 fits the buffer)
    engines = {
        "pallas_fused": ScoringEngine(arrays=arr, backend=Backend.PALLAS,
                                      fused=True),
        "pallas_materialize": ScoringEngine(arrays=arr,
                                            backend=Backend.PALLAS,
                                            fused=False),
        "packed_fused": ScoringEngine(arrays=arr_packed,
                                      backend=Backend.PALLAS_PACKED,
                                      fused=True),
        "packed_materialize": ScoringEngine(arrays=arr_packed,
                                            backend=Backend.PALLAS_PACKED,
                                            fused=False),
    }

    def runner(e):
        def run():
            s, i, _ = e.search(q_dims, q_vals, q_dense,
                               h=h, alpha=alpha, beta=beta)
            return np.asarray(s), np.asarray(i)
        return run

    run_engine()  # jit warmup
    run_host()
    s_eng, _ = timeit(run_engine, repeat=9)
    s_host, _ = timeit(run_host, repeat=9)
    secs = {}
    for name, e in engines.items():
        run = runner(e)
        run()
        secs[name], _ = timeit(run, repeat=5)

    from repro.kernels.lut16 import candidate_buffer_width, default_interpret
    interpret = bool(default_interpret())
    qps = {name: nq / s for name, s in secs.items()}
    qps_eng = nq / s_eng
    qps_host = nq / s_host
    bytes_unpacked = int(arr.codes.nbytes)
    bytes_packed = int(packed_codes.nbytes)
    emit("engine_host_loop", s_host / nq * 1e6, f"qps={qps_host:.1f}")
    emit("engine_single_jit", s_eng / nq * 1e6,
         f"qps={qps_eng:.1f};speedup={s_host / s_eng:.2f}x")
    emit("engine_fused_pass1", secs["pallas_fused"] / nq * 1e6,
         f"qps={qps['pallas_fused']:.1f};"
         f"vs_materialize="
         f"{secs['pallas_materialize'] / secs['pallas_fused']:.2f}x")
    emit("engine_packed4bit", secs["packed_fused"] / nq * 1e6,
         f"qps={qps['packed_fused']:.1f};codes_bytes={bytes_packed};"
         f"unpacked_bytes={bytes_unpacked};"
         f"hbm_reduction={bytes_unpacked / bytes_packed:.2f}x;"
         f"vs_unpacked_fused="
         f"{secs['pallas_fused'] / secs['packed_fused']:.2f}x")

    # structural half of the packed-speedup floor: the fused pass-1 jaxpr
    # holds no (Q, N) fp32 score matrix (see predicted_pass1_bytes for why
    # the materialize round-trip is what sank packed QPS)
    import functools
    from repro.kernels.ops import dense_scores_materialized, lut16_adc_topk
    c1 = min(max(alpha * h, h), idx.num_points)
    lut = adc_lut(q_dense, idx.codebooks)
    no_dense_mat = not dense_scores_materialized(
        functools.partial(lut16_adc_topk, k=c1, fused=True, packed=True),
        packed_codes, lut)

    # predicted-vs-measured pass-1 bytes/point (roofline satellite)
    from repro.roofline.pass1 import measured_bytes, predicted_pass1_bytes
    cbuf = candidate_buffer_width(c1)
    pred = {
        "fused_bytes_per_point": predicted_pass1_bytes(
            q=nq, n=idx.num_points, k_codes=arr.codes.shape[1],
            fused=True, cbuf=cbuf) / idx.num_points,
        "materialize_bytes_per_point": predicted_pass1_bytes(
            q=nq, n=idx.num_points, k_codes=arr.codes.shape[1],
            fused=False, cbuf=cbuf) / idx.num_points,
        "fused_packed_bytes_per_point": predicted_pass1_bytes(
            q=nq, n=idx.num_points, k_codes=packed_codes.shape[1],
            packed=True, fused=True, cbuf=cbuf) / idx.num_points,
    }
    meas = measured_bytes(
        functools.partial(lut16_adc_topk, k=c1, fused=True),
        jnp.asarray(arr.codes), lut)
    roofline = {"interpret": interpret, "predicted": pred,
                "measured_fused_bytes_per_point":
                    None if meas is None else meas / idx.num_points}

    from .kernels_bench import autotune_fused_blocks
    autotune = autotune_fused_blocks(packed=False)

    with open(OUT_JSON, "w") as f:
        json.dump({"workload": "engine_bench",
                   "interpret": interpret,
                   "num_points": idx.num_points, "num_queries": nq,
                   "h": h, "alpha": alpha, "beta": beta,
                   "host_loop_qps": qps_host, "engine_qps": qps_eng,
                   "speedup": qps_eng / qps_host,
                   "engine_fused_qps": qps["pallas_fused"],
                   "engine_unfused_qps": qps["pallas_materialize"],
                   "fused_vs_materialize_speedup":
                       qps["pallas_fused"] / qps["pallas_materialize"],
                   "engine_packed_qps": qps["packed_fused"],
                   "engine_packed_unfused_qps": qps["packed_materialize"],
                   "packed_vs_unpacked_speedup":
                       qps["packed_fused"] / qps["pallas_fused"],
                   "no_dense_materialization": no_dense_mat,
                   "codes_bytes_unpacked": bytes_unpacked,
                   "codes_bytes_packed": bytes_packed,
                   "pass1_roofline": roofline,
                   "autotune": autotune}, f, indent=2)


if __name__ == "__main__":
    main()
