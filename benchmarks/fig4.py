"""Paper Figure 4 reproduction: the cache-sorting cost model (Eq. 4 / Eq. 5).

(a) fraction of accumulator cache-lines touched, unsorted vs sorted bound,
    N=1M, alpha=2, B=16;
(b) reduction factor E[C_unsort]/E[C_sort] as a function of B, N, alpha
    (B of the unsorted index fixed to 16, as in the paper).

Also: a *measured* counterpart on synthetic power-law data — the model is
only useful if the real Algorithm 1 tracks it.
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp

import repro.core.cache_sort as cs

from .common import emit


def main():
    # ---- (a) fractions at the paper's setting -----------------------------
    n, b, d = 1_000_000, 16, 1000
    p = cs.power_law_probs(d, 2.0)
    un = cs.expected_cost_unsorted(p, p, n, b)
    so = cs.expected_cost_sorted_bound(p, p, n, b)
    emit("fig4a_frac_unsorted", 0.0, f"value={un / (n / b):.4f}")
    emit("fig4a_frac_sorted_bound", 0.0, f"value={so / (n / b):.4f}")
    emit("fig4a_model_reduction", 0.0, f"value={un / so:.2f}x")

    # ---- (b) reduction vs (B, N, alpha) ------------------------------------
    for alpha in (1.5, 2.0, 2.5):
        for nn in (10 ** 5, 10 ** 6, 10 ** 7):
            for bb in (16, 32, 64):
                pp = cs.power_law_probs(d, alpha)
                u = cs.expected_cost_unsorted(pp, pp, nn, 16)
                s = cs.expected_cost_sorted_bound(pp, pp, nn, bb)
                emit(f"fig4b_alpha{alpha}_N{nn:.0e}_B{bb}", 0.0,
                     f"reduction={u / max(s, 1e-9):.2f}x")

    # ---- measured: Algorithm 1 on synthetic power-law data -----------------
    rng = np.random.default_rng(0)
    n, d = 20000, 2000
    pj = np.minimum(1.0, cs.power_law_probs(d, 2.0) * 20)
    x = sp.csr_matrix(((rng.random((n, d)) < pj[None, :])
                       * rng.lognormal(0, 1, (n, d))).astype(np.float32))
    pi = cs.cache_sort(x)
    for b in (16, 32, 128):
        qd = np.flatnonzero(rng.random(d) < pj)       # query from same law
        c_un = cs.measured_block_cost(x, b, qd)
        c_so = cs.measured_block_cost(x, b, qd, pi=pi)
        emit(f"fig4_measured_B{b}", 0.0,
             f"unsorted={c_un};sorted={c_so};reduction={c_un / max(c_so, 1):.2f}x")


if __name__ == "__main__":
    main()
