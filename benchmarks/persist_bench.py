"""Persistence benchmark (DESIGN.md §7): snapshot bandwidth, WAL append
latency, replay rate, and recovery-vs-rebuild.

Emits CSV rows like the other benchmark modules AND writes
``BENCH_persist.json`` with the documented schema (README "Persistence"):

    workload     points/dims of the synthetic index + streamed row count
    snapshot     {bytes, write_s, write_mb_s, load_s, load_mb_s}:
                 leaf-blob volume and the verified write/load bandwidth of
                 one committed generation
    wal          {records, append_us, bytes_per_record,
                 acked_mutations_per_s, group_commit}: mean fsync'd append
                 latency of single-row insert records (a throwaway log —
                 measured pure, off the real store); ``group_commit``
                 {batch, per_record_fsync_us, acked_mutations_per_s,
                 speedup_vs_per_record} compares one-ack-one-fsync against
                 ``append_many`` batches sharing a single fsync
                 (DESIGN.md §7.6)
    recovery     {replayed_records, replayed_rows, recover_s,
                 replay_rows_per_s, rebuild_s, speedup_vs_rebuild}: full
                 restart (snapshot load + WAL tail replay) vs re-running
                 the batch build from raw rows — the reason the subsystem
                 exists
    delta_snapshot
                 {checkpoint_s, recovery_seconds, replayed_records}: a
                 live-delta checkpoint (rotate + delta-state snapshot +
                 WAL truncation) followed by a timed restart that replays
                 only the short post-checkpoint tail
    smoke        true when run with --smoke (CI scale)

All scratch stores live in a temp directory that is removed even when a
measurement fails (ISSUE 5 satellite: no leaked snapshot dirs).

Run:  PYTHONPATH=src python -m benchmarks.persist_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

from repro import persist
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.persist.wal import RECORD_DELETE
from repro.data import make_hybrid_dataset
from repro.serve import QueryService

from .common import emit

OUT_JSON = "BENCH_persist.json"
H = 20


def _store_bytes(root: str) -> int:
    """Total leaf-blob volume of the committed snapshot (manifest sizes)."""
    cur = persist.read_current(root)
    with open(os.path.join(root, cur["snapshot"], "manifest.json")) as f:
        manifest = json.load(f)
    return sum(int(m["nbytes"]) for m in manifest["leaves"].values())


def main(smoke: bool = False):
    """Run the persistence benches; prints CSV rows, writes
    BENCH_persist.json, and cleans its temp stores up on ANY exit path."""
    n, d_s, nnz, n_delta = ((4000, 6000, 24, 128) if smoke
                            else (20000, 20000, 48, 512))
    wal_probes = 32 if smoke else 128
    ds = make_hybrid_dataset(num_points=n + n_delta, num_queries=8,
                             d_sparse=d_s, d_dense=64, nnz_per_row=nnz,
                             seed=5)
    idx = HybridIndex.build(ds.x_sparse[:n], ds.x_dense[:n],
                            HybridIndexParams(keep_top=96, head_dims=64,
                                              kmeans_iters=6),
                            mutable=True)
    tmp = tempfile.mkdtemp(prefix="persist-bench-")
    try:
        root = os.path.join(tmp, "store")

        # -- snapshot write/load bandwidth --------------------------------
        t0 = time.perf_counter()
        dur = persist.bootstrap(root, idx)
        write_s = time.perf_counter() - t0
        snap_bytes = _store_bytes(root)
        mb = snap_bytes / 2**20
        emit("persist_snapshot_write", write_s * 1e6,
             f"mb={mb:.1f};mb_per_s={mb / write_s:.1f}")
        t0 = time.perf_counter()
        persist.load_snapshot(root)
        load_s = time.perf_counter() - t0
        emit("persist_snapshot_load", load_s * 1e6,
             f"mb_per_s={mb / load_s:.1f}")
        dur.close()

        # -- WAL append latency (throwaway log, fsync'd single rows) ------
        wal = persist.MutationWAL(os.path.join(tmp, "wal-probe"))
        t0 = time.perf_counter()
        for i in range(wal_probes):
            wal.append_insert(ds.x_sparse[n + (i % n_delta)],
                              ds.x_dense[n + (i % n_delta)][None],
                              np.asarray([n + i]))
        append_s = (time.perf_counter() - t0) / wal_probes
        wal_bytes = os.path.getsize(wal.segment_paths[-1])
        wal.close()
        emit("persist_wal_append", append_s * 1e6,
             f"bytes_per_record={wal_bytes // wal_probes}")

        # -- group commit: shared fsync vs one-ack-one-fsync --------------
        # tiny delete records so the fsync, not payload serialization,
        # dominates both sides — the protocol cost being amortized
        gc_batch = 128
        gc_probes = wal_probes * 16
        wal2 = persist.MutationWAL(os.path.join(tmp, "wal-group"))
        for i in range(gc_batch):               # warm both paths
            wal2.append_delete([i])
        t0 = time.perf_counter()
        for i in range(gc_probes):
            wal2.append_delete([i])             # ack = a private fsync
        per_record_s = (time.perf_counter() - t0) / gc_probes
        # identical single-id records on both sides — only the ack protocol
        # differs
        batch_entries = [(RECORD_DELETE, {"ids": np.asarray([i], np.int64)})
                         for i in range(gc_batch)]
        t0 = time.perf_counter()
        for _ in range(gc_probes // gc_batch):
            wal2.append_many(batch_entries)
        group_s = (time.perf_counter() - t0) / gc_probes
        wal2.close()
        acked_per_s = 1.0 / group_s
        gc_speedup = per_record_s / group_s
        emit("persist_wal_group_commit", group_s * 1e6,
             f"batch={gc_batch};acked_per_s={acked_per_s:.0f};"
             f"speedup_vs_per_record={gc_speedup:.1f}x")

        # -- stream mutations into the real store, then recover -----------
        svc = QueryService(restore_from=root, h=H, cache_size=0,
                           auto_compact=False)
        for lo in range(0, n_delta, 16):
            svc.insert(ds.x_sparse[n + lo: n + lo + 16],
                       ds.x_dense[n + lo: n + lo + 16])
        svc.delete(list(range(8)))
        svc.close()

        t0 = time.perf_counter()
        rec = persist.recover(root)
        recover_s = time.perf_counter() - t0
        rec.durability.close()
        replay_s = max(recover_s - load_s, 1e-9)
        replay_rate = n_delta / replay_s
        emit("persist_recover", recover_s * 1e6,
             f"replayed={rec.replayed};replay_rows_per_s={replay_rate:.1f}")

        # -- the alternative: rebuild the batch index from raw rows -------
        xs, xd, ids = rec.index.mutable_state.survivors()
        t0 = time.perf_counter()
        HybridIndex.build(xs, xd, idx.params, mutable=True, ext_ids=ids)
        rebuild_s = time.perf_counter() - t0
        emit("persist_rebuild_baseline", rebuild_s * 1e6,
             f"recover_speedup={rebuild_s / recover_s:.2f}x")

        # -- delta-state checkpoint: restart = snapshot + short tail ------
        svc = QueryService(restore_from=root, h=H, cache_size=0,
                           auto_compact=False)
        t0 = time.perf_counter()
        svc.checkpoint()                        # delta-state snapshot cut
        ckpt_s = time.perf_counter() - t0
        tail = 4
        for i in range(tail):                   # short post-checkpoint tail
            svc.insert(ds.x_sparse[n + i], ds.x_dense[n + i][None])
        svc.close()
        t0 = time.perf_counter()
        rec2 = persist.recover(root)
        ckpt_recover_s = time.perf_counter() - t0
        rec2.durability.close()
        assert rec2.replayed == tail, (
            f"checkpoint did not truncate the tail: replayed {rec2.replayed}")
        emit("persist_delta_snapshot_recover", ckpt_recover_s * 1e6,
             f"checkpoint_s={ckpt_s:.3f};replayed={rec2.replayed}")

        out = {
            "workload": {"num_points": n, "d_sparse": d_s, "d_dense": 64,
                         "streamed_rows": n_delta, "h": H},
            "snapshot": {"bytes": int(snap_bytes), "write_s": write_s,
                         "write_mb_s": mb / write_s, "load_s": load_s,
                         "load_mb_s": mb / load_s},
            "wal": {"records": wal_probes, "append_us": append_s * 1e6,
                    "bytes_per_record": wal_bytes // wal_probes,
                    "acked_mutations_per_s": acked_per_s,
                    "group_commit": {
                        "batch": gc_batch,
                        "per_record_fsync_us": per_record_s * 1e6,
                        "acked_mutations_per_s": acked_per_s,
                        "speedup_vs_per_record": gc_speedup}},
            "recovery": {"replayed_records": int(rec.replayed),
                         "replayed_rows": int(n_delta),
                         "recover_s": recover_s,
                         "replay_rows_per_s": replay_rate,
                         "rebuild_s": rebuild_s,
                         "speedup_vs_rebuild": rebuild_s / recover_s},
            "delta_snapshot": {"checkpoint_s": ckpt_s,
                               "recovery_seconds": ckpt_recover_s,
                               "replayed_records": int(rec2.replayed)},
            "smoke": smoke,
        }
        with open(OUT_JSON, "w") as f:
            json.dump(out, f, indent=2)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: small index, fewer probes")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
