"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig4]

Prints ``name,us_per_call,derived`` CSV rows.
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table2,table3,fig4,kernels,engine,"
                         "serve,persist,cluster,roofline")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only
             else ["fig4", "kernels", "engine", "serve", "persist",
                   "cluster", "table2", "table3", "roofline"])
    from . import (cluster_bench, engine_bench, fig4, kernels_bench,
                   persist_bench, roofline_table, serve_bench, table2,
                   table3)
    mods = {"table2": table2, "table3": table3, "fig4": fig4,
            "kernels": kernels_bench, "engine": engine_bench,
            "serve": serve_bench, "persist": persist_bench,
            "cluster": cluster_bench, "roofline": roofline_table}
    print("name,us_per_call,derived")
    for n in names:
        mods[n].main()


if __name__ == '__main__':
    main()
