"""Paper Table 3 analogue: QuerySim-shaped data (power-law alpha~2 sparse
activity, ~134 nnz/row, 200 dense dims), CPU-scaled 5M -> 5e4 rows.

The paper's headline: hybrid ~20x faster than exact sparse inverted index at
91% recall@20, with sparse-only and dense-only baselines collapsing to ~0-45%
recall.  We reproduce the ordering and the recall cliff.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import baselines as bl
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.data import make_hybrid_dataset

from .common import emit


def main(n: int = 50000):
    ds = make_hybrid_dataset(num_points=n, num_queries=16, d_sparse=200000,
                             d_dense=64, nnz_per_row=134, alpha=2.0,
                             dense_weight=2.0, seed=3)
    q = ds.q_sparse.shape[0]
    true_ids, _ = bl.exact_topk(ds.q_sparse, ds.q_dense, ds.x_sparse,
                                ds.x_dense, 20)

    rows = []
    res = bl.sparse_brute_force(ds.q_sparse, ds.q_dense, ds.x_sparse,
                                ds.x_dense, 20)
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))
    res = bl.sparse_inverted_index(ds.q_sparse[:4], ds.q_dense[:4],
                                   ds.x_sparse, ds.x_dense, 20)
    rows.append((res.name, res.seconds * q / 4,
                 bl.recall_at_h(res.ids, true_ids[:4])))
    # overfetch fractions follow the paper's ratios at 5M scale (0.1-0.4%)
    res = bl.hamming512(ds.q_sparse, ds.q_dense, ds.x_sparse, ds.x_dense, 20,
                        overfetch=max(100, n // 1000))
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))
    res = bl.dense_pq_reorder(ds.q_sparse, ds.q_dense, ds.x_sparse,
                              ds.x_dense, 20, overfetch=max(200, n // 500))
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))
    res = bl.sparse_only(ds.q_sparse, ds.q_dense, ds.x_sparse, ds.x_dense, 20)
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))
    res = bl.sparse_only(ds.q_sparse, ds.q_dense, ds.x_sparse, ds.x_dense, 20,
                         overfetch=max(400, n // 250))
    rows.append((res.name, res.seconds, bl.recall_at_h(res.ids, true_ids)))

    idx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                            HybridIndexParams(keep_top=192, head_dims=128,
                                              kmeans_iters=6))
    idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=25, beta=6)  # jit warmup
    t0 = time.perf_counter()
    r = idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=25, beta=6)
    hybrid_s = time.perf_counter() - t0
    rows.append(("hybrid_ours", hybrid_s, bl.recall_at_h(r.ids, true_ids)))

    base = dict((nm, s) for nm, s, _ in rows)
    inv_s = base.get("sparse_inverted_index", 1.0)
    for name, secs, rec in rows:
        speedup = inv_s / secs if secs > 0 else 0.0
        emit(f"table3_{name}", secs / q * 1e6,
             f"recall={rec:.3f};speedup_vs_inverted={speedup:.1f}x")


if __name__ == "__main__":
    main()
