"""Cluster serving benchmark (DESIGN.md §8): router fan-out QPS vs the
in-process service, wire-level batching before/after, multi-router and
failover probes, the per-hop latency breakdown, and replica catch-up rate
over WAL shipping.

Spawns a REAL local cluster (subprocess shard servers on loopback — the
same harness the fault tests use) with FOUR scorers + one replica, then
measures:

* router QPS at batch sizes Q ∈ {1, 8, 32} against the in-process
  ``QueryService`` on the same built index, under BOTH wire disciplines:
  ``lockstep`` (one blocking RPC per shard per chunk — the pre-batching
  shape) and the default pipelined+coalesced path (every request on the
  wire before any reply is read, one shared pre-serialized frame per
  fetch depth, concurrent chunks folded into ``msearch`` frames, §8.8);
* two routers sharing the cluster: cross-router mutation visibility
  (server-side authority, §8.4) asserted bit-identical, and aggregate
  concurrent-search throughput;
* failover: SIGKILL the primary, ``failover()`` promotes the caught-up
  replica, and the first post-promotion search — timed end to end and
  asserted bit-identical to the in-process comparator (§8.7);
* the router's per-hop breakdown {serialize, wire, queue, score, merge}
  sourced from its request SPANS (DESIGN.md §9.2: ``tracer.take()`` +
  ``stage_totals``, not client-field scraping), normalized per query;
* replica catch-up: shipping paused, a burst of mutations logged at the
  primary, shipping resumed — applied records per second until the
  replica reaches the primary's exact seq.

Emits CSV rows like the other benchmark modules AND writes
``BENCH_cluster.json`` (README "Cluster" schema):

    workload              points/dims/scorers of the spawned cluster
    qps                   per Q: {router_qps, inproc_qps, rpc_overhead_x,
                          lockstep_qps, rpc_overhead_x_lockstep,
                          batching_speedup_x}
    hops                  {serialize_us, wire_us, queue_us, score_us,
                          merge_us} per query, plus the raw totals, the
                          trace count, and ``span_sourced: true``
    multi_router          {routers, agg_qps, equivalence_checked}
    failover              {promote_s, first_search_s, term,
                          equivalence_checked}
    replication           {burst_records, catchup_s, catchup_records_per_s}
    equivalence_checked   true — bitwise ids+scores parity assertions
                          between router and in-process results ran inside
                          the bench (a benchmark of the WRONG answer is
                          worthless)
    smoke                 true when run with --smoke (CI scale)

Run:  PYTHONPATH=src python -m benchmarks.cluster_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.data import make_hybrid_dataset
from repro.obs import stage_totals
from repro.serve import QueryService
from repro.serve.cluster import LocalCluster, ShardClient, wait_ready

from .common import emit

OUT_JSON = "BENCH_cluster.json"
H = 10
BATCHES = (1, 8, 32)
NUM_SCORERS = 4


def _sub(ds, q):
    """First ``q`` queries of the dataset (router and service both
    bucket-pad, so parity holds at any batch size)."""
    return ds.q_sparse[:q], ds.q_dense[:q]


def _assert_parity(router, comp, qs, qd):
    s_r, i_r = router.search_sparse(qs, qd)
    s_c, i_c = comp.search_sparse(qs, qd)
    np.testing.assert_array_equal(i_r, i_c)
    np.testing.assert_array_equal(s_r, s_c)


def _time_search(router, qs, qd, iters):
    router.search_sparse(qs, qd)                # warm
    router.obs.tracer.take()    # drop warm traces: hops = measured runs only
    t0 = time.perf_counter()
    for _ in range(iters):
        router.search_sparse(qs, qd)
    return (time.perf_counter() - t0) / iters


def main(smoke: bool = False):
    """Run the cluster benches; prints CSV rows, writes BENCH_cluster.json,
    and tears the subprocess cluster + temp stores down on ANY exit."""
    n, d_s, nnz, burst = ((384, 960, 12, 48) if smoke
                          else (4000, 6000, 24, 200))
    iters = 4 if smoke else 16
    ds = make_hybrid_dataset(num_points=n + burst, num_queries=max(BATCHES),
                             d_sparse=d_s, d_dense=32, nnz_per_row=nnz,
                             seed=7)
    params = HybridIndexParams(keep_top=24, head_dims=16, kmeans_iters=2)
    tmp = tempfile.mkdtemp(prefix="cluster-bench-")
    out: dict = {"workload": {"num_points": n, "d_sparse": d_s,
                              "d_dense": 32, "num_scorers": NUM_SCORERS,
                              "h": H},
                 "qps": {}, "smoke": smoke}
    try:
        idx = HybridIndex.build(ds.x_sparse[:n], ds.x_dense[:n], params,
                                mutable=True)
        comp = QueryService(
            index=HybridIndex.build(ds.x_sparse[:n], ds.x_dense[:n],
                                    params, mutable=True),
            h=H, cache_size=0, auto_compact=False)
        with LocalCluster.launch(idx, tmp, num_scorers=NUM_SCORERS,
                                 num_replicas=1) as cluster:
            router = cluster.router(h=H)
            r_lock = cluster.router(h=H, lockstep=True)

            # -- equivalence gate: a fast wrong answer is no answer -------
            qs, qd = _sub(ds, max(BATCHES))
            _assert_parity(router, comp, qs, qd)
            _assert_parity(r_lock, comp, qs, qd)
            out["equivalence_checked"] = True

            # -- QPS: pipelined vs lockstep vs in-process, per Q ----------
            for q in BATCHES:
                qs, qd = _sub(ds, q)
                comp.search_sparse(qs, qd)          # warm
                router_s = _time_search(router, qs, qd, iters)
                lock_s = _time_search(r_lock, qs, qd, iters)
                t0 = time.perf_counter()
                for _ in range(iters):
                    comp.search_sparse(qs, qd)
                inproc_s = (time.perf_counter() - t0) / iters
                out["qps"][str(q)] = {
                    "router_qps": q / router_s,
                    "lockstep_qps": q / lock_s,
                    "inproc_qps": q / inproc_s,
                    "rpc_overhead_x": router_s / inproc_s,
                    "rpc_overhead_x_lockstep": lock_s / inproc_s,
                    "batching_speedup_x": lock_s / router_s}
                emit(f"cluster_router_q{q}", router_s * 1e6,
                     f"router_qps={q / router_s:.1f};"
                     f"inproc_qps={q / inproc_s:.1f};"
                     f"overhead={router_s / inproc_s:.2f}x;"
                     f"lockstep_overhead={lock_s / inproc_s:.2f}x")

            # per-hop breakdown of the LAST batch-size loop, per query —
            # SPAN-SOURCED (DESIGN.md §9.2): drain the router's finished
            # trace ring and sum the per-stage tags, instead of scraping
            # client timing fields (which raced under concurrent chunks)
            traces = router.obs.tracer.take()
            totals = stage_totals(traces)
            nq = max(BATCHES) * iters
            out["hops"] = {
                **{f"{k[:-2]}_us": v / nq * 1e6 for k, v in totals.items()},
                "totals_s": totals, "traces": len(traces),
                "span_sourced": True}
            emit("cluster_hops", sum(totals.values()) / nq * 1e6,
                 ";".join(f"{k[:-2]}={v / nq * 1e6:.0f}us"
                          for k, v in totals.items()))

            # -- two routers, one truth (DESIGN.md §8.4) ------------------
            # a delete through the SECOND router is immediately visible —
            # bit-identically — through the first (server-side authority)
            r_lock.delete([0])
            comp.delete([0])
            qs, qd = _sub(ds, max(BATCHES))
            _assert_parity(router, comp, qs, qd)
            qs1, qd1 = _sub(ds, 8)
            done = []
            def hammer(r):
                for _ in range(iters):
                    r.search_sparse(qs1, qd1)
                done.append(1)
            t0 = time.perf_counter()
            ths = [threading.Thread(target=hammer, args=(r,))
                   for r in (router, r_lock)]
            for t in ths:
                t.start()
            for t in ths:
                t.join()
            multi_s = time.perf_counter() - t0
            agg = 2 * 8 * iters / multi_s
            out["multi_router"] = {"routers": 2, "agg_qps": agg,
                                   "equivalence_checked": True}
            emit("cluster_multi_router", multi_s / (2 * iters) * 1e6,
                 f"routers=2;agg_qps={agg:.1f}")
            r_lock.close()

            # -- replica catch-up rate over WAL shipping ------------------
            repl = ShardClient("127.0.0.1", cluster.replicas[0].port)
            repl.call("fault", {"mode": "pause_shipping"})
            for j in range(burst):
                router.insert(ds.x_sparse[n + j], ds.x_dense[n + j])
                comp.insert(ds.x_sparse[n + j], ds.x_dense[n + j])
            repl.call("fault", {"mode": "resume_shipping"})
            t0 = time.perf_counter()
            while True:
                st = wait_ready(repl)
                if st["applied_seq"] >= router._last_seq:
                    break
                time.sleep(0.01)
            catchup_s = time.perf_counter() - t0
            repl.close()
            rate = burst / catchup_s
            out["replication"] = {"burst_records": burst,
                                  "catchup_s": catchup_s,
                                  "catchup_records_per_s": rate}
            emit("cluster_replica_catchup", catchup_s * 1e6,
                 f"records={burst};records_per_s={rate:.1f}")

            # -- failover: kill the coordinator, promote, keep serving ----
            qs, qd = _sub(ds, max(BATCHES))
            cluster.kill_primary()
            t0 = time.perf_counter()
            term = router.failover()
            promote_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            _assert_parity(router, comp, qs, qd)    # bit-identical AFTER
            first_search_s = time.perf_counter() - t0
            out["failover"] = {"promote_s": promote_s,
                               "first_search_s": first_search_s,
                               "term": term, "equivalence_checked": True}
            emit("cluster_failover", promote_s * 1e6,
                 f"term={term};first_search_us={first_search_s * 1e6:.0f}")
            router.close()
        comp.close()
        with open(OUT_JSON, "w") as f:
            json.dump(out, f, indent=2)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: small corpus, fewer iterations")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
