"""Emit the roofline table from dry-run artifacts (results/*.json)."""

from __future__ import annotations

import json
import os

from .common import emit

RESULTS = [
    ("results/dryrun_single_pod.json", "16x16"),
    ("results/dryrun_multi_pod.json", "2x16x16"),
]


def main():
    for path, mesh in RESULTS:
        if not os.path.exists(path):
            emit(f"roofline_{mesh}", 0.0, "missing (run launch.dryrun)")
            continue
        with open(path) as f:
            text = f.read().strip()
        if text.startswith("["):
            rows = json.loads(text)
        else:                      # JSONL (incremental sweep output)
            rows = [json.loads(l) for l in text.splitlines() if l.strip()]
        for r in rows:
            tag = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
            if r.get("status") == "ok" and "compute_s" in r:
                bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
                emit(tag, bound * 1e6,
                     f"dominant={r['dominant']};useful={r['useful_ratio']:.3f};"
                     f"bytes_per_dev={r['bytes_per_device']:.3e};"
                     f"fits={r.get('fits_hbm')}")
            elif r.get("status") == "ok":   # compile-proof-only rows
                emit(tag, 0.0,
                     f"compiled;bytes_per_dev={r.get('bytes_per_device', 0):.3e};"
                     f"fits={r.get('fits_hbm')}")
            else:
                emit(tag, 0.0, r.get("status", "?"))


if __name__ == "__main__":
    main()
