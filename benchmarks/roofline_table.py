"""Emit the roofline table from dry-run artifacts (results/*.json)."""

from __future__ import annotations

import json
import os

from .common import emit

RESULTS = [
    ("results/dryrun_single_pod.json", "16x16"),
    ("results/dryrun_multi_pod.json", "2x16x16"),
]


def pass1_rows():
    """Predicted-vs-measured pass-1 bytes/point from BENCH_engine.json
    (DESIGN.md §2.5's byte equation) — the CI-smoke half of the roofline
    table: interpret-mode measurements are labeled so they can never pose
    as TPU numbers."""
    if not os.path.exists("BENCH_engine.json"):
        emit("roofline_pass1", 0.0,
             "missing (run benchmarks.run --only engine)")
        return
    with open("BENCH_engine.json") as f:
        bench = json.load(f)
    rl = bench.get("pass1_roofline")
    if not rl:
        emit("roofline_pass1", 0.0, "BENCH_engine.json has no pass1_roofline")
        return
    pred = rl["predicted"]
    meas = rl.get("measured_fused_bytes_per_point")
    emit("roofline_pass1_fused", 0.0,
         f"predicted_bytes_per_point={pred['fused_bytes_per_point']:.1f};"
         f"measured={'n/a' if meas is None else f'{meas:.1f}'};"
         f"interpret={rl['interpret']}")
    emit("roofline_pass1_materialize", 0.0,
         f"predicted_bytes_per_point="
         f"{pred['materialize_bytes_per_point']:.1f};"
         f"vs_fused="
         f"{pred['materialize_bytes_per_point'] / pred['fused_bytes_per_point']:.1f}x")
    emit("roofline_pass1_fused_packed", 0.0,
         f"predicted_bytes_per_point="
         f"{pred['fused_packed_bytes_per_point']:.1f}")


def main():
    pass1_rows()
    for path, mesh in RESULTS:
        if not os.path.exists(path):
            emit(f"roofline_{mesh}", 0.0, "missing (run launch.dryrun)")
            continue
        with open(path) as f:
            text = f.read().strip()
        if text.startswith("["):
            rows = json.loads(text)
        else:                      # JSONL (incremental sweep output)
            rows = [json.loads(l) for l in text.splitlines() if l.strip()]
        for r in rows:
            tag = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
            if r.get("status") == "ok" and "compute_s" in r:
                bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
                emit(tag, bound * 1e6,
                     f"dominant={r['dominant']};useful={r['useful_ratio']:.3f};"
                     f"bytes_per_dev={r['bytes_per_device']:.3e};"
                     f"fits={r.get('fits_hbm')}")
            elif r.get("status") == "ok":   # compile-proof-only rows
                emit(tag, 0.0,
                     f"compiled;bytes_per_dev={r.get('bytes_per_device', 0):.3e};"
                     f"fits={r.get('fits_hbm')}")
            else:
                emit(tag, 0.0, r.get("status", "?"))


if __name__ == "__main__":
    main()
