"""Serving-layer benchmark (DESIGN.md §5): QueryService vs the single-query
ScoringEngine loop, cache warm-up, refresh pause, and the packed-vs-unpacked
QPS sweep over the batch buckets.

Emits CSV rows like the other benchmark modules AND writes
``BENCH_serve.json`` with the documented schema (README "Serving"):

    workload                 points/queries/dims of the synthetic index
    single_query_loop_qps    one engine.search per query, Q=1 (the baseline
                             the batched service must beat >= 2x at Q=32)
    service_qps              {bucket: QPS} for the cold-cache service fed the
                             stream in chunks of that bucket
    batched_speedup_q32      service_qps["32"] / single_query_loop_qps
    cache                    {cold_qps, warm_qps, hit_rate} for an identical
                             repeated query stream through the LRU cache
    refresh                  {swap_s, first_search_after_s}: the refresh()
                             call itself (must not block on in-flight work)
                             and the first search against the new generation
    packed                   {bucket: {unpacked_qps, packed_qps, ratio}} —
                             Backend.PALLAS vs Backend.PALLAS_PACKED at
                             Q in {1, 8, 32} (interpret-mode numbers are a
                             structural proxy off-TPU; the HBM bytes halving
                             in BENCH_engine.json is the hardware claim)
    obs                      observability overhead at Q=32 (DESIGN.md §9.4):
                             {baseline_qps, disabled_qps, enabled_qps,
                             disabled_ratio, enabled_ratio, breakdown,
                             span_sourced} — baseline is Observability.off(),
                             disabled the default bundle, enabled adds
                             tracing; CI floors the ratios (>= 0.97 / 0.90)
    profile                  per-pass device-time attribution (§9.3):
                             {pass1_s, full_s, pass23_s, pass1_fraction,
                             iters, backend, profiler_available}
    smoke                    true when run with --smoke (CI scale)

``--stream`` instead runs the streaming-mutation workload (DESIGN.md §6)
and writes ``BENCH_stream.json``:

    workload                 points/queries/dims of the synthetic index
    delta_free_qps           service QPS before any mutation (chunk 8)
    delta_qps                same stream with delta_rows live delta-shard
                             rows fanned in (the headline: must stay within
                             2x of delta_free_qps — interpret-mode numbers
                             are a structural proxy off-TPU)
    delta_ratio              delta_qps / delta_free_qps
    delta_rows               live rows in the delta when delta_qps ran
    insert_rate_rows_per_s   encode-on-insert throughput (batches of 16,
                             fused incremental device appends — the default)
    insert                   {incremental_rows_per_s, rebuild_rows_per_s,
                             speedup, incremental_bytes_per_row,
                             rebuild_bytes_per_row, upload_reduction}:
                             fused dynamic_update_slice appends vs
                             per-insert full re-materialization, the two
                             modes ALTERNATED batch-by-batch over the same
                             fill window so both see the same delta sizes;
                             off-TPU the rates are a structural proxy and
                             the bytes columns (structural host->device
                             upload per inserted row) carry the hardware
                             claim
    sustained                {qps, insert_rate, rounds}: interleaved
                             insert-batch + query-stream rounds on one wall
                             clock — the serving-while-mutating claim
    compaction               {seconds, rows_folded}: the full REBUILD fold
                             (retrain=True: k-means + column space redone),
                             measured on a discarded clone
    merge_compaction         {seconds, rows_folded, speedup_vs_rebuild}:
                             the frozen-artifact MERGE fold
                             (retrain=False, DESIGN.md §6.2) driving the
                             real refresh() swap
    post_compact_qps         stream QPS on the merged generation
    smoke                    true when run with --smoke (CI scale)

Run:  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--stream]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core.engine import Backend, ScoringEngine
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.core.pq import pack_codes
from repro.core.sparse_index import sparse_queries_to_padded
from repro.data import make_hybrid_dataset
from repro.obs import (Observability, device_trace, pass_breakdown,
                       profiler_available)
from repro.serve import QueryService

from .common import emit, timeit

OUT_JSON = "BENCH_serve.json"
OUT_STREAM_JSON = "BENCH_stream.json"
BUCKETS = (1, 8, 32)
H, ALPHA, BETA = 20, 20, 5


def _build(smoke: bool):
    """kernels_bench-scale workload (BENCH_engine.json's), or a CI-sized one."""
    n, d_s, nnz = (4000, 6000, 24) if smoke else (20000, 20000, 48)
    ds = make_hybrid_dataset(num_points=n, num_queries=32, d_sparse=d_s,
                             d_dense=64, nnz_per_row=nnz, seed=3)
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                            HybridIndexParams(keep_top=96, head_dims=64,
                                              kmeans_iters=6))
    q_dims, q_vals = sparse_queries_to_padded(ds.q_sparse, idx.cols,
                                              nq_max=idx.params.nq_max)
    return ds, idx, q_dims, q_vals, np.asarray(ds.q_dense, np.float32)


def _stream_qps(svc: QueryService, q_dims, q_vals, q_dense,
                chunk: int, repeat: int) -> float:
    """Feed the 32-query stream through the service in `chunk`-sized requests."""
    nq = q_dims.shape[0]

    def run():
        for lo in range(0, nq, chunk):
            svc.search(q_dims[lo:lo + chunk], q_vals[lo:lo + chunk],
                       q_dense[lo:lo + chunk])

    run()  # warm the jit cache for this bucket
    secs, _ = timeit(run, repeat=repeat)
    return nq / secs


def _engine_bucket_qps(engine: ScoringEngine, q_dims, q_vals, q_dense,
                       bucket: int, repeat: int) -> float:
    """Raw engine QPS at one padded batch size (the packed-vs-unpacked probe
    bypasses the service so cache/bucketing logic can't mask the kernel)."""
    nq = q_dims.shape[0]
    chunks = []
    for lo in range(0, nq, bucket):
        pad = bucket - min(bucket, nq - lo)
        chunks.append(tuple(
            jnp.asarray(np.pad(a[lo:lo + bucket], [(0, pad)] + [(0, 0)] *
                               (a.ndim - 1), constant_values=c))
            for a, c in ((q_dims, engine.arrays.d_active), (q_vals, 0),
                         (q_dense, 0))))

    def run():
        outs = [engine.search(*c, h=H, alpha=ALPHA, beta=BETA)
                for c in chunks]
        return [np.asarray(o[1]) for o in outs]

    run()
    secs, _ = timeit(run, repeat=repeat)
    return nq / secs


def _obs_overhead(idx, q_dims, q_vals, q_dense, repeat):
    """Observability overhead probe at Q=32 (DESIGN.md §9.4): three
    identically configured services — ``baseline`` (Observability.off()),
    ``disabled`` (the default: metrics on, trace off), ``enabled``
    (metrics + tracing) — measured in INTERLEAVED best-of rounds so
    machine drift hits every mode equally.  Returns the qps per mode, the
    ratios vs baseline (CI floors: disabled >= 0.97, enabled >= 0.90),
    and a span-sourced dispatch/merge breakdown from the enabled mode."""
    modes = {"baseline": Observability.off(),
             "disabled": None,       # service default bundle
             "enabled": Observability(metrics=True, trace=True)}
    svcs = {k: QueryService(idx.engine, h=H, alpha=ALPHA, beta=BETA,
                            buckets=BUCKETS, cache_size=0,
                            **({} if v is None else {"obs": v}))
            for k, v in modes.items()}
    nq = q_dims.shape[0]
    for s in svcs.values():                  # shared-engine jit warmup
        s.search(q_dims, q_vals, q_dense)
    svcs["enabled"].obs.tracer.take()        # breakdown: measured runs only
    best = dict.fromkeys(svcs)
    # each round is ~1ms/mode; best-of-many so scheduler jitter cannot
    # fake an overhead the CI ratio floors would trip on
    for _ in range(max(25, repeat * 5)):
        for k, s in svcs.items():
            t0 = time.perf_counter()
            s.search(q_dims, q_vals, q_dense)
            dt = time.perf_counter() - t0
            if best[k] is None or dt < best[k]:
                best[k] = dt
    qps = {k: nq / v for k, v in best.items()}
    # span-sourced serve breakdown: sum the serve.batch children's
    # dispatch/merge tags over the enabled mode's measured traces
    traces = svcs["enabled"].obs.tracer.take()
    disp = merge = 0.0
    nbatch = 0
    for t in traces:
        for c in t.get("children", ()):
            tags = c.get("tags", {})
            disp += tags.get("dispatch_s", 0.0)
            merge += tags.get("merge_s", 0.0)
            nbatch += 1
    served = nq * len(traces) or 1
    for s in svcs.values():
        s.close()
    return {"baseline_qps": qps["baseline"],
            "disabled_qps": qps["disabled"],
            "enabled_qps": qps["enabled"],
            "disabled_ratio": qps["disabled"] / qps["baseline"],
            "enabled_ratio": qps["enabled"] / qps["baseline"],
            "breakdown": {"dispatch_us_per_q": disp / served * 1e6,
                          "merge_us_per_q": merge / served * 1e6,
                          "traces": len(traces), "batches": nbatch},
            "span_sourced": True}


def main(smoke: bool = False, profile_dir: str | None = None):
    """Run the serving benches; prints CSV rows and writes BENCH_serve.json."""
    repeat = 2 if smoke else 5
    ds, idx, q_dims, q_vals, q_dense = _build(smoke)
    nq = q_dims.shape[0]

    # -- baseline: one engine.search per query ---------------------------
    singles = [(jnp.asarray(q_dims[i:i + 1]), jnp.asarray(q_vals[i:i + 1]),
                jnp.asarray(q_dense[i:i + 1])) for i in range(nq)]

    def single_loop():
        return [np.asarray(idx.engine.search(*s, h=H, alpha=ALPHA,
                                             beta=BETA)[1]) for s in singles]

    single_loop()
    s_single, _ = timeit(single_loop, repeat=repeat)
    qps_single = nq / s_single
    emit("serve_single_query_loop", s_single / nq * 1e6,
         f"qps={qps_single:.1f}")

    # -- batched service across buckets (cold cache) ---------------------
    svc = QueryService(idx.engine, h=H, alpha=ALPHA, beta=BETA,
                       buckets=BUCKETS, cache_size=0)
    service_qps = {}
    for bucket in BUCKETS:
        qps = _stream_qps(svc, q_dims, q_vals, q_dense, bucket, repeat)
        service_qps[str(bucket)] = qps
        emit(f"serve_service_q{bucket}", 1e6 / qps,
             f"qps={qps:.1f};speedup_vs_single={qps / qps_single:.2f}x")

    # -- warm-cache repeat of the same stream ----------------------------
    cached = QueryService(idx.engine, h=H, alpha=ALPHA, beta=BETA,
                          buckets=BUCKETS, cache_size=4 * nq)
    t0 = time.perf_counter()
    cached.search(q_dims, q_vals, q_dense)
    cold_s = time.perf_counter() - t0
    warm_s, _ = timeit(lambda: cached.search(q_dims, q_vals, q_dense),
                       repeat=repeat)
    info = cached.cache_info()
    emit("serve_cache_warm", warm_s / nq * 1e6,
         f"qps={nq / warm_s:.1f};hit_rate={info.hit_rate:.3f}")

    # -- observability overhead + span-sourced breakdown (DESIGN.md §9.4) -
    # (before the refresh section: refresh() DONATES idx.engine's retired
    # buffers, so these probes must run while that engine is still live)
    obs = _obs_overhead(idx, q_dims, q_vals, q_dense, repeat)
    emit("serve_obs_overhead", 1e6 / obs["enabled_qps"],
         f"disabled_ratio={obs['disabled_ratio']:.3f};"
         f"enabled_ratio={obs['enabled_ratio']:.3f}")

    # -- per-pass device-time attribution (DESIGN.md §9.3) ----------------
    with device_trace(profile_dir):
        prof = pass_breakdown(idx.engine, jnp.asarray(q_dims),
                              jnp.asarray(q_vals), jnp.asarray(q_dense),
                              h=H, alpha=ALPHA, beta=BETA,
                              iters=2 if smoke else 3)
    prof["profiler_available"] = profiler_available()
    emit("serve_pass_breakdown", prof["full_s"] * 1e6,
         f"pass1_fraction={prof['pass1_fraction']:.3f};"
         f"pass1_us={prof['pass1_s'] * 1e6:.0f}")

    # -- refresh pause ----------------------------------------------------
    idx2 = HybridIndex.build(ds.x_sparse, ds.x_dense,
                             dataclasses.replace(idx.params, seed=11))
    t0 = time.perf_counter()
    svc.refresh(idx2.engine)
    swap_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    svc.search(q_dims, q_vals, q_dense)
    first_after_s = time.perf_counter() - t0
    emit("serve_refresh_swap", swap_s * 1e6,
         f"first_search_after_us={first_after_s * 1e6:.0f}")

    # -- packed vs unpacked across buckets (satellite of PR 3) -----------
    arr = idx2.engine.arrays
    eng_unpacked = ScoringEngine(arrays=arr, backend=Backend.PALLAS)
    eng_packed = ScoringEngine(
        arrays=dataclasses.replace(
            arr, codes=jnp.asarray(pack_codes(np.asarray(arr.codes))),
            codes_packed=True),
        backend=Backend.PALLAS_PACKED)
    packed = {}
    for bucket in BUCKETS:
        up = _engine_bucket_qps(eng_unpacked, q_dims, q_vals, q_dense,
                                bucket, repeat)
        pk = _engine_bucket_qps(eng_packed, q_dims, q_vals, q_dense,
                                bucket, repeat)
        packed[str(bucket)] = {"unpacked_qps": up, "packed_qps": pk,
                               "ratio": pk / up}
        emit(f"serve_packed_q{bucket}", 1e6 / pk,
             f"packed_qps={pk:.1f};unpacked_qps={up:.1f};"
             f"ratio={pk / up:.2f}x")

    out = {
        "workload": {"num_points": idx.num_points, "num_queries": nq,
                     "d_dense": 64, "h": H, "alpha": ALPHA, "beta": BETA},
        "single_query_loop_qps": qps_single,
        "service_qps": service_qps,
        "batched_speedup_q32": service_qps["32"] / qps_single,
        "cache": {"cold_qps": nq / cold_s, "warm_qps": nq / warm_s,
                  "hit_rate": info.hit_rate},
        "refresh": {"swap_s": swap_s, "first_search_after_s": first_after_s},
        "packed": packed,
        "obs": obs,
        "profile": prof,
        "smoke": smoke,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=2)


def _sparse_stream_qps(svc: QueryService, q_sparse, q_dense,
                       chunk: int, repeat: int) -> float:
    """Stream RAW sparse queries through search_sparse in chunk-sized
    requests (per-generation encoding included: the compact column space
    changes at compaction, so this is what a streaming client pays)."""
    nq = q_sparse.shape[0]

    def run():
        for lo in range(0, nq, chunk):
            svc.search_sparse(q_sparse[lo:lo + chunk],
                              q_dense[lo:lo + chunk])

    run()  # warm the jit cache for this bucket / delta capacity
    secs, _ = timeit(run, repeat=repeat)
    return nq / secs


def stream_main(smoke: bool = False):
    """Streaming-mutation workload (DESIGN.md §6): QPS with and without a
    live delta shard, sustained insert+query interleave, compaction cost.
    Prints CSV rows and writes BENCH_stream.json."""
    repeat = 2 if smoke else 5
    chunk = 8
    n, d_s, nnz = (4000, 6000, 24) if smoke else (20000, 20000, 48)
    n_delta = 128 if smoke else 512
    ds = make_hybrid_dataset(num_points=n + n_delta, num_queries=32,
                             d_sparse=d_s, d_dense=64, nnz_per_row=nnz,
                             seed=3)
    idx = HybridIndex.build(ds.x_sparse[:n], ds.x_dense[:n],
                            HybridIndexParams(keep_top=96, head_dims=64,
                                              kmeans_iters=6),
                            mutable=True,
                            # pre-size the delta so the fill measures the
                            # steady-state append paths, not growth steps
                            delta_capacity=n_delta)
    svc = QueryService(index=idx, h=H, alpha=ALPHA, beta=BETA,
                       buckets=BUCKETS, cache_size=0, auto_compact=False)
    qs, qd = ds.q_sparse, np.asarray(ds.q_dense, np.float32)

    # -- baseline: no mutations yet ---------------------------------------
    qps_free = _sparse_stream_qps(svc, qs, qd, chunk, repeat)
    emit("stream_delta_free", 1e6 / qps_free, f"qps={qps_free:.1f}")

    # -- fill the delta, measure insert rate + structural upload volume:
    # full re-materialization vs fused dynamic_update_slice appends
    # (DESIGN.md §6.1; wall-clock off-TPU is a structural proxy — the
    # hardware claim is the bytes column) ---------------------------------
    delta = idx.mutable_state.delta

    def _insert_batch(s, incremental):
        delta.incremental = incremental
        b0 = delta.upload_bytes
        t0 = time.perf_counter()
        svc.insert(ds.x_sparse[n + s: n + s + 16],
                   ds.x_dense[n + s: n + s + 16])
        return time.perf_counter() - t0, delta.upload_bytes - b0

    # warm BOTH paths over the first half, then ALTERNATE mode batch-by-
    # batch over the second half so each path is timed at the same delta
    # sizes — timing them in disjoint windows flatters whichever runs
    # while the delta is smaller (the old rebuild-first ordering reported
    # incremental appends SLOWER than re-materialization)
    half = n_delta // 2
    for i, s in enumerate(range(0, half, 16)):
        _insert_batch(s, incremental=i % 2 == 0)
    elapsed = {True: 0.0, False: 0.0}
    volume = {True: 0.0, False: 0.0}
    rows = {True: 0, False: 0}
    for i, s in enumerate(range(half, n_delta, 16)):
        mode = i % 2 == 0
        dt, db = _insert_batch(s, mode)
        elapsed[mode] += dt
        volume[mode] += db
        rows[mode] += 16
    insert_rate = rows[True] / elapsed[True]
    rebuild_rate = rows[False] / elapsed[False]
    incr_bytes = volume[True] / rows[True]
    rebuild_bytes = volume[False] / rows[False]
    emit("stream_insert_incremental", 1e6 / insert_rate,
         f"rows_per_s={insert_rate:.1f};rebuild_rows_per_s="
         f"{rebuild_rate:.1f};speedup={insert_rate / rebuild_rate:.2f}x;"
         f"bytes_per_row={incr_bytes:.0f}_vs_{rebuild_bytes:.0f}")
    delta_rows = svc.stats()["delta_rows"]
    assert delta_rows == n_delta

    # -- QPS with the delta fanned in (the headline ratio) ----------------
    qps_delta = _sparse_stream_qps(svc, qs, qd, chunk, repeat)
    ratio = qps_delta / qps_free
    emit("stream_delta_live", 1e6 / qps_delta,
         f"qps={qps_delta:.1f};ratio_vs_free={ratio:.2f}x;"
         f"delta_rows={delta_rows}")

    # -- sustained interleave: insert batches racing the query stream -----
    rounds = 3 if smoke else 6
    t0 = time.perf_counter()
    done = 0
    for r in range(rounds):
        svc.insert(ds.x_sparse[n + (r % 8) * 8: n + (r % 8) * 8 + 8],
                   ds.x_dense[n + (r % 8) * 8: n + (r % 8) * 8 + 8],
                   ids=np.arange(n + n_delta + r * 8,
                                 n + n_delta + r * 8 + 8))
        for lo in range(0, 32, chunk):
            svc.search_sparse(qs[lo:lo + chunk], qd[lo:lo + chunk])
        done += 8
    wall = time.perf_counter() - t0
    sustained_qps = rounds * 32 / wall
    sustained_ins = done / wall
    emit("stream_sustained", 1e6 / sustained_qps,
         f"qps={sustained_qps:.1f};inserts_per_s={sustained_ins:.1f}")

    # -- compaction: rebuild vs merge fold-down ---------------------------
    folded = svc.stats()["delta_rows"]
    # rebuild cost on a DISCARDED result, so the serving index keeps its
    # delta and the merge below folds the identical state
    t0 = time.perf_counter()
    svc._index.mutable_state.compact(retrain=True)
    rebuild_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    svc.compact(retrain=False)              # the real refresh() swap
    merge_s = time.perf_counter() - t0
    qps_post = _sparse_stream_qps(svc, qs, qd, chunk, repeat)
    emit("stream_compaction", rebuild_s * 1e6,
         f"rows_folded={folded};post_compact_qps={qps_post:.1f}")
    emit("stream_merge_compaction", merge_s * 1e6,
         f"rows_folded={folded};"
         f"speedup_vs_rebuild={rebuild_s / merge_s:.2f}x")

    out = {
        "workload": {"num_points": n, "num_queries": 32, "d_dense": 64,
                     "h": H, "alpha": ALPHA, "beta": BETA, "chunk": chunk},
        "delta_free_qps": qps_free,
        "delta_qps": qps_delta,
        "delta_ratio": ratio,
        "delta_rows": int(delta_rows),
        "insert_rate_rows_per_s": insert_rate,
        # fused incremental appends vs per-insert re-materialization,
        # alternated batch-by-batch over one fill window (same delta sizes
        # for both modes); the bytes columns carry the hardware claim
        # (host->device structural upload per inserted row) independent of
        # interpret-mode wall clock
        "insert": {"incremental_rows_per_s": insert_rate,
                   "rebuild_rows_per_s": rebuild_rate,
                   "speedup": insert_rate / rebuild_rate,
                   "incremental_bytes_per_row": incr_bytes,
                   "rebuild_bytes_per_row": rebuild_bytes,
                   "upload_reduction": rebuild_bytes / max(incr_bytes, 1.0)},
        "sustained": {"qps": sustained_qps, "insert_rate": sustained_ins,
                      "rounds": rounds},
        "compaction": {"seconds": rebuild_s, "rows_folded": int(folded)},
        "merge_compaction": {"seconds": merge_s, "rows_folded": int(folded),
                             "speedup_vs_rebuild": rebuild_s / merge_s},
        "post_compact_qps": qps_post,
        "smoke": smoke,
    }
    with open(OUT_STREAM_JSON, "w") as f:
        json.dump(out, f, indent=2)
    svc.close()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: small index, fewer repeats")
    ap.add_argument("--stream", action="store_true",
                    help="run the streaming-mutation workload instead "
                         "(writes BENCH_stream.json)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler device trace of the "
                         "pass-breakdown probe into this directory "
                         "(DESIGN.md §9.3; no-op when the profiler is "
                         "unavailable)")
    args = ap.parse_args()
    if args.stream:
        print("name,us_per_call,derived")
        stream_main(smoke=args.smoke)
    else:
        main(smoke=args.smoke, profile_dir=args.profile_dir)
