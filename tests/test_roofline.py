"""Roofline analysis unit tests (HLO parsing, model flops accounting)."""

import numpy as np

from repro.configs import SHAPES, get_config
from repro.roofline import collective_bytes_from_hlo, model_flops
from repro.roofline.analysis import _shape_bytes, count_params


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[2,3]{1,0}") == 12
    assert _shape_bytes("u8[100]") == 100
    assert _shape_bytes("f32[]") == 4


def test_collective_parse():
    hlo = """
      %all-reduce.1 = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
      %ag = bf16[256,64]{1,0} all-gather(%y), dimensions={0}
      %rs.2 = f32[8]{0} reduce-scatter(%z)
      %done = f32[16,128]{1,0} all-reduce-done(%w)
      %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%p, %q)
    """
    out = collective_bytes_from_hlo(hlo)
    assert out["all-reduce"] == 16 * 128 * 4
    assert out["all-gather"] == 256 * 64 * 2
    assert out["reduce-scatter"] == 32
    assert out["all-to-all"] == 2 * 16 * 4
    assert out["count"] == 4


def test_count_params_orders_of_magnitude():
    """Analytic param counts should land near the published model sizes."""
    total, active = count_params(get_config("qwen2-7b"))
    assert 6e9 < total < 9e9
    total, active = count_params(get_config("deepseek-67b"))
    assert 55e9 < total < 75e9
    total, active = count_params(get_config("qwen3-moe-235b-a22b"))
    assert 180e9 < total < 260e9
    assert 15e9 < active < 30e9           # A22B
    total, active = count_params(get_config("mamba2-780m"))
    assert 0.5e9 < total < 1.1e9


def test_model_flops_scaling():
    cfg = get_config("qwen2-7b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    f_prefill = model_flops(cfg, SHAPES["prefill_32k"])
    f_decode = model_flops(cfg, SHAPES["decode_32k"])
    # train does fwd+bwd (~3x fwd) on 4k x 256; prefill fwd on 32k x 32
    assert f_train > f_prefill > f_decode
    # decode is ~2*N_active*B plus attention reads
    _, active = count_params(cfg)
    assert f_decode > 2 * active * 128


def test_moe_flops_use_active_params():
    dense = model_flops(get_config("deepseek-67b"), SHAPES["train_4k"])
    moe = model_flops(get_config("qwen3-moe-235b-a22b"), SHAPES["train_4k"])
    # 235B total but ~22B active: train flops must be far below a 67B dense
    assert moe < dense
