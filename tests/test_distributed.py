"""Distributed pieces that need >1 device: run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count (jax locks device count at
first init, so the main test process cannot do this itself)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_sharded_search_matches_single_device():
    """Paper's 200-shard online system: sharded pass-1 == unsharded top-k."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.core.distributed import sharded_pass1_topk
        from repro.core.pq import adc_scores_ref

        mesh = make_test_mesh((4,), ("data",))
        rng = np.random.default_rng(0)
        n, kpq, l, q, nq, d_act, lm = 1024, 8, 16, 4, 16, 64, 8
        codes = jnp.asarray(rng.integers(0, l, (n, kpq)), jnp.uint8)
        lut = jnp.asarray(rng.normal(size=(q, kpq, l)), jnp.float32)
        # per-shard inverted indices: rows local to each shard
        shards = 4
        inv_rows = jnp.asarray(
            rng.integers(0, n // shards, (shards * d_act, lm)), jnp.int32)
        inv_vals = jnp.asarray(rng.normal(size=(shards * d_act, lm)),
                               jnp.float32)
        q_dims = jnp.asarray(rng.integers(0, d_act, (q, nq)), jnp.int32)
        q_vals = jnp.asarray(rng.normal(size=(q, nq)), jnp.float32)

        vals, ids = sharded_pass1_topk(mesh, codes, lut, inv_rows, inv_vals,
                                       q_dims, q_vals, k=10)

        # single-device reference
        dense = adc_scores_ref(codes, lut)
        sparse = np.zeros((q, n), np.float32)
        for s in range(shards):
            off = s * (n // shards)
            rows = np.asarray(inv_rows[s*d_act:(s+1)*d_act])
            valsv = np.asarray(inv_vals[s*d_act:(s+1)*d_act])
            for qi in range(q):
                for j, w in zip(np.asarray(q_dims)[qi],
                                np.asarray(q_vals)[qi]):
                    rr = rows[j]; vv = valsv[j]
                    ok = rr < n // shards
                    np.add.at(sparse[qi], rr[ok] + off, w * vv[ok])
        ref = np.asarray(dense) + sparse
        want = np.sort(ref, axis=1)[:, -10:][:, ::-1]
        np.testing.assert_allclose(np.sort(np.asarray(vals))[:, ::-1],
                                   np.sort(want)[:, ::-1], rtol=1e-4,
                                   atol=1e-4)
        print("SHARDED OK")
    """)
    assert "SHARDED OK" in out


def test_sharded_three_pass_matches_single_device_engine():
    """The distributed path now runs ALL THREE passes per shard (paper §7.2:
    each server refines its own candidates, the coordinator merges).  With
    per-shard overfetch covering every local row, the merged result must equal
    the global top-h of the fully refined scores."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.core.distributed import sharded_three_pass_topk
        from repro.core.pq import adc_scores_ref

        mesh = make_test_mesh((4,), ("data",))
        rng = np.random.default_rng(5)
        n, kpq, l, q, nq, d_act, lm, dd, R = 512, 8, 16, 4, 8, 32, 8, 16, 6
        shards = 4
        codes = jnp.asarray(rng.integers(0, l, (n, kpq)), jnp.uint8)
        lut = jnp.asarray(rng.normal(size=(q, kpq, l)), jnp.float32)
        inv_rows = jnp.asarray(
            rng.integers(0, n // shards, (shards * d_act, lm)), jnp.int32)
        inv_vals = jnp.asarray(rng.normal(size=(shards * d_act, lm)),
                               jnp.float32)
        res_q = jnp.asarray(rng.integers(-128, 128, (n, dd)), jnp.int8)
        res_scale = jnp.asarray(rng.uniform(0.01, 0.1, dd), jnp.float32)
        res_zero = jnp.asarray(rng.normal(size=dd), jnp.float32)
        sres_cols = jnp.asarray(rng.integers(0, d_act, (n, R)), jnp.int32)
        sres_vals = jnp.asarray(rng.normal(size=(n, R)), jnp.float32)
        q_dims = jnp.asarray(rng.integers(0, d_act, (q, nq)), jnp.int32)
        q_vals = jnp.asarray(rng.normal(size=(q, nq)), jnp.float32)
        q_dense = jnp.asarray(rng.normal(size=(q, dd)), jnp.float32)
        q_cols = jnp.zeros((q, d_act + 1), jnp.float32)
        qi = jnp.broadcast_to(jnp.arange(q)[:, None], q_dims.shape)
        q_cols = q_cols.at[qi, q_dims].add(q_vals).at[:, d_act].set(0.0)

        h = 10
        # alpha*h >= n//shards => every local row is refined through all
        # three passes, so the merged top-h is the exact global answer.
        vals, ids = sharded_three_pass_topk(
            mesh, codes, lut, inv_rows, inv_vals, res_q, res_scale, res_zero,
            sres_cols, sres_vals, q_dims, q_vals, q_dense, q_cols,
            h=h, alpha=(n // shards) // h + 1, beta=(n // shards) // h + 1)

        # single-device fully-refined reference
        dense = np.asarray(adc_scores_ref(codes, lut))
        sparse = np.zeros((q, n), np.float32)
        for s in range(shards):
            off = s * (n // shards)
            rows = np.asarray(inv_rows[s*d_act:(s+1)*d_act])
            valsv = np.asarray(inv_vals[s*d_act:(s+1)*d_act])
            for qi2 in range(q):
                for j, w in zip(np.asarray(q_dims)[qi2],
                                np.asarray(q_vals)[qi2]):
                    rr = rows[j]; vv = valsv[j]
                    ok = rr < n // shards
                    np.add.at(sparse[qi2], rr[ok] + off, w * vv[ok])
        qs = np.asarray(q_dense) * np.asarray(res_scale)[None]
        dres = (np.asarray(res_q, np.float32) @ qs.T).T \\
            + (128.0 * qs.sum(-1) + np.asarray(q_dense) @ np.asarray(res_zero))[:, None]
        qc = np.asarray(q_cols)
        sres = np.einsum('nr,qnr->qn', np.asarray(sres_vals),
                         qc[:, np.asarray(sres_cols)])
        total = dense + sparse + dres + sres
        want = np.sort(total, axis=1)[:, -h:][:, ::-1]
        np.testing.assert_allclose(np.asarray(vals), want, rtol=1e-4,
                                   atol=1e-4)
        print("THREE PASS OK")
    """)
    assert "THREE PASS OK" in out


def test_small_mesh_train_step_lowers_and_runs():
    """A reduced config train step actually RUNS (not just compiles) on a
    4-device (2,2) mesh — catches sharding bugs the dry-run can't."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.models import Model
        from repro.models.common import sharding_rules
        from repro.models.shardings import param_pspecs, batch_pspecs, tree_pspecs
        from repro.optim import AdamWConfig, adamw_init
        from repro.train import make_train_step
        from repro.data.pipeline import DataConfig, synthetic_batch
        from jax.sharding import NamedSharding

        mesh = make_test_mesh((2, 2), ("data", "model"))
        cfg = get_config("qwen2-moe-a2.7b-smoke")
        m = Model(cfg)
        ocfg = AdamWConfig(warmup_steps=0, decay_steps=10)
        params = m.init(jax.random.PRNGKey(0))
        opt = adamw_init(params, ocfg)
        pspec = param_pspecs(params, mesh)
        ospec = tree_pspecs(opt, mesh, params)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            params, pspec)
        opt = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            opt, ospec)
        batch = synthetic_batch(DataConfig(cfg.vocab_size, 32, 8), 0)
        bspec = batch_pspecs(batch, mesh)
        batch = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            batch, bspec)
        with sharding_rules(mesh):
            step = jax.jit(make_train_step(m, ocfg, 2))
            p2, o2, metrics = step(params, opt, batch)
        loss = float(metrics["nll"])
        assert loss == loss and loss > 0, loss
        print("MESH TRAIN OK", loss)
    """, devices=4)
    assert "MESH TRAIN OK" in out


def test_small_mesh_decode_runs():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.models import Model
        from repro.models.common import sharding_rules

        mesh = make_test_mesh((2, 2), ("data", "model"))
        cfg = get_config("recurrentgemma-9b-smoke")
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        with sharding_rules(mesh):
            state = m.init_decode_state(params, 4, 64)
            tok = jnp.zeros((4,), jnp.int32)
            lg, state = jax.jit(m.decode_step)(params, state, tok)
        assert lg.shape == (4, cfg.vocab_size)
        print("MESH DECODE OK")
    """, devices=4)
    assert "MESH DECODE OK" in out


def test_sharded_search_onehot_adc_matches_gather():
    """§Perf pair-3 optimization: MXU one-hot ADC == gather ADC."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.core.distributed import make_sharded_search_fn

        mesh = make_test_mesh((4,), ("data",))
        rng = np.random.default_rng(3)
        n, kpq, l, q, nq, d_act, lm = 512, 8, 16, 4, 8, 32, 8
        shards = 4
        args = (
            jnp.asarray(rng.integers(0, l, (n, kpq)), jnp.uint8),
            jnp.asarray(rng.normal(size=(q, kpq, l)), jnp.float32),
            jnp.asarray(rng.integers(0, n // shards,
                                     (shards * d_act, lm)), jnp.int32),
            jnp.asarray(rng.normal(size=(shards * d_act, lm)), jnp.float32),
            jnp.asarray(rng.integers(0, d_act, (q, nq)), jnp.int32),
            jnp.asarray(rng.normal(size=(q, nq)), jnp.float32),
            jnp.arange(shards, dtype=jnp.int32) * (n // shards),
        )
        va, ia = make_sharded_search_fn(mesh, k=10, adc="gather")(*args)
        vb, ib = make_sharded_search_fn(mesh, k=10, adc="onehot")(*args)
        # bf16 contraction => loose score tolerance, ids should mostly agree
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=3e-2, atol=3e-2)
        assert (np.asarray(ia) == np.asarray(ib)).mean() > 0.9
        print("ONEHOT ADC OK")
    """)
    assert "ONEHOT ADC OK" in out


def test_sharded_search_packed_adc_matches_gather():
    """Packed 4-bit codes shard rows exactly like unpacked ones (half the
    per-device HBM); pallas-packed pass-1 == gather pass-1."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.core.distributed import make_sharded_search_fn
        from repro.core.pq import pack_codes

        mesh = make_test_mesh((4,), ("data",))
        rng = np.random.default_rng(9)
        n, kpq, l, q, nq, d_act, lm = 512, 8, 16, 4, 8, 32, 8
        shards = 4
        codes = rng.integers(0, l, (n, kpq)).astype(np.uint8)
        packed = jnp.asarray(pack_codes(codes))
        assert packed.nbytes * 2 == codes.nbytes
        rest = (
            jnp.asarray(rng.normal(size=(q, kpq, l)), jnp.float32),
            jnp.asarray(rng.integers(0, n // shards,
                                     (shards * d_act, lm)), jnp.int32),
            jnp.asarray(rng.normal(size=(shards * d_act, lm)), jnp.float32),
            jnp.asarray(rng.integers(0, d_act, (q, nq)), jnp.int32),
            jnp.asarray(rng.normal(size=(q, nq)), jnp.float32),
            jnp.arange(shards, dtype=jnp.int32) * (n // shards),
        )
        va, ia = make_sharded_search_fn(mesh, k=10, adc="gather")(
            jnp.asarray(codes), *rest)
        vb, ib = make_sharded_search_fn(mesh, k=10, adc="pallas-packed")(
            packed, *rest)
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   rtol=1e-5, atol=1e-5)
        assert (np.asarray(ia) == np.asarray(ib)).all()
        print("PACKED SHARDED OK")
    """)
    assert "PACKED SHARDED OK" in out


def test_sharded_fused_pass1_matches_materialize():
    """The per-shard fused scan-and-select (DESIGN.md §2.5) must be
    bit-identical to the materialize-then-topk shard path, on both Pallas
    backends, through the full fan-out merge."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.core.distributed import make_sharded_search_fn
        from repro.core.pq import pack_codes
        import repro.kernels.ops as ops

        mesh = make_test_mesh((4,), ("data",))
        rng = np.random.default_rng(17)
        n, kpq, l, q, nq, d_act, lm = 512, 8, 16, 4, 8, 32, 8
        shards = 4
        codes = rng.integers(0, l, (n, kpq)).astype(np.uint8)
        packed = jnp.asarray(pack_codes(codes))
        rest = (
            jnp.asarray(rng.normal(size=(q, kpq, l)), jnp.float32),
            jnp.asarray(rng.integers(0, n // shards,
                                     (shards * d_act, lm)), jnp.int32),
            jnp.asarray(rng.normal(size=(shards * d_act, lm)), jnp.float32),
            jnp.asarray(rng.integers(0, d_act, (q, nq)), jnp.int32),
            jnp.asarray(rng.normal(size=(q, nq)), jnp.float32),
            jnp.arange(shards, dtype=jnp.int32) * (n // shards),
        )
        for adc, c in (("pallas", jnp.asarray(codes)), ("pallas-packed",
                                                        packed)):
            vf, idf = make_sharded_search_fn(mesh, k=10, adc=adc)(c, *rest)
            ops.MAX_FUSED_CANDIDATES = 0      # force the materialize route
            vm, idm = make_sharded_search_fn(mesh, k=10, adc=adc)(c, *rest)
            ops.MAX_FUSED_CANDIDATES = 1024
            assert (np.asarray(idf) == np.asarray(idm)).all(), adc
            np.testing.assert_array_equal(np.asarray(vf), np.asarray(vm))
        print("FUSED SHARDED OK")
    """)
    assert "FUSED SHARDED OK" in out


def test_moe_shardmap_combine_matches_pjit():
    """§Perf pair-1 optimization: explicit shard_map combine == pjit path."""
    out = _run("""
        import dataclasses, jax, numpy as np
        from repro.configs import get_config
        from repro.models import Model
        from repro.models.common import sharding_rules
        from repro.launch.mesh import make_test_mesh

        cfg0 = dataclasses.replace(get_config("qwen3-moe-235b-a22b-smoke"),
                                   capacity_factor=16.0)
        cfg1 = dataclasses.replace(cfg0, moe_shardmap_combine=True)
        m0, m1 = Model(cfg0), Model(cfg1)
        params = m0.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                              0, cfg0.vocab_size)}
        mesh = make_test_mesh((2, 2), ("data", "model"))
        with sharding_rules(mesh):
            a, _ = jax.jit(m0.forward)(params, batch)
            b, _ = jax.jit(m1.forward)(params, batch)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-2, atol=3e-2)
        print("SHARDMAP COMBINE OK")
    """, devices=4)
    assert "SHARDMAP COMBINE OK" in out


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint written on one mesh restores onto a different mesh."""
    out = _run(f"""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_test_mesh
        from repro.checkpoint import save_checkpoint, restore_checkpoint

        mesh4 = make_test_mesh((4,), ("data",))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh4, P("data")))
        save_checkpoint(r"{tmp_path}", 1, {{"x": x}})

        mesh2 = make_test_mesh((2, 2), ("data", "model"))
        got = restore_checkpoint(r"{tmp_path}", 1, {{"x": x}}, mesh=mesh2,
                                 pspec_tree={{"x": P("data", "model")}})
        assert got["x"].sharding.spec == P("data", "model")
        assert float(got["x"].sum()) == float(x.sum())
        print("ELASTIC OK")
    """, devices=4)
    assert "ELASTIC OK" in out
