"""End-to-end system behaviour: the paper's full pipeline on synthetic data
mirroring its public-dataset experiment (Table 2 shape), plus the LM-serving
integration."""

import numpy as np
import pytest

from repro.core import baselines as bl
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.data import make_hybrid_dataset


def test_table2_style_pipeline():
    """Netflix/Movielens-style: hybrid index beats LSH-style hashing and
    matches exact methods' recall within tolerance, end to end."""
    ds = make_hybrid_dataset(num_points=3000, num_queries=10, d_sparse=5000,
                             d_dense=32, nnz_per_row=32, seed=11)
    true_ids, _ = bl.exact_topk(ds.q_sparse, ds.q_dense, ds.x_sparse,
                                ds.x_dense, 20)
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                            HybridIndexParams(keep_top=48, kmeans_iters=5))
    r = idx.search(ds.q_sparse, ds.q_dense, h=20)
    hybrid = bl.recall_at_h(r.ids, true_ids)
    ham = bl.hamming512(ds.q_sparse, ds.q_dense, ds.x_sparse, ds.x_dense, 20,
                        overfetch=200)
    assert hybrid >= 0.85
    assert hybrid >= bl.recall_at_h(ham.ids, true_ids)


def test_searcher_handles_queries_with_unseen_dims():
    ds = make_hybrid_dataset(num_points=1000, num_queries=4, d_sparse=3000,
                             d_dense=16, nnz_per_row=16, seed=3)
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                            HybridIndexParams(keep_top=32, kmeans_iters=3))
    # shift query dims so many are absent from the shard's compact space
    import scipy.sparse as sp
    q = ds.q_sparse.tocoo()
    q = sp.csr_matrix((q.data, (q.row, (q.col + 2500) % 3000)),
                      shape=q.shape)
    r = idx.search(q, ds.q_dense, h=5)
    assert r.ids.shape == (4, 5)
    assert np.isfinite(r.scores).all()


def test_empty_sparse_queries():
    ds = make_hybrid_dataset(num_points=500, num_queries=3, d_sparse=1000,
                             d_dense=16, nnz_per_row=8, seed=5)
    import scipy.sparse as sp
    empty_q = sp.csr_matrix((3, 1000), dtype=np.float32)
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                            HybridIndexParams(keep_top=16, kmeans_iters=3))
    r = idx.search(empty_q, ds.q_dense, h=5)
    # dense-only ranking still returns sane results
    true_ids, _ = bl.exact_topk(empty_q, ds.q_dense, ds.x_sparse, ds.x_dense,
                                5)
    assert bl.recall_at_h(r.ids, true_ids) > 0.5
