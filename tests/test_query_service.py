"""QueryService (serve/query_service.py; DESIGN.md §5, §6): bucketed
micro-batching bounds the jit cache, the LRU result cache counts exactly,
refresh() is consistent with exactly one index generation and donates the
retired buffers, the shard fan-out matches the single-device engine, and the
streaming mutation path (insert/delete/compaction) never serves tombstoned,
duplicated, or stale-cached results — including under threaded load."""

import dataclasses
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import split_index_arrays
from repro.core.engine import query_fingerprint, release_index_arrays
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.core.sparse_index import sparse_queries_to_padded
from repro.serve import QueryService

PARAMS = HybridIndexParams(keep_top=48, head_dims=48, kmeans_iters=6)


@pytest.fixture(scope="module")
def served(small_hybrid):
    ds = small_hybrid
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense, PARAMS)
    q_dims, q_vals = sparse_queries_to_padded(ds.q_sparse, idx.cols,
                                              nq_max=idx.params.nq_max)
    return ds, idx, q_dims, q_vals, np.asarray(ds.q_dense, np.float32)


def test_service_matches_hybrid_index(served):
    """Bucketed/cached request path returns the engine's results: ids are
    bit-identical, scores within batch-padding reduction noise."""
    ds, idx, q_dims, q_vals, q_dense = served
    svc = QueryService(idx.engine, h=10, alpha=20, beta=5, id_map=idx.pi)
    s, ids = svc.search(q_dims, q_vals, q_dense)
    ref = idx.search(ds.q_sparse, ds.q_dense, h=10, alpha=20, beta=5)
    np.testing.assert_array_equal(ids, ref.ids)
    np.testing.assert_allclose(s, ref.scores, rtol=1e-6, atol=1e-6)


def test_single_query_1d_inputs(served):
    """A client sending one unbatched query gets the row-0 result back."""
    ds, idx, q_dims, q_vals, q_dense = served
    svc = QueryService(idx.engine, h=10, id_map=idx.pi)
    batch_s, batch_i = svc.search(q_dims, q_vals, q_dense)
    s, ids = svc.search(q_dims[0], q_vals[0], q_dense[0])
    assert s.shape == (1, 10) and ids.shape == (1, 10)
    np.testing.assert_array_equal(ids[0], batch_i[0])


def test_bucketing_bounds_jit_cache(served):
    """A ragged request stream (every batch size 1..max) never pads to more
    than len(buckets) distinct shapes — the declared jit-cache bound."""
    _, idx, q_dims, q_vals, q_dense = served
    svc = QueryService(idx.engine, h=5, buckets=(1, 4, 12), cache_size=0)
    rng = np.random.default_rng(0)
    for _ in range(8):
        q = int(rng.integers(1, q_dims.shape[0] + 1))
        rows = rng.choice(q_dims.shape[0], q, replace=False)
        svc.search(q_dims[rows], q_vals[rows], q_dense[rows])
    info = svc.jit_cache_info()
    assert set(info.batch_shapes) <= {1, 4, 12}
    assert len(info.batch_shapes) <= len(svc.buckets)
    assert info.entries <= info.bound == len(svc.buckets)


def test_oversized_batch_is_chunked(served):
    """Requests above the largest bucket split into largest-bucket chunks
    instead of minting a new shape."""
    ds, idx, q_dims, q_vals, q_dense = served
    svc = QueryService(idx.engine, h=10, buckets=(1, 4), cache_size=0,
                       id_map=idx.pi)
    s, ids = svc.search(q_dims, q_vals, q_dense)   # 12 queries > bucket 4
    assert svc.jit_cache_info().batch_shapes == (4,)
    ref = idx.search(ds.q_sparse, ds.q_dense, h=10, alpha=20, beta=5)
    np.testing.assert_array_equal(ids, ref.ids)


def test_cache_counters_exact_and_eviction(served):
    """LRU behavior to the letter: per-row hit/miss counts, capacity-bounded
    size, FIFO-of-least-recently-used eviction."""
    _, idx, q_dims, q_vals, q_dense = served
    svc = QueryService(idx.engine, h=5, cache_size=4)

    def one(i):
        return svc.search(q_dims[i:i + 1], q_vals[i:i + 1],
                          q_dense[i:i + 1])

    one(0), one(1), one(2)                       # 3 distinct queries: misses
    info = svc.cache_info()
    assert (info.hits, info.misses, info.size) == (0, 3, 3)

    one(1)                                       # repeat: pure hit
    info = svc.cache_info()
    assert (info.hits, info.misses) == (1, 3)

    one(3), one(4)                               # 5th distinct query evicts
    info = svc.cache_info()                      # the LRU entry (query 0)
    assert (info.size, info.capacity, info.evictions) == (4, 4, 1)

    one(0)                                       # evicted => miss again
    assert svc.cache_info().misses == 6
    one(4)                                       # still resident => hit
    assert svc.cache_info().hits == 2
    assert svc.cache_info().hit_rate == 2 / 8


def test_cache_disabled(served):
    """cache_size=0 bypasses the cache entirely (misses still counted)."""
    _, idx, q_dims, q_vals, q_dense = served
    svc = QueryService(idx.engine, h=5, cache_size=0)
    svc.search(q_dims, q_vals, q_dense)
    svc.search(q_dims, q_vals, q_dense)
    info = svc.cache_info()
    assert info.hits == 0 and info.size == 0
    assert info.misses == 2 * q_dims.shape[0]


def test_fingerprint_distinguishes_params(served):
    """The cache key covers search params and index generation — h=5 and
    h=10 results for the same query must not collide."""
    _, idx, q_dims, q_vals, q_dense = served
    a = query_fingerprint(q_dims[0], q_vals[0], q_dense[0], 5, 20, 5, 0)
    b = query_fingerprint(q_dims[0], q_vals[0], q_dense[0], 10, 20, 5, 0)
    c = query_fingerprint(q_dims[0], q_vals[0], q_dense[0], 5, 20, 5, 1)
    assert len({a, b, c}) == 3
    svc = QueryService(idx.engine, cache_size=16)
    s5, _ = svc.search(q_dims[:1], q_vals[:1], q_dense[:1], h=5)
    s10, _ = svc.search(q_dims[:1], q_vals[:1], q_dense[:1], h=10)
    assert s5.shape == (1, 5) and s10.shape == (1, 10)
    assert svc.cache_info().hits == 0


def test_sharded_fanout_matches_single_device(served):
    """Fan-out over 4 per-shard engines with full per-shard refinement
    returns bit-identical top-k ids to the unsharded engine (scores to
    kernel-accumulation noise) — the §7.2 merge done on host."""
    ds, idx, q_dims, q_vals, q_dense = served
    # alpha*h covers every local row => per-shard refinement is exact
    n_local = idx.num_points // 4
    alpha = beta = n_local // 10 + 1
    ref_svc = QueryService(idx.engine, h=10, alpha=alpha, beta=beta,
                           cache_size=0, id_map=idx.pi)
    fan = QueryService(idx.engine, h=10, alpha=alpha, beta=beta,
                       cache_size=0, num_shards=4, id_map=idx.pi)
    ref_s, ref_i = ref_svc.search(q_dims, q_vals, q_dense)
    s, ids = fan.search(q_dims, q_vals, q_dense)
    np.testing.assert_array_equal(ids, ref_i)
    np.testing.assert_allclose(s, ref_s, rtol=1e-5, atol=1e-5)


def test_split_index_arrays_shapes(served):
    """The fan-out entry point slices every row-parallel structure and
    localizes the inverted index; column-space structures are shared."""
    _, idx, *_ = served
    arr = idx.engine.arrays
    shards, offsets = split_index_arrays(arr, 4)
    n_local = arr.num_points // 4
    assert list(offsets) == [0, n_local, 2 * n_local, 3 * n_local]
    for s in shards:
        assert s.num_points == n_local
        assert s.codes.shape[0] == n_local
        assert s.dense_residual.q.shape[0] == n_local
        assert s.sparse_residual.cols.shape[0] == n_local
        assert int(s.inv_index.rows.max()) <= n_local
        assert s.codebooks is arr.codebooks
        assert s.head_pos is arr.head_pos
    with pytest.raises(ValueError, match="equal shards"):
        split_index_arrays(arr, 7)


def test_refresh_mid_stream_consistency(small_hybrid):
    """Results during a refresh are consistent with exactly ONE of the two
    index generations; requests after refresh() returns see the new one;
    the retired generation's buffers are donated once idle."""
    ds = small_hybrid
    idx_a = HybridIndex.build(ds.x_sparse, ds.x_dense, PARAMS)
    idx_b = HybridIndex.build(ds.x_sparse, ds.x_dense,
                              dataclasses.replace(PARAMS, seed=11))
    q_dims, q_vals = sparse_queries_to_padded(ds.q_sparse, idx_a.cols,
                                              nq_max=idx_a.params.nq_max)
    q_dense = np.asarray(ds.q_dense, np.float32)

    # deterministic per-generation references through identical bucketing
    ref_a = QueryService(idx_a.engine, h=10, cache_size=0).search(
        q_dims, q_vals, q_dense)
    ref_b = QueryService(idx_b.engine, h=10, cache_size=0).search(
        q_dims, q_vals, q_dense)
    assert not np.array_equal(ref_a[0], ref_b[0])   # generations distinguishable

    svc = QueryService(idx_a.engine, h=10, cache_size=64)
    futures = [svc.submit(q_dims, q_vals, q_dense) for _ in range(4)]
    svc.refresh(idx_b.engine)
    futures += [svc.submit(q_dims, q_vals, q_dense) for _ in range(2)]
    results = [f.result() for f in futures]
    for s, ids in results:
        from_a = np.array_equal(s, ref_a[0]) and np.array_equal(ids, ref_a[1])
        from_b = np.array_equal(s, ref_b[0]) and np.array_equal(ids, ref_b[1])
        assert from_a != from_b                     # exactly one generation
    # post-refresh submissions (and any later search) see generation B only
    s, ids = svc.search(q_dims, q_vals, q_dense)
    np.testing.assert_array_equal(s, ref_b[0])
    for s, ids in results[4:]:
        np.testing.assert_array_equal(s, ref_b[0])

    # donation: retired generation's device buffers are gone, new ones alive
    assert idx_a.engine.arrays.codes.is_deleted()
    assert not idx_b.engine.arrays.codes.is_deleted()
    svc.close()


def test_release_index_arrays_keep(small_hybrid):
    """The donation hook skips every leaf shared with a kept pytree."""
    ds = small_hybrid
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                            dataclasses.replace(PARAMS, kmeans_iters=2))
    arr = idx.engine.arrays
    shards, _ = split_index_arrays(arr, 2)
    # shards share codebooks with the parent: keeping the parent must
    # protect those leaves while the shard's own slices are freed
    deleted = release_index_arrays(shards[0], keep=[arr])
    assert deleted > 0
    assert shards[0].codes.is_deleted()
    assert not arr.codes.is_deleted()
    assert not shards[0].codebooks.centers.is_deleted()   # shared => kept


# -- streaming mutation (DESIGN.md §6) ---------------------------------------

MUT_PARAMS = HybridIndexParams(keep_top=32, head_dims=24, kmeans_iters=4)


@pytest.fixture()
def mut_served():
    """Small mutable index + service (fresh per test: mutation is stateful)."""
    from repro.data import make_hybrid_dataset
    ds = make_hybrid_dataset(num_points=800, num_queries=8, d_sparse=2000,
                             d_dense=16, nnz_per_row=24, seed=21)
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense, MUT_PARAMS,
                            mutable=True)
    return ds, idx


def test_insert_invalidates_result_cache(mut_served):
    """REGRESSION (ISSUE 4 satellite): the cache fingerprint must cover the
    delta-shard mutation version, not just the main generation — a warm
    query re-executes after insert() instead of serving pre-insert results."""
    ds, idx = mut_served
    svc = QueryService(index=idx, h=5, cache_size=64, auto_compact=False)
    s0, i0 = svc.search_sparse(ds.q_sparse[:1], ds.q_dense[:1])
    svc.search_sparse(ds.q_sparse[:1], ds.q_dense[:1])
    assert svc.cache_info().hits == 1
    new = svc.insert(ds.q_sparse[0] * 1e3, ds.q_dense[0])
    s1, i1 = svc.search_sparse(ds.q_sparse[:1], ds.q_dense[:1])
    info = svc.cache_info()
    assert (info.hits, info.misses) == (1, 2)     # post-insert lookup missed
    assert i1[0, 0] == new[0] and new[0] not in i0
    # delete must invalidate too
    svc.delete(new)
    s2, i2 = svc.search_sparse(ds.q_sparse[:1], ds.q_dense[:1])
    assert svc.cache_info().misses == 3
    assert new[0] not in i2
    svc.close()


def test_service_mutation_matches_core_index(mut_served):
    """The service's delta fan-out + host merge returns exactly what the
    core mutable search returns — single-engine and 4-shard fan-out alike."""
    ds, idx = mut_served
    svc = QueryService(index=idx, h=10, cache_size=0, auto_compact=False)
    svc.insert(ds.q_sparse[:3] * 1e3, ds.q_dense[:3])
    svc.delete([1, 2, 3])
    ref = idx.search(ds.q_sparse, ds.q_dense, h=10)
    s, ids = svc.search_sparse(ds.q_sparse, ds.q_dense)
    np.testing.assert_array_equal(ids, ref.ids)
    np.testing.assert_array_equal(s, ref.scores)
    fan = QueryService(index=idx, h=10, cache_size=0, num_shards=4,
                       auto_compact=False)
    s4, i4 = fan.search_sparse(ds.q_sparse, ds.q_dense)
    np.testing.assert_array_equal(i4, ref.ids)
    np.testing.assert_allclose(s4, ref.scores, rtol=1e-5, atol=1e-5)
    svc.close(); fan.close()


def test_service_compact_preserves_results_and_resets_delta(mut_served):
    """compact() folds the delta through refresh(): same logical results
    (dominant inserts stay top-1, deletes stay gone), delta/tombstone
    counters reset, generation bumped, old buffers donated."""
    ds, idx = mut_served
    svc = QueryService(index=idx, h=5, cache_size=16, auto_compact=False)
    new = svc.insert(ds.q_sparse[:2] * 1e3, ds.q_dense[:2])
    svc.delete([5, 6])
    old_arrays = idx.engine.arrays
    v = svc.compact()
    assert v == svc.version > 0
    st = svc.stats()
    assert st["compactions"] == 1
    assert st["delta_rows"] == 0 and st["deleted_pending"] == 0
    s, ids = svc.search_sparse(ds.q_sparse, ds.q_dense)
    assert ids[0, 0] == new[0] and ids[1, 0] == new[1]
    assert 5 not in ids and 6 not in ids
    assert old_arrays.codes.is_deleted()          # retired gen donated
    # compacting an unmutated index is a no-op
    assert svc.compact() == v
    svc.close()


def test_auto_compaction_triggers_in_background(mut_served):
    """Crossing the compact_min_rows floor spawns the background rebuild;
    the service keeps serving and ends up on a fresh generation with an
    empty delta."""
    ds, idx = mut_served
    svc = QueryService(index=idx, h=5, cache_size=0, auto_compact=True,
                       compact_min_rows=8, compact_ratio=0.0)
    new = svc.insert(ds.x_sparse[:8], ds.x_dense[:8] * 0 + ds.q_dense[0])
    deadline = time.time() + 120
    while svc.stats()["compactions"] == 0 and time.time() < deadline:
        svc.search_sparse(ds.q_sparse[:1], ds.q_dense[:1])  # keep serving
        time.sleep(0.05)
    st = svc.stats()
    assert st["compactions"] >= 1 and st["delta_rows"] == 0
    s, ids = svc.search_sparse(ds.q_sparse, ds.q_dense, h=20)
    assert set(new) <= set(np.asarray(ids).ravel()) | set()
    svc.close()


def test_refresh_rejected_on_mutable_service(mut_served):
    """External refresh() would pair the live delta (sharing the retired
    generation's device buffers and column space) with a foreign main
    index — the mutable path must route through compact() instead."""
    ds, idx = mut_served
    svc = QueryService(index=idx, h=5, cache_size=0, auto_compact=False)
    svc.insert(ds.q_sparse[0], ds.q_dense[0])
    with pytest.raises(ValueError, match="compact"):
        svc.refresh(idx.engine)
    svc.close()


def test_mutation_under_load(mut_served):
    """Stress: threaded searches racing insert()/delete()/background
    compaction must never observe a tombstoned id (deleted before the
    search started), a duplicate id within one result row, or a
    non-monotone score row (the mixed-generation smell) — extends the
    refresh old-xor-new consistency test to continuous mutation."""
    ds, idx = mut_served
    svc = QueryService(index=idx, h=10, cache_size=0, auto_compact=True,
                       compact_min_rows=20, compact_ratio=0.0)
    deleted_log: set[int] = set()
    log_lock = threading.Lock()
    stop = threading.Event()
    failures: list[str] = []

    def searcher():
        qi = 0
        while not stop.is_set():
            with log_lock:
                dead_before = set(deleted_log)
            s, ids = svc.search_sparse(ds.q_sparse[qi % 8: qi % 8 + 1],
                                       ds.q_dense[qi % 8: qi % 8 + 1])
            qi += 1
            row = ids[0]
            real = row[row >= 0]
            if len(set(real)) != len(real):
                failures.append(f"duplicate ids: {row}")
            if set(int(e) for e in real) & dead_before:
                failures.append(f"tombstoned id served: {row}")
            srow = s[0][np.isfinite(s[0])]
            if np.any(np.diff(srow) > 1e-4):
                failures.append(f"non-monotone scores: {s[0]}")

    threads = [threading.Thread(target=searcher) for _ in range(3)]
    for t in threads:
        t.start()
    rng = np.random.default_rng(3)
    known = list(range(800))
    try:
        for i in range(30):
            src = int(rng.integers(0, 800))
            new = svc.insert(ds.x_sparse[src], ds.x_dense[src])
            known.append(int(new[0]))
            if i % 4 == 3 and known:
                victim = known.pop(int(rng.integers(0, len(known))))
                if svc.delete([victim]):
                    with log_lock:
                        deleted_log.add(victim)
            time.sleep(0.01)
        svc.compact()
    finally:
        stop.set()
        for t in threads:
            t.join()
        svc.close()
    assert not failures, failures[:5]
    # post-quiesce: every tombstoned id stays gone
    s, ids = svc.search_sparse(ds.q_sparse, ds.q_dense, h=20)
    assert not (set(np.asarray(ids).ravel()) & deleted_log)
    assert svc.stats()["compactions"] >= 1


def test_service_merge_compact_preserves_results_and_resets_delta(mut_served):
    """compact(retrain=False) folds the delta via MERGE compaction through
    the same refresh() swap: dominant inserts stay served, deletes stay
    gone, counters reset, the frozen codebooks carry over unchanged, and
    the retired generation's own buffers are donated while the leaves the
    merged generation shares (codebooks, scalar grid) survive."""
    ds, idx = mut_served
    svc = QueryService(index=idx, h=5, cache_size=16, auto_compact=False)
    new = svc.insert(ds.q_sparse[:2] * 1e3, ds.q_dense[:2])
    svc.delete([5, 6])
    old_arrays = idx.engine.arrays
    old_codebooks = idx.codebooks
    v = svc.compact(retrain=False)
    assert v == svc.version > 0
    st = svc.stats()
    assert st["compactions"] == 1
    assert st["delta_rows"] == 0 and st["deleted_pending"] == 0
    assert svc._index.codebooks is old_codebooks   # frozen artifacts kept
    s, ids = svc.search_sparse(ds.q_sparse, ds.q_dense)
    assert ids[0, 0] == new[0] and ids[1, 0] == new[1]
    assert 5 not in ids and 6 not in ids
    assert old_arrays.codes.is_deleted()           # retired gen donated...
    assert not old_arrays.codebooks.centers.is_deleted()   # ...shared kept
    svc.close()


def test_mutation_under_load_with_merge_compaction(mut_served):
    """Stress (mirrors test_mutation_under_load, merge policy): threaded
    searches racing insert()/delete()/background MERGE compaction
    (compact_retrain=False) must never observe a tombstoned id (deleted
    before the search started), a duplicate id within one result row, or a
    non-monotone score row (the mixed-generation smell) — and the folds
    that happened must really have taken the merge path (frozen codebooks
    identical across every generation swap)."""
    ds, idx = mut_served
    codebooks0 = idx.codebooks
    svc = QueryService(index=idx, h=10, cache_size=0, auto_compact=True,
                       compact_min_rows=20, compact_ratio=0.0,
                       compact_retrain=False)
    deleted_log: set[int] = set()
    log_lock = threading.Lock()
    stop = threading.Event()
    failures: list[str] = []

    def searcher():
        qi = 0
        while not stop.is_set():
            with log_lock:
                dead_before = set(deleted_log)
            s, ids = svc.search_sparse(ds.q_sparse[qi % 8: qi % 8 + 1],
                                       ds.q_dense[qi % 8: qi % 8 + 1])
            qi += 1
            row = ids[0]
            real = row[row >= 0]
            if len(set(real)) != len(real):
                failures.append(f"duplicate ids: {row}")
            if set(int(e) for e in real) & dead_before:
                failures.append(f"tombstoned id served: {row}")
            srow = s[0][np.isfinite(s[0])]
            if np.any(np.diff(srow) > 1e-4):
                failures.append(f"non-monotone scores: {s[0]}")

    threads = [threading.Thread(target=searcher) for _ in range(3)]
    for t in threads:
        t.start()
    rng = np.random.default_rng(7)
    known = list(range(800))
    try:
        for i in range(30):
            src = int(rng.integers(0, 800))
            new = svc.insert(ds.x_sparse[src], ds.x_dense[src])
            known.append(int(new[0]))
            if i % 4 == 3 and known:
                victim = known.pop(int(rng.integers(0, len(known))))
                if svc.delete([victim]):
                    with log_lock:
                        deleted_log.add(victim)
            time.sleep(0.01)
        svc.compact()
    finally:
        stop.set()
        for t in threads:
            t.join()
        svc.close()
    assert not failures, failures[:5]
    # post-quiesce: tombstones stay gone, folds happened, and every one of
    # them was a merge — the original codebooks object is still serving
    s, ids = svc.search_sparse(ds.q_sparse, ds.q_dense, h=20)
    assert not (set(np.asarray(ids).ravel()) & deleted_log)
    assert svc.stats()["compactions"] >= 1
    assert svc._index.codebooks is codebooks0


def test_refresh_version_invalidates_cache(small_hybrid):
    """Cache keys include the generation: a warm query re-executes (miss)
    after refresh instead of serving the old index's result."""
    ds = small_hybrid
    idx_a = HybridIndex.build(ds.x_sparse, ds.x_dense, PARAMS)
    idx_b = HybridIndex.build(ds.x_sparse, ds.x_dense,
                              dataclasses.replace(PARAMS, seed=11))
    q_dims, q_vals = sparse_queries_to_padded(ds.q_sparse, idx_a.cols,
                                              nq_max=idx_a.params.nq_max)
    q_dense = np.asarray(ds.q_dense, np.float32)
    svc = QueryService(idx_a.engine, h=10, cache_size=64)
    svc.search(q_dims[:1], q_vals[:1], q_dense[:1])
    svc.search(q_dims[:1], q_vals[:1], q_dense[:1])
    assert svc.cache_info().hits == 1
    assert svc.refresh(idx_b.engine) == 1
    svc.search(q_dims[:1], q_vals[:1], q_dense[:1])
    info = svc.cache_info()
    assert info.hits == 1 and info.misses == 2


def test_metrics_exact_under_threaded_search(served):
    """Registry-backed counters stay EXACT under threaded load (ISSUE 10
    satellite): N threads race single-row searches through one service;
    afterwards ``serve.requests`` equals the total rows served,
    hits + misses account for every cache lookup, and the span ring holds
    (at most ``keep_traces``) finished ``serve.search`` roots whose qn
    tags also sum to the total."""
    from repro.obs import Observability
    _, idx, q_dims, q_vals, q_dense = served
    svc = QueryService(idx.engine, h=5, cache_size=256,
                       obs=Observability(trace=True, keep_traces=4096))
    n_threads, n_iters = 4, 40
    errors: list[BaseException] = []

    def worker(tid):
        try:
            for i in range(n_iters):
                j = (tid + i) % q_dims.shape[0]
                svc.search(q_dims[j:j + 1], q_vals[j:j + 1],
                           q_dense[j:j + 1])
        except BaseException as e:          # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    total = n_threads * n_iters
    snap = svc.metrics()
    assert snap["serve.requests"] == total
    assert snap["serve.cache.hits"] + snap["serve.cache.misses"] == total
    info = svc.cache_info()
    assert (info.hits, info.misses) == (snap["serve.cache.hits"],
                                        snap["serve.cache.misses"])
    # misses are bounded by distinct fingerprints × racing threads (two
    # threads may miss the same cold query before either populates it)
    assert snap["serve.cache.misses"] <= q_dims.shape[0] * n_threads
    assert snap["serve.batches"] == snap["serve.cache.misses"]
    traces = svc.obs.tracer.take()
    roots = [t for t in traces if t["name"] == "serve.search"]
    assert len(roots) == total
    assert sum(t["tags"]["qn"] for t in roots) == total
    assert sum(t["tags"]["cache_hits"] for t in roots) == info.hits
