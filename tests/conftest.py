import numpy as np
import pytest
import scipy.sparse as sp


@pytest.fixture(scope="module", autouse=True)
def _reclaim_jit_maps():
    """XLA:CPU keeps every compiled executable mmap'd for the life of the
    process; one full-suite run accumulates enough of them (hundreds of
    pallas-interpret compilations) to exhaust ``vm.max_map_count``, after
    which the NEXT backend_compile segfaults.  Dropping the jit caches at
    every module boundary unmaps retired executables and keeps the map
    count bounded; cross-module recompiles are cheap next to the suite."""
    yield
    import jax
    jax.clear_caches()


@pytest.fixture(scope="session")
def small_hybrid():
    """Shared small hybrid dataset with planted neighbors."""
    from repro.data import make_hybrid_dataset
    return make_hybrid_dataset(num_points=4000, num_queries=12,
                               d_sparse=8000, d_dense=32, nnz_per_row=40,
                               seed=7)


@pytest.fixture(scope="session")
def exact_topk(small_hybrid):
    """Cached brute-force scores + exact top-20 ids for the shared
    pinned-seed dataset — the recall-regression reference
    (tests/test_recall.py): computed once per session so every recall
    assertion compares against identical ground truth."""
    ds = small_hybrid
    exact = (np.asarray((ds.q_sparse @ ds.x_sparse.T).todense())
             + np.asarray(ds.q_dense, np.float32)
             @ np.asarray(ds.x_dense, np.float32).T)
    ids = np.argsort(-exact, axis=1)[:, :20]
    return exact, ids


@pytest.fixture(scope="session")
def powerlaw_sparse():
    rng = np.random.default_rng(0)
    n, d = 1500, 300
    pj = np.minimum(1.0, np.arange(1, d + 1) ** -1.5 * 3)
    mask = rng.random((n, d)) < pj[None, :]
    vals = (rng.lognormal(0, 1, (n, d)) * mask).astype(np.float32)
    return sp.csr_matrix(vals)
