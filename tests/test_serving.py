"""Serving: PQ hybrid head (paper technique) + generation loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.serve import greedy_generate
from repro.serve.hybrid_head import HybridLMHead

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("qwen2-7b-smoke")
    m = Model(cfg)
    return cfg, m, m.init(KEY)


def test_pq_head_topk_recall(model_and_params):
    cfg, m, params = model_and_params
    head = HybridLMHead(cfg)
    hp = head.build(params["lm_head"])
    h = jax.random.normal(KEY, (16, cfg.d_model), jnp.float32)
    _, ia = head.approx_topk(hp, h, None, 20, 8, 0.0)
    _, ie = head.exact_topk(hp, h, None, 20, 0.0)
    rec = np.mean([len(set(a.tolist()) & set(e.tolist())) / 20
                   for a, e in zip(np.asarray(ia), np.asarray(ie))])
    assert rec >= 0.9


def test_pq_head_kernel_path(model_and_params):
    cfg, m, params = model_and_params
    h = jax.random.normal(KEY, (8, cfg.d_model), jnp.float32)
    a = HybridLMHead(cfg, use_kernel=False)
    b = HybridLMHead(cfg, use_kernel=True)
    hpa = a.build(params["lm_head"])
    _, ia = a.approx_topk(hpa, h, None, 10, 8, 0.0)
    _, ib = b.approx_topk(hpa, h, None, 10, 8, 0.0)
    assert (np.asarray(ia) == np.asarray(ib)).mean() > 0.95


def test_pq_head_packed_backend(model_and_params):
    """pallas-packed head: vocab-side codes stored two-per-byte (half the
    decode-time pass-1 stream), retrieval unchanged."""
    cfg, m, params = model_and_params
    h = jax.random.normal(KEY, (8, cfg.d_model), jnp.float32)
    a = HybridLMHead(cfg)
    b = HybridLMHead(cfg, backend="pallas-packed")
    hpa = a.build(params["lm_head"])
    hpb = b.build(params["lm_head"])
    assert hpb.codes_packed
    v, k = hpa.codes.shape
    assert hpb.codes.shape == (v, (k + 1) // 2)
    _, ia = a.approx_topk(hpa, h, None, 10, 8, 0.0)
    _, ib = b.approx_topk(hpb, h, None, 10, 8, 0.0)
    assert (np.asarray(ia) == np.asarray(ib)).mean() > 0.95


def test_pq_head_bucketed_decode_batches(model_and_params):
    """approx_topk_bucketed (DESIGN.md §5): ragged decode batches pad up to
    the static buckets — same ids as the unpadded call, for every size."""
    cfg, m, params = model_and_params
    head = HybridLMHead(cfg)
    hp = head.build(params["lm_head"])
    hid = jax.random.normal(KEY, (7, cfg.d_model), jnp.float32)
    counts = jnp.zeros((7, cfg.vocab_size), jnp.float32)
    # b=7 with buckets (2, 4) also exercises the oversized-batch chunking
    # (7 -> chunks of 4 + 3, the tail padded up to 4)
    for b in (1, 3, 7):
        va, ia = head.approx_topk(hp, hid[:b], counts[:b], 10, 8, 0.1)
        vb, ib = head.approx_topk_bucketed(hp, hid[:b], counts[:b], 10, 8,
                                           0.1, buckets=(2, 4))
        assert ib.shape == (b, 10)
        assert (np.asarray(ia) == np.asarray(ib)).mean() > 0.95


def test_hybrid_penalty_changes_ranking(model_and_params):
    """The sparse (repetition-count) component must steer retrieval — the
    hybrid q·x = dense + sparse decomposition doing real work."""
    cfg, m, params = model_and_params
    head = HybridLMHead(cfg)
    hp = head.build(params["lm_head"])
    h = jax.random.normal(KEY, (1, cfg.d_model), jnp.float32)
    _, top_plain = head.approx_topk(hp, h, None, 1, 8, 0.0)
    winner = int(top_plain[0, 0])
    counts = jnp.zeros((1, cfg.vocab_size), jnp.float32).at[0, winner].set(1e4)
    _, top_pen = head.approx_topk(hp, h, counts, 1, 8, penalty=1.0)
    assert int(top_pen[0, 0]) != winner


def test_generate_pq_vs_exact(model_and_params):
    cfg, m, params = model_and_params
    prompt = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    exact = greedy_generate(m, params, prompt, 6, 48, use_pq_head=False)
    pq = greedy_generate(m, params, prompt, 6, 48, use_pq_head=True)
    assert (np.asarray(exact) == np.asarray(pq)).mean() >= 0.8


def test_generate_with_penalty_reduces_repetition(model_and_params):
    cfg, m, params = model_and_params
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    plain = np.asarray(greedy_generate(m, params, prompt, 12, 48,
                                       penalty=0.0))
    pen = np.asarray(greedy_generate(m, params, prompt, 12, 48,
                                     penalty=5.0))

    def rep(x):
        return np.mean([len(row) - len(set(row.tolist())) for row in x])

    assert rep(pen) <= rep(plain)
