"""Durable index persistence (repro/persist, DESIGN.md §7).

The headline property (ISSUE 5 acceptance): for random insert/delete/
search/compact interleavings served through a durable ``QueryService``,
killing the process and truncating the WAL at ARBITRARY byte offsets, then
recovering, yields search results bit-identical — ids AND scores — to an
index that applied exactly the mutations whose WAL records survived
complete ("recover to the last complete record"), across backends
{ref, pallas, pallas-packed} and odd/even PQ subspace counts.

Plus unit coverage of the two mechanisms the property rests on: the framed
checksummed WAL (torn tails, crc corruption, rotation/truncation, reopen-
after-crash) and the snapshot store (bit-exact leaf round trip, checksum
verification, pristine-only rule, atomic commit leaving no litter on
failure).

Group commit + delta-state snapshots (DESIGN.md §7.6) extend the matrix:
one shared fsync acks a whole batch of framed records (crash between batch
fsyncs loses only unacked mutations, property-tested at arbitrary WAL
byte-truncation points), and ``checkpoint()`` folds the LIVE delta into a
snapshot so recovery under sustained ingest replays only a short tail.
"""

import os
import shutil
import tempfile

import numpy as np
import pytest
import scipy.sparse as sp

from _hypothesis_compat import given, settings, strategies as st

from repro import persist
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.data import make_hybrid_dataset
from repro.persist.wal import _scan_segment
from repro.serve import QueryService

# -- shared tiny workload ----------------------------------------------------

N0, N_POOL, NQ = 120, 170, 3
D_SPARSE, NNZ = 360, 12

_DS_CACHE = {}


def _cached_dataset(d_dense):
    if d_dense not in _DS_CACHE:
        _DS_CACHE[d_dense] = make_hybrid_dataset(
            num_points=N_POOL, num_queries=NQ, d_sparse=D_SPARSE,
            d_dense=d_dense, nnz_per_row=NNZ, seed=23)
    return _DS_CACHE[d_dense]


def _params(backend, k):
    return HybridIndexParams(keep_top=24, head_dims=12, kmeans_iters=3,
                             backend=backend, pq_subspaces=k)


def _build_mutable(ds, params, n0=N0):
    return HybridIndex.build(ds.x_sparse[:n0], ds.x_dense[:n0], params,
                             mutable=True)


def _search(index, ds, h=8):
    r = index.search(ds.q_sparse, ds.q_dense, h=h)
    return np.asarray(r.ids), np.asarray(r.scores)


# -- WAL framing / truncation / corruption -----------------------------------

def _tiny_wal(root, n=3):
    wal = persist.MutationWAL(os.path.join(root, "wal"))
    seqs = []
    for i in range(n):
        seqs.append(wal.append_insert(
            sp.csr_matrix(np.eye(2, 5, dtype=np.float32) * (i + 1)),
            np.full((2, 3), i, np.float32),
            np.asarray([2 * i, 2 * i + 1])))
    wal.close()
    return wal.segment_paths[-1], seqs


def test_wal_roundtrip_and_reopen(tmp_path):
    """Append/replay round trip is bit-exact (dtypes included), and
    reopening continues the sequence after the last complete record."""
    root = str(tmp_path)
    path, seqs = _tiny_wal(root)
    wal = persist.MutationWAL(os.path.join(root, "wal"))
    records = wal.records()
    assert [r.seq for r in records] == seqs == [1, 2, 3]
    a = records[1].arrays
    assert a["data"].dtype == np.float32
    np.testing.assert_array_equal(
        a["dense"], np.full((2, 3), 1, np.float32))
    np.testing.assert_array_equal(a["ids"], [2, 3])
    assert wal.next_seq == 4
    wal.append_delete([7])
    assert wal.records()[-1].kind == persist.RECORD_DELETE
    wal.close()


def test_wal_truncation_every_byte_offset(tmp_path):
    """Truncating the log at EVERY byte offset recovers exactly the records
    that are complete below the cut — never a partial one, never a crash."""
    root = str(tmp_path)
    path, _ = _tiny_wal(root)
    full = open(path, "rb").read()
    records, size, clean = _scan_segment(path)
    assert clean and size == len(full) and len(records) == 3
    counts = []
    for cut in range(len(full) + 1):
        with open(path, "wb") as f:
            f.write(full[:cut])
        got, valid, _ = _scan_segment(path)
        # every surviving record is an original prefix, in order
        assert [g.seq for g in got] == [r.seq for r in records[:len(got)]]
        assert valid <= cut
        # reopening for append truncates the torn tail and resumes
        wal = persist.MutationWAL(os.path.join(root, "wal"))
        assert wal.next_seq == (got[-1].seq + 1 if got else 1)
        assert os.path.getsize(path) == valid
        wal.close()
        counts.append(len(got))
    assert counts[0] == 0 and counts[-1] == 3
    assert sorted(set(counts)) == [0, 1, 2, 3]   # every prefix reachable


def test_wal_crc_corruption_stops_replay(tmp_path):
    """A flipped byte — in a payload OR in the header's seq field — fails
    the crc and replay stops at the last record before it instead of
    silently skipping or reordering a mutation."""
    root = str(tmp_path)
    path, _ = _tiny_wal(root)
    full = open(path, "rb").read()
    records, _, _ = _scan_segment(path)
    assert len(records) == 3
    buf = bytearray(full)
    buf[len(buf) // 2] ^= 0xFF            # inside record 2's payload
    with open(path, "wb") as f:
        f.write(bytes(buf))
    got, _, clean = _scan_segment(path)
    assert not clean and len(got) < 3
    # header corruption: flip a byte of record 1's seq field (offset 3-10)
    # — the crc covers the header prefix, so this must NOT decode as a
    # valid record with a different seq
    buf = bytearray(full)
    buf[5] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(buf))
    got, valid, clean = _scan_segment(path)
    assert not clean and len(got) == 0 and valid == 0


def test_wal_refuses_midlog_bitrot(tmp_path):
    """Corruption with intact records decodable AFTER it is bitrot, not a
    torn tail: reopening for append must refuse to truncate the acked
    records away, and replay over a corrupt SEALED segment must raise."""
    root = str(tmp_path)
    path, _ = _tiny_wal(root)
    buf = bytearray(open(path, "rb").read())
    records, _, _ = _scan_segment(path)
    assert len(records) == 3
    buf[len(buf) // 2] ^= 0xFF            # record 2; record 3 stays intact
    with open(path, "wb") as f:
        f.write(bytes(buf))
    with pytest.raises(ValueError, match="bitrot"):
        persist.MutationWAL(os.path.join(root, "wal"))


def test_wal_refuses_corrupt_sealed_segment(tmp_path):
    """A rotated (non-active) segment can never hold a torn tail — any
    anomaly there is acked-data loss and replay raises."""
    wal = persist.MutationWAL(str(tmp_path / "wal"))
    for _ in range(2):
        wal.append_delete([1])
    wal.rotate()
    wal.append_delete([2])
    sealed = wal.segment_paths[0]
    buf = bytearray(open(sealed, "rb").read())
    buf[-1] ^= 0xFF                       # corrupt the sealed segment
    with open(sealed, "wb") as f:
        f.write(bytes(buf))
    with pytest.raises(ValueError, match="sealed"):
        wal.records()
    wal.close()


def test_wal_rotate_and_truncate_segments(tmp_path):
    """rotate() cuts a fresh segment at next_seq; truncate_before drops
    fully superseded segments and never the active one."""
    wal = persist.MutationWAL(str(tmp_path / "wal"))
    for _ in range(3):
        wal.append_delete([1])
    first_new = wal.rotate()
    assert first_new == 4
    wal.append_delete([2])
    assert len(wal.segment_paths) == 2
    assert wal.truncate_before(first_new) == 1
    assert len(wal.segment_paths) == 1
    assert [r.seq for r in wal.records()] == [4]
    assert wal.truncate_before(10 ** 6) == 0      # active never deleted
    wal.close()


# -- WAL group commit (DESIGN.md §7.6) ----------------------------------------

def test_wal_group_commit_defers_and_batches_fsync(tmp_path, monkeypatch):
    """sync=False appends defer the disk sync; one ``sync_to`` then fsyncs
    ONCE for the whole raced-in batch, later calls below the watermark are
    no-ops, and ``append_many`` amortizes framing + flush + fsync the same
    way — the shared-fsync ack path."""
    import repro.persist.wal as wal_mod
    calls = {"n": 0}
    real_fsync = os.fsync

    def counting_fsync(fd):
        calls["n"] += 1
        return real_fsync(fd)

    monkeypatch.setattr(wal_mod.os, "fsync", counting_fsync)
    wal = persist.MutationWAL(str(tmp_path / "wal"))
    seqs = [wal.append_delete([i], sync=False) for i in range(5)]
    assert seqs == [1, 2, 3, 4, 5]
    assert calls["n"] == 0 and wal._synced_seq == 0    # nothing acked yet
    wal.sync_to(seqs[-1])
    assert calls["n"] == 1 and wal._synced_seq == 5    # one fsync, all acked
    for s in seqs:
        wal.sync_to(s)                                 # already covered
    assert calls["n"] == 1
    wal.sync_to(wal.append_delete([9], sync=False))
    assert calls["n"] == 2 and wal._synced_seq == 6
    got = wal.append_many([
        (persist.RECORD_DELETE, {"ids": np.asarray([j], np.int64)})
        for j in range(4)])
    assert got == [7, 8, 9, 10]
    assert calls["n"] == 3 and wal._synced_seq == 10
    assert [r.seq for r in wal.records()] == list(range(1, 11))
    wal.close()


def test_wal_group_commit_rotate_seals_durably(tmp_path):
    """A deferred (sync=False) record followed by ``rotate()`` lands
    fsync'd INSIDE the sealed segment — sealing must never strand a
    flushed-but-unsynced group-commit record in a file no later
    ``sync_to`` can reach — and the sync watermark resets to the new
    segment's base."""
    wal = persist.MutationWAL(str(tmp_path / "wal"))
    a = wal.append_delete([1], sync=False)
    assert wal._synced_seq == 0
    first = wal.rotate()
    assert first == 2 and wal._synced_seq == 1         # sealed ⇒ durable
    b = wal.append_delete([2], sync=False)
    assert wal._synced_seq == 1
    wal.sync_to(b)
    assert wal._synced_seq == 2
    assert [r.seq for r in wal.records()] == [a, b] == [1, 2]
    wal.close()
    reopened = persist.MutationWAL(str(tmp_path / "wal"))
    assert reopened.next_seq == 3
    assert [r.seq for r in reopened.records()] == [1, 2]
    reopened.close()


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 9999))
def test_group_commit_crash_matrix_truncation(seed):
    """Group-commit batches, then a crash at ARBITRARY WAL byte offsets:
    the surviving log is always a clean in-order record prefix, and every
    batch whose shared fsync returned before the cut point — i.e. the
    flushed size at ack time is below the cut — survives in full.  Acked
    mutations are never lost; only records past the last covering fsync
    can fall off."""
    rng = np.random.default_rng(seed)
    root = tempfile.mkdtemp(prefix="persist-gc-")
    try:
        wal = persist.MutationWAL(os.path.join(root, "wal"))
        seg = wal.segment_paths[-1]
        acked = []                  # (flushed bytes at ack, last acked seq)
        for b in range(5):
            entries = [(persist.RECORD_DELETE,
                        {"ids": np.asarray([10 * b + j], np.int64)})
                       for j in range(int(rng.integers(1, 5)))]
            seqs = wal.append_many(entries)        # one shared fsync = ack
            acked.append((os.path.getsize(seg), seqs[-1]))
        wal.close()
        full = open(seg, "rb").read()
        assert acked[-1][0] == len(full)
        cuts = sorted({0, len(full)}
                      | {int(c) for c in rng.integers(0, len(full) + 1,
                                                      size=12)}
                      | {s for s, _ in acked})
        for cut in cuts:
            with open(seg, "wb") as f:
                f.write(full[:cut])
            got, valid, _ = _scan_segment(seg)
            assert [g.seq for g in got] == list(range(1, len(got) + 1))
            assert valid <= cut
            for size_at_ack, last_seq in acked:
                if cut >= size_at_ack:      # crash struck after this ack
                    assert last_seq <= len(got), \
                        f"acked seq {last_seq} lost at cut {cut}"
    finally:
        shutil.rmtree(root, ignore_errors=True)


# -- term fencing (DESIGN.md §8.7) --------------------------------------------

def test_wal_term_monotone_and_persisted(tmp_path):
    """Terms only grow, survive close/reopen via the TERM file, and stamp
    every subsequently appended record."""
    wal = persist.MutationWAL(os.path.join(str(tmp_path), "wal"))
    assert wal.term == 1
    wal.append_delete([1])
    wal.set_term(3)
    with pytest.raises(ValueError, match="monotone"):
        wal.set_term(2)
    wal.set_term(3)                       # idempotent re-adopt is fine
    wal.append_delete([2])
    wal.close()
    wal = persist.MutationWAL(os.path.join(str(tmp_path), "wal"))
    assert wal.term == 3
    terms = [r.term for r in wal.records()]
    assert terms == [1, 3]
    wal.close()


def test_wal_append_frames_zombie_fence(tmp_path):
    """A shipped frame stamped with a term below the follower's is REFUSED
    (the zombie ex-primary fence), while an overlapping re-ship of frames
    the log already holds stays idempotent — the seq<next_seq skip runs
    BEFORE the fence, so old same-term history never trips it."""
    root = str(tmp_path)
    old = persist.MutationWAL(os.path.join(root, "old"))     # term 1
    s1 = old.append_delete([1])
    buf1, _ = old.read_frames(s1)
    follower = persist.MutationWAL(os.path.join(root, "f"))
    follower.append_frames(buf1)                # term-1 history lands
    follower.set_term(2)                        # learns of a promotion
    # the deposed primary keeps writing in term 1 …
    s2 = old.append_delete([2])
    buf2, _ = old.read_frames(s2)
    with pytest.raises(ValueError, match="zombie"):
        follower.append_frames(buf2)            # … and is refused
    assert follower.next_seq == s2              # nothing landed
    # re-shipping already-held term-1 frames is still a no-op, not a raise
    assert follower.append_frames(buf1) == []
    old.close()
    follower.close()


def test_wal_append_frames_adopts_higher_term(tmp_path):
    """A shipped frame carrying a HIGHER term is adopted durably before it
    lands, and the noop term barrier replays as a no-op through recovery's
    ``apply_record``."""
    root = str(tmp_path)
    new = persist.MutationWAL(os.path.join(root, "new"))
    new.set_term(5)
    sn = new.append_noop()                      # the promotion barrier
    assert new.records()[-1].kind == persist.RECORD_NOOP
    buf, seqs = new.read_frames(sn)
    follower = persist.MutationWAL(os.path.join(root, "f"))
    recs = follower.append_frames(buf)
    assert seqs == [sn] and [r.seq for r in recs] == [sn]
    assert follower.term == 5                   # adopted …
    follower.close()
    follower = persist.MutationWAL(os.path.join(root, "f"))
    assert follower.term == 5                   # … and persisted
    persist.apply_record(object(), recs[0])     # noop touches nothing
    follower.close()
    new.close()


def test_wal_start_seq_bootstrap_continues_at_horizon(tmp_path):
    """A brand-new log opened with ``start_seq=N`` (a follower whose
    fetched snapshot has ``replay_from_seq=N``) accepts shipped frames
    starting at N instead of seeing a 1..N-1 gap — the post-compaction
    bootstrap path."""
    root = str(tmp_path)
    primary = persist.MutationWAL(os.path.join(root, "p"))
    for i in range(4):
        primary.append_delete([i])
    primary.rotate()                            # compaction cut at seq 5
    s5 = primary.append_delete([99])
    assert s5 == 5
    buf, _ = primary.read_frames(5)
    fresh = persist.MutationWAL(os.path.join(root, "f"), start_seq=5)
    assert fresh.next_seq == 5
    recs = fresh.append_frames(buf)             # no gap error
    assert [r.seq for r in recs] == [5]
    fresh.close()
    # start_seq is ignored once segments exist: reopen resumes after 5
    fresh = persist.MutationWAL(os.path.join(root, "f"), start_seq=1)
    assert fresh.next_seq == 6
    fresh.close()
    primary.close()


# -- snapshot store -----------------------------------------------------------

@pytest.mark.parametrize("backend,k", [("ref", 4), ("pallas-packed", 3)])
def test_snapshot_roundtrip_bit_identical(tmp_path, backend, k):
    """write_snapshot -> load_snapshot reproduces the index bit for bit
    (search ids AND scores), including packed odd-K codes."""
    ds = _cached_dataset(12)
    idx = _build_mutable(ds, _params(backend, k))
    root = str(tmp_path)
    persist.write_snapshot(root, idx, replay_from_seq=1)
    loaded, manifest = persist.load_snapshot(root)
    assert manifest["scalars"]["codes_packed"] == (backend == "pallas-packed")
    ids0, s0 = _search(idx, ds)
    ids1, s1 = _search(loaded, ds)
    np.testing.assert_array_equal(ids1, ids0)
    np.testing.assert_array_equal(s1, s0)
    # the loaded index is mutable and serves inserts immediately
    new = loaded.insert(ds.q_sparse[0] * 1e3, ds.q_dense[0])
    assert loaded.search(ds.q_sparse, ds.q_dense, h=4).ids[0, 0] == new[0]


def test_snapshot_checksum_mismatch_raises(tmp_path):
    """A corrupted leaf blob must fail recovery loudly, never serve."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    root = str(tmp_path)
    persist.write_snapshot(root, idx, replay_from_seq=1)
    snap = persist.list_snapshots(root)[-1]
    blob = os.path.join(root, snap, "codes.bin")
    buf = bytearray(open(blob, "rb").read())
    buf[0] ^= 0xFF
    with open(blob, "wb") as f:
        f.write(bytes(buf))
    with pytest.raises(ValueError, match="checksum mismatch"):
        persist.load_snapshot(root)
    # verify=False skips the check (benchmark path) and does load
    persist.load_snapshot(root, verify=False)


def test_snapshot_requires_pristine_generation(tmp_path):
    """Snapshots are build/compaction outputs: a pending delta or tombstone
    belongs to the WAL, and write_snapshot refuses it."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    idx.insert(ds.q_sparse[0], ds.q_dense[0])
    with pytest.raises(ValueError, match="pristine"):
        persist.write_snapshot(str(tmp_path), idx, replay_from_seq=1)
    immutable = HybridIndex.build(ds.x_sparse[:40], ds.x_dense[:40],
                                  _params("ref", 4))
    with pytest.raises(ValueError, match="mutable"):
        persist.write_snapshot(str(tmp_path), immutable, replay_from_seq=1)


def test_snapshot_write_failure_leaves_store_clean(tmp_path, monkeypatch):
    """A crash mid-snapshot must leave the previous snapshot authoritative
    and sweep its own temp directory — no torn commit, no litter."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    root = str(tmp_path)
    persist.write_snapshot(root, idx, replay_from_seq=1)
    before = persist.read_current(root)

    import repro.persist.snapshot as snap_mod
    real = snap_mod.write_array_blob
    calls = {"n": 0}

    def flaky(path, arr):
        calls["n"] += 1
        if calls["n"] > 3:
            raise OSError("disk full (injected)")
        return real(path, arr)

    monkeypatch.setattr(snap_mod, "write_array_blob", flaky)
    with pytest.raises(OSError, match="injected"):
        persist.write_snapshot(root, idx, replay_from_seq=5)
    monkeypatch.setattr(snap_mod, "write_array_blob", real)
    assert persist.read_current(root) == before
    assert not [d for d in os.listdir(root) if d.startswith(".tmp-snap")]
    persist.load_snapshot(root)          # previous snapshot still loads


def test_snapshot_names_stay_monotone_across_gc(tmp_path):
    """REGRESSION: snapshot numbering must be max+1, not count+1 — after
    keep_last GC shrinks the list, a recycled name would collide with a
    still-existing directory at the commit rename."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    root = str(tmp_path)
    for i in range(4):
        persist.write_snapshot(root, idx, replay_from_seq=i + 1,
                               keep_last=2)
    assert persist.list_snapshots(root) == ["snap-000003", "snap-000004"]
    assert persist.read_current(root)["snapshot"] == "snap-000004"
    persist.load_snapshot(root)


def test_bootstrap_refuses_existing_store(tmp_path):
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    root = str(tmp_path / "store")
    persist.bootstrap(root, idx).close()
    with pytest.raises(ValueError, match="already holds"):
        persist.bootstrap(root, idx)
    with pytest.raises(FileNotFoundError, match="CURRENT"):
        persist.recover(str(tmp_path / "nowhere"))


def test_bootstrap_rejection_leaves_no_litter(tmp_path):
    """Bootstrapping a non-pristine index is rejected BEFORE the WAL is
    created: no stray wal/ directory, no open handle, and the root can be
    bootstrapped cleanly after compacting."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    idx.insert(ds.q_sparse[0], ds.q_dense[0])
    root = str(tmp_path / "store")
    with pytest.raises(ValueError, match="pristine"):
        persist.bootstrap(root, idx)
    assert not os.path.exists(os.path.join(root, "wal"))
    assert persist.read_current(root) is None
    persist.bootstrap(root, idx.compact()).close()
    assert persist.recover(root).replayed == 0


# -- delta-state snapshots (DESIGN.md §7.6) -----------------------------------

@pytest.mark.parametrize("backend,k", [("ref", 4), ("pallas-packed", 3)])
def test_delta_snapshot_roundtrip_bit_identical(tmp_path, backend, k):
    """A LIVE index — delta rows, an upsert, tombstones pending — round-
    trips through a delta-state snapshot bit for bit (ids AND scores, delta
    internals included), and the loaded index keeps serving mutations."""
    ds = _cached_dataset(12)
    idx = _build_mutable(ds, _params(backend, k))
    new = idx.insert(ds.x_sparse[N0:N0 + 9], ds.x_dense[N0:N0 + 9])
    idx.insert(ds.x_sparse[N0 + 9], ds.x_dense[N0 + 9],
               ids=[int(new[2])])                       # upsert a delta row
    assert idx.delete([3, int(new[0])]) == 2            # main + delta kill
    root = str(tmp_path)
    persist.write_snapshot(root, idx, replay_from_seq=1, delta_state=True)
    loaded, manifest = persist.load_snapshot(root)
    assert manifest["scalars"]["delta_state"]
    st0, st1 = idx.mutable_state, loaded.mutable_state
    assert st1.next_id == st0.next_id
    assert st1.main_tombstones == st0.main_tombstones
    assert list(st1.extra_ids) == list(st0.extra_ids)
    assert list(st1.extra_alive) == list(st0.extra_alive)
    assert st1.delta.count == st0.delta.count
    assert st1.delta.dropped_nnz == st0.delta.dropped_nnz
    ids0, s0 = _search(idx, ds)
    ids1, s1 = _search(loaded, ds)
    np.testing.assert_array_equal(ids1, ids0)
    np.testing.assert_array_equal(s1, s0)
    got = loaded.insert(ds.q_sparse[0] * 1e3, ds.q_dense[0])
    assert loaded.search(ds.q_sparse, ds.q_dense, h=4).ids[0, 0] == got[0]


def test_pristine_snapshot_still_refuses_live_state(tmp_path):
    """The default (non-delta) write path keeps the pristine-only rule:
    live deltas belong to checkpoint(), not compaction snapshots."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    idx.insert(ds.q_sparse[0], ds.q_dense[0])
    with pytest.raises(ValueError, match="delta_state=True"):
        persist.write_snapshot(str(tmp_path), idx, replay_from_seq=1)


def test_service_checkpoint_restores_with_short_tail(tmp_path):
    """svc.checkpoint() cuts a delta-state snapshot mid-stream: a restore
    replays ONLY the post-checkpoint WAL tail and is bit-identical to the
    live pre-close state."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    root = str(tmp_path / "store")
    svc = QueryService(index=idx, h=8, cache_size=0, auto_compact=False,
                       persist_dir=root)
    svc.insert(ds.x_sparse[N0:N0 + 10], ds.x_dense[N0:N0 + 10])
    svc.delete([1, 4])
    svc.checkpoint()
    assert persist.read_current(root)["snapshot"] == "snap-000002"
    svc.insert(ds.x_sparse[N0 + 10:N0 + 13], ds.x_dense[N0 + 10:N0 + 13])
    svc.delete([7])
    s_live, i_live = svc.search_sparse(ds.q_sparse, ds.q_dense)
    live_stats = svc.stats()
    svc.close()

    svc2 = QueryService(restore_from=root, h=8, cache_size=0,
                        auto_compact=False)
    stats = svc2.stats()
    assert stats["recovered_replayed"] == 2             # only the tail
    assert stats["delta_rows"] == live_stats["delta_rows"]
    assert stats["deleted_pending"] == live_stats["deleted_pending"]
    s_rec, i_rec = svc2.search_sparse(ds.q_sparse, ds.q_dense)
    np.testing.assert_array_equal(i_rec, i_live)
    np.testing.assert_array_equal(s_rec, s_live)
    svc2.close()


def test_service_checkpoint_requires_durability(tmp_path):
    ds = _cached_dataset(8)
    svc = QueryService(index=_build_mutable(ds, _params("ref", 4)), h=8,
                       cache_size=0, auto_compact=False)
    with pytest.raises(ValueError, match="durable service"):
        svc.checkpoint()
    svc.close()


def test_service_auto_delta_checkpoint(tmp_path):
    """delta_snapshot_records=3 cuts a checkpoint every third logged
    mutation: after 7 mutations two auto-checkpoints exist and a restore
    replays only the 1-record tail."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    root = str(tmp_path / "store")
    svc = QueryService(index=idx, h=8, cache_size=0, auto_compact=False,
                       persist_dir=root, delta_snapshot_records=3)
    for j in range(7):
        svc.insert(ds.x_sparse[N0 + j], ds.x_dense[N0 + j])
    assert persist.read_current(root)["snapshot"] == "snap-000003"
    s_live, i_live = svc.search_sparse(ds.q_sparse, ds.q_dense)
    svc.close()
    svc2 = QueryService(restore_from=root, h=8, cache_size=0,
                        auto_compact=False)
    assert svc2.stats()["recovered_replayed"] == 1
    s_rec, i_rec = svc2.search_sparse(ds.q_sparse, ds.q_dense)
    np.testing.assert_array_equal(i_rec, i_live)
    np.testing.assert_array_equal(s_rec, s_live)
    svc2.close()


def test_service_acks_only_after_shared_fsync(tmp_path):
    """Every service mutation returns with its WAL record fsync-covered:
    the sync watermark tracks the last assigned seq after each ack (the
    group-commit ack-after-shared-fsync contract)."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    svc = QueryService(index=idx, h=8, cache_size=0, auto_compact=False,
                       persist_dir=str(tmp_path / "store"))
    wal = svc._durability.wal
    for j in range(3):
        svc.insert(ds.x_sparse[N0 + j], ds.x_dense[N0 + j])
        assert wal._synced_seq == wal.next_seq - 1
    svc.delete([0])
    assert wal._synced_seq == wal.next_seq - 1
    svc.close()


# -- crash-recovery property (the acceptance criterion) ----------------------

def _run_durable_ops(svc, ds, rng, n_ops, compact_at=None,
                     checkpoint_at=None):
    """Random insert/upsert/delete interleaving through a durable service;
    returns the per-op records needed to rebuild any prefix by hand.
    Ops AFTER the last compaction/checkpoint cut are returned separately
    (the WAL tail)."""
    tail_ops = []
    live = list(svc._index.mutable_state.ids_built)
    pool = list(range(N0, N_POOL))
    for t in range(n_ops):
        if compact_at is not None and t == compact_at:
            svc.compact()
            tail_ops = []
        if checkpoint_at is not None and t == checkpoint_at:
            svc.checkpoint()
            tail_ops = []
        if rng.random() < 0.62 or len(live) < 4:
            src = pool.pop(0)
            ext = int(rng.choice(live)) if rng.random() < 0.25 else None
            got = svc.insert(ds.x_sparse[src], ds.x_dense[src], ids=ext)
            if ext is None:
                live.append(int(got[0]))
            tail_ops.append(("ins", ds.x_sparse[src], ds.x_dense[src],
                             got.copy()))
        else:
            ext = int(rng.choice(live))
            svc.delete([ext])
            live.remove(ext)
            tail_ops.append(("del", np.asarray([ext], np.int64)))
    return tail_ops


def _apply_ops(index, ops):
    for op in ops:
        if op[0] == "ins":
            index.mutable_state.insert(op[1], op[2], ids=op[3])
        else:
            index.mutable_state.delete(op[1])


def _check_crash_recovery(backend, k, d_dense, seed, compact_mid=False,
                          checkpoint_mid=False):
    """Kill-and-recover at arbitrary WAL byte offsets == an index that
    applied exactly the complete records' mutations, bit for bit."""
    ds = _cached_dataset(d_dense)
    params = _params(backend, k)
    rng = np.random.default_rng(seed)
    root = tempfile.mkdtemp(prefix="persist-prop-")
    try:
        idx = _build_mutable(ds, params)
        svc = QueryService(index=idx, h=8, cache_size=0, auto_compact=False,
                           persist_dir=root)
        n_ops = 10
        tail_ops = _run_durable_ops(
            svc, ds, rng, n_ops,
            compact_at=5 if compact_mid else None,
            checkpoint_at=5 if checkpoint_mid else None)
        ids_live, s_live = _search(svc._index, ds)
        svc.close()

        active = persist.MutationWAL(os.path.join(root, "wal"))
        seg = active.segment_paths[-1]
        active.close()
        full = open(seg, "rb").read()
        records, size, clean = _scan_segment(seg)
        assert clean and len(records) == len(tail_ops)

        # crash points: empty tail, torn header, torn payload, a clean
        # record boundary, and the full log (pure restart)
        mid = size // 2
        offsets = sorted({0, 7, mid, size - 3, size})
        expected = None          # progressive prefix rebuild (offsets sorted)
        applied = 0
        for cut in offsets:
            crash = tempfile.mkdtemp(prefix="persist-crash-")
            shutil.rmtree(crash)
            shutil.copytree(root, crash)
            seg_c = os.path.join(crash, "wal", os.path.basename(seg))
            with open(seg_c, "r+b") as f:
                f.truncate(cut)
            rec = persist.recover(crash)
            rec.durability.close()
            if expected is None:
                expected, _ = persist.load_snapshot(root)
            n = rec.replayed
            assert n == len([r for r in _scan_segment(seg_c)[0]])
            _apply_ops(expected, tail_ops[applied:n])
            applied = max(applied, n)
            ids_r, s_r = _search(rec.index, ds)
            ids_e, s_e = _search(expected, ds)
            np.testing.assert_array_equal(ids_r, ids_e)
            np.testing.assert_array_equal(s_r, s_e)
            shutil.rmtree(crash, ignore_errors=True)
        # the full-log recovery must equal the live pre-crash state exactly
        np.testing.assert_array_equal(ids_e, ids_live)
        np.testing.assert_array_equal(s_e, s_live)
    finally:
        shutil.rmtree(root, ignore_errors=True)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 9999))
def test_crash_recovery_ref_even_k(seed):
    """recover() ≡ applied-prefix index: ref backend, even K."""
    _check_crash_recovery("ref", 4, 8, seed)


@settings(max_examples=1, deadline=None)
@given(st.integers(0, 9999))
def test_crash_recovery_ref_odd_k(seed):
    """recover() ≡ applied-prefix index: ref backend, odd K."""
    _check_crash_recovery("ref", 3, 12, seed)


@settings(max_examples=1, deadline=None)
@given(st.integers(0, 9999))
def test_crash_recovery_pallas_even_k(seed):
    """recover() ≡ applied-prefix index: pallas backend, even K."""
    _check_crash_recovery("pallas", 4, 8, seed)


@settings(max_examples=1, deadline=None)
@given(st.integers(0, 9999))
def test_crash_recovery_pallas_odd_k(seed):
    """recover() ≡ applied-prefix index: pallas backend, odd K."""
    _check_crash_recovery("pallas", 3, 12, seed)


@settings(max_examples=1, deadline=None)
@given(st.integers(0, 9999))
def test_crash_recovery_packed_even_k(seed):
    """recover() ≡ applied-prefix index: packed 4-bit codes, even K."""
    _check_crash_recovery("pallas-packed", 4, 8, seed)


@settings(max_examples=1, deadline=None)
@given(st.integers(0, 9999))
def test_crash_recovery_packed_odd_k(seed):
    """recover() ≡ applied-prefix index: packed codes, odd-K phantom
    nibble through the WAL-replayed delta append."""
    _check_crash_recovery("pallas-packed", 3, 12, seed)


@settings(max_examples=1, deadline=None)
@given(st.integers(0, 9999))
def test_crash_recovery_with_mid_stream_compaction(seed):
    """Compaction mid-interleaving cuts a snapshot + truncates the WAL;
    crash recovery over the post-compaction tail stays bit-identical."""
    _check_crash_recovery("ref", 4, 8, seed, compact_mid=True)


@settings(max_examples=1, deadline=None)
@given(st.integers(0, 9999))
def test_crash_recovery_with_delta_checkpoint(seed):
    """A delta-state checkpoint mid-interleaving (live delta + tombstones
    folded into the snapshot): crashes at arbitrary byte offsets in the
    post-checkpoint tail recover bit-identically from the delta snapshot
    plus the surviving records."""
    _check_crash_recovery("ref", 4, 8, seed, checkpoint_mid=True)


# -- durable service integration ----------------------------------------------

def test_service_restore_matches_live(tmp_path):
    """Close a durable service mid-stream, restore_from the store: search
    results, delta rows and tombstones are all bit-identical."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    root = str(tmp_path / "store")
    svc = QueryService(index=idx, h=8, cache_size=0, auto_compact=False,
                       persist_dir=root)
    new = svc.insert(ds.x_sparse[N0:N0 + 12], ds.x_dense[N0:N0 + 12])
    svc.delete([int(new[0]), 3, 9])
    s_live, i_live = svc.search_sparse(ds.q_sparse, ds.q_dense)
    live_stats = svc.stats()
    svc.close()

    svc2 = QueryService(restore_from=root, h=8, cache_size=0,
                        auto_compact=False)
    s_rec, i_rec = svc2.search_sparse(ds.q_sparse, ds.q_dense)
    np.testing.assert_array_equal(i_rec, i_live)
    np.testing.assert_array_equal(s_rec, s_live)
    stats = svc2.stats()
    assert stats["delta_rows"] == live_stats["delta_rows"] == 11
    assert stats["deleted_pending"] == live_stats["deleted_pending"] == 2
    assert stats["recovered_replayed"] == 2 and stats["durable"]
    assert stats["wal_next_seq"] == live_stats["wal_next_seq"]
    svc2.close()


def test_service_compact_checkpoints_and_truncates(tmp_path):
    """compact() on a durable service cuts a snapshot, advances CURRENT,
    and truncates the WAL so the next restore replays nothing."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    root = str(tmp_path / "store")
    svc = QueryService(index=idx, h=8, cache_size=0, auto_compact=False,
                       persist_dir=root)
    svc.insert(ds.x_sparse[N0:N0 + 8], ds.x_dense[N0:N0 + 8])
    assert persist.read_current(root)["snapshot"] == "snap-000001"
    svc.compact()
    assert persist.read_current(root)["snapshot"] == "snap-000002"
    s_live, i_live = svc.search_sparse(ds.q_sparse, ds.q_dense)
    svc.close()
    svc2 = QueryService(restore_from=root, h=8, cache_size=0,
                        auto_compact=False)
    assert svc2.stats()["recovered_replayed"] == 0
    s_rec, i_rec = svc2.search_sparse(ds.q_sparse, ds.q_dense)
    np.testing.assert_array_equal(i_rec, i_live)
    np.testing.assert_array_equal(s_rec, s_live)
    svc2.close()


def test_service_persist_arg_validation(tmp_path):
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    with pytest.raises(ValueError, match="don't also pass"):
        QueryService(index=idx, restore_from=str(tmp_path))
    with pytest.raises(ValueError, match="bootstraps a NEW store"):
        QueryService(persist_dir=str(tmp_path))
    with pytest.raises(FileNotFoundError):
        QueryService(restore_from=str(tmp_path / "missing"))


def test_service_poisoned_after_wal_append_failure(tmp_path, monkeypatch):
    """A failed WAL append propagates (the batch was never acked) and
    poisons the durability handle: further mutations are refused, searches
    keep serving, and a restart recovers to the pre-failure state."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    root = str(tmp_path / "store")
    svc = QueryService(index=idx, h=8, cache_size=0, auto_compact=False,
                       persist_dir=root)
    ok = svc.insert(ds.x_sparse[N0:N0 + 2], ds.x_dense[N0:N0 + 2])
    s_before, i_before = svc.search_sparse(ds.q_sparse, ds.q_dense)

    def boom(*a, **k):
        raise OSError("disk full (injected)")

    monkeypatch.setattr(svc._durability.wal, "append_insert", boom)
    with pytest.raises(OSError, match="injected"):
        svc.insert(ds.x_sparse[N0 + 2:N0 + 4], ds.x_dense[N0 + 2:N0 + 4])
    with pytest.raises(RuntimeError, match="poisoned"):
        svc.insert(ds.x_sparse[N0 + 4:N0 + 5], ds.x_dense[N0 + 4:N0 + 5])
    with pytest.raises(RuntimeError, match="poisoned"):
        svc.delete([int(ok[0])])
    svc.search_sparse(ds.q_sparse, ds.q_dense)      # serving still works
    svc.close()
    # restart recovers the pre-failure state: only the acked batch replays
    # (compare service-to-service so both sides use the same bucket
    # padding — reduction shapes are part of bit-identity)
    svc2 = QueryService(restore_from=root, h=8, cache_size=0,
                        auto_compact=False)
    assert svc2.stats()["recovered_replayed"] == 1
    s_r, i_r = svc2.search_sparse(ds.q_sparse, ds.q_dense)
    np.testing.assert_array_equal(i_r, i_before)
    np.testing.assert_array_equal(s_r, s_before)
    svc2.close()


def test_delta_capacity_survives_recovery(tmp_path):
    """The pre-sized delta capacity is recorded in the manifest, so WAL
    replay after restart doesn't re-pay the growth re-materializations."""
    ds = _cached_dataset(8)
    idx = HybridIndex.build(ds.x_sparse[:N0], ds.x_dense[:N0],
                            _params("ref", 4), mutable=True,
                            delta_capacity=256)
    root = str(tmp_path / "store")
    persist.bootstrap(root, idx).close()
    loaded = HybridIndex.load(root)
    assert loaded.mutable_state.delta.capacity == 256


def test_hybrid_index_save_load(tmp_path):
    """The one-shot save()/load() pair round-trips without a service."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    root = str(tmp_path / "store")
    idx.save(root)
    loaded = HybridIndex.load(root)
    ids0, s0 = _search(idx, ds)
    ids1, s1 = _search(loaded, ds)
    np.testing.assert_array_equal(ids1, ids0)
    np.testing.assert_array_equal(s1, s0)
    # backend override serves the same snapshot through another engine
    alt = HybridIndex.load(root, backend="onehot-mxu")
    ids2, _ = _search(alt, ds)
    np.testing.assert_array_equal(ids2, ids0)


# -- incremental delta device appends (ISSUE 5 satellite) ---------------------

def test_incremental_append_matches_rematerialization():
    """The dynamic_update_slice append path produces device arrays (and
    search results) identical to full re-materialization."""
    ds = _cached_dataset(8)
    params = _params("ref", 4)
    fast = _build_mutable(ds, params)
    slow = _build_mutable(ds, params)
    slow.mutable_state.delta.incremental = False
    # force an early snapshot so the incremental path has a struct to update
    fast.search(ds.q_sparse, ds.q_dense, h=4)
    for lo in (N0, N0 + 5):
        rows = slice(lo, lo + 5)
        fast.insert(ds.x_sparse[rows], ds.x_dense[rows])
        slow.insert(ds.x_sparse[rows], ds.x_dense[rows])
        fast.delete([lo])
        slow.delete([lo])
        a = fast.mutable_state.delta.snapshot().arrays
        b = slow.mutable_state.delta.snapshot().arrays
        np.testing.assert_array_equal(np.asarray(a.codes),
                                      np.asarray(b.codes))
        np.testing.assert_array_equal(np.asarray(a.inv_index.rows),
                                      np.asarray(b.inv_index.rows))
        np.testing.assert_array_equal(np.asarray(a.inv_index.vals),
                                      np.asarray(b.inv_index.vals))
        np.testing.assert_array_equal(np.asarray(a.dense_residual.q),
                                      np.asarray(b.dense_residual.q))
        np.testing.assert_array_equal(np.asarray(a.sparse_residual.cols),
                                      np.asarray(b.sparse_residual.cols))
        np.testing.assert_array_equal(np.asarray(a.sparse_residual.vals),
                                      np.asarray(b.sparse_residual.vals))
        rf = fast.search(ds.q_sparse, ds.q_dense, h=8)
        rs = slow.search(ds.q_sparse, ds.q_dense, h=8)
        np.testing.assert_array_equal(rf.ids, rs.ids)
        np.testing.assert_array_equal(rf.scores, rs.scores)
    # the second round really did take the incremental path
    assert fast.mutable_state.delta._arrays_struct is not None


def test_incremental_append_survives_capacity_growth():
    """Growth (capacity doubling / rectangle widening) invalidates the
    device copy and falls back to re-materialization — still correct."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    st_ = idx.mutable_state
    cap0 = st_.delta.capacity
    idx.insert(ds.x_sparse[N0:N0 + 2], ds.x_dense[N0:N0 + 2])
    idx.search(ds.q_sparse, ds.q_dense, h=4)          # materializes struct
    idx.insert(ds.x_sparse[N0 + 2:N0 + 3], ds.x_dense[N0 + 2:N0 + 3])
    assert st_.delta._arrays_struct is not None       # incremental applied
    m = cap0 + 3                                      # force doubling
    rows = sp.vstack([ds.q_sparse[0] * 1e3] * m).tocsr()
    ids = idx.insert(rows, np.tile(ds.q_dense[0], (m, 1)))
    assert st_.delta.capacity > cap0
    r = idx.search(ds.q_sparse, ds.q_dense, h=m + 2)
    assert set(ids) <= set(r.ids[0])
