"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_sparse import (block_sparse_matmul_pallas,
                                        dense_to_bcsr)
from repro.kernels.lut16 import lut16_adc_pallas
from repro.kernels.ops import block_sparse_matmul, lut16_adc
from repro.kernels.ref import (bcsr_to_dense_ref, block_sparse_ref,
                               lut16_adc_ref)

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# LUT16 ADC
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,l,q", [
    (512, 16, 16, 8),
    (1024, 32, 16, 8),
    (512, 8, 8, 16),
    (2048, 64, 16, 4),
    (512, 16, 4, 8),
])
def test_lut16_shapes(n, k, l, q):
    codes = jnp.asarray(RNG.integers(0, l, (n, k)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(q, k, l)).astype(np.float32))
    want = lut16_adc_ref(codes, lut)
    got = lut16_adc_pallas(codes, lut, bq=min(8, q), bn=256, bk=min(8, k))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut16_compute_dtypes(dtype):
    codes = jnp.asarray(RNG.integers(0, 16, (512, 16)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(8, 16, 16)).astype(np.float32))
    want = np.asarray(lut16_adc_ref(codes, lut))
    got = np.asarray(lut16_adc_pallas(codes, lut, bq=8, bn=256, bk=8,
                                      compute_dtype=dtype))
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


def test_lut16_padding_wrapper():
    """Non-multiple shapes go through ops.lut16_adc padding."""
    codes = jnp.asarray(RNG.integers(0, 16, (777, 13)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(5, 13, 16)).astype(np.float32))
    want = lut16_adc_ref(codes, lut)
    got = lut16_adc(codes, lut)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_lut16_single_query_2d_lut():
    codes = jnp.asarray(RNG.integers(0, 16, (256, 8)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(8, 16)).astype(np.float32))
    got = lut16_adc(codes, lut)
    want = lut16_adc_ref(codes, lut[None])[0]
    assert got.shape == (256,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_lut16_packed_4bit():
    """Paper §6.1.1 storage: two 4-bit codes per byte — half the HBM stream,
    same scores."""
    from repro.kernels.lut16 import pack_codes
    codes = RNG.integers(0, 16, (512, 16)).astype(np.uint8)
    lut = jnp.asarray(RNG.normal(size=(8, 16, 16)).astype(np.float32))
    want = lut16_adc_ref(jnp.asarray(codes), lut)
    packed = jnp.asarray(pack_codes(codes))
    assert packed.shape == (512, 8)
    got = lut16_adc_pallas(packed, lut, bq=8, bn=256, bk=8, packed=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("k", [12, 13])
def test_pack_unpack_roundtrip(k):
    """pack_codes/unpack_codes invert each other for even AND odd K; odd K
    zero-pads a phantom high nibble that unpack slices off."""
    from repro.kernels.lut16 import pack_codes, unpack_codes
    codes = RNG.integers(0, 16, (64, k)).astype(np.uint8)
    packed = pack_codes(codes)
    assert packed.shape == (64, (k + 1) // 2)
    if k % 2:
        assert (packed[:, -1] >> 4 == 0).all()      # phantom nibble is zero
    np.testing.assert_array_equal(np.asarray(unpack_codes(packed, k)), codes)


def test_pack_codes_rejects_wide_codes():
    """Codes outside [0, 16) would corrupt the neighbouring nibble — the old
    silent-misbehavior case must now raise."""
    from repro.kernels.lut16 import pack_codes, unpack_codes
    with pytest.raises(ValueError, match="4-bit"):
        pack_codes(np.full((4, 8), 16, np.uint8))
    with pytest.raises(ValueError, match="4-bit"):
        pack_codes(np.full((4, 8), -1, np.int32))
    with pytest.raises(ValueError):
        unpack_codes(np.zeros((4, 4), np.uint8), 6)   # 4 bytes can't hold 6


@pytest.mark.parametrize("n,k,q", [(512, 16, 8), (777, 13, 5), (300, 1, 3)])
def test_lut16_packed_via_ops_wrapper(n, k, q):
    """ops.lut16_adc(packed=True): block padding + the odd-K phantom
    subspace (zero LUT column) both handled in the wrapper."""
    from repro.kernels.lut16 import pack_codes
    codes = RNG.integers(0, 16, (n, k)).astype(np.uint8)
    lut = jnp.asarray(RNG.normal(size=(q, k, 16)).astype(np.float32))
    want = lut16_adc_ref(jnp.asarray(codes), lut)
    got = lut16_adc(jnp.asarray(pack_codes(codes)), lut, packed=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_lut16_packed_shape_mismatch_raises():
    from repro.kernels.lut16 import pack_codes
    packed = jnp.asarray(pack_codes(RNG.integers(0, 16, (64, 8))
                                    .astype(np.uint8)))      # (64, 4)
    lut16 = jnp.asarray(RNG.normal(size=(2, 16, 16)).astype(np.float32))
    with pytest.raises(ValueError, match="cannot hold"):
        lut16_adc(packed, lut16, packed=True)                # 16 != 2*4
    lut_l8 = jnp.asarray(RNG.normal(size=(2, 8, 8)).astype(np.float32))
    with pytest.raises(ValueError, match="l == 16"):
        lut16_adc(packed, lut_l8, packed=True)


# ---------------------------------------------------------------------------
# block-sparse tile-skipping matmul
# ---------------------------------------------------------------------------

def _random_block_sparse(n, d, br, bc, density):
    x = RNG.normal(size=(n, d)).astype(np.float32)
    mask = RNG.random((n // br, d // bc)) < density
    return x * np.kron(mask, np.ones((br, bc), np.float32))


@pytest.mark.parametrize("n,d,br,bc,density", [
    (256, 256, 64, 64, 0.3),
    (512, 128, 128, 128, 0.5),
    (384, 256, 128, 128, 0.1),
    (256, 512, 64, 128, 0.0),     # fully-empty matrix
    (256, 256, 64, 64, 1.0),      # fully-dense
])
def test_block_sparse_shapes(n, d, br, bc, density):
    xm = _random_block_sparse(n, d, br, bc, density)
    tiles, ptr, col = dense_to_bcsr(xm, br, bc)
    q = jnp.asarray(RNG.normal(size=(8, d)).astype(np.float32))
    want = block_sparse_ref(q, jnp.asarray(xm))
    ms = int(np.max(ptr[1:] - ptr[:-1], initial=1))
    got = block_sparse_matmul_pallas(q, jnp.asarray(tiles), jnp.asarray(ptr),
                                     jnp.asarray(col), bq=8,
                                     max_steps=max(ms, 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_bcsr_roundtrip():
    xm = _random_block_sparse(256, 256, 64, 64, 0.4)
    tiles, ptr, col = dense_to_bcsr(xm, 64, 64)
    back = np.asarray(bcsr_to_dense_ref(tiles, ptr, col, 256))
    np.testing.assert_allclose(back, xm, atol=0)


def test_bcsr_tile_count_is_skip_metric():
    """Stored tiles == nonzero tiles: what cache sorting minimizes."""
    xm = _random_block_sparse(256, 256, 64, 64, 0.25)
    tiles, ptr, col = dense_to_bcsr(xm, 64, 64)
    nz_tiles = int((np.abs(xm.reshape(4, 64, 4, 64)).max(axis=(1, 3)) > 0)
                   .sum())
    assert tiles.shape[0] == max(nz_tiles, 1)


def test_block_sparse_through_head_wrapper():
    import scipy.sparse as sp
    from repro.core.sparse_index import build_tile_sparse_head, score_head_ref
    xm = _random_block_sparse(256, 256, 128, 128, 0.4)
    head = build_tile_sparse_head(sp.csr_matrix(xm), np.arange(256),
                                  block_rows=128, block_cols=128)
    q = jnp.asarray(RNG.normal(size=(5, head.block.shape[1]))
                    .astype(np.float32))
    got = block_sparse_matmul(q, head)
    want = score_head_ref(head, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# Fused scan-and-select (DESIGN.md §2.5)
# ---------------------------------------------------------------------------

def _topk_oracle(scores: np.ndarray, k: int):
    import jax
    s, i = jax.lax.top_k(jnp.asarray(scores), k)
    return np.asarray(s), np.asarray(i)


@pytest.mark.parametrize("n,k_sub,l,q", [
    (1000, 8, 16, 4),      # non-multiple N
    (4000, 7, 16, 12),     # odd K (phantom nibble when packed)
    (300, 5, 8, 3),        # small l, tiny N
    (2048, 16, 16, 8),     # exact-multiple shapes
])
@pytest.mark.parametrize("packed", [False, True])
@pytest.mark.parametrize("topk", [5, 37, 128])
def test_fused_topk_matches_materialize(n, k_sub, l, q, packed, topk):
    """Fused scan-and-select ≡ materialize-then-topk, bit for bit: the two
    paths share the per-block partial sums and the bias-at-select ordering,
    so both scores AND ids must be exactly equal."""
    from repro.kernels.ops import lut16_adc_topk
    if packed and l != 16:
        pytest.skip("packed kernel requires l == 16")
    codes = RNG.integers(0, l, (n, k_sub)).astype(np.uint8)
    lut = jnp.asarray(RNG.normal(size=(q, k_sub, l)).astype(np.float32))
    if packed:
        from repro.kernels.lut16 import pack_codes
        codes_in = jnp.asarray(pack_codes(codes))
    else:
        codes_in = jnp.asarray(codes)
    bias = jnp.asarray(RNG.normal(size=(q, n)).astype(np.float32))
    for b in (None, bias):
        sf, idf = lut16_adc_topk(codes_in, lut, topk, bias=b,
                                 packed=packed, fused=True)
        sm, idm = lut16_adc_topk(codes_in, lut, topk, bias=b,
                                 packed=packed, fused=False)
        np.testing.assert_array_equal(np.asarray(idf), np.asarray(idm))
        np.testing.assert_array_equal(np.asarray(sf), np.asarray(sm))


def test_fused_topk_matches_ref_oracle():
    """Against the pure-jnp oracle: same ids as ref-scores + lax.top_k (the
    deterministic lowest-index tie-break), scores within fp32 tolerance."""
    from repro.kernels.ops import lut16_adc_topk
    n, k_sub, l, q, topk = 1500, 12, 16, 6, 64
    codes = RNG.integers(0, l, (n, k_sub)).astype(np.uint8)
    lut = jnp.asarray(RNG.normal(size=(q, k_sub, l)).astype(np.float32))
    ref = np.asarray(lut16_adc_ref(jnp.asarray(codes), lut))
    want_s, want_i = _topk_oracle(ref, topk)
    got_s, got_i = lut16_adc_topk(jnp.asarray(codes), lut, topk, fused=True)
    np.testing.assert_array_equal(np.asarray(got_i), want_i)
    np.testing.assert_allclose(np.asarray(got_s), want_s,
                               rtol=1e-5, atol=1e-4)


def test_fused_topk_tombstones_never_surface():
    """-inf row_mask rows must never appear as finite-score candidates, and
    slots the live pool can't fill get id -1 (merge_topk_host's contract)."""
    from repro.kernels.ops import lut16_adc_topk
    n, k_sub, l, q = 900, 6, 16, 5
    codes = jnp.asarray(RNG.integers(0, l, (n, k_sub)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(q, k_sub, l)).astype(np.float32))
    mask = np.zeros(n, np.float32)
    dead = RNG.choice(n, 300, replace=False)
    mask[dead] = -np.inf
    sf, idf = lut16_adc_topk(codes, lut, 64, row_mask=jnp.asarray(mask),
                             fused=True)
    sm, idm = lut16_adc_topk(codes, lut, 64, row_mask=jnp.asarray(mask),
                             fused=False)
    np.testing.assert_array_equal(np.asarray(idf), np.asarray(idm))
    np.testing.assert_array_equal(np.asarray(sf), np.asarray(sm))
    sf, idf = np.asarray(sf), np.asarray(idf)
    assert not (set(idf[np.isfinite(sf)].ravel().tolist())
                & set(dead.tolist()))
    # more candidates than live rows: the overflow slots are (-inf, -1)
    mask2 = np.full(n, -np.inf, np.float32)
    mask2[:10] = 0.0
    s2, i2 = lut16_adc_topk(codes, lut, 32, row_mask=jnp.asarray(mask2),
                            fused=True)
    s2, i2 = np.asarray(s2), np.asarray(i2)
    assert np.isfinite(s2[:, :10]).all()
    assert set(i2[:, :10].ravel().tolist()) <= set(range(10))
    assert (i2[~np.isfinite(s2)] == -1).all()


def test_fused_topk_buffer_overflow_falls_back(monkeypatch):
    """k above the VMEM candidate buffer cap must route to the materialize
    fallback — same results, no fused kernel."""
    import repro.kernels.ops as ops
    n, k_sub, l, q = 600, 6, 16, 4
    codes = jnp.asarray(RNG.integers(0, l, (n, k_sub)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(q, k_sub, l)).astype(np.float32))
    want_s, want_i = ops.lut16_adc_topk(codes, lut, 40, fused=True)
    monkeypatch.setattr(ops, "MAX_FUSED_CANDIDATES", 16)
    got_s, got_i = ops.lut16_adc_topk(codes, lut, 40, fused=True)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    # and the fallback it routed to materializes (structurally observable)
    import functools
    assert ops.dense_scores_materialized(
        functools.partial(ops.lut16_adc_topk, k=40, fused=True), codes, lut)
    monkeypatch.undo()
    assert not ops.dense_scores_materialized(
        functools.partial(ops.lut16_adc_topk, k=40, fused=True), codes, lut)


def test_fused_topk_rejects_bad_k():
    from repro.kernels.ops import lut16_adc_topk
    codes = jnp.asarray(RNG.integers(0, 16, (128, 4)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(2, 4, 16)).astype(np.float32))
    with pytest.raises(ValueError, match="top-k needs"):
        lut16_adc_topk(codes, lut, 0)
    with pytest.raises(ValueError, match="top-k needs"):
        lut16_adc_topk(codes, lut, 129)


def test_fused_jaxpr_has_no_dense_materialization():
    """The structural half of the packed-speedup floor (ISSUE 6): in the
    no-bias fused path, NO fp32 tensor of shape (Q>1, >=N) exists anywhere
    in the jaxpr — the (Q, N) score matrix is provably absent.  The
    materialize path trips the same detector, proving it detects."""
    import functools
    from repro.kernels.ops import dense_scores_materialized, lut16_adc_topk
    codes = jnp.asarray(RNG.integers(0, 16, (512, 8)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(4, 8, 16)).astype(np.float32))
    mask = jnp.zeros(512, jnp.float32)
    for kwargs in ({}, {"row_mask": mask}):
        assert not dense_scores_materialized(
            functools.partial(lut16_adc_topk, k=32, fused=True, **kwargs),
            codes, lut)
    assert dense_scores_materialized(
        functools.partial(lut16_adc_topk, k=32, fused=False), codes, lut)


def test_candidate_buffer_width():
    from repro.kernels.lut16 import candidate_buffer_width
    assert candidate_buffer_width(1) == 128
    assert candidate_buffer_width(128) == 128
    assert candidate_buffer_width(129) == 256
    assert candidate_buffer_width(400) == 512


# ---------------------------------------------------------------------------
# Value-forward inverted scoring (SINDI; DESIGN.md §2.5)
# ---------------------------------------------------------------------------

def _toy_sparse_problem(n, d, qn, *, density=0.01, q_density=0.02, seed=0,
                        nq_max=32):
    import scipy.sparse as sp
    from repro.core.sparse_index import (build_compact_columns,
                                         build_padded_inverted_index,
                                         sparse_queries_to_padded)
    x = sp.random(n, d, density=density, random_state=seed, format="csr")
    cols, xc = build_compact_columns(x)
    inv = build_padded_inverted_index(xc)
    qs = sp.random(qn, d, density=q_density, random_state=seed + 1,
                   format="csr")
    qd, qv = sparse_queries_to_padded(qs, cols, nq_max=nq_max)
    return inv, qd, qv


@pytest.mark.parametrize("n,d,qn", [
    (700, 500, 9),         # non-multiple N, non-multiple Q
    (512, 200, 8),         # exact multiples
    (50, 80, 3),           # tiny: single row block
])
def test_value_forward_matches_score_inverted(n, d, qn):
    from repro.core.sparse_index import score_inverted
    from repro.kernels.ops import score_inverted_vf
    inv, qd, qv = _toy_sparse_problem(n, d, qn, seed=n)
    ref = np.asarray(score_inverted(inv, jnp.asarray(qd), jnp.asarray(qv)))
    got = np.asarray(score_inverted_vf(inv, qd, qv))
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_value_forward_duplicate_dims_and_empty_rows():
    """A query repeating a dim accumulates twice; an all-pad query row
    scores exactly zero everywhere."""
    from repro.core.sparse_index import score_inverted
    from repro.kernels.ops import score_inverted_vf
    inv, qd, qv = _toy_sparse_problem(300, 150, 4, seed=9)
    qd = np.asarray(qd).copy()
    qv = np.asarray(qv).copy()
    qd[0, 1] = qd[0, 0]                      # duplicate dim in query 0
    qv[0, 1] = 0.5
    d_active = int(np.asarray(inv.rows).shape[0])
    qd[2, :] = d_active                      # query 2: empty (all pad)
    qv[2, :] = 0.0
    ref = np.asarray(score_inverted(inv, jnp.asarray(qd), jnp.asarray(qv)))
    got = np.asarray(score_inverted_vf(inv, qd, qv))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
    assert np.all(got[2] == 0.0)


def test_value_forward_stream_layout():
    """Planner invariants the kernel's index maps rely on: chunk-aligned
    ptr in chunk units, block-local row ids, pad rows == bn."""
    from repro.core.sparse_index import build_value_forward_stream
    inv, qd, qv = _toy_sparse_problem(700, 500, 9, seed=5)
    st = build_value_forward_stream(inv, qd, qv, bq=8, bn=256, chunk=64)
    rows = np.asarray(st.rows)
    ptr = np.asarray(st.ptr)
    assert rows.shape[1] % st.chunk == 0
    assert rows.min() >= 0 and rows.max() <= st.bn
    qb = rows.shape[0]
    nb1 = st.num_row_blocks + 1
    assert ptr.shape == (qb * nb1,)
    for b in range(qb):
        seg = ptr[b * nb1:(b + 1) * nb1]
        assert (np.diff(seg) >= 0).all()
        assert seg[-1] * st.chunk <= rows.shape[1]
