"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_sparse import (block_sparse_matmul_pallas,
                                        dense_to_bcsr)
from repro.kernels.lut16 import lut16_adc_pallas
from repro.kernels.ops import block_sparse_matmul, lut16_adc
from repro.kernels.ref import (bcsr_to_dense_ref, block_sparse_ref,
                               lut16_adc_ref)

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# LUT16 ADC
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,l,q", [
    (512, 16, 16, 8),
    (1024, 32, 16, 8),
    (512, 8, 8, 16),
    (2048, 64, 16, 4),
    (512, 16, 4, 8),
])
def test_lut16_shapes(n, k, l, q):
    codes = jnp.asarray(RNG.integers(0, l, (n, k)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(q, k, l)).astype(np.float32))
    want = lut16_adc_ref(codes, lut)
    got = lut16_adc_pallas(codes, lut, bq=min(8, q), bn=256, bk=min(8, k))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut16_compute_dtypes(dtype):
    codes = jnp.asarray(RNG.integers(0, 16, (512, 16)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(8, 16, 16)).astype(np.float32))
    want = np.asarray(lut16_adc_ref(codes, lut))
    got = np.asarray(lut16_adc_pallas(codes, lut, bq=8, bn=256, bk=8,
                                      compute_dtype=dtype))
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


def test_lut16_padding_wrapper():
    """Non-multiple shapes go through ops.lut16_adc padding."""
    codes = jnp.asarray(RNG.integers(0, 16, (777, 13)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(5, 13, 16)).astype(np.float32))
    want = lut16_adc_ref(codes, lut)
    got = lut16_adc(codes, lut)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_lut16_single_query_2d_lut():
    codes = jnp.asarray(RNG.integers(0, 16, (256, 8)).astype(np.uint8))
    lut = jnp.asarray(RNG.normal(size=(8, 16)).astype(np.float32))
    got = lut16_adc(codes, lut)
    want = lut16_adc_ref(codes, lut[None])[0]
    assert got.shape == (256,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_lut16_packed_4bit():
    """Paper §6.1.1 storage: two 4-bit codes per byte — half the HBM stream,
    same scores."""
    from repro.kernels.lut16 import pack_codes
    codes = RNG.integers(0, 16, (512, 16)).astype(np.uint8)
    lut = jnp.asarray(RNG.normal(size=(8, 16, 16)).astype(np.float32))
    want = lut16_adc_ref(jnp.asarray(codes), lut)
    packed = jnp.asarray(pack_codes(codes))
    assert packed.shape == (512, 8)
    got = lut16_adc_pallas(packed, lut, bq=8, bn=256, bk=8, packed=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("k", [12, 13])
def test_pack_unpack_roundtrip(k):
    """pack_codes/unpack_codes invert each other for even AND odd K; odd K
    zero-pads a phantom high nibble that unpack slices off."""
    from repro.kernels.lut16 import pack_codes, unpack_codes
    codes = RNG.integers(0, 16, (64, k)).astype(np.uint8)
    packed = pack_codes(codes)
    assert packed.shape == (64, (k + 1) // 2)
    if k % 2:
        assert (packed[:, -1] >> 4 == 0).all()      # phantom nibble is zero
    np.testing.assert_array_equal(np.asarray(unpack_codes(packed, k)), codes)


def test_pack_codes_rejects_wide_codes():
    """Codes outside [0, 16) would corrupt the neighbouring nibble — the old
    silent-misbehavior case must now raise."""
    from repro.kernels.lut16 import pack_codes, unpack_codes
    with pytest.raises(ValueError, match="4-bit"):
        pack_codes(np.full((4, 8), 16, np.uint8))
    with pytest.raises(ValueError, match="4-bit"):
        pack_codes(np.full((4, 8), -1, np.int32))
    with pytest.raises(ValueError):
        unpack_codes(np.zeros((4, 4), np.uint8), 6)   # 4 bytes can't hold 6


@pytest.mark.parametrize("n,k,q", [(512, 16, 8), (777, 13, 5), (300, 1, 3)])
def test_lut16_packed_via_ops_wrapper(n, k, q):
    """ops.lut16_adc(packed=True): block padding + the odd-K phantom
    subspace (zero LUT column) both handled in the wrapper."""
    from repro.kernels.lut16 import pack_codes
    codes = RNG.integers(0, 16, (n, k)).astype(np.uint8)
    lut = jnp.asarray(RNG.normal(size=(q, k, 16)).astype(np.float32))
    want = lut16_adc_ref(jnp.asarray(codes), lut)
    got = lut16_adc(jnp.asarray(pack_codes(codes)), lut, packed=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_lut16_packed_shape_mismatch_raises():
    from repro.kernels.lut16 import pack_codes
    packed = jnp.asarray(pack_codes(RNG.integers(0, 16, (64, 8))
                                    .astype(np.uint8)))      # (64, 4)
    lut16 = jnp.asarray(RNG.normal(size=(2, 16, 16)).astype(np.float32))
    with pytest.raises(ValueError, match="cannot hold"):
        lut16_adc(packed, lut16, packed=True)                # 16 != 2*4
    lut_l8 = jnp.asarray(RNG.normal(size=(2, 8, 8)).astype(np.float32))
    with pytest.raises(ValueError, match="l == 16"):
        lut16_adc(packed, lut_l8, packed=True)


# ---------------------------------------------------------------------------
# block-sparse tile-skipping matmul
# ---------------------------------------------------------------------------

def _random_block_sparse(n, d, br, bc, density):
    x = RNG.normal(size=(n, d)).astype(np.float32)
    mask = RNG.random((n // br, d // bc)) < density
    return x * np.kron(mask, np.ones((br, bc), np.float32))


@pytest.mark.parametrize("n,d,br,bc,density", [
    (256, 256, 64, 64, 0.3),
    (512, 128, 128, 128, 0.5),
    (384, 256, 128, 128, 0.1),
    (256, 512, 64, 128, 0.0),     # fully-empty matrix
    (256, 256, 64, 64, 1.0),      # fully-dense
])
def test_block_sparse_shapes(n, d, br, bc, density):
    xm = _random_block_sparse(n, d, br, bc, density)
    tiles, ptr, col = dense_to_bcsr(xm, br, bc)
    q = jnp.asarray(RNG.normal(size=(8, d)).astype(np.float32))
    want = block_sparse_ref(q, jnp.asarray(xm))
    ms = int(np.max(ptr[1:] - ptr[:-1], initial=1))
    got = block_sparse_matmul_pallas(q, jnp.asarray(tiles), jnp.asarray(ptr),
                                     jnp.asarray(col), bq=8,
                                     max_steps=max(ms, 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_bcsr_roundtrip():
    xm = _random_block_sparse(256, 256, 64, 64, 0.4)
    tiles, ptr, col = dense_to_bcsr(xm, 64, 64)
    back = np.asarray(bcsr_to_dense_ref(tiles, ptr, col, 256))
    np.testing.assert_allclose(back, xm, atol=0)


def test_bcsr_tile_count_is_skip_metric():
    """Stored tiles == nonzero tiles: what cache sorting minimizes."""
    xm = _random_block_sparse(256, 256, 64, 64, 0.25)
    tiles, ptr, col = dense_to_bcsr(xm, 64, 64)
    nz_tiles = int((np.abs(xm.reshape(4, 64, 4, 64)).max(axis=(1, 3)) > 0)
                   .sum())
    assert tiles.shape[0] == max(nz_tiles, 1)


def test_block_sparse_through_head_wrapper():
    import scipy.sparse as sp
    from repro.core.sparse_index import build_tile_sparse_head, score_head_ref
    xm = _random_block_sparse(256, 256, 128, 128, 0.4)
    head = build_tile_sparse_head(sp.csr_matrix(xm), np.arange(256),
                                  block_rows=128, block_cols=128)
    q = jnp.asarray(RNG.normal(size=(5, head.block.shape[1]))
                    .astype(np.float32))
    got = block_sparse_matmul(q, head)
    want = score_head_ref(head, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
