"""Trainer, optimizer, checkpointing, data pipeline."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import Model
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule)
from repro.train import Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_math():
    cfg = AdamWConfig(lr_peak=1e-2, lr_min=1e-2, warmup_steps=0,
                      decay_steps=1, weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.asarray([[1.0, -2.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.5, 0.5]], jnp.float32)}
    p_before = np.asarray(p["w"]).copy()     # p is donated by adamw_update
    st_ = adamw_init(p, cfg)
    p1, st1, _ = adamw_update(p, g, st_, cfg)
    m = 0.1 * 0.5
    v = 0.05 * 0.25
    upd = (m / 0.1) / (np.sqrt(v / 0.05) + cfg.eps)
    np.testing.assert_allclose(np.asarray(p1["w"]), p_before - 1e-2 * upd,
                               rtol=1e-5)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90.0))
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert n2 == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                      decay_steps=100)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100, 200)]
    assert lrs[0] == 0.0
    assert lrs[2] == pytest.approx(1e-3, rel=1e-3)
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-2)
    assert lrs[3] < lrs[2]


def test_bf16_moments_still_learn():
    cfg = AdamWConfig(moment_dtype="bfloat16", warmup_steps=0,
                      decay_steps=10, lr_peak=1e-2, lr_min=1e-2)
    p = {"w": jnp.ones((4, 4), jnp.float32)}
    st_ = adamw_init(p, cfg)
    assert st_["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4), jnp.float32)}
    p1, st1, _ = adamw_update(p, g, st_, cfg)
    assert float(p1["w"][0, 0]) < 1.0


def test_int8_moment_quantization_roundtrip():
    from repro.optim.adamw import _dequantize, _quantize
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = _quantize(x, 256)
    back = np.asarray(_dequantize(q, s, (1000,)))
    assert np.abs(back - np.asarray(x)).max() < np.abs(np.asarray(x)).max() / 100


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic():
    dc = DataConfig(vocab_size=128, seq_len=16, global_batch=4)
    a = synthetic_batch(dc, 7)
    b = synthetic_batch(dc, 7)
    c = synthetic_batch(dc, 8)
    assert (a["tokens"] == b["tokens"]).all()
    assert not (a["tokens"] == c["tokens"]).all()


def test_data_labels_are_shifted_stream():
    dc = DataConfig(vocab_size=997, seq_len=32, global_batch=2)
    b = synthetic_batch(dc, 0)
    assert b["tokens"].shape == (2, 32) and b["labels"].shape == (2, 32)
    assert int(b["tokens"].max()) < 997


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 64), st.integers(1, 8), st.integers(0, 100))
def test_property_data_shapes(seq, batch, step):
    dc = DataConfig(vocab_size=64, seq_len=seq, global_batch=batch)
    b = synthetic_batch(dc, step)
    assert b["tokens"].shape == (batch, seq)
    assert int(b["tokens"].min()) >= 0 and int(b["tokens"].max()) < 64


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    got = restore_checkpoint(str(tmp_path), 5, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_gc_keep_last(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), s, tree, keep_last=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_3", "step_4"]


# ---------------------------------------------------------------------------
# trainer end-to-end
# ---------------------------------------------------------------------------

def test_trainer_learns_and_resumes(tmp_path):
    cfg = get_config("qwen2-7b-smoke")
    m = Model(cfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    tc = TrainerConfig(num_steps=10, microbatches=2, ckpt_every=5,
                       ckpt_dir=str(tmp_path), log_every=100)
    tr = Trainer(m, AdamWConfig(warmup_steps=3, decay_steps=50), dc, tc)
    params, opt, hist = tr.run(jax.random.PRNGKey(0))
    # single-step losses on random tokens are noisy; compare a trailing
    # average against the leading one so the assertion tests the trend
    losses = [h["loss"] for h in hist]
    assert sum(losses[-3:]) / 3 < sum(losses[:3]) / 3
    # resume: picks up at step 10
    tr2 = Trainer(m, AdamWConfig(warmup_steps=3, decay_steps=50), dc, tc)
    _, _, h2 = tr2.run(jax.random.PRNGKey(0), num_steps=12)
    assert [h["step"] for h in h2] == [10, 11]


def test_microbatch_equivalence():
    """1 vs 4 microbatches produce (nearly) the same update."""
    from repro.train import make_train_step
    cfg = get_config("stablelm-1.6b-smoke")
    m = Model(cfg)
    ocfg = AdamWConfig(warmup_steps=0, decay_steps=10)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, ocfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    batch = synthetic_batch(dcfg, 0)
    p1, _, m1 = jax.jit(make_train_step(m, ocfg, 1))(params, opt, batch)
    opt2 = adamw_init(params, ocfg)
    p4, _, m4 = jax.jit(make_train_step(m, ocfg, 4))(params, opt2, batch)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-3
    assert abs(float(m1["nll"]) - float(m4["nll"])) < 5e-2
