"""Use `hypothesis` when installed; otherwise a minimal deterministic stand-in.

The seed environment does not ship hypothesis, and the tier-1 suite must
still collect and run there.  The fallback reproduces the tiny subset the
tests use — ``@settings(max_examples=..., deadline=...)``, ``@given(...)``
and ``strategies.integers(lo, hi)`` — by running the property on the two
boundary points plus a fixed-seed random sample.  It is NOT a shrinker or a
coverage-guided explorer; install the real package (requirements.txt) for
that.
"""

try:
    from hypothesis import given, settings, strategies  # noqa: F401
except ModuleNotFoundError:
    import random

    class _Integers:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = int(lo), int(hi)

        def sample(self, rng: random.Random) -> int:
            return rng.randint(self.lo, self.hi)

    class strategies:  # noqa: N801 — mimics the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Integers:
            return _Integers(min_value, max_value)

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats: _Integers):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(0xC0FFEE)
                fn(*(s.lo for s in strats))
                fn(*(s.hi for s in strats))
                for _ in range(max(n - 2, 0)):
                    fn(*(s.sample(rng) for s in strats))
            # plain attribute copy: functools.wraps would expose the wrapped
            # signature and pytest would treat the params as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples", 20)
            return wrapper
        return deco
