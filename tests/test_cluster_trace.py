"""Cluster-wire tracing (ISSUE 10 acceptance, DESIGN.md §9.2): one
cluster search produces a COMPLETE trace — a chunk root plus one hop
span per shard RPC whose serialize/wire/queue/score stages sum exactly
to the hop's measured wall time (wire_s is the residual, so the
reconciliation is an identity whenever the residual is positive), with
the shard's own ``shard.search`` span attached as a child — across both
the pipelined fan-out path AND the ``part="full"`` direct path.  The
trace also survives the fault paths: a torn-connection reconnect heal
annotates the live hop span, a zombie primary's fenced ack annotates
the mutation span, and a primary failover leaves an election trace and
keeps producing complete search traces afterwards."""

import numpy as np
import pytest

from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.data import make_hybrid_dataset
from repro.serve import QueryService
from repro.serve.cluster import (ClusterRouter, LocalCluster, ShardClient,
                                 StaleTermError, wait_ready)

# -- shared tiny workload (mirrors tests/test_cluster.py) ---------------------

N0, N_POOL, NQ = 96, 140, 3
D_SPARSE, NNZ = 240, 8

_DS = make_hybrid_dataset(num_points=N_POOL, num_queries=NQ,
                          d_sparse=D_SPARSE, d_dense=16,
                          nnz_per_row=NNZ, seed=11)


def _build(n0=N0):
    return HybridIndex.build(
        _DS.x_sparse[:n0], _DS.x_dense[:n0],
        HybridIndexParams(keep_top=16, head_dims=8, kmeans_iters=2,
                          backend="ref", pq_subspaces=4), mutable=True)


def _comparator():
    return QueryService(index=_build(), h=8, cache_size=0,
                        auto_compact=False)


def _walk(node):
    yield node
    for c in node.get("children", ()):
        yield from _walk(c)


def _annotations(trace):
    out = []
    for node in _walk(trace):
        out.extend(node.get("annotations", ()))
    return out


HOP_STAGES = ("serialize_s", "wire_s", "queue_s", "score_s")


def _check_hop(hop):
    """One finished hop span: every stage tag present and non-negative,
    and serialize + wire + queue + score reconciles with the measured
    wall.  ``wire_s`` is the residual ``max(0, wall - measured)``: when
    it is positive the stages sum EXACTLY to wall; when the server-side
    stages overshoot the client wall (clock granularity) the sum may
    only exceed it — never undershoot."""
    tags = hop["tags"]
    for k in HOP_STAGES:
        assert k in tags, f"hop missing stage {k}: {tags}"
        assert tags[k] >= 0.0
    wall = tags["wall_s"]
    assert wall > 0.0
    total = sum(tags[k] for k in HOP_STAGES)
    assert total >= wall - 1e-9
    if tags["wire_s"] > 0.0:
        assert total == pytest.approx(wall, abs=1e-9)
    assert hop["duration_s"] is not None and hop["duration_s"] > 0.0


def _remote_children(hop):
    return [c for c in hop["children"] if c["name"] == "shard.search"]


# -- the acceptance property --------------------------------------------------

def test_fanout_trace_complete_and_reconciled(tmp_path):
    """The pipelined fan-out: each chunk root carries one ``rpc`` hop per
    scorer plus the delta hop, every hop reconciles stage-by-stage with
    its wall, each carries the shard's serialized ``shard.search`` child
    (stripped of queue_s/score_s — those live as hop stage tags, the
    double-count guard), and the cumulative ``hops()`` counters equal the
    span-sourced stage totals over the drained ring."""
    from repro.obs import stage_totals
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        comp = _comparator()
        try:
            assert router.obs.tracer.enabled    # router default: trace ON
            router.obs.tracer.take()            # drop bootstrap traces
            for _ in range(2):
                s_r, i_r = router.search_sparse(_DS.q_sparse, _DS.q_dense)
            s_c, i_c = comp.search_sparse(_DS.q_sparse, _DS.q_dense)
            np.testing.assert_array_equal(i_r, i_c)
            np.testing.assert_array_equal(s_r, s_c)

            traces = router.obs.tracer.take()
            roots = [t for t in traces if t["name"] == "cluster.search"]
            assert len(roots) == 2              # NQ=3 → one chunk each
            for root in roots:
                assert root["tags"]["qn"] == NQ
                assert root["tags"]["path"] == "fanout"
                assert root["tags"]["gen"] == 1
                assert root["tags"]["merge_s"] > 0.0
                root_wall = root["tags"]["wall_s"]
                hops = [c for c in root["children"] if c["name"] == "rpc"]
                assert sorted(h["tags"]["part"] for h in hops) == \
                    ["delta", "main", "main"]
                for hop in hops:
                    _check_hop(hop)
                    # hop walls are measured inside the root's window
                    assert hop["tags"]["wall_s"] <= root_wall + 1e-6
                    (remote,) = _remote_children(hop)
                    assert remote["duration_s"] > 0.0
                    assert remote["tags"]["part"] in ("main", "delta")
                    assert "queue_s" not in remote["tags"]
                    assert "score_s" not in remote["tags"]
                    # same trace id end to end
                    assert hop["trace_id"] == root["trace_id"]
                    assert remote["trace_id"] == root["trace_id"]
                    assert remote["parent_id"] == hop["span_id"]

            # span-sourced totals == the cumulative hop counters (same
            # folds, so bit-equal up to summation order)
            totals = stage_totals(traces)
            assert totals["score_s"] > 0.0 and totals["merge_s"] > 0.0
            for k, v in router.hops().items():
                assert v == pytest.approx(totals[k], rel=1e-9)

            # the registry snapshot exposes the same counters
            snap = router.metrics()
            assert snap["cluster.hop.score_s"] == \
                pytest.approx(totals["score_s"], rel=1e-9)
        finally:
            router.close()
            comp.close()


def test_direct_path_trace_complete(tmp_path):
    """The adaptive-cutoff path (``part="full"``, Q=1): ONE hop to the
    primary, same stage reconciliation, same attached shard span."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        try:
            router.obs.tracer.take()
            router.search_sparse(_DS.q_sparse[:1], _DS.q_dense[:1])
            assert router.stats["direct_reads"] == 1
            roots = [t for t in router.obs.tracer.take()
                     if t["name"] == "cluster.search"]
            (root,) = roots
            assert root["tags"]["path"] == "direct"
            assert root["tags"]["merge_s"] > 0.0
            (hop,) = [c for c in root["children"] if c["name"] == "rpc"]
            assert hop["tags"]["part"] == "full"
            _check_hop(hop)
            (remote,) = _remote_children(hop)
            assert remote["tags"]["part"] == "full"
            assert remote["parent_id"] == hop["span_id"]
        finally:
            router.close()


def test_mutation_traces(tmp_path):
    """Mutations trace too: ``cluster.insert`` / ``cluster.delete`` roots
    each carry one primary hop with a reconciled stage breakdown."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        try:
            router.obs.tracer.take()
            router.insert(_DS.x_sparse[N0], _DS.x_dense[N0])
            router.delete([3])
            traces = router.obs.tracer.take()
            names = [t["name"] for t in traces]
            assert names == ["cluster.insert", "cluster.delete"]
            for t in traces:
                (hop,) = [c for c in t["children"] if c["name"] == "rpc"]
                _check_hop(hop)
        finally:
            router.close()


# -- fault paths --------------------------------------------------------------

def test_trace_survives_reconnect_heal(tmp_path):
    """A connection dropped mid-exchange heals with a fresh-socket resend
    — and the SAME hop span times the resend and records the heal as a
    ``reconnect_resend`` annotation, so the trace stays complete."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        comp = _comparator()
        try:
            router.obs.tracer.take()
            sc = ShardClient("127.0.0.1", cluster.scorers[0].port)
            sc.call("fault", {"mode": "close_next"})
            sc.close()
            before = sum(c.reconnects for c in router.scorers)
            s_r, i_r = router.search_sparse(_DS.q_sparse, _DS.q_dense)
            s_c, i_c = comp.search_sparse(_DS.q_sparse, _DS.q_dense)
            np.testing.assert_array_equal(i_r, i_c)
            np.testing.assert_array_equal(s_r, s_c)
            assert sum(c.reconnects for c in router.scorers) == before + 1
            (root,) = [t for t in router.obs.tracer.take()
                       if t["name"] == "cluster.search"]
            notes = _annotations(root)
            assert any(n.startswith("reconnect_resend") for n in notes)
            # the healed hop still reconciles
            for hop in (c for c in root["children"] if c["name"] == "rpc"):
                _check_hop(hop)
        finally:
            router.close()
            comp.close()


def test_failover_and_term_fence_traces(tmp_path):
    """The election leaves a ``cluster.failover`` trace (candidate poll +
    promote-winner annotations, the new term as a tag); a zombie
    primary's fenced ack leaves a ``term_fenced`` annotation on the
    refused mutation's span; and the promoted cluster keeps producing
    complete search traces."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"), num_scorers=2,
                             num_replicas=1) as cluster:
        r1 = cluster.router(h=8)
        try:
            r1.insert(_DS.x_sparse[N0], _DS.x_dense[N0])
            rc = ShardClient("127.0.0.1", cluster.replicas[0].port)
            try:
                import time
                deadline = time.monotonic() + 60.0
                while wait_ready(rc)["applied_seq"] < r1._last_seq:
                    assert time.monotonic() < deadline
                    time.sleep(0.05)
            finally:
                rc.close()
            promoted_port = cluster.replicas[0].port
            r1.obs.tracer.take()
            assert r1.failover() == 2          # old primary left ALIVE
            (fo,) = [t for t in r1.obs.tracer.take()
                     if t["name"] == "cluster.failover"]
            assert fo["tags"]["term"] == 2
            notes = fo["annotations"]
            assert any(n.startswith("candidate") for n in notes)
            assert any(n.startswith("promote winner=") for n in notes)

            # a second router that knows term 2, pointed at the zombie:
            # the refused ack is annotated on the mutation's own span
            r2 = ClusterRouter(f"127.0.0.1:{promoted_port}",
                               [s.addr for s in cluster.scorers], [])
            try:
                assert r2.term == 2 and r2.obs.tracer.enabled
                r2.primary.close()
                r2.primary = ShardClient("127.0.0.1",
                                         cluster.primary.port)
                r2.obs.tracer.take()
                with pytest.raises(StaleTermError, match="deposed"):
                    r2.insert(_DS.x_sparse[N0 + 1], _DS.x_dense[N0 + 1])
                (mt,) = [t for t in r2.obs.tracer.take()
                         if t["name"] == "cluster.insert"]
                assert any(n.startswith("term_fenced:")
                           for n in _annotations(mt))
            finally:
                r2.close()

            # the promoted primary serves — with a complete trace
            r1.obs.tracer.take()
            r1.search_sparse(_DS.q_sparse, _DS.q_dense)
            (root,) = [t for t in r1.obs.tracer.take()
                       if t["name"] == "cluster.search"]
            hops = [c for c in root["children"] if c["name"] == "rpc"]
            assert sorted(h["tags"]["part"] for h in hops) == \
                ["delta", "main", "main"]
            for hop in hops:
                _check_hop(hop)
                assert _remote_children(hop)
        finally:
            r1.close()


# -- server-side introspection ------------------------------------------------

def test_stats_rpc_op(tmp_path):
    """The ``stats`` RPC: role/gen/applied_seq plus the server's own
    registry snapshot — per-op counters and the score-time histogram fed
    by the searches above it."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        try:
            router.search_sparse(_DS.q_sparse, _DS.q_dense)
            c = ShardClient("127.0.0.1", cluster.scorers[0].port)
            try:
                st, _ = c.call("stats")
            finally:
                c.close()
            assert st["role"] == "scorer" and st["gen"] == 1
            m = st["metrics"]
            assert m["server.op.search"] >= 1
            assert m["server.score_s"]["count"] >= 1
            assert m["server.score_s"]["sum"] > 0.0
        finally:
            router.close()


def test_tracing_disabled_router_adds_no_wire_overhead(tmp_path):
    """An ``Observability.off()`` router sends NO trace meta, gets NO
    trace replies, records NO spans — and still serves bit-identically
    (the per-request opt-in contract: servers only trace when asked)."""
    from repro.obs import Observability
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8, obs=Observability.off())
        comp = _comparator()
        try:
            s_r, i_r = router.search_sparse(_DS.q_sparse, _DS.q_dense)
            s_c, i_c = comp.search_sparse(_DS.q_sparse, _DS.q_dense)
            np.testing.assert_array_equal(i_r, i_c)
            np.testing.assert_array_equal(s_r, s_c)
            assert router.obs.tracer.take() == []
            assert router.metrics() == {}
            assert router.hops() == {k: 0 for k in router.hops()}
        finally:
            router.close()
            comp.close()
