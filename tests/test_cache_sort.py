"""Cache sorting (paper Algorithm 1) and the Eq. 4/5 cost model."""

import numpy as np
import pytest
import scipy.sparse as sp
from _hypothesis_compat import given, settings, strategies as st

import repro.core.cache_sort as cs


def test_permutation_valid(powerlaw_sparse):
    pi = cs.cache_sort(powerlaw_sparse)
    assert sorted(pi.tolist()) == list(range(powerlaw_sparse.shape[0]))


def test_sorted_reduces_measured_cost(powerlaw_sparse):
    x = powerlaw_sparse
    pi = cs.cache_sort(x)
    rng = np.random.default_rng(1)
    worse = 0
    for trial in range(8):
        qd = np.unique(rng.integers(0, x.shape[1], size=30))
        c_un = cs.measured_block_cost(x, 16, qd)
        c_so = cs.measured_block_cost(x, 16, qd, pi=pi)
        worse += int(c_so > c_un)
    assert worse == 0, "cache sorting increased block touches"


def test_sorted_cost_strictly_better_on_head_dims(powerlaw_sparse):
    """For the most-active dimensions the clustering effect must be large."""
    x = powerlaw_sparse
    pi = cs.cache_sort(x)
    head = np.argsort(-cs.dimension_activity(x))[:5]
    c_un = cs.measured_block_cost(x, 16, head)
    c_so = cs.measured_block_cost(x, 16, head, pi=pi)
    assert c_so < c_un


def test_eq4_matches_montecarlo():
    """Eq. 4 E[C_unsort] against brute-force expectation on iid data."""
    rng = np.random.default_rng(3)
    n, d, b = 512, 40, 16
    p = np.minimum(1.0, np.arange(1, d + 1, dtype=float) ** -1.2)
    qd = np.arange(d)
    costs = []
    for _ in range(30):
        x = sp.csr_matrix((rng.random((n, d)) < p[None, :]).astype(np.float32))
        costs.append(cs.measured_block_cost(x, b, qd))
    expected = cs.expected_cost_unsorted(p, np.ones(d), n, b)
    assert abs(np.mean(costs) - expected) / expected < 0.05


def test_eq5_upper_bounds_sorted_cost():
    rng = np.random.default_rng(4)
    n, d, b = 1024, 60, 16
    p = np.minimum(1.0, np.arange(1, d + 1, dtype=float) ** -1.5)
    x = sp.csr_matrix((rng.random((n, d)) < p[None, :]).astype(np.float32))
    pi = cs.cache_sort(x)
    measured = cs.measured_block_cost(x, b, np.arange(d), pi=pi)
    bound = cs.expected_cost_sorted_bound(p, np.ones(d), n, b)
    # Eq.5 is an expectation upper bound; allow small MC slack.
    assert measured <= bound * 1.25


def test_figure4_shape():
    """Fig 4a: sorted bound under unsorted expectation across alpha."""
    n, b, d = 1_000_000, 16, 1000
    for alpha in (1.5, 2.0, 3.0):
        p = cs.power_law_probs(d, alpha)
        un = cs.expected_cost_unsorted(p, p, n, b)
        so = cs.expected_cost_sorted_bound(p, p, n, b)
        assert so < un


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 200), st.integers(5, 40), st.integers(0, 10_000))
def test_property_permutation(n, d, seed):
    rng = np.random.default_rng(seed)
    x = sp.csr_matrix((rng.random((n, d)) < 0.1).astype(np.float32))
    pi = cs.cache_sort(x)
    assert len(pi) == n
    assert sorted(pi.tolist()) == list(range(n))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 64), st.integers(0, 10_000))
def test_property_sorted_never_worse_total(b, seed):
    rng = np.random.default_rng(seed)
    n, d = 400, 50
    pj = np.minimum(1.0, np.arange(1, d + 1) ** -1.3)
    x = sp.csr_matrix(((rng.random((n, d)) < pj[None, :])
                       * rng.random((n, d))).astype(np.float32))
    pi = cs.cache_sort(x)
    all_dims = np.arange(d)
    assert (cs.measured_block_cost(x, b, all_dims, pi=pi)
            <= cs.measured_block_cost(x, b, all_dims))
