"""Observability layer (repro/obs, DESIGN.md §9): registry get-or-create
identity and type safety, exact counters under threaded stress, the
zero-allocation null path, bounded-bucket histograms, span trees + their
wire roundtrip (wire_context → from_wire → to_wire → attach_remote), the
tracer's bounded ring, stage_totals aggregation, and the /metrics HTTP
exporter on an ephemeral port."""

import json
import threading
import urllib.request

import pytest

from repro.obs import (DEFAULT_BOUNDS, NULL_COUNTER, NULL_GAUGE,
                       NULL_HISTOGRAM, NULL_SPAN, MetricsRegistry,
                       Observability, Tracer, stage_totals,
                       start_metrics_server)

# -- registry ----------------------------------------------------------------


def test_registry_get_or_create_identity():
    """Same name → the SAME instrument object (call sites hoist the
    lookup once); same name under a different kind is a hard error, not a
    silent shadow."""
    reg = MetricsRegistry()
    c1 = reg.counter("a.b")
    c2 = reg.counter("a.b")
    assert c1 is c2
    g = reg.gauge("a.g")
    assert reg.gauge("a.g") is g
    h = reg.histogram("a.h")
    assert reg.histogram("a.h") is h
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("a.b")
    with pytest.raises(TypeError, match="already registered"):
        reg.counter("a.h")


def test_registry_thread_safety_exact_counts():
    """8 threads × 5000 increments through racing get-or-create lookups
    land on ONE instrument and lose nothing: the exact-count contract
    cache_info()/stats() rely on."""
    reg = MetricsRegistry()
    n_threads, n_incs = 8, 5000
    seen = []

    def worker():
        c = reg.counter("stress.c")       # racing get-or-create
        seen.append(c)
        g = reg.gauge("stress.g")
        h = reg.histogram("stress.h")
        for i in range(n_incs):
            c.inc()
            g.add(1.0)
            h.observe(1e-4)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(c is seen[0] for c in seen)
    assert reg.counter("stress.c").value == n_threads * n_incs
    assert reg.gauge("stress.g").value == float(n_threads * n_incs)
    assert reg.histogram("stress.h").count == n_threads * n_incs


def test_disabled_registry_null_path():
    """A disabled registry hands out the shared null singletons, stays
    empty, and reads zeros — instrumented code runs unchanged."""
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("x")
    assert c is NULL_COUNTER
    assert reg.gauge("y") is NULL_GAUGE
    assert reg.histogram("z") is NULL_HISTOGRAM
    c.inc(100)
    NULL_GAUGE.set(5.0)
    NULL_HISTOGRAM.observe(1.0)
    assert c.value == 0 and NULL_GAUGE.value == 0.0
    assert NULL_HISTOGRAM.snapshot()["count"] == 0
    assert reg.snapshot() == {}


def test_histogram_buckets_and_aggregates():
    """Samples land in their cumulative bucket (overflow included) and
    the running aggregates (count/sum/mean/min/max/last) are exact."""
    reg = MetricsRegistry()
    h = reg.histogram("lat", bounds=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.005, 0.05, 5.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 4
    assert snap["sum"] == pytest.approx(5.0555)
    assert snap["mean"] == pytest.approx(5.0555 / 4)
    assert snap["min"] == 0.0005 and snap["max"] == 5.0
    assert snap["last"] == 5.0
    assert snap["buckets"] == {"0.001": 1, "0.01": 1, "0.1": 1, "+inf": 1}
    # default bounds are the fixed latency ladder — bounded, never a
    # per-sample append
    hd = reg.histogram("lat.default")
    assert hd.bounds == DEFAULT_BOUNDS


def test_render_text_exposition():
    """Prometheus-style text: one line per counter/gauge, _count/_sum/
    _last per histogram, dots flattened to underscores."""
    reg = MetricsRegistry()
    reg.counter("serve.cache.hits").inc(3)
    reg.gauge("wal.unsynced_backlog").set(2)
    reg.histogram("wal.fsync_s").observe(0.002)
    text = reg.render_text()
    assert "serve_cache_hits 3" in text
    assert "wal_unsynced_backlog 2" in text
    assert "wal_fsync_s_count 1" in text
    assert "wal_fsync_s_last 0.002" in text


# -- spans + tracer ----------------------------------------------------------


def test_span_tree_wire_roundtrip():
    """The cluster propagation cycle in miniature: a client hop span
    ships its wire_context, the server builds a child via from_wire,
    serializes it with to_wire, and the client folds it back in with
    attach_remote — ids line up, tags and annotations survive."""
    tr = Tracer(enabled=True)
    root = tr.root("cluster.search", qn=3)
    hop = root.child("rpc", peer="127.0.0.1:1", part="main")
    ctx = hop.wire_context()
    assert ctx == {"tid": root.trace_id, "sid": hop.span_id}

    srv = Tracer(enabled=True)
    remote = srv.from_wire(ctx, "shard.search", role="scorer")
    assert remote.trace_id == root.trace_id
    assert remote.parent_id == hop.span_id
    remote.set("rows", 48)
    remote.annotate("reloaded gen=2")
    wire = remote.to_wire()
    assert wire["name"] == "shard.search"
    assert wire["duration_s"] is not None
    assert wire["rows"] == 48 and wire["role"] == "scorer"

    hop.attach_remote(wire)
    hop.add("serialize_s", 0.001)
    hop.end()
    hop.end()                              # idempotent: duration frozen
    d0 = hop.duration_s
    assert d0 is not None and hop.duration_s == d0
    root.end()

    (trace,) = tr.take()
    assert trace["name"] == "cluster.search" and trace["tags"]["qn"] == 3
    (hd,) = trace["children"]
    assert hd["tags"]["serialize_s"] == 0.001
    (rd,) = hd["children"]
    assert rd["span_id"] == wire["sid"]
    assert rd["tags"]["rows"] == 48
    assert rd["annotations"] == ["reloaded gen=2"]
    # attach_remote(None) is a no-op so callers pass rmeta.get("trace")
    hop.attach_remote(None)
    assert len(hd["children"]) == 1


def test_null_span_and_disabled_tracer():
    """The disabled path: falsy NULL_SPAN whose children are itself,
    whose wire_context is None (nothing added to request meta), usable as
    a context manager; a disabled tracer roots to it and records
    nothing."""
    tr = Tracer(enabled=False)
    sp = tr.root("x")
    assert sp is NULL_SPAN and not sp
    assert sp.child("y") is sp
    assert sp.wire_context() is None
    assert sp.to_wire() is None and sp.to_dict() is None
    with sp as s:
        s.set("k", 1)
        s.add("t", 0.5)
        s.annotate("e")
    assert tr.take() == [] and tr.last() is None
    # absent wire context → NULL_SPAN server-side (per-request opt-in)
    live = Tracer(enabled=True)
    assert live.from_wire(None, "shard.search") is NULL_SPAN


def test_tracer_ring_bounded_and_drained():
    """Finished roots land in a deque(maxlen=keep): only the newest
    ``keep`` survive, take() drains, last() peeks without draining."""
    tr = Tracer(enabled=True, keep=4)
    for i in range(7):
        with tr.root("r", i=i):
            pass
    assert tr.last()["tags"]["i"] == 6
    got = tr.take()
    assert [t["tags"]["i"] for t in got] == [3, 4, 5, 6]
    assert tr.take() == []


def test_stage_totals_sums_all_spans():
    """stage_totals sums every STAGES tag over every span of every tree —
    root merge_s plus per-hop stage tags, non-stage tags ignored."""
    tr = Tracer(enabled=True)
    for _ in range(2):
        root = tr.root("cluster.search")
        root.add("merge_s", 0.25)
        for _ in range(2):
            h = root.child("rpc")
            h.add("serialize_s", 0.5)
            h.add("wire_s", 0.125)
            h.add("queue_s", 0.0625)
            h.add("score_s", 1.0)
            h.set("wall_s", 2.0)           # not a stage: ignored
            h.end()
        root.end()
    totals = stage_totals(tr.take())
    assert totals == {"serialize_s": 2.0, "wire_s": 0.5, "queue_s": 0.25,
                      "score_s": 4.0, "merge_s": 0.5}


# -- Observability bundle + exporter -----------------------------------------


def test_observability_defaults_and_off():
    """Default bundle: metrics ON, tracing OFF; .off() nulls both."""
    obs = Observability()
    assert obs.metrics.enabled and not obs.tracer.enabled
    assert obs.enabled
    off = Observability.off()
    assert not off.enabled
    assert off.metrics.counter("x") is NULL_COUNTER
    assert off.tracer.root("y") is NULL_SPAN


def test_metrics_http_exporter():
    """The --metrics-port endpoint on an ephemeral port: /metrics serves
    the text exposition, /metrics.json the snapshot, anything else 404s."""
    reg = MetricsRegistry()
    reg.counter("serve.requests").inc(7)
    reg.histogram("wal.fsync_s").observe(0.001)
    srv = start_metrics_server(reg, port=0)
    try:
        assert srv.port > 0
        base = f"http://127.0.0.1:{srv.port}"
        text = urllib.request.urlopen(f"{base}/metrics").read().decode()
        assert "serve_requests 7" in text
        assert "wal_fsync_s_count 1" in text
        snap = json.loads(
            urllib.request.urlopen(f"{base}/metrics.json").read())
        assert snap["serve.requests"] == 7
        assert snap["wal.fsync_s"]["count"] == 1
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{base}/nope")
    finally:
        srv.close()
