"""Streaming mutable index (core/streaming.py, DESIGN.md §6).

Two headline properties, one per compaction policy (DESIGN.md §6.2):

* ``compact(retrain=True)`` — for ARBITRARY insert/delete/search
  interleavings the rebuilt index is indistinguishable — bit-identical
  top-k ids AND scores — from a from-scratch ``HybridIndex.build`` on the
  same surviving rows, because the rebuild re-runs the deterministic batch
  build on the retained corpus in canonical order.
* ``compact(retrain=False)`` (merge compaction) — the folded index keeps
  the FROZEN codebooks / scalar grid / column space, so equivalence is
  RELAXED: every row's refined score must match the host-side
  frozen-encoding oracle to float tolerance, and the top-k id sets must
  agree with a scratch rebuild up to the measured encoding tolerance
  (perturbation bound on the exact scores).

Both hold across backends {ref, pallas, pallas-packed} and odd/even PQ
subspace counts (the packed odd-K case exercises the phantom-nibble
append); the property tests are what keep those contracts honest as the
delta/merge machinery evolves.

Plus unit coverage of the delta machinery: tombstone masks, capacity
doubling, posting-list growth, frozen-artifact encoding, upserts, and the
out-of-compact-space dim buffering rule.
"""

import numpy as np
import pytest
import scipy.sparse as sp

from _hypothesis_compat import given, settings, strategies as st

from repro.core.engine import ScoringEngine, tombstone_mask
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.core.pq import (encode_rows, pack_codes, pq_decode, pq_encode,
                           scalar_quantize, scalar_quantize_rows)
from repro.core.sparse_index import DeltaPostings
from repro.data import make_hybrid_dataset

# -- shared tiny workload ----------------------------------------------------

N0, N_POOL, NQ = 240, 300, 3
D_SPARSE, NNZ = 360, 12


def _dataset(d_dense):
    return make_hybrid_dataset(num_points=N_POOL, num_queries=NQ,
                               d_sparse=D_SPARSE, d_dense=d_dense,
                               nnz_per_row=NNZ, seed=11)


_DS_CACHE = {}


def _cached_dataset(d_dense):
    if d_dense not in _DS_CACHE:
        _DS_CACHE[d_dense] = _dataset(d_dense)
    return _DS_CACHE[d_dense]


def _params(backend, k):
    return HybridIndexParams(keep_top=24, head_dims=12, kmeans_iters=3,
                             backend=backend, pq_subspaces=k)


def _build_mutable(ds, params):
    return HybridIndex.build(ds.x_sparse[:N0], ds.x_dense[:N0], params,
                             mutable=True)


# -- incremental-vs-rebuild equivalence property -----------------------------

def _check_equivalence(backend: str, k: int, d_dense: int, seed: int):
    """Random interleaving of inserts (incl. upserts), deletes and searches;
    after compaction the streaming index must equal a scratch build on the
    surviving rows, bit for bit, and every intermediate search must respect
    the tombstones."""
    ds = _cached_dataset(d_dense)
    params = _params(backend, k)
    idx = _build_mutable(ds, params)

    rng = np.random.default_rng(seed)
    # model of the logical contents: ext id -> corpus pool row feeding it
    live = {i: i for i in range(N0)}
    deleted: set[int] = set()
    pool = list(range(N0, N_POOL))        # rows never used twice as-new
    n_inserts, n_deletes = 20, 16
    ops = ["ins"] * n_inserts + ["del"] * n_deletes
    rng.shuffle(ops)

    def check_search():
        r = idx.search(ds.q_sparse, ds.q_dense, h=8)
        for row in r.ids:
            real = row[row >= 0]
            assert len(set(real)) == len(real), "duplicate ids in one result"
            for e in real:
                assert e not in deleted, "tombstoned id served"
                assert int(e) in live, "unknown id served"

    upserts = 0
    for t, op in enumerate(ops):
        if op == "ins":
            src = pool.pop(0)
            if upserts < 4 and live and rng.random() < 0.3:
                ext = int(rng.choice(sorted(live)))   # upsert an existing id
                upserts += 1
            else:
                ext = None
            got = idx.insert(ds.x_sparse[src], ds.x_dense[src], ids=ext)
            live[int(got[0])] = src
        else:
            ext = int(rng.choice(sorted(live)))
            assert idx.delete([ext]) == 1
            del live[ext]
            deleted.add(ext)
        if t % 9 == 0:
            check_search()
    check_search()

    # fold down and rebuild from scratch on the same survivors (retrain=True
    # pins the full-rebuild policy: merge compaction keeps frozen encodings
    # and is only RELAXED-equivalent — covered by its own suite below)
    compacted = idx.compact(retrain=True)
    xs, xd, ids = idx.mutable_state.survivors()
    assert set(ids) == set(live)
    scratch = HybridIndex.build(xs, xd, params)

    r_stream = compacted.search(ds.q_sparse, ds.q_dense, h=10)
    r_scratch = scratch.search(ds.q_sparse, ds.q_dense, h=10)
    np.testing.assert_array_equal(r_stream.ids, ids[r_scratch.ids])
    np.testing.assert_array_equal(r_stream.scores, r_scratch.scores)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 9999))
def test_equivalence_ref_even_k(seed):
    """compact() ≡ rebuild: ref backend, even K."""
    _check_equivalence("ref", 4, 8, seed)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 9999))
def test_equivalence_ref_odd_k(seed):
    """compact() ≡ rebuild: ref backend, odd K."""
    _check_equivalence("ref", 3, 12, seed)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 9999))
def test_equivalence_pallas_even_k(seed):
    """compact() ≡ rebuild: pallas backend, even K."""
    _check_equivalence("pallas", 4, 8, seed)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 9999))
def test_equivalence_pallas_odd_k(seed):
    """compact() ≡ rebuild: pallas backend, odd K."""
    _check_equivalence("pallas", 3, 12, seed)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 9999))
def test_equivalence_packed_even_k(seed):
    """compact() ≡ rebuild: packed 4-bit codes, even K."""
    _check_equivalence("pallas-packed", 4, 8, seed)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 9999))
def test_equivalence_packed_odd_k(seed):
    """compact() ≡ rebuild: packed codes with the odd-K phantom nibble."""
    _check_equivalence("pallas-packed", 3, 12, seed)


# -- merge-compaction relaxed-equivalence property ---------------------------

def _frozen_oracle(qs, qd, xs, xd, merged):
    """Host-side oracle for the merged generation's full-refinement score of
    every survivor row: the exact sparse dot RESTRICTED to the frozen
    compact column space, plus the dot against the frozen PQ + int8-residual
    dense reconstruction.  Recomputes the encode exactly as merge compaction
    does (deterministic argmin over unchanged codebooks, frozen scalar
    grid), so any gap beyond float-accumulation noise is a merge bug."""
    cols = np.asarray(merged.cols.global_ids)
    sparse = np.asarray((qs[:, cols] @ xs[:, cols].T).todense())
    codes = encode_rows(xd, merged.codebooks, pack=False)
    recon = np.asarray(pq_decode(codes, merged.codebooks))
    scale = np.asarray(merged.dense_residual.scale)
    zero = np.asarray(merged.dense_residual.zero)
    resq = scalar_quantize_rows(xd - recon, scale, zero)
    deq = (resq.astype(np.float32) + 128.0) * scale + zero
    return sparse + qd @ (recon + deq).T                      # (Q, n)


def _check_merge_equivalence(backend: str, k: int, d_dense: int, seed: int):
    """Random insert/upsert/delete interleaving with MERGE compactions
    (``retrain=False``) folded mid-stream; every intermediate search must
    respect tombstones, and the final merged generation must be
    RELAXED-equivalent to a scratch rebuild on the same survivors:

    * full-depth refined scores match the frozen-encoding oracle to float
      tolerance (the merge represents every row losslessly WITHIN the
      frozen artifact space);
    * with tau = the measured max |refined - exact| per index, every id
      whose exact score clears the h-th exact score by 2*tau appears in
      that index's top-h, and every served id's exact score is within
      2*tau of the h-th (the standard perturbation bound — "same top-k ids
      modulo ties within encoding tolerance").
    """
    ds = _cached_dataset(d_dense)
    params = _params(backend, k)
    idx = _build_mutable(ds, params)

    rng = np.random.default_rng(seed)
    live = {i: i for i in range(N0)}
    deleted: set[int] = set()
    pool = list(range(N0, N_POOL))
    n_inserts, n_deletes, n_merges, n_upserts = 14, 10, 2, 3
    ops = ["ins"] * n_inserts + ["del"] * n_deletes + ["merge"] * n_merges
    rng.shuffle(ops)
    # exactly n_upserts of the inserts re-use a live id, so the survivor
    # count is the same for every seed (keeps engine shapes stable)
    upsert_at = set(rng.choice(n_inserts, size=n_upserts, replace=False))

    def check_search():
        r = idx.search(ds.q_sparse, ds.q_dense, h=8)
        for row in r.ids:
            real = row[row >= 0]
            assert len(set(real)) == len(real), "duplicate ids in one result"
            for e in real:
                assert e not in deleted, "tombstoned id served"
                assert int(e) in live, "unknown id served"

    ins_seen = 0
    for t, op in enumerate(ops):
        if op == "merge":
            idx = idx.compact(retrain=False)
            check_search()
        elif op == "ins":
            src = pool.pop(0)
            ext = (int(rng.choice(sorted(live)))
                   if ins_seen in upsert_at else None)
            ins_seen += 1
            got = idx.insert(ds.x_sparse[src], ds.x_dense[src], ids=ext)
            live[int(got[0])] = src
        else:
            ext = int(rng.choice(sorted(live)))
            assert idx.delete([ext]) == 1
            del live[ext]
            deleted.add(ext)
        if t % 7 == 0:
            check_search()
    check_search()

    merged = idx.compact(retrain=False)
    xs, xd, ids = idx.mutable_state.survivors()
    assert set(ids) == set(live)
    scratch = HybridIndex.build(xs, xd, params)

    n = xs.shape[0]
    assert n == N0 + n_inserts - n_upserts - n_deletes   # shape-stable
    qs, qd = ds.q_sparse, ds.q_dense
    xd32 = np.asarray(xd, np.float32)
    exact = np.asarray((qs @ xs.T).todense()) + qd @ xd32.T
    pred = _frozen_oracle(qs, qd, xs, xd32, merged)
    id_to_col = {int(e): j for j, e in enumerate(ids)}

    # full refinement depth: every survivor's refined score comes back
    r_m = merged.search(qs, qd, h=n)
    r_s = scratch.search(qs, qd, h=n)
    m_ids = np.asarray(r_m.ids)
    s_ids = ids[np.asarray(r_s.ids)]

    h = 10
    for q in range(qs.shape[0]):
        assert {int(e) for e in m_ids[q]} == set(id_to_col), \
            "merged full-depth search lost or duplicated rows"
        assert {int(e) for e in s_ids[q]} == set(id_to_col), \
            "scratch full-depth search lost or duplicated rows"
        cols_m = [id_to_col[int(e)] for e in m_ids[q]]
        cols_s = [id_to_col[int(e)] for e in s_ids[q]]
        sm = np.asarray(r_m.scores[q])
        ss = np.asarray(r_s.scores[q])
        # merge represents rows losslessly within the frozen space
        np.testing.assert_allclose(sm, pred[q, cols_m], rtol=2e-3, atol=2e-2)
        # perturbation-bound top-k agreement against the exact scores
        kth = np.sort(exact[q])[::-1][h - 1]
        for got_ids, got_scores, got_cols, label in (
                (m_ids[q], sm, cols_m, "merged"),
                (s_ids[q], ss, cols_s, "scratch")):
            tau = np.abs(got_scores - exact[q, got_cols]).max()
            tol = 2.0 * tau + 1e-3
            top = {int(e) for e in got_ids[:h]}
            must = {int(ids[j])
                    for j in np.flatnonzero(exact[q] > kth + tol)}
            assert must <= top, \
                f"{label}: clear exact top-{h} id missing (tau={tau})"
            for e in top:
                assert exact[q, id_to_col[e]] >= kth - tol, \
                    f"{label}: served id {e} not justified (tau={tau})"


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 9999))
def test_merge_equivalence_ref_even_k(seed):
    """merge compact ≈ rebuild (relaxed): ref backend, even K."""
    _check_merge_equivalence("ref", 4, 8, seed)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 9999))
def test_merge_equivalence_ref_odd_k(seed):
    """merge compact ≈ rebuild (relaxed): ref backend, odd K."""
    _check_merge_equivalence("ref", 3, 12, seed)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 9999))
def test_merge_equivalence_pallas_even_k(seed):
    """merge compact ≈ rebuild (relaxed): pallas backend, even K."""
    _check_merge_equivalence("pallas", 4, 8, seed)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 9999))
def test_merge_equivalence_pallas_odd_k(seed):
    """merge compact ≈ rebuild (relaxed): pallas backend, odd K."""
    _check_merge_equivalence("pallas", 3, 12, seed)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 9999))
def test_merge_equivalence_packed_even_k(seed):
    """merge compact ≈ rebuild (relaxed): packed 4-bit codes, even K."""
    _check_merge_equivalence("pallas-packed", 4, 8, seed)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 9999))
def test_merge_equivalence_packed_odd_k(seed):
    """merge compact ≈ rebuild (relaxed): packed codes, odd-K phantom
    nibble."""
    _check_merge_equivalence("pallas-packed", 3, 12, seed)


def test_merge_compact_preserves_main_rows_and_ids():
    """Main-resident survivors re-encode IDENTICALLY under merge (frozen
    deterministic encode): codes and residuals of the new generation match
    a retrained rebuild only on the rows the original build encoded — and
    external ids, next_id, and the frozen artifacts all carry over."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    idx.insert(ds.x_sparse[N0:N0 + 4], ds.x_dense[N0:N0 + 4])
    assert idx.delete([0, 5]) == 2
    merged = idx.compact(retrain=False)
    ms = merged.mutable_state
    assert merged.num_points == N0 + 4 - 2
    assert ms.next_id == idx.mutable_state.next_id
    assert set(np.asarray(ms.ids_built)) == \
        (set(range(N0 + 4)) - {0, 5})
    # frozen artifacts are the SAME objects, not retrained copies
    assert merged.codebooks is idx.codebooks
    assert merged.cols is idx.cols
    # and the merged index still serves mutations
    got = merged.insert(ds.x_sparse[N0 + 4], ds.x_dense[N0 + 4])
    assert int(got[0]) == ms.next_id - 1
    r = merged.search(ds.q_sparse, ds.q_dense, h=5)
    assert (np.asarray(r.ids) >= 0).all()


def test_merge_compact_auto_policy_on_dropped_dims():
    """compact() auto-routes: merge when the frozen column space covered
    everything, full rebuild as soon as ANY mutation dropped sparse nnz
    (delta-buffered or folded by an earlier forced merge)."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    seen = set(np.asarray(idx.cols.global_ids))
    in_space = next(j for j in range(D_SPARSE) if j in seen)
    row = sp.csr_matrix(([1.0], ([0], [in_space])), shape=(1, D_SPARSE))
    idx.insert(row, np.zeros((1, 8), np.float32))
    auto = idx.compact()                       # nothing dropped -> merge
    assert auto.codebooks is idx.codebooks
    fresh = next(j for j in range(D_SPARSE) if j not in seen)
    row2 = sp.csr_matrix(([1.0], ([0], [fresh])), shape=(1, D_SPARSE))
    auto.insert(row2, np.zeros((1, 8), np.float32))
    assert auto.mutable_state.delta.dropped_nnz == 1
    retrained = auto.compact()                 # dropped nnz -> rebuild
    assert retrained.codebooks is not auto.codebooks
    assert retrained.mutable_state.main_dropped_nnz == 0
    # forced merge instead would carry the debt forward on the new state
    forced = auto.compact(retrain=False)
    assert forced.mutable_state.main_dropped_nnz == 1
    assert forced.mutable_state.delta.dropped_nnz == 0
    retrained2 = forced.compact()              # debt still forces rebuild
    assert retrained2.codebooks is not forced.codebooks


# -- delta shard unit coverage ----------------------------------------------

@pytest.fixture(scope="module")
def small_mutable():
    ds = _cached_dataset(8)
    return ds, _build_mutable(ds, _params("ref", 4))


def test_fresh_mutable_matches_plain_build(small_mutable):
    """An untouched mutable index returns the plain build's exact results
    (ids default to build-row positions)."""
    ds, idx = small_mutable
    plain = HybridIndex.build(ds.x_sparse[:N0], ds.x_dense[:N0],
                              _params("ref", 4))
    a = idx.search(ds.q_sparse, ds.q_dense, h=10)
    b = plain.search(ds.q_sparse, ds.q_dense, h=10)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.scores, b.scores)


def test_insert_is_searchable_and_delete_tombstones():
    """A dominant inserted row becomes top-1 immediately; deleting it (and a
    main row) removes both from every later result."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    new = idx.insert(ds.q_sparse[0] * 1e3, ds.q_dense[0])
    r = idx.search(ds.q_sparse, ds.q_dense, h=5)
    assert r.ids[0, 0] == new[0]
    victim = int(r.ids[0, 1])
    assert idx.delete([new[0], victim]) == 2
    r2 = idx.search(ds.q_sparse, ds.q_dense, h=5)
    assert new[0] not in r2.ids and victim not in r2.ids


def test_upsert_replaces_row():
    """Re-inserting an existing external id supersedes the old row — the new
    content is served under the same id, with no duplicates."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    ext = 7
    idx.insert(ds.q_sparse[1] * 1e3, ds.q_dense[1], ids=[ext])
    r = idx.search(ds.q_sparse, ds.q_dense, h=8)
    assert r.ids[1, 0] == ext
    for row in r.ids:
        assert len(set(row[row >= 0])) == len(row[row >= 0])
    # upsert the upsert: still exactly one copy, now dominant for query 2
    idx.insert(ds.q_sparse[2] * 1e3, ds.q_dense[2], ids=[ext])
    r2 = idx.search(ds.q_sparse, ds.q_dense, h=8)
    assert r2.ids[2, 0] == ext
    assert (r2.ids[1] == ext).sum() <= 1


def test_delta_capacity_doubles_and_preserves_rows():
    """Inserting past the initial capacity doubles the mirrors; every live
    row stays searchable and the capacity stays a power-of-two multiple."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    st_ = idx.mutable_state
    cap0 = st_.delta.capacity
    m = cap0 + 3
    rows = sp.vstack([ds.q_sparse[0] * 1e3] * m).tocsr()
    dense = np.tile(ds.q_dense[0], (m, 1))
    ids = idx.insert(rows, dense)
    assert st_.delta.capacity >= m
    assert st_.delta.capacity % cap0 == 0
    r = idx.search(ds.q_sparse, ds.q_dense, h=m + 2)
    assert set(ids) <= set(r.ids[0])


def test_failed_upsert_leaves_old_row_intact():
    """REGRESSION: a rejected insert (bad width, mismatched rows) must not
    tombstone the rows it would have upserted — retire-after-encode."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    before = idx.search(ds.q_sparse, ds.q_dense, h=5)
    ext = int(before.ids[0, 0])
    with pytest.raises(ValueError, match="dense width"):
        idx.insert(ds.q_sparse[0], np.zeros((1, 9), np.float32), ids=[ext])
    with pytest.raises(ValueError, match="row-count mismatch"):
        idx.insert(ds.q_sparse[0], ds.q_dense[:2], ids=[ext])
    assert idx.delta_version == 0
    after = idx.search(ds.q_sparse, ds.q_dense, h=5)
    np.testing.assert_array_equal(after.ids, before.ids)
    np.testing.assert_array_equal(after.scores, before.scores)


def test_delta_rejects_duplicate_batch_ids():
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    rows = sp.vstack([ds.q_sparse[0], ds.q_sparse[1]]).tocsr()
    with pytest.raises(ValueError, match="duplicate external ids"):
        idx.insert(rows, ds.q_dense[:2], ids=[5, 5])


def test_negative_ids_rejected():
    """-1 is the merge layer's empty-slot sentinel; external ids must not
    collide with it (insert and build paths both reject)."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    with pytest.raises(ValueError, match="non-negative"):
        idx.insert(ds.q_sparse[0], ds.q_dense[0], ids=[-1])
    with pytest.raises(ValueError, match="non-negative"):
        HybridIndex.build(ds.x_sparse[:40], ds.x_dense[:40],
                          _params("ref", 4), mutable=True,
                          ext_ids=np.arange(40) - 1)


def test_compaction_never_reuses_deleted_ids():
    """REGRESSION: the auto-id counter survives compaction — deleting the
    highest-assigned id then compacting must not re-mint it for the next
    insert (a resurrected tombstone)."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    new = idx.insert(ds.q_sparse[0], ds.q_dense[0])     # id N0
    assert idx.delete(new) == 1
    idx2 = idx.compact()
    again = idx2.insert(ds.q_sparse[1], ds.q_dense[1])
    assert again[0] > new[0]


def test_compact_empty_corpus_raises():
    """Deleting every row leaves nothing for the batch build (k-means needs
    data): compact() fails loudly instead of crashing deep in the build."""
    ds = _cached_dataset(8)
    idx = HybridIndex.build(ds.x_sparse[:N0], ds.x_dense[:N0],
                            _params("ref", 4), mutable=True)
    assert idx.delete(list(range(N0))) == N0
    assert idx.mutable_state.live_rows == 0
    with pytest.raises(ValueError, match="empty corpus"):
        idx.compact()


def test_delete_only_mutation_reuses_structural_arrays():
    """A tombstone-only mutation must not re-upload the delta: only the
    mask leaf changes between snapshots."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    ids = idx.insert(ds.q_sparse[:2] * 1e3, ds.q_dense[:2])
    st_ = idx.mutable_state
    snap1 = st_.delta.snapshot()
    idx.delete([ids[0]])
    snap2 = st_.delta.snapshot()
    assert snap2.arrays.codes is snap1.arrays.codes          # shared
    assert snap2.arrays.valid_mask is not snap1.arrays.valid_mask
    r = idx.search(ds.q_sparse, ds.q_dense, h=5)
    assert ids[0] not in r.ids and ids[1] == r.ids[1, 0]


def test_out_of_space_dims_buffer_until_compaction():
    """Sparse dims the main build never saw can't be scored by the delta
    (frozen compact column space) but live in the retained corpus, so
    compaction makes them searchable."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    seen = set(np.asarray(idx.cols.global_ids))
    fresh = next(j for j in range(D_SPARSE) if j not in seen)
    row = sp.csr_matrix(([100.0], ([0], [fresh])), shape=(1, D_SPARSE))
    new = idx.insert(row, np.zeros((1, 8), np.float32))
    assert idx.mutable_state.delta.dropped_nnz == 1
    q = row  # query exactly on the unseen dim
    r = idx.search(q, np.zeros((1, 8), np.float32), h=3)
    assert r.scores[0, 0] < 100.0 * 100.0   # not scorable pre-compaction
    idx2 = idx.compact()
    r2 = idx2.search(q, np.zeros((1, 8), np.float32), h=3)
    assert r2.ids[0, 0] == new[0]
    assert r2.scores[0, 0] == pytest.approx(100.0 * 100.0, rel=1e-3)


def test_tombstone_mask_values():
    m = np.asarray(tombstone_mask(8, 5, np.array(
        [False, True, False, False, True, False, False, False])))
    assert list(np.isneginf(m)) == [False, True, False, False, True,
                                    True, True, True]
    assert (m[~np.isneginf(m)] == 0.0).all()


def test_delta_postings_growth_padding_and_spill():
    dp = DeltaPostings(d_active=4, l_max=2, l_cap=4)
    assert dp.append(0, [1, 2], [0.5, 0.25])[0].size == 0
    dp.append(1, [1], [1.0])
    dp.append(2, [1], [2.0])          # dim 1 overflows l_max=2 -> doubles
    assert dp.l_max == 4
    dp.append(3, [1], [3.0])          # dim 1 now full at l_cap=4
    sd, sv = dp.append(4, [1, 3], [4.0, 0.5])   # dim 1 spills, dim 3 fits
    assert list(sd) == [1] and list(sv) == [4.0]
    assert dp.l_max == 4              # cap held: no further growth
    inv = dp.to_padded(num_points=8)
    rows = np.asarray(inv.rows)
    assert rows.shape == (4, 4)
    assert list(rows[1]) == [0, 1, 2, 3]
    assert rows[0, 0] == 8            # empty slots use the sentinel
    assert rows[3, 0] == 4
    assert inv.num_points == 8


def test_delta_spill_is_scored_exactly():
    """Entries past the postings cap flow through the pass-3 rows: a dim
    hot across many delta rows still scores exactly (h == capacity)."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    st_ = idx.mutable_state
    hot = int(np.asarray(idx.cols.global_ids)[0])
    m = st_.delta._postings.l_cap + 4         # force spill on the hot dim
    rows = sp.csr_matrix((np.full(m, 2.0), (np.arange(m), np.full(m, hot))),
                         shape=(m, D_SPARSE))
    ids = idx.insert(rows, np.zeros((m, 8), np.float32))
    assert st_.delta._rmax >= 1
    assert (np.asarray(st_.delta._row_cols[: st_.delta.count]) <
            idx.cols.num_active).any()        # something actually spilled
    q = sp.csr_matrix(([1.0], ([0], [hot])), shape=(1, D_SPARSE))
    r = idx.search(q, np.zeros((1, 8), np.float32), h=m)
    got = {int(e): s for e, s in zip(r.ids[0], r.scores[0]) if e in set(ids)}
    assert len(got) == m                      # every inserted row found
    for s in got.values():                    # 1.0 * 2.0 exactly, all rows
        assert s == pytest.approx(2.0, abs=1e-4)


def test_encode_rows_matches_batch_encode():
    """encode-on-insert against frozen codebooks == batch pq_encode, and the
    packed form == pack_codes of it (odd K -> phantom nibble)."""
    ds = _cached_dataset(12)
    for k in (3, 4):
        idx = _build_mutable(ds, _params("ref", k))
        xd = ds.x_dense[N0:N0 + 5]
        ref = np.asarray(pq_encode(xd, idx.codebooks))
        np.testing.assert_array_equal(
            encode_rows(xd, idx.codebooks, pack=False), ref)
        np.testing.assert_array_equal(
            encode_rows(xd, idx.codebooks, pack=True), pack_codes(ref))


def test_scalar_quantize_rows_matches_frozen_grid():
    """Row quantization with frozen scale/zero reproduces scalar_quantize
    bit-for-bit on the rows that defined the grid."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(32, 6)).astype(np.float32)
    sq = scalar_quantize(x)
    rows = scalar_quantize_rows(x, np.asarray(sq.scale), np.asarray(sq.zero))
    np.testing.assert_array_equal(rows, np.asarray(sq.q))


def test_valid_mask_blocks_dead_slots_in_engine():
    """The -inf mask keeps tombstoned/empty delta slots out of the top-k of
    EVERY pass — even when the requested h exceeds the live count."""
    ds = _cached_dataset(8)
    idx = _build_mutable(ds, _params("ref", 4))
    st_ = idx.mutable_state
    idx.insert(ds.q_sparse[0] * 1e3, ds.q_dense[0])
    idx.insert(ds.q_sparse[1] * 1e3, ds.q_dense[1])
    idx.delete([N0])                       # tombstone the first delta slot
    snap = st_.delta.snapshot()
    assert snap.live == 1 and snap.count == 2
    eng = ScoringEngine(arrays=snap.arrays, backend=idx.engine.backend)
    import jax.numpy as jnp
    from repro.core.sparse_index import sparse_queries_to_padded
    qd, qv = sparse_queries_to_padded(ds.q_sparse, idx.cols, nq_max=256)
    s, pos, _ = eng.search(jnp.asarray(qd), jnp.asarray(qv),
                           jnp.asarray(ds.q_dense), h=snap.capacity,
                           alpha=20, beta=5)
    s, pos = np.asarray(s), np.asarray(pos)
    finite = np.isfinite(s)
    assert finite.sum(axis=1).max() == 1       # only the live slot
    assert set(pos[finite]) == {1}
