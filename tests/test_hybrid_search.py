"""End-to-end HybridIndex behaviour: recall, residual repair, baselines."""

import numpy as np
import pytest

from repro.core import baselines as bl
from repro.core.hybrid import HybridIndex, HybridIndexParams


@pytest.fixture(scope="module")
def built(small_hybrid):
    ds = small_hybrid
    idx = HybridIndex.build(
        ds.x_sparse, ds.x_dense,
        HybridIndexParams(keep_top=48, head_dims=48, kmeans_iters=6))
    true_ids, _ = bl.exact_topk(ds.q_sparse, ds.q_dense, ds.x_sparse,
                                ds.x_dense, 20)
    return ds, idx, true_ids


def test_recall_at_20(built):
    ds, idx, true_ids = built
    r = idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=20, beta=5)
    assert bl.recall_at_h(r.ids, true_ids) >= 0.85


def test_residual_reorder_improves_recall(built):
    """Pass-1-only candidates vs full 3-pass (paper §5's point)."""
    ds, idx, true_ids = built
    r = idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=3, beta=2,
                   return_pass1=True)
    full = bl.recall_at_h(r.ids, true_ids)
    pass1 = bl.recall_at_h(r.pass1_ids[:, :20], true_ids)
    assert full >= pass1


def test_alpha_monotone(built):
    """Recall@h is non-decreasing in the overfetch alpha (Prop. 4 flavor)."""
    ds, idx, true_ids = built
    recs = []
    for alpha in (2, 8, 24):
        r = idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=alpha, beta=5)
        recs.append(bl.recall_at_h(r.ids, true_ids))
    assert recs[-1] >= recs[0] - 0.02


def test_refined_scores_near_exact(built):
    """After all 3 passes scores should match exact inner products up to the
    int8 dense-residual quantization error."""
    ds, idx, true_ids = built
    r = idx.search(ds.q_sparse, ds.q_dense, h=5, alpha=20, beta=10)
    exact = idx.exact_scores(ds.q_sparse, ds.q_dense, ds.x_sparse, ds.x_dense)
    got = r.scores
    want = np.take_along_axis(exact, r.ids, axis=1)
    assert np.abs(got - want).max() < 0.15 * max(np.abs(want).max(), 1.0)


def test_hybrid_beats_single_modality(built):
    """The paper's core claim: neither sparse-only nor dense-only retrieval
    reaches hybrid recall when signal lives in both components."""
    ds, idx, true_ids = built
    r = idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=20, beta=5)
    hybrid_rec = bl.recall_at_h(r.ids, true_ids)
    sparse_only = bl.sparse_only(ds.q_sparse, ds.q_dense, ds.x_sparse,
                                 ds.x_dense, 20)
    dense_only = bl.dense_pq_reorder(ds.q_sparse, ds.q_dense, ds.x_sparse,
                                     ds.x_dense, 20, overfetch=100)
    assert hybrid_rec >= bl.recall_at_h(sparse_only.ids, true_ids) - 0.05
    assert hybrid_rec >= bl.recall_at_h(dense_only.ids, true_ids) - 0.05


def test_baselines_exact_methods_perfect(small_hybrid):
    ds = small_hybrid
    true_ids, _ = bl.exact_topk(ds.q_sparse, ds.q_dense, ds.x_sparse,
                                ds.x_dense, 10)
    for fn in (bl.dense_brute_force, bl.sparse_brute_force):
        res = fn(ds.q_sparse, ds.q_dense, ds.x_sparse, ds.x_dense, 10)
        assert bl.recall_at_h(res.ids, true_ids) == 1.0
    res = bl.sparse_inverted_index(ds.q_sparse[:3], ds.q_dense[:3],
                                   ds.x_sparse, ds.x_dense, 10)
    assert bl.recall_at_h(res.ids, true_ids[:3]) == 1.0


def test_hamming_baseline_runs(small_hybrid):
    ds = small_hybrid
    res = bl.hamming512(ds.q_sparse, ds.q_dense, ds.x_sparse, ds.x_dense,
                        10, overfetch=500)
    assert res.ids.shape == (ds.q_sparse.shape[0], 10)


def test_kernel_path_matches_ref_path(small_hybrid):
    """use_lut16_kernel=True must retrieve the same ids."""
    ds = small_hybrid
    a = HybridIndex.build(ds.x_sparse, ds.x_dense,
                          HybridIndexParams(keep_top=48, kmeans_iters=4,
                                            use_lut16_kernel=False))
    b = HybridIndex.build(ds.x_sparse, ds.x_dense,
                          HybridIndexParams(keep_top=48, kmeans_iters=4,
                                            use_lut16_kernel=True))
    ra = a.search(ds.q_sparse[:4], ds.q_dense[:4], h=10)
    rb = b.search(ds.q_sparse[:4], ds.q_dense[:4], h=10)
    assert (ra.ids == rb.ids).mean() > 0.95
