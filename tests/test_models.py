"""Model zoo: per-arch smoke, decode consistency, layer-level references."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import Model

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, key=KEY, s=S):
    batch = {}
    if cfg.frontend == "tokens":
        batch["tokens"] = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(key, (B, s, cfg.d_model),
                                            jnp.float32)
    if cfg.num_cond_tokens:
        batch["cond"] = jax.random.normal(key, (B, cfg.num_cond_tokens,
                                                cfg.d_model))
    batch["labels"] = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_grad(arch):
    """Assigned-architecture smoke: reduced config, one forward + one train
    gradient on CPU, asserting shapes and no NaNs."""
    cfg = get_config(arch + "-smoke")
    m = Model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    logits, aux = jax.jit(m.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(m.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(not bool(jnp.isnan(g).any()) for g in flat)


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch):
    """prefill(S-1) + decode(1) == teacher-forced forward at last position.
    MoE archs need high capacity_factor to eliminate drop nondeterminism."""
    cfg = get_config(arch + "-smoke")
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    m = Model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    logits_tf, _ = jax.jit(m.forward)(params, batch)
    want = logits_tf[:, -1]

    pre = dict(batch)
    if cfg.frontend == "tokens":
        pre["tokens"] = batch["tokens"][:, : S - 1]
        last = batch["tokens"][:, S - 1]
    else:
        pre["embeds"] = batch["embeds"][:, : S - 1]
        last = batch["embeds"][:, S - 1: S]
    pre.pop("labels")
    _, state = jax.jit(m.prefill, static_argnums=2)(params, pre, 64)
    got, _ = jax.jit(m.decode_step)(params, state, last)
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert rel < 3e-2, (arch, rel)


def test_banded_equals_full_attention():
    from repro.models import attention as at
    rng = jax.random.PRNGKey(1)
    q = jax.random.normal(rng, (2, 64, 2, 2, 16), jnp.float32)  # (B,S,Hkv,G,hd)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 64, 2, 16))
    full = at.full_attention(q, k, v, causal=True, dtype=jnp.float32)
    band = at.banded_causal_attention(q, k, v, chunk=16, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(band), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_banded_local_window():
    """Windowed banded attention == full attention with a window mask."""
    from repro.models import attention as at
    rng = jax.random.PRNGKey(2)
    s, w = 64, 16
    q = jax.random.normal(rng, (1, s, 2, 1, 8), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, s, 2, 8))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (1, s, 2, 8))
    band = at.banded_causal_attention(q, k, v, chunk=16, window=w,
                                      dtype=jnp.float32)
    # reference: dense scores with the window mask
    sc = jnp.einsum("bshk,bmhk->bhsm", q[:, :, :, 0], k) * 8 ** -0.5
    iq = jnp.arange(s)[:, None]
    ik = jnp.arange(s)[None, :]
    mask = (iq >= ik) & (iq - ik < w)
    sc = jnp.where(mask[None, None], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    want = jnp.einsum("bhsm,bmhk->bshk", pr, v)
    np.testing.assert_allclose(np.asarray(band[:, :, :, 0]),
                               np.asarray(want), rtol=2e-3, atol=2e-3)


def test_ssd_equals_naive_recurrence():
    """Chunked SSD == step-by-step linear recurrence."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(0)
    b, s, h, p, n = 1, 24, 2, 4, 8
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y, s_last = ssd_chunked(x, dt, a, bb, cc, chunk=8)

    # naive: S_t = exp(a*dt_t) S_{t-1} + dt_t * B_t (x) x_t ; y_t = C_t . S_t
    st = np.zeros((b, h, p, n), np.float32)
    ys = np.zeros((b, s, h, p), np.float32)
    for t in range(s):
        decay = np.exp(np.asarray(a)[None, :] * np.asarray(dt)[:, t])
        st = st * decay[:, :, None, None] + np.einsum(
            "bhp,bn,bh->bhpn", np.asarray(x)[:, t], np.asarray(bb)[:, t],
            np.asarray(dt)[:, t])
        ys[:, t] = np.einsum("bhpn,bn->bhp", st, np.asarray(cc)[:, t])
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_last), st, rtol=2e-3, atol=2e-3)


def test_rglru_scan_equals_steps():
    """Associative-scan RG-LRU == sequential decode steps."""
    from repro.configs import get_config
    from repro.models import rglru as rg
    cfg = get_config("recurrentgemma-9b-smoke")
    p = rg.init_rglru(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 12, cfg.d_model),
                          jnp.float32)
    full = rg.rglru_block(x, p, cfg)
    state = rg.rglru_decode_init(cfg, 2, jnp.float32)
    outs = []
    for t in range(12):
        o, state = rg.rglru_decode_step(x[:, t:t + 1], p, cfg, state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=3e-2, atol=3e-2)


def test_moe_capacity_drops_bounded():
    """With cf=1.0 some tokens may drop, but gates of kept tokens are intact:
    output norm stays within a sane band of the high-capacity output."""
    from repro.models import mlp as mlp_mod
    cfg = get_config("qwen3-moe-235b-a22b-smoke")
    p = mlp_mod.init_moe(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, cfg.d_model),
                          jnp.float32)
    y_low, _ = mlp_mod.moe(x, p, cfg)
    y_high, _ = mlp_mod.moe(x, p, dataclasses.replace(cfg,
                                                      capacity_factor=16.0))
    # overlap: most tokens unaffected by drops
    close = np.isclose(np.asarray(y_low), np.asarray(y_high),
                       rtol=1e-2, atol=1e-2).mean()
    assert close > 0.5


def test_kv_repeat_preserves_decode_consistency():
    """§Perf pair-2 optimization: kv_repeat changes sharding feasibility, not
    semantics — prefill+decode must still match teacher-forced forward."""
    cfg = dataclasses.replace(get_config("deepseek-67b-smoke"), kv_repeat=2)
    m = Model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    logits_tf, _ = jax.jit(m.forward)(params, batch)
    pre = {"tokens": batch["tokens"][:, : S - 1]}
    _, state = jax.jit(m.prefill, static_argnums=2)(params, pre, 64)
    got, _ = jax.jit(m.decode_step)(params, state, batch["tokens"][:, S - 1])
    rel = float(jnp.abs(got - logits_tf[:, -1]).max()
                / jnp.abs(logits_tf[:, -1]).max())
    assert rel < 3e-2, rel


def test_unroll_matches_scan():
    cfg = get_config("qwen2-7b-smoke")
    m1 = Model(cfg)
    m2 = Model(dataclasses.replace(cfg, unroll=True))
    params = m1.init(KEY)
    batch = _batch(cfg)
    a, _ = jax.jit(m1.forward)(params, batch)
    b, _ = jax.jit(m2.forward)(params, batch)
    # same math, different fusion order: bf16 activations => loose tolerance
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-2,
                               atol=5e-2)
