"""ScoringEngine parity: the single-jit three-pass search must reproduce the
pre-refactor host-driven HybridIndex.search (numpy round trips between every
pass) on the synthetic hybrid fixtures, across all backends."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import residual as res
from repro.core.engine import Backend, ScoringEngine
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.core.pq import adc_lut, adc_scores_ref
from repro.core.sparse_index import (queries_head_dense, score_head_ref,
                                     score_inverted, sparse_queries_to_padded)


def host_loop_search(idx: HybridIndex, q_sparse, q_dense, h: int,
                     alpha: int, beta: int):
    """The pre-refactor search: each pass a separate dispatch with a host
    transfer in between (the reference the engine must match bit-for-bit)."""
    p = idx.params
    c1 = min(max(alpha * h, h), idx.num_points)
    c2 = min(max(beta * h, h), c1)
    q_dense = jnp.asarray(np.asarray(q_dense, np.float32))
    q_dims_np, q_vals_np = sparse_queries_to_padded(q_sparse, idx.cols,
                                                    nq_max=p.nq_max)
    q_dims, q_vals = jnp.asarray(q_dims_np), jnp.asarray(q_vals_np)

    # pass 1 (host-driven): sparse + head + dense ADC, overfetch c1
    sparse_scores = score_inverted(idx.inv_index, q_dims, q_vals)
    if idx.head is not None:
        q_head = jnp.asarray(queries_head_dense(
            q_dims_np, q_vals_np, idx.head_dim_ids, idx.head.block.shape[1]))
        head_scores = np.asarray(score_head_ref(idx.head, q_head))
        sparse_scores = np.asarray(sparse_scores) + head_scores[:, :idx.num_points]
    lut = adc_lut(q_dense, idx.codebooks)
    approx = jnp.asarray(np.asarray(sparse_scores)
                         + np.asarray(adc_scores_ref(idx.codes, lut)))
    s1, ids1 = res.topk_candidates(approx, c1)
    s1, ids1 = jnp.asarray(np.asarray(s1)), jnp.asarray(np.asarray(ids1))

    # pass 2: + dense residual, keep c2 (host sync again)
    extra_d = res.dense_residual_scores(idx.dense_residual, ids1, q_dense)
    s2, ids2 = res.reorder_pass(s1, ids1, extra_d, c2)
    s2, ids2 = jnp.asarray(np.asarray(s2)), jnp.asarray(np.asarray(ids2))

    # pass 3: + sparse residual, return h
    from repro.core.engine import scatter_queries_compact
    q_cols = scatter_queries_compact(q_dims, q_vals, idx.cols.num_active)
    extra_s = res.sparse_residual_scores(idx.sparse_residual, ids2, q_cols)
    s3, ids3 = res.reorder_pass(s2, ids2, extra_s, h)
    return np.asarray(s3), idx.pi[np.asarray(ids3)]


@pytest.fixture(scope="module")
def built(small_hybrid):
    ds = small_hybrid
    idx = HybridIndex.build(
        ds.x_sparse, ds.x_dense,
        HybridIndexParams(keep_top=48, head_dims=48, kmeans_iters=6))
    return ds, idx


def test_engine_matches_host_loop_ref(built):
    """ref backend: ids must match exactly, scores bit-for-bit."""
    ds, idx = built
    want_s, want_i = host_loop_search(idx, ds.q_sparse, ds.q_dense,
                                      h=20, alpha=20, beta=5)
    got = idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=20, beta=5)
    np.testing.assert_array_equal(got.ids, want_i)
    np.testing.assert_array_equal(got.scores, want_s)


@pytest.mark.parametrize("backend", ["ref", "onehot-mxu", "pallas"])
def test_engine_backends_agree(built, backend):
    """Every backend retrieves (near-)identical ids; onehot-mxu contracts in
    bf16 so scores get a loose tolerance."""
    ds, idx = built
    if backend == "pallas":
        # rebuild with BCSR head tiles so the Pallas head path is exercised
        pidx = HybridIndex.build(
            ds.x_sparse, ds.x_dense,
            HybridIndexParams(keep_top=48, head_dims=48, kmeans_iters=6,
                              backend="pallas"))
        eng = pidx.engine
        assert eng.arrays.head_max_steps > 0
    else:
        eng = ScoringEngine(arrays=idx.engine.arrays,
                            backend=Backend.from_name(backend))
    q_dims_np, q_vals_np = sparse_queries_to_padded(
        ds.q_sparse, idx.cols, nq_max=idx.params.nq_max)
    s, ids, _ = eng.search(jnp.asarray(q_dims_np), jnp.asarray(q_vals_np),
                           jnp.asarray(ds.q_dense), h=10, alpha=20, beta=5)
    ref = idx.search(ds.q_sparse, ds.q_dense, h=10, alpha=20, beta=5)
    got_ids = idx.pi[np.asarray(ids)]
    if backend == "ref":
        np.testing.assert_array_equal(got_ids, ref.ids)
        np.testing.assert_array_equal(np.asarray(s), ref.scores)
    else:
        assert (got_ids == ref.ids).mean() > 0.9
        np.testing.assert_allclose(np.sort(np.asarray(s)), np.sort(ref.scores),
                                   rtol=3e-2, atol=3e-2)


def test_engine_no_head_block(small_hybrid):
    """use_head_block=False path (head=None pytree leaf) works end to end."""
    ds = small_hybrid
    idx = HybridIndex.build(
        ds.x_sparse, ds.x_dense,
        HybridIndexParams(keep_top=48, kmeans_iters=4, use_head_block=False))
    want_s, want_i = host_loop_search(idx, ds.q_sparse, ds.q_dense,
                                      h=10, alpha=10, beta=3)
    got = idx.search(ds.q_sparse, ds.q_dense, h=10, alpha=10, beta=3)
    np.testing.assert_array_equal(got.ids, want_i)
    np.testing.assert_array_equal(got.scores, want_s)


def test_explicit_zero_alpha_beta_not_treated_as_default(built):
    """alpha=1/beta=1 must be honored (the old `alpha or p.alpha` bug made
    falsy overrides silently fall back to the params defaults)."""
    ds, idx = built
    r = idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=1, beta=1,
                   return_pass1=True)
    # alpha=1 => pass-1 candidate set is exactly h, not params.alpha*h
    assert r.pass1_ids.shape == (ds.q_sparse.shape[0], 20)


# ---------------------------------------------------------------------------
# packed 4-bit codes as an engine backend (paper §6.1.1 storage; DESIGN.md §3)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def packed_built(small_hybrid):
    """Same params/seed as `built` but with packed two-per-byte code storage
    and the pallas-packed backend — codebooks and codes are identical."""
    ds = small_hybrid
    idx = HybridIndex.build(
        ds.x_sparse, ds.x_dense,
        HybridIndexParams(keep_top=48, head_dims=48, kmeans_iters=6,
                          backend="pallas-packed"))
    return ds, idx


def test_packed_storage_halves_code_bytes(built, packed_built):
    """The acceptance metric: dense-code HBM footprint is halved, and it's
    the ONLY resident copy (HybridIndex.codes aliases the engine array)."""
    _, idx = built
    _, pidx = packed_built
    assert pidx.engine.arrays.codes_packed
    assert pidx.engine.arrays.codes.nbytes * 2 == idx.engine.arrays.codes.nbytes
    assert pidx.codes is pidx.engine.arrays.codes


def test_packed_backend_bit_identical_topk(built, packed_built):
    """PALLAS_PACKED through the full three-pass search returns bit-identical
    top-k ids to REF (scores within f32 kernel-accumulation noise)."""
    ds, idx = built
    _, pidx = packed_built
    ref = idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=20, beta=5)
    got = pidx.search(ds.q_sparse, ds.q_dense, h=20, alpha=20, beta=5)
    np.testing.assert_array_equal(got.ids, ref.ids)
    np.testing.assert_allclose(got.scores, ref.scores, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "onehot-mxu"])
def test_unpack_then_score_path(built, packed_built, backend):
    """Non-Pallas backends on packed storage unpack in-jit and score exactly
    like their unpacked path: REF is bit-for-bit, onehot matches onehot."""
    ds, idx = built
    _, pidx = packed_built
    q_dims_np, q_vals_np = sparse_queries_to_padded(
        ds.q_sparse, idx.cols, nq_max=idx.params.nq_max)
    args = (jnp.asarray(q_dims_np), jnp.asarray(q_vals_np),
            jnp.asarray(ds.q_dense))
    b = Backend.from_name(backend)
    want = ScoringEngine(arrays=idx.engine.arrays, backend=b).search(
        *args, h=20, alpha=20, beta=5)
    got = ScoringEngine(arrays=pidx.engine.arrays, backend=b).search(
        *args, h=20, alpha=20, beta=5)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))


def test_packed_backend_small_codebook_fails_at_build(small_hybrid):
    """pallas-packed needs l == 16 (the kernel's LUT width); l < 16 must be
    rejected when the engine is constructed, not at the first search."""
    ds = small_hybrid
    with pytest.raises(ValueError, match="l == 16"):
        HybridIndex.build(
            ds.x_sparse, ds.x_dense,
            HybridIndexParams(keep_top=48, head_dims=32, kmeans_iters=2,
                              pq_codes=8, backend="pallas-packed"))


def test_packed_odd_subspace_count(small_hybrid):
    """K_U odd (here K=1): the phantom pad nibble must not change the search
    relative to the unpacked ref build."""
    ds = small_hybrid
    p = dict(keep_top=48, head_dims=32, kmeans_iters=4, pq_subspaces=1)
    ref = HybridIndex.build(ds.x_sparse, ds.x_dense, HybridIndexParams(**p))
    pidx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                             HybridIndexParams(**p, backend="pallas-packed"))
    assert pidx.engine.arrays.codes.shape == (ds.x_sparse.shape[0], 1)
    r = ref.search(ds.q_sparse, ds.q_dense, h=10)
    g = pidx.search(ds.q_sparse, ds.q_dense, h=10)
    np.testing.assert_array_equal(g.ids, r.ids)
    np.testing.assert_allclose(g.scores, r.scores, rtol=1e-5, atol=1e-5)


def test_engine_is_single_dispatch(built):
    """The three passes lower into ONE jitted computation: the jaxpr of the
    engine search contains the top_k chain with no host boundary."""
    import jax
    from repro.core.engine import three_pass_search
    ds, idx = built
    q_dims_np, q_vals_np = sparse_queries_to_padded(
        ds.q_sparse, idx.cols, nq_max=idx.params.nq_max)
    closed = jax.make_jaxpr(
        lambda a, d, v, q: three_pass_search(a, d, v, q, h=10, c1=100, c2=40,
                                             backend=Backend.REF))(
        idx.engine.arrays, jnp.asarray(q_dims_np), jnp.asarray(q_vals_np),
        jnp.asarray(ds.q_dense))
    text = str(closed)
    assert text.count("top_k") >= 3          # all three passes traced together


# ---------------------------------------------------------------------------
# fused scan-and-select pass 1 (DESIGN.md §2.5)
# ---------------------------------------------------------------------------

def _padded_queries(ds, idx):
    q_dims_np, q_vals_np = sparse_queries_to_padded(
        ds.q_sparse, idx.cols, nq_max=idx.params.nq_max)
    return (jnp.asarray(q_dims_np), jnp.asarray(q_vals_np),
            jnp.asarray(ds.q_dense))


@pytest.mark.parametrize("backend", ["pallas", "pallas-packed"])
def test_fused_search_bit_identical_to_materialize(built, packed_built,
                                                   backend):
    """fused=True vs fused=False through the FULL three-pass search must be
    bit-identical on both Pallas backends: the fused kernel shares the
    per-block partial sums and select ordering with the materialize path."""
    ds, idx = built
    _, pidx = packed_built
    arrays = (pidx if backend == "pallas-packed" else idx).engine.arrays
    args = _padded_queries(ds, idx)
    b = Backend.from_name(backend)
    fused = ScoringEngine(arrays=arrays, backend=b, fused=True).search(
        *args, h=20, alpha=20, beta=5)
    mat = ScoringEngine(arrays=arrays, backend=b, fused=False).search(
        *args, h=20, alpha=20, beta=5)
    for got, want in zip(fused, mat):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_pass1_topk_bit_identical(built):
    """pass1_topk (the fan-out building block) takes the same fused route
    and must agree with the materialize path bit for bit."""
    from repro.core.pq import adc_lut
    ds, idx = built
    q_dims, q_vals, q_dense = _padded_queries(ds, idx)
    lut = adc_lut(q_dense, idx.engine.arrays.codebooks)
    f = ScoringEngine(arrays=idx.engine.arrays, backend=Backend.PALLAS,
                      fused=True).pass1_topk(q_dims, q_vals, lut, 100)
    m = ScoringEngine(arrays=idx.engine.arrays, backend=Backend.PALLAS,
                      fused=False).pass1_topk(q_dims, q_vals, lut, 100)
    np.testing.assert_array_equal(np.asarray(f[1]), np.asarray(m[1]))
    np.testing.assert_array_equal(np.asarray(f[0]), np.asarray(m[0]))


def test_fused_search_respects_tombstones(packed_built):
    """valid_mask tombstones must never surface from the fused pass 1, and
    the masked fused search stays bit-identical to the masked materialize
    search (c1 well under the live-row count, so no -inf filler slots)."""
    import dataclasses
    from repro.core.engine import tombstone_mask
    ds, pidx = packed_built
    n = pidx.engine.arrays.num_points
    rng = np.random.default_rng(11)
    dead = np.zeros(n, bool)
    dead[rng.choice(n, 150, replace=False)] = True
    arrays = dataclasses.replace(pidx.engine.arrays,
                                 valid_mask=tombstone_mask(n, n, dead=dead))
    args = _padded_queries(ds, pidx)
    fused = ScoringEngine(arrays=arrays, backend=Backend.PALLAS_PACKED,
                          fused=True).search(*args, h=20, alpha=20, beta=5)
    mat = ScoringEngine(arrays=arrays, backend=Backend.PALLAS_PACKED,
                        fused=False).search(*args, h=20, alpha=20, beta=5)
    for got, want in zip(fused, mat):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    s, ids = np.asarray(fused[0]), np.asarray(fused[1])
    dead_ids = set(np.flatnonzero(dead).tolist())
    assert np.isfinite(s).all()
    assert not (set(ids.ravel().tolist()) & dead_ids)
    # pass-1 candidates too, not just the final h
    ids1 = np.asarray(fused[2])
    assert not (set(ids1.ravel().tolist()) & dead_ids)


def test_fused_overflow_candidates_fall_back_in_engine(built):
    """c1 above MAX_FUSED_CANDIDATES must take the materialize route inside
    three_pass_search (static decision) and still return correct results."""
    import repro.kernels.ops as ops
    ds, idx = built
    args = _padded_queries(ds, idx)
    # alpha=100, h=20 -> c1 = 2000 > 1024: routed to materialize
    assert 100 * 20 > ops.MAX_FUSED_CANDIDATES
    big = ScoringEngine(arrays=idx.engine.arrays, backend=Backend.PALLAS,
                        fused=True).search(*args, h=20, alpha=100, beta=5)
    mat = ScoringEngine(arrays=idx.engine.arrays, backend=Backend.PALLAS,
                        fused=False).search(*args, h=20, alpha=100, beta=5)
    for got, want in zip(big, mat):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _uint8_pallas_calls(closed):
    """(max output width) of every pallas_call consuming a uint8 operand in
    the traced computation — i.e. the LUT16 code-scan kernels."""
    from repro.kernels.ops import _walk_jaxpr_eqns
    widths = []
    for eqn in _walk_jaxpr_eqns(closed.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        if not any(getattr(getattr(v, "aval", None), "dtype", None)
                   == jnp.uint8 for v in eqn.invars):
            continue
        widths.append(max(v.aval.shape[-1] for v in eqn.outvars))
    return widths


@pytest.mark.parametrize("fused", [True, False])
def test_engine_jaxpr_code_scan_output_width(built, fused):
    """Structural acceptance (ISSUE 6): in the fused engine the code-scan
    pallas_call emits only candidate-buffer-width outputs — the (Q, N) score
    matrix never crosses the kernel boundary to HBM.  The materialize engine
    trips the same detector with a full-N output, proving it detects."""
    import jax
    from repro.core.engine import three_pass_search
    from repro.kernels.lut16 import candidate_buffer_width
    ds, idx = built
    q_dims, q_vals, q_dense = _padded_queries(ds, idx)
    c1 = 200
    closed = jax.make_jaxpr(
        lambda a, d, v, q: three_pass_search(
            a, d, v, q, h=10, c1=c1, c2=40, backend=Backend.PALLAS,
            fused=fused))(idx.engine.arrays, q_dims, q_vals, q_dense)
    widths = _uint8_pallas_calls(closed)
    assert widths, "no code-scan pallas_call found in the engine jaxpr"
    n = idx.engine.arrays.num_points
    if fused:
        assert max(widths) <= candidate_buffer_width(c1) < n
    else:
        assert max(widths) >= n
