"""Product quantization: codebooks, ADC, scalar residual, whitening."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import pq


@pytest.fixture(scope="module")
def gauss():
    rng = np.random.default_rng(0)
    return rng.normal(size=(3000, 32)).astype(np.float32)


def test_encode_decode_reduces_mse(gauss):
    x = jnp.asarray(gauss)
    cb = pq.train_codebooks(x, num_subspaces=16, num_codes=16, iters=8)
    rec = pq.pq_decode(pq.pq_encode(x, cb), cb)
    mse = float(((rec - x) ** 2).mean())
    assert mse < 0.5 * float(x.var())


def test_more_codes_less_error(gauss):
    x = jnp.asarray(gauss)
    errs = []
    for l in (4, 16):
        cb = pq.train_codebooks(x, num_subspaces=16, num_codes=l, iters=8)
        rec = pq.pq_decode(pq.pq_encode(x, cb), cb)
        errs.append(float(((rec - x) ** 2).mean()))
    assert errs[1] < errs[0]


def test_adc_equals_decode_dot(gauss):
    x = jnp.asarray(gauss[:500])
    cb = pq.train_codebooks(x, num_subspaces=8, num_codes=16, iters=5)
    codes = pq.pq_encode(x, cb)
    q = jnp.asarray(np.random.default_rng(1).normal(size=(6, 32)),
                    jnp.float32)
    lut = pq.adc_lut(q, cb)
    scores = pq.adc_scores_ref(codes, lut)
    exact = q @ pq.pq_decode(codes, cb).T
    np.testing.assert_allclose(np.asarray(scores), np.asarray(exact),
                               rtol=1e-4, atol=1e-4)


def test_adc_single_query(gauss):
    x = jnp.asarray(gauss[:200])
    cb = pq.train_codebooks(x, num_subspaces=8, num_codes=16, iters=4)
    codes = pq.pq_encode(x, cb)
    q = jnp.asarray(gauss[0])
    lut = pq.adc_lut(q, cb)
    assert lut.shape == (8, 16)
    s = pq.adc_scores_ref(codes, lut)
    assert s.shape == (200,)


def test_codes_in_range(gauss):
    x = jnp.asarray(gauss[:256])
    cb = pq.train_codebooks(x, num_subspaces=4, num_codes=16, iters=3)
    codes = np.asarray(pq.pq_encode(x, cb))
    assert codes.min() >= 0 and codes.max() < 16
    assert codes.dtype == np.uint8


def test_scalar_quant_roundtrip(gauss):
    sq = pq.scalar_quantize(jnp.asarray(gauss))
    rec = np.asarray(pq.scalar_dequantize(sq))
    rng_per_dim = gauss.max(0) - gauss.min(0)
    err = np.abs(rec - gauss)
    assert (err <= rng_per_dim[None, :] / 255.0 + 1e-5).all()


def test_whitening_preserves_inner_products(gauss):
    p, p_inv_t = pq.whitening_transform(gauss[:1000])
    x = gauss[:50]
    q = gauss[50:60]
    lhs = (q @ np.asarray(p_inv_t)) @ (x @ np.asarray(p)).T
    rhs = q @ x.T
    np.testing.assert_allclose(lhs, rhs, rtol=2e-2, atol=2e-2)


def test_proposition1_rate_distortion_order(gauss):
    """More bits per dim => lower bound decreases; empirical k-means MSE
    should track the 2^{-2b/d} ordering (Prop. 1)."""
    x = jnp.asarray(gauss)
    mse = {}
    for k in (8, 16):           # 8 subspaces = 1 bit/dim, 16 = 2 bits/dim
        cb = pq.train_codebooks(x, num_subspaces=k, num_codes=16, iters=8)
        rec = pq.pq_decode(pq.pq_encode(x, cb), cb)
        mse[k] = float(((rec - x) ** 2).mean())
    assert mse[16] < mse[8]


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(0, 1000))
def test_property_adc_linear_in_query(k, seed):
    """ADC score is linear in q: score(aq) = a*score(q)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64, 8 * k)), jnp.float32)
    cb = pq.train_codebooks(x, num_subspaces=k, num_codes=8, iters=2)
    codes = pq.pq_encode(x, cb)
    q = jnp.asarray(rng.normal(size=(1, 8 * k)), jnp.float32)
    s1 = pq.adc_scores_ref(codes, pq.adc_lut(q, cb))
    s2 = pq.adc_scores_ref(codes, pq.adc_lut(2.0 * q, cb))
    np.testing.assert_allclose(np.asarray(2.0 * s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)
