"""Recall-regression harness (DESIGN.md §6, §7; ISSUE satellite).

Pinned-seed dataset (conftest ``small_hybrid``) + cached exact scores
(conftest ``exact_topk``): recall@20 of the three-pass search is asserted
against RECORDED floors in three index states — fresh batch build, streaming
delta present, and post-compaction — so future kernel or merge changes can't
silently trade recall for speed.  Observed values at recording time (2026-07,
seed 7): fresh 1.000, delta-present 0.996, post-compaction 1.000, packed
delta 0.996; floors leave ~4pp of slack for benign numeric drift.

The persistence tier (DESIGN.md §7) rides the same floors: an index
RECOVERED from a snapshot store + WAL replay must hold the delta-present
floor when the tail is replayed into a live delta, and the fresh-build
floor after a durable compaction — recovery that silently lost rows or
resurrected tombstones would show up here even if bit-level parity tests
were ever loosened.
"""

import dataclasses
import shutil

import numpy as np
import pytest

from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.serve import QueryService

PARAMS = HybridIndexParams(keep_top=48, head_dims=48, kmeans_iters=6)
H = 20
N_STREAM = 400            # rows streamed in, out of the 4000-row dataset

FLOOR_FRESH = 0.97
FLOOR_DELTA = 0.95
FLOOR_POST_COMPACTION = 0.97


def _recall(ids, exact_ids):
    return float(np.mean([len(set(ids[i]) & set(exact_ids[i])) / H
                          for i in range(ids.shape[0])]))


@pytest.fixture(scope="module")
def streamed(small_hybrid):
    """Mutable index built on 90% of the corpus with the last 10% streamed
    in — the delta-present serving state."""
    ds = small_hybrid
    n0 = ds.num_points - N_STREAM
    idx = HybridIndex.build(ds.x_sparse[:n0], ds.x_dense[:n0], PARAMS,
                            mutable=True)
    idx.insert(ds.x_sparse[n0:], ds.x_dense[n0:])
    return ds, idx


def test_fresh_build_recall_floor(small_hybrid, exact_topk):
    """Batch build on the full corpus holds the recorded recall@20 floor."""
    ds = small_hybrid
    _, exact_ids = exact_topk
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense, PARAMS)
    r = idx.search(ds.q_sparse, ds.q_dense, h=H)
    assert _recall(r.ids, exact_ids) >= FLOOR_FRESH


def test_delta_present_recall_floor(streamed, exact_topk):
    """With 10% of the corpus living in the delta shard (frozen codebooks,
    frozen residual grid, posting lists only), recall@20 must not fall
    below the recorded floor."""
    ds, idx = streamed
    _, exact_ids = exact_topk
    assert idx.mutable_state.delta.live_count == N_STREAM
    r = idx.search(ds.q_sparse, ds.q_dense, h=H)
    assert _recall(r.ids, exact_ids) >= FLOOR_DELTA


def test_post_compaction_recall_floor(streamed, exact_topk):
    """Rebuild compaction folds the delta into a fresh batch build; recall
    returns to (at least) the fresh-build floor."""
    ds, idx = streamed
    _, exact_ids = exact_topk
    idx2 = idx.compact(retrain=True)
    assert idx2.mutable_state.delta.live_count == 0
    r = idx2.search(ds.q_sparse, ds.q_dense, h=H)
    assert _recall(r.ids, exact_ids) >= FLOOR_POST_COMPACTION


@pytest.fixture(scope="module")
def durable_streamed(small_hybrid, tmp_path_factory):
    """A durable store whose WAL tail holds the last 10% of the corpus:
    built on 90%, the rest streamed through a WAL-logging service, then the
    process "dies" (service closed).  Recovery replays the tail into a live
    delta — the delta-present restart state."""
    ds = small_hybrid
    n0 = ds.num_points - N_STREAM
    root = str(tmp_path_factory.mktemp("recall-store"))
    idx = HybridIndex.build(ds.x_sparse[:n0], ds.x_dense[:n0], PARAMS,
                            mutable=True)
    svc = QueryService(index=idx, h=H, cache_size=0, auto_compact=False,
                       persist_dir=root)
    svc.insert(ds.x_sparse[n0:], ds.x_dense[n0:])
    svc.close()
    return ds, root


def test_recovered_delta_recall_floor(durable_streamed, exact_topk):
    """Recovery from a delta-present store (snapshot + WAL-replayed tail)
    holds the same recall@20 floor as the live delta-present index."""
    ds, root = durable_streamed
    _, exact_ids = exact_topk
    idx = HybridIndex.load(root)
    assert idx.mutable_state.delta.live_count == N_STREAM
    r = idx.search(ds.q_sparse, ds.q_dense, h=H)
    assert _recall(r.ids, exact_ids) >= FLOOR_DELTA


def test_recovered_post_compaction_recall_floor(durable_streamed, exact_topk,
                                                tmp_path):
    """A durable compaction cuts a snapshot; recovery from THAT snapshot
    (empty WAL tail) holds the fresh-build floor."""
    ds, root = durable_streamed
    _, exact_ids = exact_topk
    copy = str(tmp_path / "store")          # leave the shared fixture as-is
    shutil.copytree(root, copy)
    svc = QueryService(restore_from=copy, h=H, cache_size=0,
                       auto_compact=False)
    svc.compact(retrain=True)
    svc.close()
    idx = HybridIndex.load(copy)
    assert idx.mutable_state.delta.live_count == 0
    r = idx.search(ds.q_sparse, ds.q_dense, h=H)
    assert _recall(r.ids, exact_ids) >= FLOOR_POST_COMPACTION


@pytest.mark.parametrize("backend", ["ref", "pallas-packed"])
def test_merge_compaction_recall_drift(small_hybrid, exact_topk, backend):
    """Recall@20 must hold the delta-present floor after ≥5 CONSECUTIVE
    merge-compaction cycles with NO codebook retraining (DESIGN.md §6.2):
    each cycle deletes a slice of rows, re-inserts the same content under
    the same ids (so the logical corpus — and the cached exact top-20 —
    never changes), and folds with compact(retrain=False).  By the last
    cycle every streamed row has been re-encoded against the original
    frozen codebooks, the worst-case drift the merge policy allows."""
    ds = small_hybrid
    _, exact_ids = exact_topk
    params = dataclasses.replace(PARAMS, backend=backend)
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense, params, mutable=True)
    codebooks0 = idx.codebooks
    cycles = 5
    per = N_STREAM // cycles
    n0 = ds.num_points - N_STREAM
    for c in range(cycles):
        lo = n0 + c * per
        churn = list(range(lo, lo + per))
        assert idx.delete(churn) == per
        idx.insert(ds.x_sparse[lo:lo + per], ds.x_dense[lo:lo + per],
                   ids=churn)
        idx = idx.compact(retrain=False)
        assert idx.codebooks is codebooks0        # really the merge path
        assert idx.mutable_state.delta.live_count == 0
        r = idx.search(ds.q_sparse, ds.q_dense, h=H)
        rec = _recall(r.ids, exact_ids)
        assert rec >= FLOOR_DELTA, f"cycle {c}: recall {rec}"


def test_packed_delta_recall_floor(small_hybrid, exact_topk):
    """The packed 4-bit delta append path (two codes per byte) holds the
    same delta-present floor as unpacked storage."""
    ds = small_hybrid
    _, exact_ids = exact_topk
    n0 = ds.num_points - N_STREAM
    params = HybridIndexParams(keep_top=48, head_dims=48, kmeans_iters=6,
                               backend="pallas-packed")
    idx = HybridIndex.build(ds.x_sparse[:n0], ds.x_dense[:n0], params,
                            mutable=True)
    idx.insert(ds.x_sparse[n0:], ds.x_dense[n0:])
    r = idx.search(ds.q_sparse, ds.q_dense, h=H)
    assert _recall(r.ids, exact_ids) >= FLOOR_DELTA
