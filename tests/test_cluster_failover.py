"""Multi-router correctness + primary failover (ISSUE 9, DESIGN.md §8.4,
§8.7, §8.8).

The tentpole properties:

* N routers over ONE cluster are bit-identical to one router (and to the
  in-process ``QueryService``) at every step of an interleaving in which
  the routers alternate mutations — authority lives server-side under a
  ``(term, epoch)`` tag, so a delete issued through router A can never be
  resurrected by router B's stale private view;
* the cluster survives its coordinator: SIGKILL the primary, promote a
  caught-up replica under a fenced term, and every acked mutation is
  still served bit-identically — while a deposed (zombie) primary's acks
  are refused (``StaleTermError``) and a lagging replica is never
  promoted (``FailoverError``);
* the four satellite regressions: pinned corpus geometry for
  old-generation chunks, the replica overfetch budget covering the UNION
  of both dead sets, no-op mutations acking ``seq=None`` (and a real seq
  0 still observed), and ``fetch_store`` refusing a sha256-mismatched
  blob before committing CURRENT.
"""

import os
import threading
import time

import numpy as np
import pytest

from repro import persist
from repro.core.distributed import ceil16
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.core.sparse_index import sparse_queries_to_padded
from repro.data import make_hybrid_dataset
from repro.serve import QueryService
from repro.serve.cluster import (ClusterRouter, FailoverError, LocalCluster,
                                 RemoteError, ShardClient, StaleTermError,
                                 wait_ready)
from repro.serve.query_service import bucket_for, pad_rows

# -- shared tiny workload (mirrors tests/test_cluster.py) ---------------------

N0, N_POOL, NQ = 96, 140, 3
D_SPARSE, NNZ = 240, 8

_DS = make_hybrid_dataset(num_points=N_POOL, num_queries=NQ,
                          d_sparse=D_SPARSE, d_dense=16,
                          nnz_per_row=NNZ, seed=11)


def _build(n0=N0):
    return HybridIndex.build(
        _DS.x_sparse[:n0], _DS.x_dense[:n0],
        HybridIndexParams(keep_top=16, head_dims=8, kmeans_iters=2,
                          backend="ref", pq_subspaces=4), mutable=True)


def _comparator():
    return QueryService(index=_build(), h=8, cache_size=0,
                        auto_compact=False)


def _assert_parity(router, comp, session=None):
    s_r, i_r = router.search_sparse(_DS.q_sparse, _DS.q_dense,
                                    session=session)
    s_c, i_c = comp.search_sparse(_DS.q_sparse, _DS.q_dense)
    np.testing.assert_array_equal(i_r, i_c)
    np.testing.assert_array_equal(s_r, s_c)
    return s_r, i_r


def _wait_replica_seq(handle, seq, *, timeout=60.0):
    rc = ShardClient("127.0.0.1", handle.port)
    try:
        deadline = time.monotonic() + timeout
        while True:
            st = wait_ready(rc)
            if st["applied_seq"] >= seq:
                return st
            if time.monotonic() > deadline:
                raise AssertionError(f"replica stuck at {st}, want {seq}")
            time.sleep(0.05)
    finally:
        rc.close()


# -- tentpole (a): N routers, one truth ---------------------------------------

def test_multi_router_equivalence_interleaved(tmp_path):
    """Two routers — one pipelined+coalesced, one lockstep — ALTERNATE
    mutations over one cluster; after every step BOTH serve bit-identical
    results to the in-process comparator.  Covers the cross-router delete
    (no resurrection from a stale private view), the cross-router upsert,
    and a compaction driven by the OTHER router (generation flip learned
    via StaleGeneration + resync)."""
    rng = np.random.default_rng(905)
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        r_pipe = cluster.router(h=8)
        r_lock = cluster.router(h=8, lockstep=True)
        comp = _comparator()
        try:
            live = list(range(N0))
            pool = list(range(N0, N_POOL))
            for t in range(12):
                actor = r_pipe if t % 2 == 0 else r_lock
                if t == 6:                 # the OTHER router compacts
                    assert r_lock.compact() == 2
                    comp.compact()
                roll = rng.random()
                if roll < 0.5 or len(live) < 4:
                    src = pool.pop(0)
                    got = actor.insert(_DS.x_sparse[src], _DS.x_dense[src])
                    np.testing.assert_array_equal(
                        got, comp.insert(_DS.x_sparse[src],
                                         _DS.x_dense[src]))
                    live.append(int(got[0]))
                elif roll < 0.7:           # upsert a live id
                    src = pool.pop(0)
                    ext = int(rng.choice(live))
                    actor.insert(_DS.x_sparse[src], _DS.x_dense[src],
                                 ids=[ext])
                    comp.insert(_DS.x_sparse[src], _DS.x_dense[src],
                                ids=[ext])
                else:                      # delete through ONE router …
                    ext = int(rng.choice(live))
                    live.remove(ext)
                    assert actor.delete([ext]) == comp.delete([ext]) == 1
                # … and BOTH routers must agree with the comparator
                _assert_parity(r_pipe, comp)
                _assert_parity(r_lock, comp)
            # the non-compacting router learned the flip from the wire
            assert r_pipe.gen == r_lock.gen == 2
        finally:
            r_pipe.close()
            r_lock.close()


def test_concurrent_searches_coalesce_and_stay_bit_identical(tmp_path):
    """Racing searches through ONE router (the coalescer folds their
    same-shard requests into ``msearch`` frames) return exactly the
    sequential answer, and the client-level batching demux is pinned:
    entries queued behind an in-flight request ship as one frame and
    demultiplex to the same (meta, arrays) a solo call returns."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        comp = _comparator()
        try:
            want_s, want_i = comp.search_sparse(_DS.q_sparse, _DS.q_dense)
            results = [None] * 6
            def worker(j):
                results[j] = router.search_sparse(_DS.q_sparse,
                                                  _DS.q_dense)
            threads = [threading.Thread(target=worker, args=(j,))
                       for j in range(len(results))]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            for s, i in results:
                np.testing.assert_array_equal(i, want_i)
                np.testing.assert_array_equal(s, want_s)

            # client-level: two entries queued behind an in-flight search
            # coalesce into ONE msearch frame and demux correctly
            pin = router._pin()
            qd, qv = sparse_queries_to_padded(_DS.q_sparse, pin.cols,
                                              nq_max=router._nq_max)
            b = bucket_for(NQ, router.buckets)
            arrays = {
                "q_dims": pad_rows(np.atleast_2d(np.asarray(qd, np.int32)),
                                   b, fill=pin.d_active),
                "q_vals": pad_rows(np.atleast_2d(np.asarray(qv,
                                                            np.float32)), b),
                "q_dense": pad_rows(np.atleast_2d(np.asarray(_DS.q_dense,
                                                             np.float32)),
                                    b)}
            meta = {"part": "main", "gen": pin.gen, "h": 8,
                    "alpha": router.alpha, "beta": router.beta}
            c = router.scorers[0]
            ref_meta, ref_arr = c.call("search", meta, arrays)
            e1 = c.submit_search(meta, arrays)   # ships immediately
            e2 = c.submit_search(meta, arrays)   # queued behind e1
            e3 = c.submit_search(meta, arrays)   # queued behind e1
            for e in (e1, e2, e3):
                rm, ra = e.result()
                np.testing.assert_array_equal(ra["ids"], ref_arr["ids"])
                np.testing.assert_array_equal(ra["scores"],
                                              ref_arr["scores"])
            assert e1.width == 1                 # solo: a plain search
            assert e2.width == e3.width == 2     # one msearch frame
            assert {e2.slot, e3.slot} == {0, 1}
        finally:
            router.close()


# -- tentpole (b): the cluster survives its coordinator -----------------------

def test_failover_promotes_caught_up_replica(tmp_path):
    """Ingest → compact → ingest (replicas re-bootstrap onto the
    post-compaction store and keep shipping — the ``start_seq`` horizon
    regression), SIGKILL the primary, ``failover()``: every acked
    mutation is served bit-identically by the promoted primary, new
    mutations and a full cluster compaction work, and read-your-writes
    watermarks carry across the promotion."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"), num_scorers=2,
                             num_replicas=2) as cluster:
        router = cluster.router(h=8)
        comp = _comparator()
        sess = router.session()
        try:
            for src in range(N0, N0 + 4):
                np.testing.assert_array_equal(
                    router.insert(_DS.x_sparse[src], _DS.x_dense[src],
                                  session=sess),
                    comp.insert(_DS.x_sparse[src], _DS.x_dense[src]))
            assert router.compact() == 2
            comp.compact()
            # post-compaction mutations: a replica whose fetched snapshot
            # starts at replay_from_seq must accept these shipped frames
            assert router.delete([5], session=sess) == comp.delete([5]) == 1
            src = N0 + 4
            np.testing.assert_array_equal(
                router.insert(_DS.x_sparse[src], _DS.x_dense[src],
                              session=sess),
                comp.insert(_DS.x_sparse[src], _DS.x_dense[src]))
            _assert_parity(router, comp, session=sess)
            for h in cluster.replicas:
                _wait_replica_seq(h, router._last_seq)

            # mutations must go through the primary, never a follower
            rc = ShardClient("127.0.0.1", cluster.replicas[0].port)
            try:
                with pytest.raises(RemoteError, match="NotPrimary"):
                    rc.call("delete",
                            arrays={"ids": np.asarray([5], np.int64)})
            finally:
                rc.close()

            cluster.kill_primary()
            new_term = router.failover()
            assert new_term == 2
            st = router.status()
            assert st["promotions"] == 1 and st["term"] == 2

            # every acked mutation survived the coordinator, bit for bit
            _assert_parity(router, comp, session=sess)

            # the promoted primary takes new mutations + a full compaction
            src = N0 + 5
            np.testing.assert_array_equal(
                router.insert(_DS.x_sparse[src], _DS.x_dense[src],
                              session=sess),
                comp.insert(_DS.x_sparse[src], _DS.x_dense[src]))
            assert router.delete([7], session=sess) == comp.delete([7]) == 1
            _assert_parity(router, comp, session=sess)
            assert sess.watermark == router._last_seq
            assert router.compact() == 3
            comp.compact()
            _assert_parity(router, comp, session=sess)
        finally:
            router.close()


def test_failover_refuses_lagging_replica(tmp_path):
    """A replica that has NOT applied every acked seq is never promoted —
    ``failover()`` raises instead of silently losing acked mutations."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"), num_scorers=2,
                             num_replicas=1) as cluster:
        router = cluster.router(h=8)
        try:
            router.replicas[0].call("fault", {"mode": "pause_shipping"})
            router.insert(_DS.x_sparse[N0], _DS.x_dense[N0])   # acked …
            cluster.kill_primary()
            with pytest.raises(FailoverError, match="lose acked"):
                router.failover()              # … so the laggard loses
        finally:
            router.close()


def test_zombie_primary_acks_refused(tmp_path):
    """Promote a replica while the old primary is STILL ALIVE (the
    partition case): a router that has seen the new term refuses the
    zombie's mutation ack with ``StaleTermError`` — nothing it says can
    move watermarks or the cached liveness view."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"), num_scorers=2,
                             num_replicas=1) as cluster:
        r1 = cluster.router(h=8)
        try:
            r1.insert(_DS.x_sparse[N0], _DS.x_dense[N0])
            _wait_replica_seq(cluster.replicas[0], r1._last_seq)
            promoted_port = cluster.replicas[0].port
            assert r1.failover() == 2          # old primary NOT killed
            # a second router bootstrapped from the new primary knows
            # term 2; point it at the zombie and let the zombie answer
            r2 = ClusterRouter(f"127.0.0.1:{promoted_port}",
                               [s.addr for s in cluster.scorers], [])
            try:
                assert r2.term == 2
                r2.primary.close()
                r2.primary = ShardClient("127.0.0.1", cluster.primary.port)
                before = r2._last_seq
                with pytest.raises(StaleTermError, match="deposed"):
                    r2.insert(_DS.x_sparse[N0 + 1], _DS.x_dense[N0 + 1])
                assert r2._last_seq == before  # the ack moved nothing
            finally:
                r2.close()
        finally:
            r1.close()


# -- satellite regressions ----------------------------------------------------

def test_search_budgets_from_pinned_geometry(tmp_path):
    """A chunk budgets its ragged slice sizes from the corpus size PINNED
    together with the generation — a racing resync/compaction updating
    the router's LIVE ``_num_points`` between pin and dispatch must not
    re-budget the chunk's fetch depths from the wrong corpus."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        comp = _comparator()
        try:
            for src in range(N0, N0 + 10):
                router.insert(_DS.x_sparse[src], _DS.x_dense[src])
                comp.insert(_DS.x_sparse[src], _DS.x_dense[src])
            pin = router._pin()                # gen 1, num_points == N0
            assert pin.gen == 1 and pin.num_points == N0
            # simulate the racing thread: live geometry moves on after the
            # pin (what a concurrent resync against a compacted cluster
            # does), while this chunk is still in flight
            router._num_points = N0 + 37
            seen = []
            orig = router._slice_sizes
            router._slice_sizes = lambda n: (seen.append(n) or orig(n))
            want_s, want_i = comp.search_sparse(_DS.q_sparse, _DS.q_dense)
            qd, qv = sparse_queries_to_padded(_DS.q_sparse, pin.cols,
                                              nq_max=router._nq_max)
            s, i = router._search_pinned(
                pin, np.atleast_2d(np.asarray(qd, np.int32)),
                np.atleast_2d(np.asarray(qv, np.float32)),
                np.atleast_2d(np.asarray(_DS.q_dense, np.float32)),
                None, None, None, None)
            assert seen == [N0]                # pinned n, not the live one
            np.testing.assert_array_equal(i, want_i)
            np.testing.assert_array_equal(s, want_s)
        finally:
            router.close()


def test_direct_primary_single_query_path(tmp_path):
    """Single-query chunks take the adaptive fan-out cutoff (DESIGN.md
    §8.8): ONE ``part="full"`` primary read, bit-identical to the
    in-process service with live tombstones and delta upserts in play;
    batch chunks and the lockstep (pre-batching) router keep the full
    scatter-gather; a compaction flipped by ANOTHER router gets the
    server's StaleGeneration refusal and re-pins instead of serving
    frozen pre-flip state."""
    comp = _comparator()
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        r_lock = cluster.router(h=8, lockstep=True)
        try:
            for j in range(6):
                router.insert(_DS.x_sparse[N0 + j], _DS.x_dense[N0 + j])
                comp.insert(_DS.x_sparse[N0 + j], _DS.x_dense[N0 + j])
            assert router.delete([3, N0 + 2]) == \
                comp.delete([3, N0 + 2]) == 2
            for qi in range(2):
                qs = _DS.q_sparse[qi:qi + 1]
                qd = _DS.q_dense[qi:qi + 1]
                s_r, i_r = router.search_sparse(qs, qd)
                s_c, i_c = comp.search_sparse(qs, qd)
                np.testing.assert_array_equal(i_r, i_c)
                np.testing.assert_array_equal(s_r, s_c)
            assert router.stats["direct_reads"] == 2
            _assert_parity(router, comp)       # NQ=3 bucket: fans out
            assert router.stats["direct_reads"] == 2
            s_l, i_l = r_lock.search_sparse(_DS.q_sparse[:1],
                                            _DS.q_dense[:1])
            s_c, i_c = comp.search_sparse(_DS.q_sparse[:1],
                                          _DS.q_dense[:1])
            np.testing.assert_array_equal(i_l, i_c)
            np.testing.assert_array_equal(s_l, s_c)
            assert r_lock.stats["direct_reads"] == 0
            # the OTHER router compacts: the stale pin's direct read must
            # re-pin, not serve generation-1 rows under flipped geometry
            assert r_lock.compact() == 2
            comp.compact()
            s_r, i_r = router.search_sparse(_DS.q_sparse[:1],
                                            _DS.q_dense[:1])
            s_c, i_c = comp.search_sparse(_DS.q_sparse[:1],
                                          _DS.q_dense[:1])
            np.testing.assert_array_equal(i_r, i_c)
            np.testing.assert_array_equal(s_r, s_c)
            assert router.stats["stale_retries"] >= 1
            assert router.gen == 2
        finally:
            r_lock.close()
            router.close()
    comp.close()


def test_replica_budget_covers_fully_deleted(tmp_path):
    """The follower-read overfetch budget covers the UNION of the cached
    dead sets: 20 delta-only deletes leave ``main_dead`` empty but the
    merge still drops them from the replica's parts, so the fetch depth
    must be ``h + ceil16(20)``, not ``h``."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"), num_scorers=2,
                             num_replicas=1) as cluster:
        router = cluster.router(h=8, prefer_replica=True,
                                replica_max_lag=1_000_000)
        comp = _comparator()
        try:
            ids = router.insert(_DS.x_sparse[N0:N0 + 20],
                                _DS.x_dense[N0:N0 + 20])
            comp.insert(_DS.x_sparse[N0:N0 + 20], _DS.x_dense[N0:N0 + 20])
            assert router.delete(ids) == comp.delete(ids) == 20
            _wait_replica_seq(cluster.replicas[0], router._last_seq)
            pin = router._pin()
            assert not pin.main_dead and len(pin.fully_deleted) == 20
            depths = []
            orig = router.replicas[0].call
            def spy(cmd, meta=None, arrays=None, **kw):
                if cmd == "search":
                    depths.append(int(meta["h"]))
                return orig(cmd, meta, arrays, **kw)
            router.replicas[0].call = spy
            _assert_parity(router, comp)
            assert router.stats["replica_reads"] == NQ
            assert depths and all(d == 8 + ceil16(20) for d in depths)
        finally:
            router.close()


def test_noop_delete_acks_seq_none(tmp_path):
    """A delete that kills nothing logs nothing: its ack carries
    ``seq=None`` and moves neither the router's last-seq nor the session
    watermark — while a REAL seq of 0 (the falsy-zero regression) is
    still observed, and a real mutation's watermark equals its seq."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        sess = router.session()
        try:
            before = router._last_seq
            assert router.delete([999_999], session=sess) == 0
            assert sess.watermark == -1 and router._last_seq == before
            # seq is gated on ``is not None`` — a legitimate 0 must fold
            a = router._auth[router.gen]
            router._ack({"seq": 0, "gen": router.gen, "epoch": a.epoch,
                         "term": a.term, "delta_live": a.delta_live},
                        main_killed=(), session=sess)
            assert sess.watermark == 0
            assert router.delete([3], session=sess) == 1
            assert sess.watermark == router._last_seq > before
        finally:
            router.close()


def test_fetch_store_rejects_corrupt_blob(tmp_path):
    """Snapshot distribution verifies every fetched blob against the
    manifest's recorded sha256 BEFORE committing CURRENT: a bit-flipped
    source blob fails the fetch and leaves no committed-looking store."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=1) as cluster:
        c = ShardClient("127.0.0.1", cluster.primary.port)
        try:
            dst = str(tmp_path / "copy")
            c.fetch_store(dst)
            assert os.path.exists(os.path.join(dst, "CURRENT"))
            rec = persist.recover(dst)         # committed AND recoverable
            rec.durability.close()

            # flip one byte of a snapshot leaf at the source
            store = os.path.join(str(tmp_path / "c"), "store")
            snap = persist.read_current(store)["snapshot"]
            import json
            with open(os.path.join(store, snap, "manifest.json")) as f:
                leaf = next(iter(json.load(f)["leaves"].values()))
            blob = os.path.join(store, snap, leaf["file"])
            raw = bytearray(open(blob, "rb").read())
            raw[len(raw) // 2] ^= 0xFF
            with open(blob, "wb") as f:
                f.write(raw)

            dst2 = str(tmp_path / "copy2")
            with pytest.raises(ValueError, match="sha256"):
                c.fetch_store(dst2)
            assert not os.path.exists(os.path.join(dst2, "CURRENT"))
        finally:
            c.close()
