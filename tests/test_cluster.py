"""Cross-host serving tier (repro/serve/cluster, DESIGN.md §8).

The headline property (ISSUE 8 acceptance): a REAL local cluster —
subprocess shard servers on loopback sockets, one primary + row-sliced
scorers (+ replicas) — serves search results bit-identical (ids AND
scores) to the in-process ``QueryService`` on the same state, across
backends {ref, pallas, pallas-packed} × odd/even PQ subspace counts, at
EVERY point of a random insert/upsert/delete interleaving, through
mid-run and final compactions.

Plus the fault matrix the tier must survive WITHOUT serving wrong
answers: torn/corrupted frames healed by checksum + reconnect; a scorer
killed -9 mid-stream failed over to a caught-up replica (bit-identical)
or surfaced as an explicit ``DegradedResultError`` — never a silently
truncated top-k; a replica killed mid-ingest recovering from its local
snapshot + shipped WAL tail to the exact applied seq; read-your-writes
watermarks excluding stale replicas; and a lagging replica's stale
tombstone view never resurrecting a deleted id (the per-part drop
contract of ``merge_topk_host``, unit-pinned below).
"""

import threading
import time

import numpy as np
import pytest

from repro.core.distributed import merge_topk_host, split_index_arrays
from repro.core.engine import Backend, ScoringEngine
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.core.sparse_index import sparse_queries_to_padded
from repro.core.streaming import fanout_search
from repro.data import make_hybrid_dataset
from repro.serve import QueryService
from repro.serve.cluster import (DegradedResultError, LocalCluster,
                                 ShardClient, wait_ready)

# -- shared tiny workload ----------------------------------------------------

N0, N_POOL, NQ = 96, 140, 3
D_SPARSE, NNZ = 240, 8

_DS_CACHE = {}


def _dataset(d_dense=16):
    if d_dense not in _DS_CACHE:
        _DS_CACHE[d_dense] = make_hybrid_dataset(
            num_points=N_POOL, num_queries=NQ, d_sparse=D_SPARSE,
            d_dense=d_dense, nnz_per_row=NNZ, seed=11)
    return _DS_CACHE[d_dense]


_DS = _dataset()


def _params(backend, k):
    return HybridIndexParams(keep_top=16, head_dims=8, kmeans_iters=2,
                             backend=backend, pq_subspaces=k)


def _build(backend="ref", k=4, n0=N0, mutable=True, ds=None):
    ds = _DS if ds is None else ds
    return HybridIndex.build(ds.x_sparse[:n0], ds.x_dense[:n0],
                             _params(backend, k), mutable=mutable)


def _comparator(backend="ref", k=4, ds=None):
    return QueryService(index=_build(backend, k, ds=ds), h=8,
                        cache_size=0, auto_compact=False)


def _assert_parity(router, comp, session=None, ds=None):
    ds = _DS if ds is None else ds
    s_r, i_r = router.search_sparse(ds.q_sparse, ds.q_dense,
                                    session=session)
    s_c, i_c = comp.search_sparse(ds.q_sparse, ds.q_dense)
    np.testing.assert_array_equal(i_r, i_c)
    np.testing.assert_array_equal(s_r, s_c)
    return s_r, i_r


def _wait_replica_seq(handle, seq, *, timeout=60.0):
    """Poll a replica's status until it has applied ``seq``."""
    rc = ShardClient("127.0.0.1", handle.port)
    try:
        deadline = time.monotonic() + timeout
        while True:
            st = wait_ready(rc)
            if st["applied_seq"] >= seq:
                return st
            if time.monotonic() > deadline:
                raise AssertionError(f"replica stuck at {st}, want {seq}")
            time.sleep(0.05)
    finally:
        rc.close()


# -- the equivalence property (the acceptance criterion) ----------------------

@pytest.mark.parametrize("backend,k", [
    ("ref", 4), ("ref", 3), ("pallas", 4), ("pallas", 3),
    ("pallas-packed", 4), ("pallas-packed", 3)])
def test_cluster_equivalence_random_interleaving(tmp_path, backend, k):
    """RPC results == in-process results, bit for bit, after EVERY step of
    a random insert/upsert/delete interleaving, and through a mid-run and
    a final cluster-orchestrated compaction."""
    rng = np.random.default_rng(1000 + 10 * len(backend) + k)
    ds = _dataset(16 if k % 2 == 0 else 12)   # d_dense % K == 0
    with LocalCluster.launch(_build(backend, k, ds=ds),
                             str(tmp_path / "c"),
                             num_scorers=2, backend=backend) as cluster:
        router = cluster.router(h=8)
        comp = _comparator(backend, k, ds=ds)
        try:
            live = list(range(N0))
            pool = list(range(N0, N_POOL))
            for t in range(14):
                if t == 7:                       # mid-run compaction
                    g = router.compact()
                    comp.compact()
                    assert g == 2
                roll = rng.random()
                if roll < 0.55 or len(live) < 4:
                    src = pool.pop(0)
                    got_r = router.insert(ds.x_sparse[src],
                                          ds.x_dense[src])
                    got_c = comp.insert(ds.x_sparse[src],
                                        ds.x_dense[src])
                    np.testing.assert_array_equal(got_r, got_c)
                    live.append(int(got_r[0]))
                elif roll < 0.75:                # upsert a live id
                    src = pool.pop(0)
                    ext = int(rng.choice(live))
                    router.insert(ds.x_sparse[src], ds.x_dense[src],
                                  ids=[ext])
                    comp.insert(ds.x_sparse[src], ds.x_dense[src],
                                ids=[ext])
                else:
                    ext = int(rng.choice(live))
                    assert router.delete([ext]) == comp.delete([ext]) == 1
                    live.remove(ext)
                # bit-identical EVERY step
                _assert_parity(router, comp, ds=ds)
            router.compact()
            comp.compact()
            _assert_parity(router, comp, ds=ds)
            assert router.stats["queries"] > 0
            assert router.stats["degraded"] == 0
        finally:
            router.close()
            comp.close()


# -- fault injection ----------------------------------------------------------

def test_cluster_fault_matrix(tmp_path):
    """One cluster, the whole fault matrix in sequence: replica catch-up,
    torn/corrupt frame heal, stale-tombstone non-resurrection, RYW
    watermark exclusion, replica kill + restart mid-ingest recovering to
    the exact applied seq, scorer kill -9 failing over bit-identically,
    and finally an explicit degraded error once nothing can serve."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"), num_scorers=2,
                             num_replicas=1) as cluster:
        r1 = cluster.router(h=8, replica_max_lag=10 ** 9)
        comp = _comparator()
        repl = ShardClient("127.0.0.1", cluster.replicas[0].port)

        # seed mutations: inserts, an upsert, deletes (mirrored)
        got = r1.insert(_DS.x_sparse[N0:N0 + 6], _DS.x_dense[N0:N0 + 6])
        got_c = comp.insert(_DS.x_sparse[N0:N0 + 6], _DS.x_dense[N0:N0 + 6])
        np.testing.assert_array_equal(got, got_c)
        r1.insert(_DS.x_sparse[N0 + 6], _DS.x_dense[N0 + 6],
                  ids=[int(got[0])])
        comp.insert(_DS.x_sparse[N0 + 6], _DS.x_dense[N0 + 6],
                    ids=[int(got[0])])
        assert r1.delete([3, int(got[1])]) == 2
        assert comp.delete([3, int(got_c[1])]) == 2

        # 1) replica catches up to the primary's exact last seq
        st = _wait_replica_seq(cluster.replicas[0], r1._last_seq)
        assert st["applied_seq"] == r1._last_seq
        assert st["delta_live"] == 5
        _assert_parity(r1, comp)

        # 2) corrupted frame: detected by checksum, healed by reconnect,
        #    bits unchanged; a connection dropped mid-exchange heals too
        sc = ShardClient("127.0.0.1", cluster.scorers[0].port)
        for mode in ("corrupt_next", "close_next"):
            sc.call("fault", {"mode": mode})
            before = sum(c.reconnects for c in r1.scorers)
            _assert_parity(r1, comp)
            assert sum(c.reconnects for c in r1.scorers) == before + 1
        sc.close()

        # 3) lagging replica must NOT resurrect a deleted id: pause
        #    shipping, delete a main-generation id, force the replica
        #    route — the router's authoritative tombstone view drops it
        r2 = cluster.router(h=8, prefer_replica=True,
                            replica_max_lag=10 ** 9)
        repl.call("fault", {"mode": "pause_shipping"})
        assert r2.delete([7]) == comp.delete([7]) == 1
        s_r, i_r = _assert_parity(r2, comp)
        assert 7 not in set(i_r.ravel().tolist())
        assert r2.stats["replica_reads"] >= 1

        # 4) read-your-writes: a session write moves the watermark past
        #    the paused replica, which is excluded until it catches up
        sess = r2.session()
        r2.insert(_DS.x_sparse[N0 + 7], _DS.x_dense[N0 + 7], session=sess)
        comp.insert(_DS.x_sparse[N0 + 7], _DS.x_dense[N0 + 7])
        assert sess.watermark == r2._last_seq
        reads0 = r2.stats["replica_reads"]
        _assert_parity(r2, comp, session=sess)
        assert r2.stats["excluded_stale"] >= 1
        assert r2.stats["replica_reads"] == reads0   # replica NOT used
        repl.call("fault", {"mode": "resume_shipping"})
        _wait_replica_seq(cluster.replicas[0], r2._last_seq)
        _assert_parity(r2, comp, session=sess)
        assert r2.stats["replica_reads"] > reads0    # now eligible again
        r2.close()

        # 5) replica killed mid-ingest: restarts from its LOCAL snapshot +
        #    shipped WAL tail, resumes shipping, catches up to the exact
        #    primary seq, and serves a bit-identical follower read
        for j in range(4):
            r1.insert(_DS.x_sparse[N0 + 8 + j], _DS.x_dense[N0 + 8 + j])
            comp.insert(_DS.x_sparse[N0 + 8 + j], _DS.x_dense[N0 + 8 + j])
        cluster.kill_replica(0)
        repl.close()
        for j in range(3):
            r1.insert(_DS.x_sparse[N0 + 12 + j], _DS.x_dense[N0 + 12 + j])
            comp.insert(_DS.x_sparse[N0 + 12 + j],
                        _DS.x_dense[N0 + 12 + j])
        cluster.restart_replica(0)
        st = _wait_replica_seq(cluster.replicas[0], r1._last_seq)
        assert st["applied_seq"] == r1._last_seq
        r3 = cluster.router(h=8, prefer_replica=True,
                            replica_max_lag=10 ** 9)
        _assert_parity(r3, comp)
        assert r3.stats["replica_reads"] >= 1
        r3.close()

        # 6) scorer killed -9 mid-stream: fail over to the caught-up
        #    replica, bit-identical — never a silently truncated top-k
        rf = cluster.router(h=8, replica_max_lag=10 ** 9)
        cluster.kill_scorer(0)
        _assert_parity(rf, comp)
        assert rf.stats["failovers"] >= 1
        assert rf.stats["replica_reads"] >= 1

        # 7) replica killed too: EXPLICIT degraded error
        cluster.kill_replica(0)
        with pytest.raises(DegradedResultError, match="refusing"):
            rf.search_sparse(_DS.q_sparse, _DS.q_dense)
        assert rf.stats["degraded"] == 1
        rf.close()
        r1.close()
        comp.close()


def test_cluster_degraded_without_replicas(tmp_path):
    """No replicas configured: a dead scorer surfaces immediately as
    ``DegradedResultError`` (the no-silent-truncation contract)."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        comp = _comparator()
        _assert_parity(router, comp)
        cluster.kill_scorer(1)
        with pytest.raises(DegradedResultError, match="refusing"):
            router.search_sparse(_DS.q_sparse, _DS.q_dense)
        router.close()
        comp.close()


# -- concurrent mutations + background compaction -----------------------------

def test_cluster_concurrent_mutations_and_compaction(tmp_path):
    """Searches stay invariant-clean while a mutator thread inserts and
    deletes and a background thread runs a cluster compaction: no
    duplicate ids in a result row, no id served after its delete was
    acked, no exceptions (generation flips retry internally)."""
    with LocalCluster.launch(_build(), str(tmp_path / "c"),
                             num_scorers=2) as cluster:
        router = cluster.router(h=8)
        lock = threading.Lock()
        deleted_acked: set[int] = set()
        errors: list[BaseException] = []
        done = threading.Event()

        def mutate():
            try:
                live = []
                for t in range(12):
                    if t % 3 == 2 and live:
                        ext = live.pop(0)
                        router.delete([ext])
                        with lock:
                            deleted_acked.add(ext)
                    else:
                        got = router.insert(_DS.x_sparse[N0 + t],
                                            _DS.x_dense[N0 + t])
                        live.append(int(got[0]))
                    time.sleep(0.02)
            except BaseException as e:
                errors.append(e)
            finally:
                done.set()

        def compact_bg():
            try:
                time.sleep(0.15)
                router.compact()
            except BaseException as e:
                errors.append(e)

        threads = [threading.Thread(target=mutate),
                   threading.Thread(target=compact_bg)]
        for th in threads:
            th.start()
        searches = 0
        while not done.is_set() or searches < 6:
            with lock:
                dead_before = set(deleted_acked)
            s, ids = router.search_sparse(_DS.q_sparse, _DS.q_dense)
            searches += 1
            for row_s, row_i in zip(s, ids):
                valid = row_i[row_i >= 0]
                assert len(set(valid.tolist())) == len(valid)  # no dups
                assert not (set(valid.tolist()) & dead_before), \
                    (valid, dead_before)
                assert np.isfinite(row_s[row_i >= 0]).all()
        for th in threads:
            th.join()
        assert not errors, errors
        assert router.gen == 2                   # the compaction landed
        router.close()


# -- merge / split unit regressions (the contracts the tier rests on) ---------

def test_merge_topk_host_per_part_tombstone_views():
    """REGRESSION (ISSUE 8 satellite): ``filtered`` may be an explicit
    per-part drop list — a lagging replica's part gets the CALLER's
    authoritative tombstones, not one shared view, so its stale state can
    never resurrect a deleted id."""
    main = (np.asarray([[5.0, 4.0, 3.0]]), np.asarray([[10, 11, 12]]))
    delta = (np.asarray([[4.5, 2.0]]), np.asarray([[13, 14]]))
    # shared-view semantics: drop_ids hits every filtered part
    s, i = merge_topk_host([(main[0], main[1], True),
                            (delta[0], delta[1], False)],
                           3, drop_ids={11})
    np.testing.assert_array_equal(i, [[10, 13, 12]])
    np.testing.assert_array_equal(s, [[5.0, 4.5, 3.0]])
    # per-part view: 11 dropped from the main part only, 14 from delta's
    s, i = merge_topk_host([(main[0], main[1], [11]),
                            (delta[0], delta[1], [14])], 3)
    np.testing.assert_array_equal(i, [[10, 13, 12]])
    np.testing.assert_array_equal(s, [[5.0, 4.5, 3.0]])
    # a drop leaving fewer than h live candidates pads with id -1
    s, i = merge_topk_host([(main[0], main[1], [10, 11, 12])], 3)
    np.testing.assert_array_equal(i, [[-1, -1, -1]])
    assert not np.isfinite(s).any()


def test_merge_topk_host_dedup_upserts():
    """``dedup_upserts=True``: an id live in an unfiltered (delta) part
    proves its main copies are superseded — they are dropped from every
    filtered part even when absent from the drop lists (the cross-
    transport upsert race, DESIGN.md §8.2)."""
    main = (np.asarray([[5.0, 4.0]]), np.asarray([[10, 11]]))
    delta = (np.asarray([[4.5, -np.inf]]), np.asarray([[10, 12]]))
    s, i = merge_topk_host([(main[0], main[1], True),
                            (delta[0], delta[1], False)],
                           2, dedup_upserts=True)
    # main's 10 is dropped (delta serves the upserted copy at 4.5);
    # delta's tombstoned 12 never surfaces
    np.testing.assert_array_equal(i, [[10, 11]])
    np.testing.assert_array_equal(s, [[4.5, 4.0]])
    # without the flag the stale main copy would win — the race the
    # cluster path must close
    s0, i0 = merge_topk_host([(main[0], main[1], True),
                              (delta[0], delta[1], False)], 2)
    np.testing.assert_array_equal(i0, [[10, 10]])


def test_split_index_arrays_ragged_bit_identical():
    """A ragged ceil-split (first ``n % S`` shards one row longer) fan-out
    merges bit-identically to the unsharded search — the property that
    lets the cluster tier shard a compacted corpus of arbitrary size."""
    idx = _build(n0=95, mutable=False)
    with pytest.raises(ValueError, match=r"equal shards.*ragged=True"):
        split_index_arrays(idx.engine.arrays, 7)
    with pytest.raises(ValueError, match="equal shards"):
        split_index_arrays(idx.engine.arrays, 96)     # > n: no ragged hint
    shards, offsets = split_index_arrays(idx.engine.arrays, 7, ragged=True)
    sizes = [s.num_points for s in shards]
    assert sizes == [14, 14, 14, 14, 13, 13, 13] and sum(sizes) == 95
    np.testing.assert_array_equal(offsets, np.cumsum([0] + sizes[:-1]))
    engines = [ScoringEngine(arrays=a, backend=Backend.REF) for a in shards]
    qd, qv = sparse_queries_to_padded(_DS.q_sparse, idx.cols,
                                      nq_max=idx.params.nq_max)
    p = idx.params
    s_f, i_f = fanout_search(engines, [8] * 7, offsets,
                             np.asarray(idx.pi), None, None, set(),
                             qd, qv, _DS.q_dense, h=8,
                             alpha=p.alpha, beta=p.beta)
    r = idx.search(_DS.q_sparse, _DS.q_dense, h=8)
    np.testing.assert_array_equal(i_f, np.asarray(r.ids))
    np.testing.assert_array_equal(s_f, np.asarray(r.scores))
