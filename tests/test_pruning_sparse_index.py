"""Pruning split (Eq. 6/7) and the TPU sparse scorers."""

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
from _hypothesis_compat import given, settings, strategies as st

from repro.core import pruning
from repro.core import sparse_index as si


def test_split_is_partition(powerlaw_sparse):
    x = powerlaw_sparse
    ps = pruning.prune_split(x, keep_top=16)
    diff = np.abs(ps.index + ps.residual - x)
    assert diff.max() < 1e-6
    # no entry in both
    overlap = ps.index.multiply(ps.residual)
    assert overlap.nnz == 0


def test_keep_top_respected(powerlaw_sparse):
    ps = pruning.prune_split(powerlaw_sparse, keep_top=16)
    per_dim = np.diff(ps.index.tocsc().indptr)
    # ties at the threshold may exceed keep_top slightly; bound loosely
    assert per_dim.max() <= 16 + 8


def test_inverted_index_scoring_exact(powerlaw_sparse):
    x = powerlaw_sparse
    ps = pruning.prune_split(x, keep_top=32)
    cols, xc = si.build_compact_columns(ps.index)
    inv = si.build_padded_inverted_index(xc)
    rng = np.random.default_rng(0)
    q = sp.csr_matrix(
        (rng.random((4, x.shape[1])) < 0.05).astype(np.float32))
    qd, qv = si.sparse_queries_to_padded(q, cols, nq_max=64)
    scores = np.asarray(si.score_inverted(inv, jnp.asarray(qd),
                                          jnp.asarray(qv)))
    exact = np.asarray((q @ ps.index.T).todense())
    np.testing.assert_allclose(scores, exact, rtol=1e-5, atol=1e-5)


def test_head_block_plus_tail_equals_full(powerlaw_sparse):
    """Head tile block + tail inverted index must reproduce the full pruned
    score exactly (the two TPU paths partition the dims)."""
    from repro.core.hybrid import HybridIndex, HybridIndexParams
    from repro.core.sparse_index import queries_head_dense, score_head_ref

    x = powerlaw_sparse
    ps = pruning.prune_split(x, keep_top=32)
    cols, xc = si.build_compact_columns(x)
    idx_c = x.tocsc()[:, cols.global_ids].tocsr()
    # emulate hybrid.build's split
    from repro.core.cache_sort import dimension_activity
    pruned_c = ps.index.tocsc()[:, cols.global_ids].tocsr()
    act = dimension_activity(pruned_c)
    head_dims = np.sort(np.argsort(-act)[:16]).astype(np.int32)
    head = si.build_tile_sparse_head(pruned_c, head_dims, block_rows=64,
                                     block_cols=64)
    tail = pruned_c.tolil()
    tail[:, head_dims] = 0
    tail = tail.tocsr()
    tail.eliminate_zeros()
    inv = si.build_padded_inverted_index(tail)

    rng = np.random.default_rng(5)
    q = sp.csr_matrix((rng.random((3, x.shape[1])) < 0.05).astype(np.float32))
    qd, qv = si.sparse_queries_to_padded(q, cols, nq_max=64)
    q_head = queries_head_dense(qd, qv, np.asarray(head.head_dims),
                                head.block.shape[1])
    total = (np.asarray(si.score_inverted(inv, jnp.asarray(qd),
                                          jnp.asarray(qv)))
             + np.asarray(score_head_ref(head, jnp.asarray(q_head)))[
                 :, : x.shape[0]])
    exact = np.asarray((q @ ps.index.T).todense())
    np.testing.assert_allclose(total, exact, rtol=1e-4, atol=1e-4)


def test_padded_rows_scoring(powerlaw_sparse):
    x = powerlaw_sparse
    cols, xc = si.build_compact_columns(x)
    rows = si.build_padded_rows(xc)
    rng = np.random.default_rng(2)
    q = sp.csr_matrix((rng.random((2, x.shape[1])) < 0.05).astype(np.float32))
    qd, qv = si.sparse_queries_to_padded(q, cols, nq_max=64)
    # dense query over compact cols + pad slot
    qdense = np.zeros((2, cols.num_active + 1), np.float32)
    for i in range(2):
        for j, v in zip(qd[i], qv[i]):
            if j < cols.num_active:
                qdense[i, j] += v
    cand = jnp.asarray(rng.integers(0, x.shape[0], size=(2, 10)))
    got = np.asarray(si.score_rows(rows, cand, jnp.asarray(qdense)))
    exact_all = np.asarray((q[:, cols.global_ids] @ xc.T).todense())
    want = np.take_along_axis(exact_all, np.asarray(cand), axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 30), st.integers(0, 5000))
def test_property_prune_monotone(keep, seed):
    """Larger keep_top => index keeps at least as many entries."""
    rng = np.random.default_rng(seed)
    x = sp.csr_matrix((rng.random((100, 40)) < 0.2).astype(np.float32)
                      * rng.random((100, 40)).astype(np.float32))
    a = pruning.prune_split(x, keep_top=keep).index.nnz
    b = pruning.prune_split(x, keep_top=keep + 5).index.nnz
    assert b >= a
