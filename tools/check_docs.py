#!/usr/bin/env python
"""Markdown link-and-reference checker (CI gate).

Four classes of dangling reference have bitten (or would bite) this repo:

1. source docstrings citing ``DESIGN.md §<section>`` for sections (or a
   whole file) that don't exist — 16 files cited DESIGN.md before it was
   written;
2. intra-repo markdown links (``[text](relative/path)``) whose target file
   was renamed or never committed;
3. markdown-referenced ``examples/*.py`` files that don't exist — README
   quickstart commands live inside code fences, which the link check
   deliberately skips, so renamed examples rotted silently;
4. public ``serve/`` or ``persist/`` API without docstrings — the serving
   layer is the documented interface of DESIGN.md §5 and the durability
   layer of DESIGN.md §7, so every public function/class there must say
   what it does.

This script fails (exit 1) on any.  Zero dependencies; run from anywhere:

    python tools/check_docs.py
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", ".claude",
             "node_modules", ".venv"}

# "DESIGN.md §2" — tolerant of string-literal breaks across source lines:
# `"...(DESIGN.md "\n    "§Arch-applicability)"` has `" \n "` in between.
# Dots only bind as sub-section numbers (§2.1), never sentence punctuation.
_SECTION = r"§[A-Za-z0-9_-]+(?:\.\d+)*"
CITE_RE = re.compile(rf"DESIGN\.md[\s\"']*({_SECTION})?")
HEADING_SECTION_RE = re.compile(rf"^#+\s.*?({_SECTION})", re.M)
MD_LINK_RE = re.compile(r"\[[^\]^\n]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```.*?^```", re.M | re.S)


def _iter_files(root: Path, suffixes: tuple[str, ...]):
    for p in sorted(root.rglob("*")):
        if any(part in SKIP_DIRS for part in p.parts):
            continue
        if p.is_file() and p.suffix in suffixes:
            yield p


def check_design_citations(errors: list[str]) -> None:
    design = REPO / "DESIGN.md"
    sections: set[str] = set()
    if design.exists():
        sections = set(HEADING_SECTION_RE.findall(design.read_text()))
    for path in _iter_files(REPO, (".py",)):
        text = path.read_text(errors="replace")
        for m in CITE_RE.finditer(text):
            rel = path.relative_to(REPO)
            line = text.count("\n", 0, m.start()) + 1
            if not design.exists():
                errors.append(f"{rel}:{line}: cites DESIGN.md but the file "
                              "does not exist")
                continue
            sec = m.group(1)
            if sec is not None and sec not in sections:
                errors.append(
                    f"{rel}:{line}: cites DESIGN.md {sec} but DESIGN.md has "
                    f"no heading with {sec} (has: {' '.join(sorted(sections))})")


def check_markdown_links(errors: list[str]) -> None:
    for path in _iter_files(REPO, (".md",)):
        text = FENCE_RE.sub("", path.read_text(errors="replace"))
        for m in MD_LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            resolved = (path.parent / target).resolve()
            if not resolved.exists():
                rel = path.relative_to(REPO)
                errors.append(f"{rel}: link target does not exist: {target}")


EXAMPLE_RE = re.compile(r"\bexamples/[A-Za-z0-9_./-]+\.py\b")


def check_example_references(errors: list[str]) -> None:
    """Every ``examples/<name>.py`` mentioned in any markdown file must
    exist — INCLUDING mentions inside code fences (that's where quickstart
    commands live, and exactly what rots when an example is renamed)."""
    for path in _iter_files(REPO, (".md",)):
        text = path.read_text(errors="replace")
        for m in sorted(set(EXAMPLE_RE.findall(text))):
            if not (REPO / m).exists():
                rel = path.relative_to(REPO)
                errors.append(
                    f"{rel}: references example file that does not exist: {m}")


def _public_defs(node: ast.Module | ast.ClassDef, prefix: str = ""):
    for child in node.body:
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
            if child.name.startswith("_"):
                continue
            yield prefix + child.name, child
            if isinstance(child, ast.ClassDef):
                yield from _public_defs(child, prefix + child.name + ".")


DOC_GATED_PACKAGES = ("serve", "persist", "obs")


def check_api_docstrings(errors: list[str]) -> None:
    """The serving layer (src/repro/serve/, DESIGN.md §5), the durability
    layer (src/repro/persist/, DESIGN.md §7), the cluster tier
    (src/repro/serve/cluster/, DESIGN.md §8), and the observability layer
    (src/repro/obs/, DESIGN.md §9) are documented interfaces: every
    public function, class, and method needs a docstring.  rglob so
    nested packages (serve/cluster/) are gated too."""
    for pkg in DOC_GATED_PACKAGES:
        for path in sorted((REPO / "src" / "repro" / pkg).rglob("*.py")):
            rel = path.relative_to(REPO)
            tree = ast.parse(path.read_text(errors="replace"))
            for name, node in _public_defs(tree):
                if ast.get_docstring(node) is None:
                    errors.append(f"{rel}:{node.lineno}: public {pkg} API "
                                  f"`{name}` has no docstring")


def main() -> int:
    errors: list[str] = []
    check_design_citations(errors)
    check_markdown_links(errors)
    check_example_references(errors)
    check_api_docstrings(errors)
    if errors:
        print(f"check_docs: {len(errors)} dangling reference(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print("check_docs: all DESIGN.md citations and markdown links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
