#!/usr/bin/env python
"""Markdown link-and-reference checker (CI gate).

Two classes of dangling reference have bitten this repo:

1. source docstrings citing ``DESIGN.md §<section>`` for sections (or a
   whole file) that don't exist — 16 files cited DESIGN.md before it was
   written;
2. intra-repo markdown links (``[text](relative/path)``) whose target file
   was renamed or never committed.

This script fails (exit 1) on either.  Zero dependencies; run from anywhere:

    python tools/check_docs.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", ".claude",
             "node_modules", ".venv"}

# "DESIGN.md §2" — tolerant of string-literal breaks across source lines:
# `"...(DESIGN.md "\n    "§Arch-applicability)"` has `" \n "` in between.
# Dots only bind as sub-section numbers (§2.1), never sentence punctuation.
_SECTION = r"§[A-Za-z0-9_-]+(?:\.\d+)*"
CITE_RE = re.compile(rf"DESIGN\.md[\s\"']*({_SECTION})?")
HEADING_SECTION_RE = re.compile(rf"^#+\s.*?({_SECTION})", re.M)
MD_LINK_RE = re.compile(r"\[[^\]^\n]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```.*?^```", re.M | re.S)


def _iter_files(root: Path, suffixes: tuple[str, ...]):
    for p in sorted(root.rglob("*")):
        if any(part in SKIP_DIRS for part in p.parts):
            continue
        if p.is_file() and p.suffix in suffixes:
            yield p


def check_design_citations(errors: list[str]) -> None:
    design = REPO / "DESIGN.md"
    sections: set[str] = set()
    if design.exists():
        sections = set(HEADING_SECTION_RE.findall(design.read_text()))
    for path in _iter_files(REPO, (".py",)):
        text = path.read_text(errors="replace")
        for m in CITE_RE.finditer(text):
            rel = path.relative_to(REPO)
            line = text.count("\n", 0, m.start()) + 1
            if not design.exists():
                errors.append(f"{rel}:{line}: cites DESIGN.md but the file "
                              "does not exist")
                continue
            sec = m.group(1)
            if sec is not None and sec not in sections:
                errors.append(
                    f"{rel}:{line}: cites DESIGN.md {sec} but DESIGN.md has "
                    f"no heading with {sec} (has: {' '.join(sorted(sections))})")


def check_markdown_links(errors: list[str]) -> None:
    for path in _iter_files(REPO, (".md",)):
        text = FENCE_RE.sub("", path.read_text(errors="replace"))
        for m in MD_LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            resolved = (path.parent / target).resolve()
            if not resolved.exists():
                rel = path.relative_to(REPO)
                errors.append(f"{rel}: link target does not exist: {target}")


def main() -> int:
    errors: list[str] = []
    check_design_citations(errors)
    check_markdown_links(errors)
    if errors:
        print(f"check_docs: {len(errors)} dangling reference(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print("check_docs: all DESIGN.md citations and markdown links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
