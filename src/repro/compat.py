"""Shims over jax API surfaces that moved between releases.

The repo targets the current `jax.shard_map` / `jax.sharding.AxisType`
surface; older jax (e.g. 0.4.x, which this container ships) exposes the same
functionality as `jax.experimental.shard_map.shard_map(..., check_rep=...)`
and has no AxisType.  Keeping the fallback here means every call site stays
written against the modern API.
"""

from __future__ import annotations

import jax

__all__ = ["make_mesh", "shard_map"]


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis_types where the installed jax has them."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map, falling back to jax.experimental.shard_map (pre-0.5
    spelling: positional mesh, check_rep instead of check_vma)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
