from .adamw import (AdamWConfig, adamw_init, adamw_update,   # noqa: F401
                    global_norm, clip_by_global_norm, cosine_schedule)
