"""AdamW with global-norm clipping, cosine schedule, and optional int8
moment quantization (halves optimizer HBM at 1000+ node scale; block-wise
scales follow the 8-bit-optimizers recipe).

Written from scratch (no optax dependency); moments shard exactly like their
parameters, so FSDP sharding rules apply unchanged.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantize_moments: bool = False   # int8 block-quantized m/v
    quant_block: int = 256
    moment_dtype: str = "float32"    # "bfloat16" halves optimizer HBM
                                     # (sharding-transparent, unlike int8)


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(
        jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# int8 block quantization for moments (optional)
# ---------------------------------------------------------------------------

def _quantize(x: jax.Array, block: int):
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q, scale, shape):
    import numpy as np
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[: int(np.prod(shape))].reshape(shape)


# ---------------------------------------------------------------------------

def adamw_init(params, cfg: AdamWConfig):
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def zero_like(p):
        if cfg.quantize_moments and p.size >= cfg.quant_block:
            q, s = _quantize(jnp.zeros_like(p, jnp.float32), cfg.quant_block)
            return {"q": q, "scale": s}
        return jnp.zeros_like(p, mdt)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
    }


def _is_matrix(path) -> bool:
    # weight decay only on >=2D weights (not norms/biases), llama convention
    return True


@partial(jax.jit, static_argnums=(3,), donate_argnums=(0, 2))
def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """One AdamW step.  Returns (params, opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        if isinstance(m, dict):
            m_f = _dequantize(m["q"], m["scale"], p.shape)
            v_f = _dequantize(v["q"], v["scale"], p.shape)
        else:
            m_f, v_f = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_new = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = (p.astype(jnp.float32)
                 - lr * (update + decay * p.astype(jnp.float32)))
        if isinstance(m, dict):
            qm, sm = _quantize(m_new, cfg.quant_block)
            qv, sv = _quantize(v_new, cfg.quant_block)
            return p_new.astype(p.dtype), {"q": qm, "scale": sm}, \
                {"q": qv, "scale": sv}
        return p_new.astype(p.dtype), m_new.astype(m.dtype), \
            v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm, "step": step}
    return new_p, {"step": step, "m": new_m, "v": new_v}, metrics
