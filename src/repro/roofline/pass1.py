"""Pass-1 roofline: predicted vs measured HBM bytes for the LUT16 scan
(paper §4.1.2's single-stream bound; DESIGN.md §2.5).

The fused scan-and-select changes pass 1's byte equation: the materialize
path writes AND re-reads the (Q, N) fp32 score matrix on its way to top-k,
while the fused path's HBM traffic is just the code stream (halved again by
4-bit packing), the per-query LUTs, and the (Q, cbuf) candidate buffers.
``predicted_pass1_bytes`` is that analytic model; ``measured_bytes`` pulls
the compiler's own "bytes accessed" from ``cost_analysis()`` so the two can
sit side by side in BENCH_engine.json (benchmarks/roofline_table.py renders
the comparison).  In interpret mode the measured number reflects the CPU
lowering, so the bench labels it ``"interpret": true`` — a proxy, not a TPU
measurement.
"""

from __future__ import annotations

import jax

__all__ = ["predicted_pass1_bytes", "measured_bytes"]


def predicted_pass1_bytes(*, q: int, n: int, k_codes: int, l: int = 16,
                          packed: bool = False, fused: bool = True,
                          cbuf: int | None = None) -> int:
    """Analytic HBM bytes for one pass-1 dispatch of the dense ADC scan.

    q queries, n rows, k_codes PQ subspaces (the STORED code width: pass
    ceil(K/2) when packed), l codewords; cbuf the candidate-buffer width
    (defaults to 128, the floor of kernels.lut16.candidate_buffer_width).

    materialize (fused=False) adds the (q, n) fp32 score matrix twice —
    once written by the scan kernel, once re-read by top_k — which is the
    term that made packed *slower* than unpacked end to end: the score
    round-trip dwarfed the halved code stream."""
    if cbuf is None:
        cbuf = 128
    codes = n * k_codes                       # uint8 stream (already halved
    lut = q * k_codes * l * 4                 # when packed: k_codes=ceil(K/2))
    lut *= 2 if packed else 1                 # packed LUT pairs nibble halves
    out = q * cbuf * (4 + 4)                  # f32 scores + i32 ids
    total = codes + lut + out
    if not fused:
        total += 2 * q * n * 4                # write + re-read (Q, N) scores
    return int(total)


def measured_bytes(fn, *args) -> float | None:
    """Compiler-reported "bytes accessed" for ``jit(fn)(*args)``.

    Returns None when the backend's cost model doesn't expose the key (older
    jax returns a list of dicts; missing key on CPU interpret lowerings)."""
    compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    if not isinstance(cost, dict):
        return None
    val = cost.get("bytes accessed")
    return None if val is None else float(val)
