"""Roofline term derivation from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips × peak FLOP/s)
  memory term     = HLO_bytes / (chips × HBM bandwidth)
  collective term = collective_bytes / (chips × link bandwidth)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis().  collective_bytes
is parsed from the post-SPMD optimized HLO (compiled.as_text()): we sum the
larger of (result bytes, operand bytes) for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute — i.e. bytes that must
cross links per participating device, the standard ring-estimate upper
bound.  Async pairs (*-start/*-done) are counted once via the -start op.

NOTE on cost_analysis scope: with XLA SPMD the compiled module is the
per-device program, so flops/bytes are per-device; we multiply terms out
accordingly (see roofline_from_compiled).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["V5E", "RooflineTerms", "collective_bytes_from_hlo",
           "roofline_from_compiled", "model_flops"]

# TPU v5e per-chip constants (assignment-specified)
V5E = {
    "peak_flops": 197e12,       # bf16 FLOP/s
    "hbm_bw": 819e9,            # B/s
    "ici_bw": 50e9,             # B/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9_]+\[[^\]]*\](?:\{[^}]*\})?(?:,\s*)?)+)\s*(?:\))?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")

_SKIP_SUFFIX = ("-done",)


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum bytes moved by collectives in an optimized HLO module.

    Returns {op_kind: bytes, ..., "total": bytes, "count": n}."""
    out: dict = {}
    count = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done(" in line:        # async completion of an already-counted op
            continue
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shapes)
        out[kind] = out.get(kind, 0.0) + nbytes
        count += 1
    out["total"] = sum(v for k, v in out.items() if k != "count")
    out["count"] = count
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # whole-job FLOPs (per-device × chips)
    hlo_bytes: float            # whole-job HBM bytes
    collective_bytes: float     # whole-job link bytes
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    bytes_per_device: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_ratio,
            "bytes_per_device": self.bytes_per_device,
        }


def roofline_from_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                           chips: int, model_flops_val: float,
                           hw: dict = V5E) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax returns [dict]
        cost = cost[0]
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    coll_dev = float(coll["total"])

    mem = compiled.memory_analysis()
    bytes_per_device = float(
        getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0))

    # cost_analysis is per-device under SPMD; totals scale by chips, and the
    # roofline denominators cancel that factor back out.
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops_dev * chips,
        hlo_bytes=bytes_dev * chips,
        collective_bytes=coll_dev * chips,
        compute_s=flops_dev / hw["peak_flops"],
        memory_s=bytes_dev / hw["hbm_bw"],
        collective_s=coll_dev / hw["ici_bw"],
        model_flops=model_flops_val,
        bytes_per_device=bytes_per_device,
    )


def count_params(cfg) -> tuple[float, float]:
    """(total params, active-per-token params) from the config — analytic,
    no instantiation."""
    d, l, v = cfg.d_model, cfg.num_layers, cfg.vocab_size
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    from repro.models.model import pattern_for
    pattern = pattern_for(cfg)

    def attn_params():
        return d * hd * (hq + 2 * hkv) + hq * hd * d

    def mlp_params(f):
        return 3 * d * f

    per_type_total, per_type_active = {}, {}
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    f_moe = cfg.moe_d_ff or cfg.d_ff
    for t in set(pattern):
        if t == "ssd":
            d_in = cfg.ssm_expand * d
            n = cfg.ssm_state
            tot = d * (2 * d_in + 2 * n + d_in // cfg.ssm_headdim) + d_in * d
            per_type_total[t] = per_type_active[t] = tot
        elif t == "rglru":
            w = cfg.lru_width or d
            tot = 2 * d * w + 2 * w * w + w * d + mlp_params(cfg.d_ff)
            per_type_total[t] = per_type_active[t] = tot
        elif t == "moe":
            tot = attn_params() + d * e + e * 3 * d * f_moe \
                + (3 * d * f_moe * cfg.num_shared_experts)
            act = attn_params() + d * e + k * 3 * d * f_moe \
                + (3 * d * f_moe * cfg.num_shared_experts)
            per_type_total[t], per_type_active[t] = tot, act
        elif t == "self_cross":
            tot = 2 * attn_params() + mlp_params(cfg.d_ff)
            per_type_total[t] = per_type_active[t] = tot
        else:
            tot = attn_params() + mlp_params(cfg.d_ff)
            per_type_total[t] = per_type_active[t] = tot

    repeats = l // len(pattern)
    layers = list(pattern) * repeats + list(pattern[: l % len(pattern)])
    total = sum(per_type_total[t] for t in layers)
    active = sum(per_type_active[t] for t in layers)
    emb = v * d * (1 if cfg.frontend == "tokens" else 0) + d * v
    return float(total + emb), float(active + emb)


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs for the cell: 6·N_active·tokens for training,
    2·N_active·tokens forward-only (prefill / decode), plus the causal
    attention term 2·(q·kv)·d_head·heads per layer pair."""
    total, active = count_params(cfg)
    b, s = shape.global_batch, shape.seq_len
    from repro.models.model import pattern_for
    pattern = pattern_for(cfg)
    l = cfg.num_layers
    layers = (list(pattern) * (l // len(pattern)
                               + 1))[: l]
    hd = cfg.resolved_head_dim
    hq = cfg.num_heads

    def attn_flops(q_tokens, kv_tokens, causal):
        per_pair = 4 * hq * hd        # scores + values, fwd
        pairs = q_tokens * kv_tokens * (0.5 if causal else 1.0)
        return per_pair * pairs

    if shape.kind == "train":
        tokens = b * s
        f = 6.0 * active * tokens
        for t in layers:
            if t in ("self", "moe", "self_cross"):
                f += 3 * b * attn_flops(s, s, True)         # fwd+bwd = 3x fwd
            if t == "lattn":
                f += 3 * b * attn_flops(s, min(cfg.local_window, s), False)
            if t == "self_cross":
                f += 3 * b * attn_flops(s, cfg.num_cond_tokens, False)
        return f
    if shape.kind == "prefill":
        tokens = b * s
        f = 2.0 * active * tokens
        for t in layers:
            if t in ("self", "moe", "self_cross"):
                f += b * attn_flops(s, s, True)
            if t == "lattn":
                f += b * attn_flops(s, min(cfg.local_window, s), False)
            if t == "self_cross":
                f += b * attn_flops(s, cfg.num_cond_tokens, False)
        return f
    # decode: one token against a seq_len cache
    f = 2.0 * active * b
    for t in layers:
        if t in ("self", "moe", "self_cross"):
            f += b * attn_flops(1, s, False)
        if t == "lattn":
            f += b * attn_flops(1, min(cfg.local_window, s), False)
        if t == "self_cross":
            f += b * attn_flops(1, cfg.num_cond_tokens, False)
    return f
