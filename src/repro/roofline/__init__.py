from .analysis import (RooflineTerms, collective_bytes_from_hlo,  # noqa: F401
                       roofline_from_compiled, model_flops, V5E)
from .pass1 import measured_bytes, predicted_pass1_bytes  # noqa: F401
