from .analysis import (RooflineTerms, collective_bytes_from_hlo,  # noqa: F401
                       roofline_from_compiled, model_flops, V5E)
