"""Render the roofline markdown table from a sweep JSONL.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun_single_pod.json
"""

from __future__ import annotations

import json
import sys


def load(path):
    with open(path) as f:
        text = f.read().strip()
    if text.startswith("["):
        return json.loads(text)
    return [json.loads(l) for l in text.splitlines() if l.strip()]


def fmt_s(x):
    if x is None:
        return "-"
    return f"{x * 1e3:.1f}ms" if x < 10 else f"{x:.2f}s"


def main(path: str):
    rows = load(path)
    print("| arch | shape | mesh | compute | memory | collective | dominant "
          "| useful | GiB/dev | fits | mb |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("status") == "skip":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                  f"skip (full-attn @500k) | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | | | "
                  f"{r.get('error', '')[:40]} | | | | |")
            continue
        gib = r.get("bytes_per_device", 0) / 2 ** 30
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {fmt_s(r.get('compute_s'))} | {fmt_s(r.get('memory_s'))} "
              f"| {fmt_s(r.get('collective_s'))} | {r.get('dominant', '-')} "
              f"| {r.get('useful_ratio', 0):.3f} | {gib:.2f} "
              f"| {r.get('fits_hbm', '-')} | {r.get('microbatches', '-')} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else
         "results/dryrun_single_pod.json")
