from .hybrid_synth import make_hybrid_dataset, HybridDataset  # noqa: F401
