"""Synthetic hybrid (sparse ⊕ dense) datasets matching the paper's data model.

QuerySim statistics reproduced (paper §7.1.2, Fig. 5):
  * sparse dimension activity follows a power law  P_j ∝ j^-alpha  (Fig. 5a);
  * nonzero values are heavy-tailed positive (log-normal), median ≈ 0.054,
    long tail (Fig. 5b);
  * dense components are low-dimensional embeddings; we draw them from a
    correlated Gaussian (random low-rank mixing) so PQ has structure to learn,
    and scale sparse/dense contributions to comparable magnitude (the paper
    fine-tunes this relative weight on ROC — we expose it as `dense_weight`).

Queries are drawn from the same process (paper Prop. 1-3 assume this), plus an
optional "related query" mode that perturbs dataset points so that planted
neighbors exist.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

__all__ = ["HybridDataset", "make_hybrid_dataset"]


@dataclasses.dataclass
class HybridDataset:
    x_sparse: sp.csr_matrix      # (N, d_sparse)
    x_dense: np.ndarray          # (N, d_dense) float32
    q_sparse: sp.csr_matrix      # (Q, d_sparse)
    q_dense: np.ndarray          # (Q, d_dense)
    alpha: float

    @property
    def num_points(self) -> int:
        return self.x_sparse.shape[0]


def _sparse_powerlaw(rng, n, d, alpha, target_nnz, value_median=0.054,
                     value_sigma=1.1):
    """Rows with power-law column activity and log-normal values."""
    pj = np.arange(1, d + 1, dtype=np.float64) ** (-alpha)
    pj *= target_nnz / pj.sum()
    pj = np.minimum(pj, 1.0)
    cols_all, rows_all = [], []
    # sample per-dimension Bernoulli column-wise (vectorized over rows)
    for j in np.flatnonzero(pj > 1e-7):
        hits = np.flatnonzero(rng.random(n) < pj[j])
        rows_all.append(hits)
        cols_all.append(np.full(len(hits), j, np.int32))
    rows = np.concatenate(rows_all) if rows_all else np.empty(0, np.int64)
    cols = np.concatenate(cols_all) if cols_all else np.empty(0, np.int32)
    mu = np.log(value_median)
    vals = rng.lognormal(mu, value_sigma, size=len(rows)).astype(np.float32)
    return sp.csr_matrix((vals, (rows, cols)), shape=(n, d))


def make_hybrid_dataset(num_points: int = 20000, num_queries: int = 64,
                        d_sparse: int = 30000, d_dense: int = 64,
                        alpha: float = 2.0, nnz_per_row: float = 64.0,
                        dense_weight: float = 1.0, dense_rank: int = 16,
                        related_queries: bool = True,
                        seed: int = 0) -> HybridDataset:
    rng = np.random.default_rng(seed)
    x_sparse = _sparse_powerlaw(rng, num_points, d_sparse, alpha, nnz_per_row)

    # correlated dense embeddings: low-rank mixing + noise
    basis = rng.normal(size=(dense_rank, d_dense)).astype(np.float32)
    coef = rng.normal(size=(num_points, dense_rank)).astype(np.float32)
    x_dense = (coef @ basis + 0.3 * rng.normal(size=(num_points, d_dense))
               ).astype(np.float32)
    x_dense *= dense_weight / np.sqrt(d_dense)

    if related_queries:
        # queries = perturbed copies of random datapoints => planted neighbors
        src = rng.choice(num_points, size=num_queries, replace=False)
        q_sparse = x_sparse[src].copy()
        q_sparse.data *= rng.uniform(0.7, 1.3, size=q_sparse.nnz).astype(np.float32)
        extra = _sparse_powerlaw(rng, num_queries, d_sparse, alpha,
                                 nnz_per_row * 0.3)
        q_sparse = (q_sparse + extra).tocsr()
        q_dense = (x_dense[src]
                   + 0.2 * dense_weight / np.sqrt(d_dense)
                   * rng.normal(size=(num_queries, d_dense))).astype(np.float32)
    else:
        q_sparse = _sparse_powerlaw(rng, num_queries, d_sparse, alpha, nnz_per_row)
        coefq = rng.normal(size=(num_queries, dense_rank)).astype(np.float32)
        q_dense = ((coefq @ basis
                    + 0.3 * rng.normal(size=(num_queries, d_dense)))
                   * dense_weight / np.sqrt(d_dense)).astype(np.float32)

    return HybridDataset(x_sparse=x_sparse, x_dense=x_dense,
                         q_sparse=q_sparse.tocsr(),
                         q_dense=q_dense.astype(np.float32), alpha=alpha)
