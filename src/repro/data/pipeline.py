"""Deterministic synthetic LM data pipeline.

Batches are a pure function of (seed, step) via threefry hashing — no
iterator state to checkpoint.  Restart/resume at step k reproduces batch k
exactly (the fault-tolerance contract in train/trainer.py), stragglers can
re-derive any shard without coordination, and elastic re-sharding is just a
different slice of the same deterministic stream.

Targets are a noisy "copy previous token + drift" sequence so a real LM can
overfit it measurably (examples/train_lm.py uses loss decrease as its
acceptance test).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["DataConfig", "synthetic_batch", "input_specs_for_shape"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234


def synthetic_batch(cfg: DataConfig, step) -> dict:
    """Batch at `step`: {"tokens": (B, S) int32, "labels": (B, S) int32}.

    A Markov-ish stream: token_{t+1} = (token_t * 31 + drift_t) % V with
    occasional resets, labels = next token (standard causal LM shift)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    k1, k2, k3 = jax.random.split(key, 3)
    start = jax.random.randint(k1, (b, 1), 0, v)
    drift = jax.random.randint(k2, (b, 1), 1, 7)
    pos = jnp.arange(s + 1)[None, :]
    seq = (start + drift * pos * 31) % v
    noise_mask = jax.random.bernoulli(k3, 0.05, (b, s + 1))
    noise = jax.random.randint(key, (b, s + 1), 0, v)
    seq = jnp.where(noise_mask, noise, seq).astype(jnp.int32)
    return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def input_specs_for_shape(cfg_model, shape, *, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a given
    (arch, shape) cell — the dry-run contract (no allocation).

    train/prefill: full (B, S) token batch (or embeddings for stub
    frontends) + labels for train; decode: one token (B,) + the cell's
    decode state is built separately in launch/dryrun.py."""
    b, s = shape.global_batch, shape.seq_len
    specs: dict = {}
    if shape.kind in ("train", "prefill"):
        if cfg_model.frontend == "tokens":
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        else:
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg_model.d_model),
                                                   dtype)
        if cfg_model.num_cond_tokens:
            specs["cond"] = jax.ShapeDtypeStruct(
                (b, cfg_model.num_cond_tokens, cfg_model.d_model), dtype)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:  # decode: one new token against a seq_len-deep cache/state
        if cfg_model.frontend == "tokens":
            specs["token"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        else:
            specs["token"] = jax.ShapeDtypeStruct((b, 1, cfg_model.d_model),
                                                  dtype)
    return specs
