"""Profiling hooks (DESIGN.md §9.3): opt-in ``jax.profiler`` capture
around engine dispatch, plus per-pass device-time attribution that
feeds the roofline tables.

Everything degrades to a no-op when ``jax.profiler`` is unavailable
(CPU-only CI images, stubbed jax), so call sites never guard on
imports.
"""

from __future__ import annotations

import contextlib
import time

__all__ = ["profiler_available", "device_trace", "StepAnnotation",
           "pass_breakdown"]


def _profiler():
    try:
        import jax.profiler as p
        return p
    except Exception:
        return None


def profiler_available() -> bool:
    """True when ``jax.profiler`` can be imported (a capture directory
    will actually receive a trace)."""
    return _profiler() is not None


@contextlib.contextmanager
def device_trace(log_dir: str | None):
    """Context manager wrapping ``jax.profiler.trace(log_dir)`` around a
    region of engine dispatches.  A None ``log_dir`` (the default
    everywhere — profiling is opt-in) or a missing profiler makes this a
    no-op, so benchmarks can wrap their hot loops unconditionally."""
    p = _profiler()
    if log_dir is None or p is None:
        yield
        return
    p.start_trace(log_dir)
    try:
        yield
    finally:
        p.stop_trace()


class StepAnnotation:
    """``jax.profiler.StepTraceAnnotation`` with a no-op fallback: names
    one engine dispatch inside a device trace so per-pass device time is
    attributable in the captured timeline."""

    def __init__(self, name: str, **kw):
        p = _profiler()
        self._inner = (p.StepTraceAnnotation(name, **kw)
                       if p is not None and
                       hasattr(p, "StepTraceAnnotation") else None)

    def __enter__(self):
        if self._inner is not None:
            self._inner.__enter__()
        return self

    def __exit__(self, *exc):
        if self._inner is not None:
            return self._inner.__exit__(*exc)
        return None


def pass_breakdown(engine, q_dims, q_vals, q_dense, *, h: int,
                   alpha: int, beta: int, iters: int = 3) -> dict:
    """Per-pass device-time attribution for one engine + query batch:
    times pass-1-only top-k (the scan the roofline models) against the
    full three-pass search, both with ``block_until_ready``, and reports
    the pass-1 fraction — the measured companion to the predicted
    bytes/point in ``src/repro/roofline/`` (DESIGN.md §9.3).

    Returns ``{"pass1_s", "full_s", "pass23_s", "pass1_fraction",
    "iters", "backend"}`` (best-of-``iters`` wall seconds)."""
    import jax
    from ..core.pq import adc_lut

    c1, _ = engine.candidate_counts(h, alpha, beta)
    lut = adc_lut(q_dense, engine.arrays.codebooks)

    def _time(fn):
        best = None
        for _ in range(max(1, iters)):
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            best = dt if best is None or dt < best else best
        return best

    # warm both compiles outside the timed loop
    jax.block_until_ready(engine.pass1_topk(q_dims, q_vals, lut, c1))
    jax.block_until_ready(engine.search(q_dims, q_vals, q_dense,
                                        h=h, alpha=alpha, beta=beta))
    pass1_s = _time(lambda: engine.pass1_topk(q_dims, q_vals, lut, c1))
    full_s = _time(lambda: engine.search(q_dims, q_vals, q_dense,
                                         h=h, alpha=alpha, beta=beta))
    pass23_s = max(0.0, full_s - pass1_s)
    # wall-clock jitter can time pass-1 alone above the fused full pass;
    # an attribution fraction is [0, 1] by definition, so clamp
    frac = min(1.0, pass1_s / full_s) if full_s > 0 else 0.0
    return {"pass1_s": pass1_s, "full_s": full_s, "pass23_s": pass23_s,
            "pass1_fraction": frac,
            "iters": iters, "backend": str(engine.backend)}
