"""Text metrics endpoint (DESIGN.md §9.1): a daemon-thread HTTP server
exposing one registry as ``GET /metrics`` plain text — what
``launch/serve.py --metrics-port`` (and ``--role shard
--metrics-port``) stand up next to a serving process.
"""

from __future__ import annotations

import http.server
import json
import threading

__all__ = ["MetricsServer", "start_metrics_server"]


class MetricsServer:
    """Handle for a running metrics endpoint: ``.port`` (useful when
    bound to port 0) and ``.close()``."""

    def __init__(self, httpd, thread):
        self._httpd = httpd
        self._thread = thread
        self.port = httpd.server_address[1]

    def close(self) -> None:
        """Shut the endpoint down and join its thread."""
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


def start_metrics_server(registry, port: int = 0,
                         host: str = "127.0.0.1") -> MetricsServer:
    """Serve ``registry`` on ``http://host:port``:

    * ``GET /metrics`` — Prometheus-style text
      (``MetricsRegistry.render_text``);
    * ``GET /metrics.json`` — the raw ``snapshot()`` as JSON.

    ``port=0`` binds an ephemeral port (read it off the returned
    handle).  The server runs on a daemon thread; scrapes never touch
    the serving hot path beyond each instrument's own lock."""

    class _Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.startswith("/metrics.json"):
                body = json.dumps(registry.snapshot(),
                                  sort_keys=True).encode()
                ctype = "application/json"
            elif self.path.startswith("/metrics") or self.path == "/":
                body = registry.render_text().encode()
                ctype = "text/plain; charset=utf-8"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, fmt, *args):   # quiet: no stderr per scrape
            pass

    httpd = http.server.ThreadingHTTPServer((host, port), _Handler)
    httpd.daemon_threads = True
    t = threading.Thread(target=httpd.serve_forever, daemon=True,
                         name="metrics-exporter")
    t.start()
    return MetricsServer(httpd, t)
