"""Lock-cheap metrics registry (DESIGN.md §9.1).

Three instrument kinds — :class:`Counter`, :class:`Gauge`,
:class:`Histogram` — behind one :class:`MetricsRegistry`.  Each
instrument carries its own tiny lock so a hot increment never contends
with an unrelated instrument or with a snapshot of the whole registry;
``registry.counter(name)`` is get-or-create and always returns the SAME
object for a name, so call sites hoist the lookup once and pay only the
lock+add afterwards.

The disabled path allocates nothing per call: a registry built with
``enabled=False`` hands out the module-level ``NULL_COUNTER`` /
``NULL_GAUGE`` / ``NULL_HISTOGRAM`` singletons whose methods are no-op
``pass`` bodies, so instrumented code runs the same lines either way and
the cost of "observability off" is one attribute call on a shared
object (DESIGN.md §9.4).
"""

from __future__ import annotations

import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_COUNTER", "NULL_GAUGE", "NULL_HISTOGRAM", "NULL_REGISTRY",
    "DEFAULT_BOUNDS",
]

# Latency-oriented default buckets, in seconds: 10us .. 10s.  Bounded —
# a histogram is a fixed-size array of ints, never a per-sample append.
DEFAULT_BOUNDS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
)


class Counter:
    """Monotone counter.  ``inc(n)`` under a per-instrument lock; reads
    (``value``) are lock-free int reads (atomic under the GIL)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        """Add ``n`` (default 1) to the counter."""
        with self._lock:
            self._value += n

    @property
    def value(self) -> int | float:
        """Current total."""
        return self._value

    def snapshot(self):
        """JSON-ready value (the running total)."""
        return self._value


class Gauge:
    """Point-in-time value: ``set`` overwrites, ``add`` nudges."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        """Overwrite the gauge with ``v``."""
        self._value = v

    def add(self, dv: float) -> None:
        """Adjust the gauge by ``dv`` (locked read-modify-write)."""
        with self._lock:
            self._value += dv

    @property
    def value(self) -> float:
        """Current value."""
        return self._value

    def snapshot(self):
        """JSON-ready value (the current reading)."""
        return self._value


class Histogram:
    """Bounded-bucket histogram: fixed cumulative bounds set at creation,
    one int per bucket (+ overflow), plus running count/sum/min/max and
    the most recent sample (``last`` — what live gauges like
    ``wal_last_fsync_s`` read).  ``observe`` is one lock + O(#buckets)
    scan; no allocation per sample."""

    __slots__ = ("name", "bounds", "_counts", "_lock", "_count", "_sum",
                 "_min", "_max", "_last")

    def __init__(self, name: str, bounds=DEFAULT_BOUNDS):
        self.name = name
        self.bounds = tuple(bounds)
        self._counts = [0] * (len(self.bounds) + 1)   # +1 = overflow
        self._lock = threading.Lock()
        self._count = 0
        self._sum = 0.0
        self._min = None
        self._max = None
        self._last = None

    def observe(self, v: float) -> None:
        """Record one sample ``v`` into its bucket and the aggregates."""
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            self._last = v
            if self._min is None or v < self._min:
                self._min = v
            if self._max is None or v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        """Number of samples observed."""
        return self._count

    @property
    def sum(self) -> float:
        """Sum of all observed samples."""
        return self._sum

    @property
    def mean(self) -> float:
        """Mean sample value (0.0 when empty)."""
        return self._sum / self._count if self._count else 0.0

    @property
    def last(self):
        """Most recent sample, or None when empty."""
        return self._last

    @property
    def max(self):
        """Largest sample seen, or None when empty."""
        return self._max

    def snapshot(self) -> dict:
        """JSON-ready aggregate view (count/sum/mean/min/max/last plus
        per-bucket counts keyed by upper bound)."""
        with self._lock:
            counts = list(self._counts)
            out = {"count": self._count, "sum": self._sum,
                   "mean": self._sum / self._count if self._count else 0.0,
                   "min": self._min, "max": self._max, "last": self._last}
        out["buckets"] = {("+inf" if i == len(self.bounds)
                           else repr(self.bounds[i])): c
                          for i, c in enumerate(counts) if c}
        return out


class _NullCounter:
    """No-op counter handed out by disabled registries (shared
    singleton; ``value`` reads 0)."""
    __slots__ = ()
    name = "null"

    def inc(self, n=1):
        """No-op."""

    @property
    def value(self):
        """Always 0."""
        return 0

    def snapshot(self):
        """Always 0."""
        return 0


class _NullGauge:
    """No-op gauge singleton for the disabled path."""
    __slots__ = ()
    name = "null"

    def set(self, v):
        """No-op."""

    def add(self, dv):
        """No-op."""

    @property
    def value(self):
        """Always 0.0."""
        return 0.0

    def snapshot(self):
        """Always 0.0."""
        return 0.0


class _NullHistogram:
    """No-op histogram singleton for the disabled path."""
    __slots__ = ()
    name = "null"
    bounds = ()
    count = 0
    sum = 0.0
    mean = 0.0
    last = None
    max = None

    def observe(self, v):
        """No-op."""

    def snapshot(self):
        """Empty aggregate view."""
        return {"count": 0, "sum": 0.0, "mean": 0.0, "min": None,
                "max": None, "last": None, "buckets": {}}


NULL_COUNTER = _NullCounter()
NULL_GAUGE = _NullGauge()
NULL_HISTOGRAM = _NullHistogram()


class MetricsRegistry:
    """Name → instrument map.  ``counter``/``gauge``/``histogram`` are
    get-or-create (idempotent: same name → same object, so concurrent
    callers share one instrument); ``snapshot()`` returns a JSON-ready
    dict and ``render_text()`` a Prometheus-style exposition for the
    ``--metrics-port`` endpoint (DESIGN.md §9.1).

    ``MetricsRegistry(enabled=False)`` is the zero-allocation disabled
    path: every factory returns the shared null singleton and the
    registry stays empty."""

    def __init__(self, *, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, *args)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        """Get or create the counter ``name`` (null singleton when the
        registry is disabled)."""
        if not self.enabled:
            return NULL_COUNTER
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        """Get or create the gauge ``name``."""
        if not self.enabled:
            return NULL_GAUGE
        return self._get(name, Gauge)

    def histogram(self, name: str, bounds=DEFAULT_BOUNDS) -> Histogram:
        """Get or create the histogram ``name`` with cumulative bucket
        ``bounds`` (ignored if the histogram already exists)."""
        if not self.enabled:
            return NULL_HISTOGRAM
        return self._get(name, Histogram, bounds)

    def snapshot(self) -> dict:
        """JSON-ready ``{name: value-or-aggregate}`` over every
        registered instrument, sorted by name."""
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.snapshot() for name, m in items}

    def render_text(self) -> str:
        """Prometheus-style text exposition: one ``name value`` line per
        counter/gauge, ``name_count`` / ``name_sum`` / ``name_last``
        lines per histogram (dots in names become underscores)."""
        lines = []
        for name, m in sorted(self.snapshot().items()):
            flat = name.replace(".", "_").replace("-", "_")
            if isinstance(m, dict):                     # histogram
                lines.append(f"{flat}_count {m['count']}")
                lines.append(f"{flat}_sum {m['sum']}")
                if m["last"] is not None:
                    lines.append(f"{flat}_last {m['last']}")
            else:
                lines.append(f"{flat} {m}")
        return "\n".join(lines) + "\n"


NULL_REGISTRY = MetricsRegistry(enabled=False)
