"""Unified observability layer (DESIGN.md §9): metrics registry +
request-scoped tracing + profiling hooks, shared by ``QueryService``,
``ClusterRouter``, the shard servers, and the persistence layer.

* ``metrics`` — lock-cheap counters/gauges/bounded-bucket histograms
  behind one :class:`MetricsRegistry` (§9.1);
* ``trace`` — per-request :class:`Span` trees propagated across the
  cluster wire via frame meta (§9.2);
* ``profile`` — opt-in ``jax.profiler`` capture + per-pass device-time
  attribution (§9.3);
* ``exporter`` — the ``--metrics-port`` text endpoint.

:class:`Observability` bundles one registry + one tracer and is the
single knob every layer takes (``QueryService(obs=…)``,
``ClusterRouter(obs=…)``, ``ShardServer(obs=…)``).  The default is
metrics ON, tracing OFF; ``Observability.off()`` is the zero-cost null
bundle used as the no-obs baseline in overhead benchmarks (§9.4).
"""

from .metrics import (Counter, Gauge, Histogram,     # noqa: F401
                      MetricsRegistry, NULL_COUNTER, NULL_GAUGE,
                      NULL_HISTOGRAM, NULL_REGISTRY, DEFAULT_BOUNDS)
from .trace import (Span, Tracer, NULL_SPAN,         # noqa: F401
                    NULL_TRACER, STAGES, stage_totals)
from .profile import (StepAnnotation, device_trace,  # noqa: F401
                      pass_breakdown, profiler_available)
from .exporter import MetricsServer, start_metrics_server  # noqa: F401

__all__ = [
    "Observability", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "Span", "Tracer", "NULL_SPAN", "NULL_TRACER", "NULL_COUNTER",
    "NULL_GAUGE", "NULL_HISTOGRAM", "NULL_REGISTRY", "DEFAULT_BOUNDS",
    "STAGES", "stage_totals", "StepAnnotation", "device_trace",
    "pass_breakdown", "profiler_available", "MetricsServer",
    "start_metrics_server",
]


class Observability:
    """One registry + one tracer, the bundle every layer is handed.

    ``metrics=True, trace=False`` is the default everywhere: counters
    and gauges stay exact (``QueryService.cache_info()`` reads them)
    while the per-request span machinery stays on the null path.
    ``Observability.off()`` disables both — instruments become shared
    null singletons and counters read 0; it exists for overhead
    measurement, not production serving (DESIGN.md §9.4)."""

    def __init__(self, *, metrics: bool = True, trace: bool = False,
                 keep_traces: int = 256,
                 profile_dir: str | None = None):
        self.metrics = MetricsRegistry(enabled=metrics)
        self.tracer = Tracer(enabled=trace, keep=keep_traces)
        self.profile_dir = profile_dir

    @property
    def enabled(self) -> bool:
        """True when any instrument (metrics or tracing) is live."""
        return self.metrics.enabled or self.tracer.enabled

    @classmethod
    def off(cls) -> "Observability":
        """Fully disabled bundle: every instrument is a shared null
        singleton, every root span is :data:`NULL_SPAN`."""
        return cls(metrics=False, trace=False)
