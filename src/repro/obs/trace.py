"""Request-scoped tracing (DESIGN.md §9.2).

One :class:`Span` per unit of work — a root span per search/mutation in
``QueryService`` and ``ClusterRouter``, child spans per RPC hop and per
micro-batch.  Spans carry:

* ``tags`` — numeric/str facts (``serialize_s``, ``score_s``, ``peer``,
  ``part``); ``add()`` accumulates floats so retries fold into one tag;
* ``annotations`` — ordered event strings (``"reconnect_resend"``,
  ``"term_fenced: ..."``, failover election notes);
* ``children`` — sub-spans, ended independently.

Wire propagation rides the cluster frame protocol's JSON meta line
(serve/cluster/protocol.py): ``span.wire_context()`` is a 2-key dict
``{"tid", "sid"}`` placed under ``meta["trace"]``; a shard server that
sees it builds its own child span via ``Tracer.from_wire`` and returns
the serialized result under ``rmeta["trace"]``, which the client folds
back in with ``Span.attach_remote``.  Requests without a ``trace`` key
cost the server nothing — server-side tracing is opt-in per request.

The disabled path is the ``NULL_SPAN`` singleton: every method is a
no-op, ``child()`` returns itself, ``wire_context()`` returns None (so
nothing is added to request meta), and ``bool(NULL_SPAN)`` is False.
Instrumented code never branches on "tracing on?" — it just talks to
whatever span it was handed (DESIGN.md §9.4).
"""

from __future__ import annotations

import collections
import os
import threading
import time

__all__ = ["Span", "Tracer", "NULL_SPAN", "NULL_TRACER", "stage_totals",
           "STAGES"]

# The per-hop stage vocabulary (DESIGN.md §9.2): tag names ending in
# ``_s`` on "rpc" child spans, plus "merge_s" on the root.
STAGES = ("serialize_s", "wire_s", "queue_s", "score_s", "merge_s")


def _new_id() -> str:
    return os.urandom(8).hex()


class Span:
    """One timed unit of work in a trace tree.  Create roots via
    :meth:`Tracer.root`; children via :meth:`child`.  Usable as a
    context manager (``__exit__`` calls :meth:`end`)."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "start_s",
                 "duration_s", "tags", "annotations", "children",
                 "_tracer")

    def __init__(self, name: str, *, trace_id: str | None = None,
                 parent_id: str | None = None, tracer=None, **tags):
        self.name = name
        self.trace_id = trace_id or _new_id()
        self.span_id = _new_id()
        self.parent_id = parent_id
        self.start_s = time.perf_counter()
        self.duration_s = None
        self.tags = dict(tags)
        self.annotations: list[str] = []
        self.children: list[Span] = []
        self._tracer = tracer

    def __bool__(self) -> bool:
        return True

    def set(self, key: str, value) -> None:
        """Set tag ``key`` to ``value`` (overwrites)."""
        self.tags[key] = value

    def add(self, key: str, dv: float) -> None:
        """Accumulate float ``dv`` into tag ``key`` (0-initialized)."""
        self.tags[key] = self.tags.get(key, 0.0) + dv

    def annotate(self, event: str) -> None:
        """Append an event string to this span's annotation log."""
        self.annotations.append(event)

    def child(self, name: str, **tags) -> "Span":
        """Start a child span (same trace id, parent = this span)."""
        c = Span(name, trace_id=self.trace_id, parent_id=self.span_id,
                 **tags)
        self.children.append(c)
        return c

    def wire_context(self) -> dict:
        """The propagation context carried in frame meta under
        ``"trace"``: the trace id plus this span's id as the remote
        parent."""
        return {"tid": self.trace_id, "sid": self.span_id}

    def attach_remote(self, rdict: dict | None) -> None:
        """Fold a server-serialized child span (``rmeta["trace"]`` from
        a shard reply — see ``Span.to_wire``) in as a child of this
        span.  None is ignored so callers can pass ``rmeta.get("trace")``
        unconditionally."""
        if not rdict:
            return
        c = self.child(rdict.get("name", "remote"))
        c.span_id = rdict.get("sid", c.span_id)
        c.duration_s = rdict.get("duration_s")
        for k, v in rdict.items():
            if k not in ("name", "sid", "duration_s", "annotations"):
                c.tags[k] = v
        c.annotations.extend(rdict.get("annotations", ()))

    def end(self) -> None:
        """Stop the clock (idempotent) and, for roots created by a
        tracer, publish the finished trace into its ring."""
        if self.duration_s is None:
            self.duration_s = time.perf_counter() - self.start_s
        if self._tracer is not None:
            self._tracer._finish(self)
            self._tracer = None

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()

    def to_wire(self) -> dict:
        """Compact JSON form a shard server returns under
        ``rmeta["trace"]``: span id + duration + tags + annotations.
        (Children are not shipped — shard-side work is one level deep.)"""
        self.end() if self.duration_s is None else None
        out = {"name": self.name, "sid": self.span_id,
               "duration_s": self.duration_s, **self.tags}
        if self.annotations:
            out["annotations"] = list(self.annotations)
        return out

    def to_dict(self) -> dict:
        """Full JSON form of the span tree rooted here."""
        return {"name": self.name, "trace_id": self.trace_id,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "duration_s": self.duration_s, "tags": dict(self.tags),
                "annotations": list(self.annotations),
                "children": [c.to_dict() for c in self.children]}


class _NullSpan:
    """Shared no-op span: the zero-cost disabled path.  ``child()``
    returns itself; ``wire_context()`` is None so no trace key is added
    to request meta; falsy so rare non-hot code may branch on it."""

    __slots__ = ()
    name = "null"
    trace_id = span_id = parent_id = None
    duration_s = None
    tags: dict = {}
    annotations: list = []
    children: list = []

    def __bool__(self):
        return False

    def set(self, key, value):
        """No-op."""

    def add(self, key, dv):
        """No-op."""

    def annotate(self, event):
        """No-op."""

    def child(self, name, **tags):
        """Returns itself (children of a null span are null)."""
        return self

    def wire_context(self):
        """None — nothing is propagated."""
        return None

    def attach_remote(self, rdict):
        """No-op."""

    def end(self):
        """No-op."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    def to_wire(self):
        """None — a null span never serializes."""
        return None

    def to_dict(self):
        """None — a null span never serializes."""
        return None


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + bounded ring of finished root traces.

    ``root(name)`` returns :data:`NULL_SPAN` when disabled, so the
    per-request cost of "tracing off" is one branch.  Finished roots
    (``span.end()``) land in a ``deque(maxlen=keep)``; ``take()``
    drains them as JSON dicts — how benchmarks source their span-based
    breakdowns (DESIGN.md §9.2)."""

    def __init__(self, *, enabled: bool = True, keep: int = 256):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._finished: collections.deque = collections.deque(maxlen=keep)

    def root(self, name: str, **tags):
        """Start a root span (or :data:`NULL_SPAN` when disabled)."""
        if not self.enabled:
            return NULL_SPAN
        return Span(name, tracer=self, **tags)

    def from_wire(self, ctx: dict | None, name: str, **tags):
        """Server-side entry: a span whose trace id / parent come from a
        request's ``meta["trace"]`` context.  Returns :data:`NULL_SPAN`
        when the context is absent — per-request opt-in."""
        if not self.enabled or not ctx:
            return NULL_SPAN
        return Span(name, trace_id=ctx.get("tid"),
                    parent_id=ctx.get("sid"), **tags)

    def _finish(self, span: Span) -> None:
        with self._lock:
            self._finished.append(span)

    def take(self) -> list[dict]:
        """Drain and return every finished root trace as a dict."""
        with self._lock:
            spans = list(self._finished)
            self._finished.clear()
        return [s.to_dict() for s in spans]

    def last(self) -> dict | None:
        """Peek the most recent finished root (not drained)."""
        with self._lock:
            return self._finished[-1].to_dict() if self._finished else None


NULL_TRACER = Tracer(enabled=False)


def _walk(node: dict):
    yield node
    for c in node.get("children", ()):
        yield from _walk(c)


def stage_totals(traces: list[dict]) -> dict:
    """Aggregate per-stage seconds over a batch of finished trace dicts:
    sums every ``STAGES`` tag across every span of every tree.  This is
    the span-sourced replacement for the router's old ``hop_s``
    field-scraping — benchmarks call ``tracer.take()`` then this."""
    totals = {k: 0.0 for k in STAGES}
    for t in traces:
        for node in _walk(t):
            tags = node.get("tags", {})
            for k in STAGES:
                v = tags.get(k)
                if v is not None:
                    totals[k] += v
    return totals
