import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax-importing import: jax locks the device count at
# first init, and the production meshes below need 512 host placeholders.
# (Only the dry-run does this — tests and benches see 1 device.)

# Multi-pod dry-run: lower + compile every (architecture × input shape)
# cell on the single-pod (16×16) and multi-pod (2×16×16) production meshes,
# print memory_analysis / cost_analysis, and emit the roofline table rows.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
#       --out results/dryrun.json
#
# Failures here (sharding mismatch, OOM at compile, unsupported collective)
# are bugs in the system, not in the harness.

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.data.pipeline import input_specs_for_shape
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.models.common import sharding_rules
from repro.models.shardings import (batch_pspecs, param_pspecs, state_pspecs,
                                    tree_pspecs)
from repro.optim import AdamWConfig, adamw_init
from repro.roofline import model_flops, roofline_from_compiled
from repro.train import make_train_step

OPT_CFG = AdamWConfig()


def skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("skipped: pure full-attention arch — 524288-token dense KV "
                "cache requires sub-quadratic attention (DESIGN.md "
                "§Arch-applicability)")
    return None


def input_specs(arch: str, shape_name: str = "train_4k") -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cfg = get_config(arch)
    return input_specs_for_shape(cfg, SHAPES[shape_name])


def _shard(tree, spec_tree, mesh):
    return jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        tree, spec_tree)


def build_lowered(cfg, shape, mesh, *, microbatches: int = 1,
                  opt_cfg: AdamWConfig | None = None):
    """Lower the cell's step (train_step / prefill / serve_step) with full
    sharding annotations.  Returns the jax.stages.Lowered."""
    opt_cfg = opt_cfg or OPT_CFG
    model = Model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_pspecs(params_sds, mesh)
    params_in = _shard(params_sds, pspecs, mesh)

    with sharding_rules(mesh):
        if shape.kind == "train":
            batch_sds = input_specs_for_shape(cfg, shape)
            batch_in = _shard(batch_sds, batch_pspecs(batch_sds, mesh), mesh)
            opt_sds = jax.eval_shape(partial(adamw_init, cfg=opt_cfg),
                                     params_sds)
            ospecs = tree_pspecs(opt_sds, mesh, params_sds)
            opt_in = _shard(opt_sds, ospecs, mesh)
            step = make_train_step(model, opt_cfg, microbatches=microbatches,
                                   cast_params_bf16=cfg.params_bf16_cast)
            return jax.jit(step, donate_argnums=(0, 1)).lower(
                params_in, opt_in, batch_in)
        if shape.kind == "prefill":
            batch_sds = input_specs_for_shape(cfg, shape)
            batch_in = _shard(batch_sds, batch_pspecs(batch_sds, mesh), mesh)

            def prefill_step(params, batch):
                return model.prefill(params, batch, shape.seq_len)

            return jax.jit(prefill_step).lower(params_in, batch_in)
        # decode
        b = shape.global_batch
        cond_sds = None
        if cfg.num_cond_tokens:
            cond_sds = jax.ShapeDtypeStruct(
                (b, cfg.num_cond_tokens, cfg.d_model), jnp.bfloat16)
        state_sds = jax.eval_shape(
            partial(model.init_decode_state, batch_size=b,
                    max_len=shape.seq_len),
            params_sds, cond=cond_sds)
        state_in = _shard(state_sds, state_pspecs(state_sds, mesh), mesh)
        tok_sds = input_specs_for_shape(cfg, shape)["token"]
        tok_specs = batch_pspecs({"token": tok_sds}, mesh)["token"]
        tok_in = _shard(tok_sds, tok_specs, mesh)

        def serve_step(params, state, token):
            return model.decode_step(params, state, token)

        return jax.jit(serve_step, donate_argnums=(1,)).lower(
            params_in, state_in, tok_in)


HBM_BYTES = 16 * 1024 ** 3          # v5e
HBM_FIT = int(15.5 * 1024 ** 3)     # leave headroom for runtime buffers


def _mem_per_device(compiled) -> int:
    mem = compiled.memory_analysis()
    return int(getattr(mem, "temp_size_in_bytes", 0)
               + getattr(mem, "argument_size_in_bytes", 0)
               + getattr(mem, "output_size_in_bytes", 0)
               - getattr(mem, "alias_size_in_bytes", 0))


def _cost_tuple(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    from repro.roofline import collective_bytes_from_hlo
    coll = collective_bytes_from_hlo(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total"]))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               verbose: bool = True, probes: bool = True,
               fit_hint: dict | None = None) -> dict:
    """Full-config compile (memory proof) + two reduced-depth compiles for
    the scan-body extrapolation (XLA cost_analysis counts a while body once
    regardless of trip count — measured; see EXPERIMENTS.md §Roofline
    methodology), + auto-microbatch fit for training cells.

    probes=False skips the roofline extrapolation (multi-pod pass only needs
    the compile/memory proof).  fit_hint seeds (microbatches, opt_moments)
    from a previous sweep to avoid re-searching."""
    import dataclasses as dc
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    plen = len(Model(cfg).pattern)

    # ---- full-config compile: the memory/sharding proof -------------------
    # auto-fit: escalate microbatches (keeping per-ub batch >= data shards so
    # DP stays intact); if the fp32 optimizer alone exceeds HBM, fall back to
    # bf16 moments (sharding-transparent compression).
    data_shards = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    max_mb = max(shape.global_batch // data_shards, 1)
    t0 = time.time()
    microbatches, opt_cfg = 1, OPT_CFG
    if fit_hint:
        microbatches = min(int(fit_hint.get("microbatches", 1)), max_mb)
        if fit_hint.get("opt_moments") == "bfloat16":
            opt_cfg = AdamWConfig(moment_dtype="bfloat16")
    seen = {}
    while True:
        lowered = build_lowered(cfg, shape, mesh, microbatches=microbatches,
                                opt_cfg=opt_cfg)
        compiled = lowered.compile()
        mem_dev = _mem_per_device(compiled)
        seen[microbatches] = mem_dev
        if shape.kind != "train" or mem_dev <= HBM_FIT:
            break
        if microbatches < max_mb:
            if len(seen) >= 2:
                # temp(mb) ~ fixed + act/mb: solve from two samples and jump
                mbs = sorted(seen)[-2:]
                m1, m2 = seen[mbs[0]], seen[mbs[1]]
                act = (m1 - m2) / (1.0 / mbs[0] - 1.0 / mbs[1]) \
                    if mbs[0] != mbs[1] else 0.0
                fixed = m1 - act / mbs[0]
                target = microbatches * 2
                while (fixed + act / target > HBM_FIT
                       and target < max_mb):
                    target *= 2
                microbatches = min(target, max_mb)
            else:
                microbatches = min(microbatches * 2, max_mb)
            if verbose:
                print(f"  {mem_dev/2**30:.1f} GiB > fit; retry "
                      f"microbatches={microbatches}")
            continue
        if opt_cfg.moment_dtype == "float32":
            opt_cfg = AdamWConfig(moment_dtype="bfloat16")
            if verbose:
                print(f"  {mem_dev/2**30:.1f} GiB > fit at max microbatches; "
                      f"retry with bf16 optimizer moments")
            continue
        break                           # report honestly as not fitting
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    # ---- reduced-depth UNROLLED compiles: per-layer extrapolation ----------
    # (XLA cost_analysis counts a lax.scan body once regardless of trip
    # count, so depth information must come from unrolled probes: cost at
    # 1×pattern and 2×pattern unrolled gives the per-repeat delta.)
    if not probes:
        mem_dev = _mem_per_device(compiled)
        row = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "ok", "compile_s": t_compile,
               "microbatches": microbatches,
               "opt_moments": opt_cfg.moment_dtype,
               "bytes_per_device": float(mem_dev),
               "fits_hbm": bool(mem_dev <= HBM_BYTES),
               "mem_temp": int(getattr(mem, "temp_size_in_bytes", 0)),
               "mem_argument": int(getattr(mem, "argument_size_in_bytes", 0))}
        if verbose:
            print(f"--- {arch} × {shape_name} × {mesh_name} ---")
            print(f"compile {t_compile:.1f}s microbatches={microbatches} "
                  f"bytes/dev={mem_dev/2**30:.2f}GiB fits={row['fits_hbm']}")
        return row

    # probes run microbatches=1: a microbatch lax.scan would re-hide the
    # layer costs inside a while body; total math FLOPs are identical.
    cfg1 = dc.replace(cfg, num_layers=plen, unroll=True)
    cfg2 = dc.replace(cfg, num_layers=2 * plen, unroll=True)
    c1 = _cost_tuple(build_lowered(cfg1, shape, mesh, microbatches=1,
                                   opt_cfg=opt_cfg).compile())
    c2 = _cost_tuple(build_lowered(cfg2, shape, mesh, microbatches=1,
                                   opt_cfg=opt_cfg).compile())
    reps_total = cfg.num_layers / plen          # fractional incl. remainder
    flops_dev, bytes_dev, coll_dev = (
        base + (reps_total - 1.0) * max(two - base, 0.0)
        for base, two in zip(c1, c2))

    from repro.roofline import V5E, RooflineTerms
    mf = model_flops(cfg, shape)
    terms = RooflineTerms(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops_dev * chips, hlo_bytes=bytes_dev * chips,
        collective_bytes=coll_dev * chips,
        compute_s=flops_dev / V5E["peak_flops"],
        memory_s=bytes_dev / V5E["hbm_bw"],
        collective_s=coll_dev / V5E["ici_bw"],
        model_flops=mf, bytes_per_device=float(_mem_per_device(compiled)))

    row = terms.row()
    row.update({
        "status": "ok", "compile_s": t_compile,
        "microbatches": microbatches,
        "opt_moments": opt_cfg.moment_dtype,
        "collective_bytes": terms.collective_bytes,
        "hlo_bytes": terms.hlo_bytes,
        "fits_hbm": bool(terms.bytes_per_device <= HBM_BYTES),
        "mem_argument": int(getattr(mem, "argument_size_in_bytes", 0)),
        "mem_temp": int(getattr(mem, "temp_size_in_bytes", 0)),
        "mem_output": int(getattr(mem, "output_size_in_bytes", 0)),
        "mem_alias": int(getattr(mem, "alias_size_in_bytes", 0)),
    })
    if verbose:
        print(f"--- {arch} × {shape_name} × {mesh_name} ---")
        print(f"compile {t_compile:.1f}s microbatches={microbatches}")
        print(mem)
        print(f"roofline: compute {terms.compute_s * 1e3:.2f}ms "
              f"memory {terms.memory_s * 1e3:.2f}ms "
              f"collective {terms.collective_s * 1e3:.2f}ms "
              f"dominant={terms.dominant} useful={terms.useful_ratio:.3f} "
              f"bytes/dev={terms.bytes_per_device/2**30:.2f}GiB "
              f"fits={row['fits_hbm']}")
    return row


def lower_retrieval(*, multi_pod: bool, num_points: int = 2 ** 30,
                    verbose: bool = True) -> dict:
    """Dry-run of the paper's own system at production scale: 1B hybrid
    vectors sharded across the mesh 'data' axis.  Compiles BOTH the pass-1
    fan-out (LUT16 ADC + inverted index + local top-k + all-gather merge)
    and the full three-pass engine search (+ per-shard dense/sparse residual
    refinement, paper §5/§7.2)."""
    from repro.core.distributed import (make_sharded_search3_fn,
                                        make_sharded_search_fn)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    shards = mesh.shape["data"]
    n = num_points - num_points % (shards * 128)
    k_pq, l = 100, 16                  # 200 dense dims -> K=100 subspaces
    d_dense = 200
    d_active, l_max = 65536, 256       # per-shard compact columns
    r_max = 64                         # sparse residual entries per row
    q, nq = 128, 256

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    codes = sds((n, k_pq), jnp.uint8, P("data"))
    lut = sds((q, k_pq, l), jnp.float32, P())
    inv_rows = sds((shards * d_active, l_max), jnp.int32, P("data"))
    inv_vals = sds((shards * d_active, l_max), jnp.float32, P("data"))
    q_dims = sds((q, nq), jnp.int32, P())
    q_vals = sds((q, nq), jnp.float32, P())
    row_off = sds((shards,), jnp.int32, P("data"))

    t0 = time.time()
    fn1 = make_sharded_search_fn(mesh, k=100, adc="onehot-mxu")
    fn1.lower(codes, lut, inv_rows, inv_vals, q_dims, q_vals,
              row_off).compile()
    dt1 = time.time() - t0

    t0 = time.time()
    fn3 = make_sharded_search3_fn(mesh, h=100, alpha=5, beta=2,
                                  adc="onehot-mxu")
    compiled = fn3.lower(
        codes, lut, inv_rows, inv_vals,
        sds((n, d_dense), jnp.int8, P("data")),              # dense residual
        sds((d_dense,), jnp.float32, P()),
        sds((d_dense,), jnp.float32, P()),
        sds((n, r_max), jnp.int32, P("data")),               # sparse residual
        sds((n, r_max), jnp.float32, P("data")),
        q_dims, q_vals,
        sds((q, d_dense), jnp.float32, P()),
        sds((q, d_active + 1), jnp.float32, P()),
        row_off).compile()
    dt3 = time.time() - t0
    mem = compiled.memory_analysis()
    if verbose:
        print(f"--- retrieval 1B × {mesh_name}: pass-1 {dt1:.1f}s, "
              f"three-pass {dt3:.1f}s ---")
        print(mem)
    return {"arch": "hybrid-retrieval-1b", "shape": "search_q128",
            "mesh": mesh_name, "status": "ok", "compile_s": dt1 + dt3,
            "compile_pass1_s": dt1, "compile_three_pass_s": dt3}


# cheap-to-compile archs first so partial sweeps cover the most cells
_SWEEP_ORDER = [
    "stablelm-1.6b", "mamba2-780m", "qwen2-moe-a2.7b", "musicgen-medium",
    "qwen2-7b", "recurrentgemma-9b", "qwen2.5-14b", "deepseek-67b",
    "qwen3-moe-235b-a22b", "llama-3.2-vision-90b",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("off", "on", "both"),
                    default="off")
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out (JSONL resume)")
    ap.add_argument("--no-probes", action="store_true",
                    help="compile/memory proof only (no roofline probes)")
    ap.add_argument("--fit-from", default=None,
                    help="JSONL from a prior sweep: reuse fit decisions")
    args = ap.parse_args()

    hints = {}
    if args.fit_from and os.path.exists(args.fit_from):
        with open(args.fit_from) as f:
            for line in f:
                if line.strip():
                    r = json.loads(line)
                    hints[(r["arch"], r["shape"])] = r

    pods = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]
    cells = []
    if args.all:
        for arch in _SWEEP_ORDER:
            for shape in SHAPES:
                cells.append((arch, shape))
    elif args.arch:
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(args.arch, s) for s in shapes]

    done = set()
    rows = []
    if args.out and os.path.exists(args.out) and args.skip_done:
        with open(args.out) as f:
            for line in f:
                if line.strip():
                    r = json.loads(line)
                    rows.append(r)
                    done.add((r["arch"], r["shape"], r["mesh"]))

    def record(row):
        rows.append(row)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(row, default=str) + "\n")

    for multi_pod in pods:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        if args.retrieval and ("hybrid-retrieval-1b", "search_q128",
                               mesh_name) not in done:
            record(lower_retrieval(multi_pod=multi_pod))
        for arch, shape in cells:
            if (arch, shape, mesh_name) in done:
                continue
            try:
                record(lower_cell(arch, shape, multi_pod=multi_pod,
                                  probes=not args.no_probes,
                                  fit_hint=hints.get((arch, shape))))
            except Exception as e:  # a failure is a bug; record and continue
                traceback.print_exc()
                record({"arch": arch, "shape": shape, "mesh": mesh_name,
                        "status": "fail", "error": repr(e)})
            import sys
            sys.stdout.flush()
    fails = [r for r in rows if r.get("status") == "fail"]
    print(f"\n{len(rows)} cells: "
          f"{sum(r.get('status') == 'ok' for r in rows)} ok, "
          f"{sum(r.get('status') == 'skip' for r in rows)} skip, "
          f"{len(fails)} fail")
    if fails:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
