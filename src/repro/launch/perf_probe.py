import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# Perf-iteration probe: measure one cell's roofline terms quickly (unrolled
# 1x/2x-pattern extrapolation, no full-depth compile) under config/rule
# overrides, and append the result to results/perf_log.jsonl.
#
#   PYTHONPATH=src python -m repro.launch.perf_probe --arch qwen3-moe-235b-a22b \
#       --shape train_4k --set attn_chunk=1024 --note "bigger attn chunk"
#
# This is the §Perf inner loop: hypothesis -> --set change -> measure -> log.

import argparse
import dataclasses as dc
import json
import time

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import OPT_CFG, _cost_tuple, build_lowered
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.roofline import V5E, model_flops


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v == "true":
            v = True
        if v == "false":
            v = False
        out[k] = v
    return out


def probe(arch: str, shape_name: str, overrides: dict | None = None,
          rules: dict | None = None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    plen = len(Model(cfg).pattern)

    t0 = time.time()
    c1 = _cost_tuple(build_lowered(
        dc.replace(cfg, num_layers=plen, unroll=True), shape, mesh,
        microbatches=1, opt_cfg=OPT_CFG).compile())
    c2 = _cost_tuple(build_lowered(
        dc.replace(cfg, num_layers=2 * plen, unroll=True), shape, mesh,
        microbatches=1, opt_cfg=OPT_CFG).compile())
    reps = cfg.num_layers / plen
    flops, bytes_, coll = (a + (reps - 1.0) * max(b - a, 0.0)
                           for a, b in zip(c1, c2))
    terms = {
        "arch": arch, "shape": shape_name,
        "overrides": overrides or {},
        "compute_s": flops / V5E["peak_flops"],
        "memory_s": bytes_ / V5E["hbm_bw"],
        "collective_s": coll / V5E["ici_bw"],
        "model_flops": model_flops(cfg, shape),
        "hlo_flops_job": flops * mesh.size,
        "probe_s": time.time() - t0,
    }
    terms["dominant"] = max(
        ("compute", "memory", "collective"),
        key=lambda k: terms[f"{k}_s"])
    terms["useful_ratio"] = (terms["model_flops"] / terms["hlo_flops_job"]
                             if terms["hlo_flops_job"] else 0.0)
    if verbose:
        print(f"{arch} × {shape_name} {overrides or ''}: "
              f"compute {terms['compute_s']*1e3:.2f}ms "
              f"memory {terms['memory_s']*1e3:.2f}ms "
              f"collective {terms['collective_s']*1e3:.2f}ms "
              f"dominant={terms['dominant']} "
              f"useful={terms['useful_ratio']:.3f} "
              f"[probe {terms['probe_s']:.0f}s]")
    return terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", nargs="*", default=None,
                    help="config overrides k=v (e.g. attn_chunk=1024)")
    ap.add_argument("--note", default="")
    ap.add_argument("--log", default="results/perf_log.jsonl")
    args = ap.parse_args()
    terms = probe(args.arch, args.shape, parse_overrides(args.set))
    terms["note"] = args.note
    os.makedirs(os.path.dirname(args.log) or ".", exist_ok=True)
    with open(args.log, "a") as f:
        f.write(json.dumps(terms) + "\n")


if __name__ == "__main__":
    main()
