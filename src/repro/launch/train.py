"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b-smoke \
        --steps 100 --batch 8 --seq 128 --ckpt /tmp/ckpt_run

Any registered config (full or -smoke) is accepted; full configs on real
hardware would add --mesh to shard via the same param_pspecs rules the
dry-run proves out.  On CPU this runs single-device.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import Model
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = Model(cfg)
    trainer = Trainer(
        model,
        AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 20, 1),
                    decay_steps=args.steps),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch, seed=args.seed),
        TrainerConfig(num_steps=args.steps, microbatches=args.microbatches,
                      ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt),
    )
    _, _, hist = trainer.run(jax.random.PRNGKey(args.seed))
    losses = [h["loss"] for h in hist if not h.get("skipped")]
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
