"""Serving launchers.

LM mode (default): prefill a batch of prompts, decode N tokens, report
per-step latency — with either the exact head or the paper's PQ hybrid head.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b-smoke \
        --tokens 32 --batch 4 --pq-head

Retrieval mode (DESIGN.md §5): build a synthetic hybrid index, stand up the
batched QueryService, drive a ragged query stream through it twice (cold +
warm cache) with a mid-stream index refresh, and report QPS + cache + jit
stats.

    PYTHONPATH=src python -m repro.launch.serve --retrieval \
        --points 20000 --queries 64 --shards 4

Durable retrieval (DESIGN.md §7): ``--persist-dir DIR`` builds a mutable
index, bootstraps a snapshot store + mutation WAL there, and serves with
every mutation logged; ``--restore DIR`` resumes that store after a
crash/restart (snapshot load + WAL replay) and serves the recovered index.

    PYTHONPATH=src python -m repro.launch.serve --retrieval \
        --persist-dir /tmp/hybrid-store          # first run
    PYTHONPATH=src python -m repro.launch.serve --retrieval \
        --restore /tmp/hybrid-store              # after a restart

Cluster mode (DESIGN.md §8): ``--role shard`` runs ONE shard-server
process (primary / scorer / replica — the building block real deployments
lay out across hosts; delegates to ``repro.serve.cluster.shard_server``),
while ``--role router`` demos the whole tier locally: spawn a primary +
scorers (+ replicas) as subprocesses, route a query stream through the
fan-out with mutations interleaved, and report QPS + per-hop latency +
replication stats.

    PYTHONPATH=src python -m repro.launch.serve --role router \
        --points 2000 --cluster-scorers 2 --replicas 1
    PYTHONPATH=src python -m repro.launch.serve --role shard \
        --shard-role primary --store /tmp/hybrid-store --port 7001
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Model
from repro.serve import greedy_generate


def run_lm(args) -> None:
    """Decode-loop latency probe (exact vs PQ hybrid head)."""
    cfg = get_config(args.arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.perf_counter()
    out = greedy_generate(model, params, prompt, args.tokens, args.max_len,
                          use_pq_head=args.pq_head, penalty=args.penalty)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({dt / args.tokens * 1e3:.1f} ms/step, "
          f"head={'pq-hybrid' if args.pq_head else 'exact'})")
    print("sample:", jnp.asarray(out)[0, :16].tolist())


def _maybe_metrics_server(args, registry):
    """Stand up the ``--metrics-port`` text endpoint (DESIGN.md §9.1) over
    ``registry``; None when the flag wasn't given.  Port 0 binds an
    ephemeral port (printed)."""
    if getattr(args, "metrics_port", None) is None:
        return None
    from repro.obs import start_metrics_server
    ms = start_metrics_server(registry, args.metrics_port)
    print(f"metrics endpoint: http://127.0.0.1:{ms.port}/metrics")
    return ms


def run_durable_retrieval(args) -> None:
    """Durable serving loop (DESIGN.md §7): bootstrap or restore a snapshot
    store + WAL, mutate under load, and report recovery/persistence stats."""
    from repro.core.hybrid import HybridIndex, HybridIndexParams
    from repro.data import make_hybrid_dataset
    from repro.serve import QueryService

    ds = make_hybrid_dataset(num_points=args.points, num_queries=args.queries,
                             d_sparse=args.points, d_dense=64,
                             nnz_per_row=48, seed=args.seed)
    n0 = args.points - 64
    if args.restore:
        print(f"recovering from {args.restore} ...")
        t0 = time.perf_counter()
        svc = QueryService(restore_from=args.restore, h=args.h,
                           auto_compact=False)
        print(f"recovered in {time.perf_counter() - t0:.2f}s; "
              f"stats: {svc.stats()}")
    else:
        print(f"building durable index: {n0} points -> {args.persist_dir}")
        params = HybridIndexParams(keep_top=96, head_dims=64, kmeans_iters=6)
        idx = HybridIndex.build(ds.x_sparse[:n0], ds.x_dense[:n0], params,
                                mutable=True)
        svc = QueryService(index=idx, h=args.h,
                           persist_dir=args.persist_dir, auto_compact=False)
        new = svc.insert(ds.x_sparse[n0:], ds.x_dense[n0:])
        svc.delete(new[:8])
        print(f"logged {len(new)} inserts + 8 deletes to the WAL; "
              f"stats: {svc.stats()}")
    ms = _maybe_metrics_server(args, svc.obs.metrics)
    t0 = time.perf_counter()
    s, ids = svc.search_sparse(ds.q_sparse, ds.q_dense)
    dt = time.perf_counter() - t0
    print(f"served {ids.shape[0]} queries in {dt:.2f}s "
          f"(top ids {ids[0, :5].tolist()})")
    if ms is not None:
        ms.close()
    svc.close()


def run_retrieval(args) -> None:
    """QueryService under a ragged query stream: QPS, cache, refresh."""
    import numpy as np

    from repro.core.hybrid import HybridIndex, HybridIndexParams
    from repro.core.sparse_index import sparse_queries_to_padded
    from repro.data import make_hybrid_dataset
    from repro.serve import QueryService

    if args.restore or args.persist_dir:
        return run_durable_retrieval(args)

    print(f"building index: {args.points} points, {args.shards} shard(s)...")
    ds = make_hybrid_dataset(num_points=args.points, num_queries=args.queries,
                             d_sparse=args.points, d_dense=64,
                             nnz_per_row=48, seed=args.seed)
    params = HybridIndexParams(keep_top=96, head_dims=64, kmeans_iters=6)
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense, params)
    q_dims, q_vals = sparse_queries_to_padded(ds.q_sparse, idx.cols,
                                              nq_max=params.nq_max)
    q_dense = np.asarray(ds.q_dense, np.float32)
    svc = QueryService(idx.engine, h=args.h, buckets=(1, 8, 32),
                       cache_size=4 * args.queries, num_shards=args.shards,
                       id_map=idx.pi)
    ms = _maybe_metrics_server(args, svc.obs.metrics)

    rng = np.random.default_rng(args.seed)
    sizes = rng.integers(1, 33, 64)

    def stream():
        served = 0
        for q in sizes:
            rows = rng.integers(0, args.queries, int(q))
            svc.search(q_dims[rows], q_vals[rows], q_dense[rows])
            served += int(q)
        return served

    stream()                                    # jit warmup, cold cache
    t0 = time.perf_counter()
    n = stream()
    dt = time.perf_counter() - t0
    print(f"stream: {n} queries in {dt:.2f}s ({n / dt:.1f} QPS)")

    t0 = time.perf_counter()
    idx2 = HybridIndex.build(ds.x_sparse, ds.x_dense,
                             dataclasses.replace(params, seed=args.seed + 1))
    build_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    svc.refresh(idx2.engine, id_map=idx2.pi)
    swap_s = time.perf_counter() - t0
    print(f"refresh: rebuild {build_s:.2f}s off-path, swap {swap_s * 1e3:.2f} ms")

    info, jit = svc.cache_info(), svc.jit_cache_info()
    print(f"cache: {info.hits} hits / {info.misses} misses "
          f"(hit rate {info.hit_rate:.2f}, {info.evictions} evictions)")
    print(f"jit shapes: {jit.batch_shapes} (bound {jit.bound})")
    print("stats:", svc.stats())
    if ms is not None:
        ms.close()
    svc.close()


def run_router(args) -> None:
    """Local cluster demo (DESIGN.md §8): spawn the shard-server topology,
    drive mutations + searches through a ``ClusterRouter``, report stats."""
    import tempfile

    from repro.core.hybrid import HybridIndex, HybridIndexParams
    from repro.data import make_hybrid_dataset
    from repro.serve.cluster import LocalCluster

    n0 = args.points - 64
    ds = make_hybrid_dataset(num_points=args.points, num_queries=args.queries,
                             d_sparse=args.points, d_dense=64,
                             nnz_per_row=48, seed=args.seed)
    params = HybridIndexParams(keep_top=96, head_dims=64, kmeans_iters=6)
    idx = HybridIndex.build(ds.x_sparse[:n0], ds.x_dense[:n0], params,
                            mutable=True)
    root = tempfile.mkdtemp(prefix="cluster-demo-")
    print(f"spawning cluster: primary + {args.cluster_scorers} scorer(s) + "
          f"{args.replicas} replica(s) under {root}")
    with LocalCluster.launch(idx, root, num_scorers=args.cluster_scorers,
                             num_replicas=args.replicas) as cluster:
        router = cluster.router(h=args.h,
                                replica_max_lag=args.replica_max_lag)
        ms = _maybe_metrics_server(args, router.obs.metrics)
        new = router.insert(ds.x_sparse[n0:], ds.x_dense[n0:])
        router.delete(new[:8].tolist())
        t0 = time.perf_counter()
        s, ids = router.search_sparse(ds.q_sparse, ds.q_dense)
        dt = time.perf_counter() - t0
        print(f"served {ids.shape[0]} queries in {dt:.2f}s "
              f"(top ids {ids[0, :5].tolist()})")
        print("router status:", router.status())
        print("hop stage totals (s):", router.hops())
        if ms is not None:
            ms.close()
        router.close()


def main():
    """Parse args and dispatch to the LM, retrieval, or cluster launcher.

    ``--role shard`` short-circuits BEFORE the full parser: the remaining
    flags (with ``--shard-role`` mapped to the server's ``--role``) are
    handed verbatim to ``repro.serve.cluster.shard_server.main``, so one
    entry point launches any node of a hand-laid-out deployment."""
    import sys
    argv = sys.argv[1:]
    if "--role" in argv and argv[argv.index("--role") + 1] == "shard":
        from repro.serve.cluster import shard_server
        i = argv.index("--role")
        rest = argv[:i] + argv[i + 2:]
        rest = ["--role" if a == "--shard-role" else a for a in rest]
        return shard_server.main(rest)
    ap = argparse.ArgumentParser()
    ap.add_argument("--retrieval", action="store_true",
                    help="serve a hybrid retrieval index instead of an LM")
    # cluster mode (DESIGN.md §8)
    ap.add_argument("--role", choices=["router", "shard"],
                    help="cluster mode: 'shard' runs one shard-server "
                         "process; 'router' spawns and drives a local "
                         "cluster")
    ap.add_argument("--cluster-scorers", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=0)
    ap.add_argument("--replica-max-lag", type=int, default=0)
    # LM mode
    ap.add_argument("--arch")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--pq-head", action="store_true")
    ap.add_argument("--penalty", type=float, default=0.0)
    # retrieval mode
    ap.add_argument("--points", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--h", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--persist-dir",
                    help="bootstrap a durable snapshot store + WAL here "
                         "and serve with every mutation logged")
    ap.add_argument("--restore",
                    help="recover the index from this store (snapshot + "
                         "WAL replay) and serve it")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="expose the process's metrics registry as a text "
                         "endpoint on this port (0 = ephemeral; DESIGN.md "
                         "§9.1).  In --role shard mode the flag is "
                         "forwarded to the shard server")
    args = ap.parse_args()
    if args.role == "router":
        run_router(args)
    elif args.retrieval:
        run_retrieval(args)
    else:
        if not args.arch:
            ap.error("--arch is required in LM mode")
        run_lm(args)


if __name__ == "__main__":
    main()
