"""Serving launcher: prefill a batch of prompts, decode N tokens, report
per-step latency — with either the exact head or the paper's PQ hybrid head.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b-smoke \
        --tokens 32 --batch 4 --pq-head
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Model
from repro.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--pq-head", action="store_true")
    ap.add_argument("--penalty", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.perf_counter()
    out = greedy_generate(model, params, prompt, args.tokens, args.max_len,
                          use_pq_head=args.pq_head, penalty=args.penalty)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({dt / args.tokens * 1e3:.1f} ms/step, "
          f"head={'pq-hybrid' if args.pq_head else 'exact'})")
    print("sample:", jnp.asarray(out)[0, :16].tolist())


if __name__ == "__main__":
    main()
