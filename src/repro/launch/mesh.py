"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

from repro import compat

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is
    the slowest (DCN-connected) axis and carries only data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (requires host device count
    >= prod(shape), set via XLA_FLAGS in the test's subprocess)."""
    return compat.make_mesh(shape, axes)
