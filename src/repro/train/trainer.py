"""Training loop substrate.

make_train_step builds the pjit-able step: microbatched gradient
accumulation (lax.scan over microbatches, so accumulation lives *inside*
one XLA program and overlaps with the FSDP all-gathers), AdamW update,
metrics.

Trainer adds the production-loop concerns:
  * checkpoint/restart — deterministic data (pure function of step) means
    resume needs only (params, opt_state, step); batches re-derive;
  * async checkpointing off the critical path;
  * straggler/hang mitigation — per-step wall-clock watchdog that flags
    steps slower than `straggler_factor` × the trailing median (on real
    fleets this triggers preemption/respawn; here it logs and records);
  * loss-spike skip — optional skip of non-finite/spiking steps (keeps the
    run alive through data poison or a flaky host);
  * elastic re-mesh restore via checkpoint.restore_checkpoint(pspec_tree=…).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.optim import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainerConfig", "Trainer", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    num_steps: int = 100
    microbatches: int = 1
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0
    skip_nonfinite: bool = True


def make_train_step(model, opt_cfg: AdamWConfig, microbatches: int = 1,
                    cast_params_bf16: bool = False):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1, the global batch splits on axis 0 and gradients
    average across a lax.scan — identical math, 1/microbatches the peak
    activation memory.

    cast_params_bf16: mixed precision with fp32 master weights — matrices are
    cast to bf16 *inside* the differentiated step, so FSDP weight all-gathers
    move half the bytes (GSPMD hoists the convert before the collective);
    grads still arrive fp32 through the convert's cotangent."""

    def cast(params):
        if not cast_params_bf16:
            return params
        return jax.tree.map(
            lambda p: p.astype(jnp.bfloat16) if p.ndim >= 2 else p, params)

    def loss_fn(params, batch):
        return model.loss(cast(params), batch)

    def step(params, opt_state, batch):
        if microbatches <= 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                m_acc = jax.tree.map(jnp.add, m_acc, metrics)
                return (g_acc, m_acc), None

            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros_m = {"nll": jnp.zeros(()), "aux": jnp.zeros(()),
                       "zloss": jnp.zeros(())}
            (grads, metrics), _ = jax.lax.scan(
                acc_fn, (zeros_g, zeros_m), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, **opt_metrics)
        return params, opt_state, metrics

    return step


class Trainer:
    """Fault-tolerant single-controller loop (CPU-testable end to end)."""

    def __init__(self, model, opt_cfg: AdamWConfig, data_cfg: DataConfig,
                 tcfg: TrainerConfig):
        self.model = model
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.step_fn = jax.jit(make_train_step(model, opt_cfg,
                                               tcfg.microbatches))
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self.step_times: list[float] = []
        self.straggler_steps: list[int] = []

    def init_or_restore(self, key):
        params = self.model.init(key)
        opt_state = adamw_init(params, self.opt_cfg)
        start = 0
        last = latest_step(self.tcfg.ckpt_dir)
        if last is not None:
            tree = restore_checkpoint(self.tcfg.ckpt_dir, last,
                                      {"params": params, "opt": opt_state})
            params, opt_state = tree["params"], tree["opt"]
            start = last
        return params, opt_state, start

    def run(self, key, num_steps: int | None = None):
        params, opt_state, start = self.init_or_restore(key)
        num_steps = num_steps or self.tcfg.num_steps
        history = []
        for step in range(start, num_steps):
            batch = synthetic_batch(self.data_cfg, step)
            t0 = time.perf_counter()
            new_params, new_opt, metrics = self.step_fn(params, opt_state,
                                                        batch)
            loss = float(metrics["nll"])
            dt = time.perf_counter() - t0
            # straggler watchdog
            if len(self.step_times) >= 5:
                med = float(np.median(self.step_times[-20:]))
                if dt > self.tcfg.straggler_factor * med:
                    self.straggler_steps.append(step)
            self.step_times.append(dt)
            # loss-spike / NaN skip: keep old state, continue
            if self.tcfg.skip_nonfinite and not np.isfinite(loss):
                history.append({"step": step, "loss": loss, "skipped": True})
                continue
            params, opt_state = new_params, new_opt
            history.append({"step": step, "loss": loss, "skipped": False,
                            "sec": dt})
            if (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save_async(step + 1,
                                     {"params": params, "opt": opt_state})
            if (step + 1) % self.tcfg.log_every == 0:
                print(f"step {step + 1}: loss={loss:.4f} "
                      f"lr={float(metrics['lr']):.2e} {dt * 1e3:.0f}ms")
        self.ckpt.wait()
        return params, opt_state, history
