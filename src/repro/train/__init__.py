from .trainer import TrainerConfig, Trainer, make_train_step  # noqa: F401
