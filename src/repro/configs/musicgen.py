"""musicgen-medium [arXiv:2306.05284; hf]
48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 — decoder-only over
EnCodec tokens with cross-attention to text conditioning.

Modality frontend is a STUB per assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model) and a conditioning sequence
(B, 64, d_model); only the transformer backbone is modeled.  MusicGen's FFN
is non-gated GELU; we keep the gated form used framework-wide and note the
3/2 FLOP difference in DESIGN.md §Arch-applicability."""

from .base import ModelConfig, register

register(ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, norm="layernorm", act="gelu",
    cross_attn_every=1, num_cond_tokens=64, frontend="embeddings",
    pq_head=False,   # vocab 2048 — approximate MIPS head does not pay
))

register(ModelConfig(
    name="musicgen-medium-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=128, norm="layernorm", act="gelu",
    cross_attn_every=1, num_cond_tokens=8, frontend="embeddings",
    pq_head=False,
))
