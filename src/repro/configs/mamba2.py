"""mamba2-780m [arXiv:2405.21060; unverified]
48L d_model=1536 (attention-free) vocab=50280, SSD: d_state=128,
expand=2 (d_inner=3072), headdim=64 (48 heads), conv=4, chunk=256."""

from .base import ModelConfig, register

register(ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256, ssm_conv=4,
))

register(ModelConfig(
    name="mamba2-780m-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_chunk=16, ssm_conv=4,
))
