"""recurrentgemma-9b [arXiv:2402.19427; unverified]
38L d_model=4096 16H... pattern: (RG-LRU, RG-LRU, local-attn) 1:2;
local window 2048, MQA (kv=1), d_ff=12288 (GeGLU), vocab=256000,
lru_width=4096.  38 = 12×3 + 2 ⇒ two trailing RG-LRU layers."""

from .base import ModelConfig, register

register(ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, act="gelu", rope_theta=1e4,
    rglru_pattern=3, local_window=2048, lru_width=4096,
))

register(ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=128, vocab_size=512, act="gelu",
    rglru_pattern=3, local_window=32, lru_width=64,
))
