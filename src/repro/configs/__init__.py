from .base import (ModelConfig, ShapeConfig, SHAPES, ARCH_IDS,  # noqa: F401
                   get_config, list_archs)
