"""Model / shape configuration system.

Every assigned architecture registers an exact `ModelConfig` plus a reduced
`smoke` variant (same family, tiny dims) in its own module; `get_config(name)`
resolves either (``<arch>`` or ``<arch>-smoke``).
"""

from __future__ import annotations

import dataclasses
import importlib

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config", "list_archs",
           "register", "ARCH_IDS"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 => d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0
    norm: str = "rmsnorm"
    act: str = "silu"           # gated (SwiGLU/GeGLU per `act`)
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # hybrid (RecurrentGemma / Griffin): pattern (rglru, rglru, attn)
    rglru_pattern: int = 0      # 0 = none; 3 = attn every 3rd layer
    local_window: int = 0
    lru_width: int = 0
    # cross-attention (VLM / audio conditioning)
    cross_attn_every: int = 0   # k => layer i has cross-attn if (i+1) % k == 0
    num_cond_tokens: int = 0    # conditioning sequence length (stub frontend)
    frontend: str = "tokens"    # tokens | embeddings (stub supplies embeddings)
    # compute
    dtype: str = "bfloat16"
    remat: bool = True
    unroll: bool = False   # python-loop the layer stack (roofline probes only)
    attn_chunk: int = 512  # banded-flash chunk (peak attn memory ∝ S·chunk)
    loss_chunk: int = 512  # seq chunk for xent (never materialize B,S,V f32)
    # beyond-paper optimization levers (§Perf hillclimbs; defaults = baseline)
    kv_repeat: int = 1     # replicate KV heads r× so hkv·r divides the TP
                           # axis (vLLM-style; 2× KV cache for full attn TP)
    moe_seq_combine: bool = False  # keep MoE combine seq-sharded through the
                                   # gate-weighted k-sum (smaller all-gather)
    params_bf16_cast: bool = False  # cast matrices to bf16 inside train_step
                                    # (FSDP all-gathers move half the bytes)
    moe_shardmap_combine: bool = False  # explicit shard_map combine: psum the
                                        # (B,S,D) partial AFTER the k-sum (GSPMD
                                        # otherwise all-reduces (B,A,D) f32)

    @property
    def effective_kv_heads(self) -> int:
        return self.num_kv_heads * self.kv_repeat
    # paper-technique head (PQ-approximated logits; DESIGN.md §4)
    pq_head: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "qwen3-moe-235b-a22b", "qwen2-moe-a2.7b", "qwen2-7b", "stablelm-1.6b",
    "qwen2.5-14b", "deepseek-67b", "musicgen-medium", "recurrentgemma-9b",
    "llama-3.2-vision-90b", "mamba2-780m",
]

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all():
    for mod in ("qwen3_moe", "qwen2_moe", "qwen2_7b", "stablelm", "qwen25_14b",
                "deepseek_67b", "musicgen", "recurrentgemma", "llama_vision",
                "mamba2"):
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs(include_smoke: bool = False) -> list[str]:
    if not _REGISTRY:
        _load_all()
    return [k for k in _REGISTRY
            if include_smoke or not k.endswith("-smoke")]
