"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B; hf]
94L d_model=4096 64H (GQA kv=4, head_dim=128) moe_d_ff=1536 vocab=151936,
MoE 128 experts top-8 (no shared experts)."""

from .base import ModelConfig, register

register(ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936, qkv_bias=False, rope_theta=1e6,
    num_experts=128, num_experts_per_tok=8, num_shared_experts=0,
    moe_d_ff=1536,
))

register(ModelConfig(
    name="qwen3-moe-235b-a22b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512, rope_theta=1e6,
    num_experts=8, num_experts_per_tok=2, num_shared_experts=0, moe_d_ff=96,
))
