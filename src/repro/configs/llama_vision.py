"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 — cross-attn image
layers every 5th layer (20 of 100).

Vision frontend is a STUB per assignment: input_specs() provides precomputed
image patch embeddings (B, 1024, d_model) consumed by the cross-attention
layers; only the language backbone is modeled."""

from .base import ModelConfig, register

register(ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, rope_theta=5e5,
    cross_attn_every=5, num_cond_tokens=1024,
))

register(ModelConfig(
    name="llama-3.2-vision-90b-smoke", family="vlm",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, rope_theta=5e5,
    cross_attn_every=5, num_cond_tokens=16,
))
