"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
24L d_model=2048 16H (GQA kv=16) moe_d_ff=1408 vocab=151936,
MoE 60 routed top-4 + 4 shared experts (shared intermediate 4*1408=5632)."""

from .base import ModelConfig, register

register(ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936, qkv_bias=True, rope_theta=1e6,
    num_experts=60, num_experts_per_tok=4, num_shared_experts=4,
    moe_d_ff=1408,
))

register(ModelConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=96, vocab_size=512, qkv_bias=True, rope_theta=1e6,
    num_experts=6, num_experts_per_tok=2, num_shared_experts=2, moe_d_ff=96,
))
