"""Snapshot store: one durable, versioned copy of a pristine index
generation (DESIGN.md §7.1).

A snapshot is exactly what the batch build (or a compaction, which IS the
batch build) produces: every ``IndexArrays`` leaf — PQ codes in whatever
packing the engine serves (the packed two-per-byte form is stored as-is,
half the bytes on disk too), codebooks, the frozen residual grid, padded
posting lists, the tile head — plus the host-side artifacts search needs
(``pi``, the compact column space) and the retained corpus that makes the
generation MUTABLE again after a restart (``MutableState``'s initial rows
+ external ids + the auto-id counter).  By default mutations are NOT part
of a snapshot; they live in the WAL and are replayed through the normal
streaming machinery on recovery, so a plain snapshot is only taken at a
build/compaction point where the delta is empty (``version == 0``).  A
DELTA-STATE snapshot (``delta_state=True``; DESIGN.md §7.6) additionally
serializes the appended rows in insertion order plus the alive flags, and
load replays them through the same insert/delete machinery — recovery
under sustained ingest becomes snapshot + short WAL tail without waiting
for a compaction.

On-disk layout (all under one store root)::

    root/
      CURRENT                 {"format": 1, "snapshot": "snap-000002"}
      snap-000002/
        manifest.json         format version, params, scalars,
                              replay_from_seq, per-leaf table w/ sha256
        <leaf>.bin            raw C-order bytes per array leaf
      wal/wal-*.log           mutation segments (persist/wal.py)

Commit protocol: leaves + manifest are written into ``.tmp-snap-…``, each
blob fsync'd, then ONE atomic rename publishes the directory and CURRENT is
rewritten (tmp + rename) to point at it.  A crash anywhere before the
CURRENT swap leaves the previous snapshot authoritative and at worst a
``.tmp-snap-…`` directory that the next writer sweeps; a crash after it is
a completed commit.  Loading verifies every leaf's sha256 before the index
is allowed to serve.

Device-array re-derivation on load is the SAME deterministic host assembly
the batch build runs (``IndexArrays.build``: head scatter table, BCSR
tiles), so a loaded engine is bit-identical to the one that was saved.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.checkpoint.leaves import (fsync_dir, read_array_blob,
                                     write_array_blob)

__all__ = ["FORMAT_VERSION", "write_snapshot", "load_snapshot",
           "read_current", "list_snapshots", "store_files"]

FORMAT_VERSION = 1
_CURRENT = "CURRENT"
_MANIFEST = "manifest.json"
_SNAP_PREFIX, _TMP_PREFIX = "snap-", ".tmp-snap-"


def read_current(root: str) -> dict | None:
    """The committed CURRENT pointer, or None when the store is empty."""
    path = os.path.join(root, _CURRENT)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def list_snapshots(root: str) -> list[str]:
    """Committed snapshot directory names, oldest first."""
    if not os.path.isdir(root):
        return []
    return sorted(d for d in os.listdir(root) if d.startswith(_SNAP_PREFIX))


def store_files(root: str) -> list[str]:
    """Root-relative paths of the files a fresh follower needs to copy to
    bootstrap from this store — the snapshot-distribution manifest of the
    cluster tier (DESIGN.md §8.3): the CURRENT pointer plus every file of
    the snapshot it names, CURRENT LAST so a reader copying in order never
    commits a pointer before its target exists.  WAL segments are excluded
    on purpose — the tail ships separately (``MutationWAL.read_frames``)
    and keeps shipping after bootstrap."""
    cur = read_current(root)
    if cur is None:
        raise FileNotFoundError(
            f"{root!r} has no committed snapshot store (CURRENT missing)")
    snap = cur["snapshot"]
    snap_dir = os.path.join(root, snap)
    names = sorted(os.listdir(snap_dir))
    return [f"{snap}/{n}" for n in names] + [_CURRENT]


def _sweep_tmp(root: str) -> None:
    """Remove half-written ``.tmp-snap-…`` directories (crash litter)."""
    for d in os.listdir(root):
        if d.startswith(_TMP_PREFIX):
            shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def _index_leaves(index) -> dict[str, np.ndarray]:
    """Flatten everything a generation needs into named host arrays."""
    st = index.mutable_state
    xs0 = st.x_sparse0
    leaves = {
        "pi": index.pi,
        "cols_global_ids": np.asarray(index.cols.global_ids),
        "inv_rows": np.asarray(index.inv_index.rows),
        "inv_vals": np.asarray(index.inv_index.vals),
        "res_cols": np.asarray(index.sparse_residual.cols),
        "res_vals": np.asarray(index.sparse_residual.vals),
        "centers": np.asarray(index.codebooks.centers),
        "codes": np.asarray(index.codes),
        "dres_q": np.asarray(index.dense_residual.q),
        "dres_scale": np.asarray(index.dense_residual.scale),
        "dres_zero": np.asarray(index.dense_residual.zero),
        "corpus_data": xs0.data,
        "corpus_indices": xs0.indices,
        "corpus_indptr": xs0.indptr,
        "corpus_dense": st.x_dense0,
        "ids_built": st.ids_built,
    }
    if index.head is not None:
        leaves["head_block"] = np.asarray(index.head.block)
        leaves["head_occupancy"] = np.asarray(index.head.occupancy)
        leaves["head_dims"] = np.asarray(index.head.head_dims)
    return leaves


def _delta_leaves(st) -> dict[str, np.ndarray]:
    """Extra leaves of a DELTA-STATE snapshot (DESIGN.md §7.6): every
    appended row in INSERTION order (CSR parts + dense + external ids) plus
    the alive flags of both tiers.  Load replays the rows one by one
    through the normal ``MutableState.insert`` machinery — upsert kill
    chains included — then applies the flags as deletes, so the rebuilt
    delta shard / tombstone set is bit-identical to the one serialized."""
    if st.extra_sparse:
        xse = sp.vstack(st.extra_sparse, format="csr")
        xde = np.stack(st.extra_dense).astype(np.float32)
    else:
        xse = sp.csr_matrix((0, st.x_sparse0.shape[1]), dtype=np.float32)
        xde = np.zeros((0, st.x_dense0.shape[1]), np.float32)
    return {
        "extra_data": xse.data,
        "extra_indices": xse.indices,
        "extra_indptr": xse.indptr,
        "extra_dense": xde,
        "extra_ids": np.asarray(st.extra_ids, np.int64),
        "extra_alive": np.asarray(st.extra_alive, np.uint8),
        "alive0": st.alive0.astype(np.uint8),
    }


def write_snapshot(root: str, index, *, replay_from_seq: int,
                   keep_last: int = 2, delta_state: bool = False) -> str:
    """Serialize a mutable generation; atomic commit; returns the committed
    snapshot directory.

    ``replay_from_seq`` is the WAL sequence number recovery resumes from —
    every mutation below it is already folded into this snapshot's rows.
    ``keep_last`` older snapshots are garbage-collected after the commit.
    By default raises ``ValueError`` on a non-pristine index (pending delta
    rows or tombstones — compact first; a plain snapshot is a compaction
    output).  ``delta_state=True`` lifts that: the pending delta rows and
    alive flags are serialized too (DESIGN.md §7.6) and load replays them,
    so a LIVE index under ingest can checkpoint without compacting."""
    st = index.mutable_state
    if st is None:
        raise ValueError("snapshots need a mutable index "
                         "(HybridIndex.build(..., mutable=True))")
    if not delta_state and (st.version != 0 or st.delta.count
                            or st.main_tombstones):
        raise ValueError(
            "snapshot requires a pristine generation (no pending delta rows "
            "or tombstones): compact() first — a snapshot is by definition "
            "a build/compaction output, mutations belong to the WAL "
            "(or pass delta_state=True to checkpoint the live state)")
    os.makedirs(root, exist_ok=True)
    _sweep_tmp(root)
    # max+1, not count+1: GC shrinks the list, and a recycled name would
    # collide with a still-existing directory at the commit rename
    existing = [int(s[len(_SNAP_PREFIX):]) for s in list_snapshots(root)]
    seqno = max(existing, default=0) + 1
    name = f"{_SNAP_PREFIX}{seqno:06d}"
    tmp = os.path.join(root, f"{_TMP_PREFIX}{seqno:06d}")
    final = os.path.join(root, name)
    os.makedirs(tmp)
    try:
        leaves = _index_leaves(index)
        if delta_state:
            leaves.update(_delta_leaves(st))
        table = {k: write_array_blob(os.path.join(tmp, f"{k}.bin"), v)
                 for k, v in leaves.items()}
        manifest = {
            "format": FORMAT_VERSION,
            "replay_from_seq": int(replay_from_seq),
            "params": dataclasses.asdict(index.params),
            "scalars": {
                "num_points": int(index.num_points),
                "d_dense": int(index.d_dense),
                "inv_num_points": int(index.inv_index.num_points),
                "codes_packed": bool(index.engine.arrays.codes_packed),
                "backend": index.engine.backend.value,
                "next_id": int(st.next_id),
                "delta_capacity": int(st.delta.capacity),
                "delta_state": bool(delta_state),
                "corpus_shape": list(st.x_sparse0.shape),
                "head": (None if index.head is None else
                         {"block_rows": index.head.block_rows,
                          "block_cols": index.head.block_cols}),
            },
            "leaves": table,
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        # the blobs' contents are fsync'd, but their directory ENTRIES
        # live in tmp's dirent table — flush those before the publish
        # rename, or a committed snapshot could point at files that never
        # hit disk
        fsync_dir(tmp)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    os.rename(tmp, final)                      # publish the directory
    fsync_dir(root)
    cur_tmp = os.path.join(root, _CURRENT + ".tmp")
    with open(cur_tmp, "w") as f:
        json.dump({"format": FORMAT_VERSION, "snapshot": name}, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(cur_tmp, os.path.join(root, _CURRENT))   # commit
    fsync_dir(root)
    for old in list_snapshots(root)[:-max(keep_last, 1)]:
        shutil.rmtree(os.path.join(root, old), ignore_errors=True)
    return final


def load_snapshot(root: str, *, snapshot: str | None = None,
                  backend=None, verify: bool = True):
    """Load the committed (or a named) snapshot back into a mutable
    ``HybridIndex``; returns ``(index, manifest)``.

    Every leaf's sha256 is checked (``verify=False`` skips, for benchmarks
    only).  ``backend`` overrides the recorded engine backend — the stored
    codes stay in their recorded packing; ref/onehot backends unpack in-jit,
    so any backend can serve any snapshot."""
    from repro.core.engine import Backend, IndexArrays, ScoringEngine
    from repro.core.hybrid import HybridIndex, HybridIndexParams
    from repro.core.pq import PQCodebooks, ScalarQuant
    from repro.core.sparse_index import (CompactColumns, PaddedInvertedIndex,
                                         PaddedSparseRows, TileSparseHead)
    from repro.core.streaming import MutableState

    if snapshot is None:
        cur = read_current(root)
        if cur is None:
            raise FileNotFoundError(f"no committed snapshot under {root!r}")
        if cur.get("format") != FORMAT_VERSION:
            raise ValueError(f"unsupported snapshot store format "
                             f"{cur.get('format')!r} (have {FORMAT_VERSION})")
        snapshot = cur["snapshot"]
    snap_dir = os.path.join(root, snapshot)
    with open(os.path.join(snap_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    if manifest.get("format") != FORMAT_VERSION:
        raise ValueError(f"unsupported snapshot format "
                         f"{manifest.get('format')!r}")
    table = manifest["leaves"]

    def leaf(name):
        return read_array_blob(os.path.join(snap_dir, table[name]["file"]),
                               table[name], verify=verify)

    sc = manifest["scalars"]
    params = HybridIndexParams(**manifest["params"])
    if backend is not None:
        params = dataclasses.replace(
            params, backend=Backend.from_name(backend).value,
            pack_codes=bool(sc["codes_packed"]))
    resolved = params.resolve_backend()

    cols = CompactColumns(global_ids=leaf("cols_global_ids"))
    inv_index = PaddedInvertedIndex(rows=jnp.asarray(leaf("inv_rows")),
                                    vals=jnp.asarray(leaf("inv_vals")),
                                    num_points=int(sc["inv_num_points"]))
    head = None
    head_dim_ids = np.empty(0, np.int32)
    if sc["head"] is not None:
        head = TileSparseHead(block=jnp.asarray(leaf("head_block")),
                              occupancy=jnp.asarray(leaf("head_occupancy")),
                              head_dims=jnp.asarray(leaf("head_dims")),
                              block_rows=int(sc["head"]["block_rows"]),
                              block_cols=int(sc["head"]["block_cols"]))
        head_dim_ids = np.asarray(head.head_dims)
    sparse_residual = PaddedSparseRows(cols=jnp.asarray(leaf("res_cols")),
                                       vals=jnp.asarray(leaf("res_vals")))
    codebooks = PQCodebooks(centers=jnp.asarray(leaf("centers")))
    dres = ScalarQuant(q=jnp.asarray(leaf("dres_q")),
                       scale=jnp.asarray(leaf("dres_scale")),
                       zero=jnp.asarray(leaf("dres_zero")))
    arrays = IndexArrays.build(
        codebooks=codebooks, codes=jnp.asarray(leaf("codes")),
        inv_index=inv_index, head=head, dense_residual=dres,
        sparse_residual=sparse_residual,
        num_points=int(sc["num_points"]), d_active=cols.num_active,
        with_bcsr=resolved in (Backend.PALLAS, Backend.PALLAS_PACKED),
        pre_packed=bool(sc["codes_packed"]))
    engine = ScoringEngine(arrays=arrays, backend=resolved)
    idx = HybridIndex(params=params, num_points=int(sc["num_points"]),
                      pi=leaf("pi"), cols=cols, inv_index=inv_index,
                      head=head, head_dim_ids=head_dim_ids,
                      sparse_residual=sparse_residual, codebooks=codebooks,
                      codes=arrays.codes, dense_residual=dres,
                      d_dense=int(sc["d_dense"]), engine=engine)
    xs0 = sp.csr_matrix(
        (leaf("corpus_data"), leaf("corpus_indices"), leaf("corpus_indptr")),
        shape=tuple(sc["corpus_shape"]))
    ms = MutableState(
        idx, xs0, leaf("corpus_dense"), ext_ids=leaf("ids_built"),
        # restore the pre-sized delta capacity: replaying a long WAL tail
        # into the default would re-pay every growth re-materialization
        delta_capacity=int(sc.get("delta_capacity", 64)))
    idx.mutable_state = ms
    if sc.get("delta_state"):
        # DELTA-STATE snapshot (DESIGN.md §7.6): replay the serialized
        # appended rows one by one through the NORMAL insert path — same
        # order, same ids, so every upsert kill chain, capacity doubling
        # and posting append happens exactly as it did live — then apply
        # the stored alive flags as deletes.  Bit-identical final state.
        eids = leaf("extra_ids")
        ealive = leaf("extra_alive").astype(bool)
        alive0 = leaf("alive0").astype(bool)
        xse = sp.csr_matrix(
            (leaf("extra_data"), leaf("extra_indices"),
             leaf("extra_indptr")),
            shape=(len(eids), int(sc["corpus_shape"][1])))
        xde = leaf("extra_dense")
        for j in range(len(eids)):
            ms.insert(xse[j], xde[j:j + 1], ids=eids[j:j + 1])
        dead = [e for e, (kind, i) in ms._loc.items()
                if not (alive0[i] if kind == "init" else ealive[i])]
        if dead:
            ms.delete(sorted(dead))
    ms.next_id = max(ms.next_id, int(sc["next_id"]))
    return idx, manifest
