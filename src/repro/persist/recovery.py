"""Crash recovery: snapshot load + WAL replay (DESIGN.md §7.3).

``recover(root)`` rebuilds the exact serving state a crashed process had at
its last durably-acked mutation:

1. load the committed snapshot (``persist/snapshot.py``; every leaf
   checksum-verified) into a mutable ``HybridIndex`` — bit-identical device
   arrays, empty delta;
2. replay the WAL tail (records with ``seq >= replay_from_seq``, stopping
   at the first torn/corrupt record) through the NORMAL streaming mutation
   path — ``MutableState.insert``/``delete`` re-run encode-on-insert against
   the loaded frozen artifacts, so the rebuilt delta shard, tombstone set
   and posting lists are bit-identical to the ones the crashed process
   served (property-tested across backends and odd/even K in
   tests/test_persist.py).

``Durability`` is the attach point the serving layer drives: it owns the
WAL handle, logs every acked mutation, and cuts a new snapshot + rotates +
truncates the log at each compaction (``checkpoint()``).  The crash matrix
— which failure window loses what — is DESIGN.md §7.4.
"""

from __future__ import annotations

import dataclasses
import os
import shutil

import numpy as np
import scipy.sparse as sp

from .snapshot import load_snapshot, read_current, write_snapshot
from .wal import RECORD_DELETE, RECORD_INSERT, RECORD_NOOP, MutationWAL

__all__ = ["Durability", "RecoveryResult", "recover", "bootstrap",
           "apply_record"]

_WAL_SUBDIR = "wal"


@dataclasses.dataclass
class RecoveryResult:
    """What ``recover`` found: the rebuilt index, the re-attached
    ``Durability`` (appends continue the same WAL), the snapshot it loaded,
    how many tail records were replayed, and the last applied sequence
    number (0 when the WAL tail was empty)."""
    index: object
    durability: "Durability"
    snapshot: str
    replayed: int
    last_seq: int


def apply_record(index, record) -> None:
    """Apply one WAL record through the normal mutation path — replay and
    live serving share every line of encode/tombstone machinery."""
    if record.kind == RECORD_INSERT:
        a = record.arrays
        xs = sp.csr_matrix((a["data"], a["indices"], a["indptr"]),
                           shape=tuple(np.asarray(a["shape"])))
        index.mutable_state.insert(xs, a["dense"], ids=a["ids"])
    elif record.kind == RECORD_DELETE:
        index.mutable_state.delete(record.arrays["ids"])
    elif record.kind == RECORD_NOOP:
        pass          # term barrier: advances the applied seq, nothing else
    else:
        raise ValueError(f"unknown WAL record kind {record.kind!r} "
                         f"at seq {record.seq}")


class Durability:
    """The WAL + snapshot-store handle a durable index serves through.

    Lifecycle: ``bootstrap(root, index)`` for a fresh store (initial
    snapshot of the just-built generation + empty WAL), ``recover(root)``
    after a restart.  The owner (``QueryService`` or a direct caller)
    serializes calls — mutations are logged under the same lock that
    applies them."""

    def __init__(self, root: str, wal: MutationWAL):
        self.root = root
        self.wal = wal
        # a failed append POISONS the handle: the in-memory index has a
        # mutation the log doesn't, so acking anything further would let
        # recoverable and served state diverge silently.  The owner checks
        # ensure_ok() before accepting new mutations; serving reads on.
        self.failed = False

    def ensure_ok(self) -> None:
        """Refuse new mutations after an append failure — restart from the
        store to get back to a recoverable state."""
        if self.failed:
            raise RuntimeError(
                "durability is poisoned: a WAL append failed, so the "
                "in-memory index holds an unlogged mutation; restart from "
                f"the store at {self.root!r} to resume durable serving")

    # -- mutation logging -------------------------------------------------

    def log_insert(self, x_sparse, x_dense, ids, *,
                   sync: bool | None = None) -> int:
        """Log one applied insert batch; returns its WAL seq.  With the
        default ``sync=None`` the record is fsync'd per the WAL's policy
        before returning; ``sync=False`` defers the disk sync to a later
        ``sync(seq)`` — the group-commit ack path (DESIGN.md §7.6).
        An append failure poisons the handle (``ensure_ok``)."""
        try:
            return self.wal.append_insert(sp.csr_matrix(x_sparse),
                                          np.atleast_2d(
                                              np.asarray(x_dense,
                                                         np.float32)),
                                          ids, sync=sync)
        except BaseException:
            self.failed = True
            raise

    def log_delete(self, ids, *, sync: bool | None = None) -> int:
        """Log one applied delete; returns its WAL seq (``sync`` as in
        ``log_insert``).  An append failure poisons the handle
        (``ensure_ok``)."""
        try:
            return self.wal.append_delete(ids, sync=sync)
        except BaseException:
            self.failed = True
            raise

    def log_noop(self, *, sync: bool | None = None) -> int:
        """Log a term-barrier no-op (``MutationWAL.append_noop``) — the
        first record a freshly promoted primary writes; returns its WAL
        seq.  An append failure poisons the handle (``ensure_ok``)."""
        try:
            return self.wal.append_noop(sync=sync)
        except BaseException:
            self.failed = True
            raise

    def sync(self, seq: int) -> None:
        """Make the record at ``seq`` durable (group commit: a no-op when a
        shared fsync already covered it — see ``MutationWAL.sync_to``).
        The mutation is acked only after this returns; a failed fsync
        poisons the handle like a failed append."""
        try:
            self.wal.sync_to(seq)
        except BaseException:
            self.failed = True
            raise

    # -- snapshot cut points ----------------------------------------------

    def checkpoint(self, index, *, keep_last: int = 2) -> str:
        """Cut a durable snapshot of a pristine (just-compacted/built)
        generation: rotate the WAL so the snapshot's replay horizon starts
        a fresh segment, commit the snapshot, then truncate the segments it
        supersedes.  Crash-safe at every step — until the CURRENT pointer
        swaps, the previous snapshot + the uncut log still recover the same
        logical corpus (DESIGN.md §7.4).  Returns the snapshot directory."""
        replay_from = self.wal.rotate()
        path = write_snapshot(self.root, index,
                              replay_from_seq=replay_from,
                              keep_last=keep_last)
        self.wal.truncate_before(replay_from)
        return path

    def delta_checkpoint(self, index, *, keep_last: int = 2) -> str:
        """Cut a DELTA-STATE snapshot of a LIVE mutable index — delta rows,
        alive flags and tombstones included (DESIGN.md §7.6) — so recovery
        under sustained ingest is snapshot-load + a short WAL tail instead
        of replaying every mutation since the last compaction.  Same
        rotate/commit/truncate protocol as ``checkpoint`` (and the same
        §7.4 crash windows); the rotation fsyncs the sealed segment, so
        every record the snapshot folds in is already durable.  Returns
        the snapshot directory."""
        replay_from = self.wal.rotate()
        path = write_snapshot(self.root, index,
                              replay_from_seq=replay_from,
                              keep_last=keep_last, delta_state=True)
        self.wal.truncate_before(replay_from)
        return path

    def close(self) -> None:
        """Close the WAL append handle (idempotent)."""
        self.wal.close()


def bootstrap(root: str, index, *, sync: bool = True,
              keep_last: int = 2, metrics=None) -> Durability:
    """Initialize an EMPTY store root with the initial snapshot of a
    freshly built mutable index and an empty WAL; returns the attached
    ``Durability``.  Refuses a root that already holds a committed store
    (use ``recover`` to resume it — silently re-initializing would orphan
    its WAL tail)."""
    if read_current(root) is not None:
        raise ValueError(f"{root!r} already holds a committed snapshot "
                         "store; use persist.recover() to resume it")
    os.makedirs(root, exist_ok=True)
    # no committed store => anything under wal/ is litter from a failed
    # bootstrap; sweep it so the fresh log really starts at seq 1
    wal_dir = os.path.join(root, _WAL_SUBDIR)
    if os.path.isdir(wal_dir):
        shutil.rmtree(wal_dir)
    # snapshot FIRST (it also validates the index is pristine): a rejected
    # index must not leave an open WAL handle or a stray wal/ directory
    write_snapshot(root, index, replay_from_seq=1, keep_last=keep_last)
    return Durability(root, MutationWAL(wal_dir, sync=sync,
                                        metrics=metrics))


def recover(root: str, *, backend=None, sync: bool = True,
            verify: bool = True, metrics=None) -> RecoveryResult:
    """Snapshot-load + WAL-replay; returns the rebuilt mutable index and a
    re-attached ``Durability`` whose appends continue the recovered log
    (the torn tail, if any, was truncated when the WAL reopened)."""
    cur = read_current(root)
    if cur is None:
        raise FileNotFoundError(
            f"{root!r} has no committed snapshot store (CURRENT missing); "
            "bootstrap one with persist.bootstrap(root, index)")
    index, manifest = load_snapshot(root, backend=backend, verify=verify)
    # a store with no WAL files yet (a follower's freshly fetched snapshot
    # — WAL segments are never part of snapshot distribution) starts its
    # log AT the snapshot's replay horizon, so shipped frames continue it
    # without a fake gap
    wal = MutationWAL(os.path.join(root, _WAL_SUBDIR), sync=sync,
                      start_seq=int(manifest["replay_from_seq"]),
                      metrics=metrics)
    replayed, last_seq = 0, 0
    for record in wal.records(from_seq=manifest["replay_from_seq"]):
        apply_record(index, record)
        replayed += 1
        last_seq = record.seq
    # opportunistic hygiene: segments a committed snapshot already covers
    wal.truncate_before(manifest["replay_from_seq"])
    return RecoveryResult(index=index, durability=Durability(root, wal),
                          snapshot=cur["snapshot"], replayed=replayed,
                          last_seq=last_seq)
