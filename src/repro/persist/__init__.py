"""Durable index persistence (DESIGN.md §7): snapshot store + mutation WAL
+ crash recovery for the streaming mutable index.

* ``snapshot`` — versioned on-disk copies of a pristine generation
  (manifest + checksummed per-leaf blobs, atomic rename-on-commit);
* ``wal`` — framed, checksummed, segmented log of every acked mutation,
  truncated at each compaction snapshot;
* ``recovery`` — ``recover()`` = snapshot-load + WAL-tail replay through
  the normal streaming machinery, bit-identical to the never-crashed index;
  ``Durability``/``bootstrap()`` are the serving layer's attach points
  (``QueryService(persist_dir=…)`` / ``QueryService(restore_from=…)``,
  ``HybridIndex.load``).
"""

from .snapshot import (FORMAT_VERSION, list_snapshots,  # noqa: F401
                       load_snapshot, read_current, store_files,
                       write_snapshot)
from .wal import (RECORD_DELETE, RECORD_INSERT, RECORD_NOOP,  # noqa: F401
                  MutationWAL, WalRecord)
from .recovery import (Durability, RecoveryResult, apply_record,  # noqa: F401
                       bootstrap, recover)
