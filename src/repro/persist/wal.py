"""Mutation write-ahead log (DESIGN.md §7.2).

Every acked ``insert``/``delete`` appends ONE framed record before the call
returns, so a process crash loses at most the mutation that was still being
written — and that one was never acked to the client.  Records are the
normalized mutation inputs (CSR parts + dense rows + external ids), NOT
encoded index state: replay re-runs the exact ``core/streaming.py``
encode-on-insert machinery, which is what makes a recovered index
bit-identical to the never-crashed one (tests/test_persist.py).

Frame format (little-endian, 27-byte header)::

    magic   2s   b"WR"
    kind    u8   1 = insert, 2 = delete
    seq     u64  global monotone mutation sequence number
    term    u64  monotone primary term (DESIGN.md §8.7): every record is
                 stamped with the term of the primary that wrote it, and a
                 log refuses shipped frames from a LOWER term than its own
                 — the fence that stops a zombie ex-primary's post-
                 promotion writes from entering a follower's log
    length  u32  payload byte count
    crc32   u32  zlib.crc32 of magic+kind+seq+term+length THEN the payload
                 — the header fields are covered too, so a flipped bit in
                 ``seq``, ``term`` or ``kind`` is a detected error, not a
                 silently skipped or reordered mutation
    payload      checkpoint.leaves.pack_arrays of the record's arrays

The current term persists in a ``TERM`` file beside the segments (written
atomically + fsync'd by ``set_term``) and is additionally recovered from
the scanned active segment's records, so a restarted node can never
come back believing an OLDER term than anything it durably wrote.

Truncation policy: a reader stops at the FIRST anomaly — short header,
wrong magic, short payload, or crc mismatch — and everything before it is
the recovered state ("recover to the last complete record").  A torn tail
is expected after a crash, so reopening the log for append truncates the
garbage and resumes; corruption earlier in the stream also stops the scan
there (later records' preconditions may be gone), which recovery reports
through its replayed-count/last-seq result rather than by resurrecting
records past the damage.

Segmentation: each file ``wal-<first_seq>.log`` covers records
``[first_seq, next segment's first_seq)``.  ``rotate()`` starts a fresh
segment at a snapshot/compaction point; ``truncate_before(seq)`` deletes
whole segments strictly below the snapshot's replay horizon.  Replay after
recovery therefore touches exactly the tail the latest snapshot doesn't
already contain.
"""

from __future__ import annotations

import dataclasses
import os
import struct
import threading
import time
import zlib

import numpy as np

from repro.checkpoint.leaves import fsync_dir, pack_arrays, unpack_arrays

__all__ = ["MutationWAL", "WalRecord", "RECORD_INSERT", "RECORD_DELETE",
           "RECORD_NOOP"]

RECORD_INSERT = 1
RECORD_DELETE = 2
RECORD_NOOP = 3       # term barrier: no state change, just a durable term

_MAGIC = b"WR"
_HEADER = struct.Struct("<2sBQQII")     # magic, kind, seq, term, len, crc32
_PREFIX = struct.Struct("<2sBQQI")      # the crc-covered header fields
_SEG_PREFIX, _SEG_SUFFIX = "wal-", ".log"
_TERM_FILE = "TERM"


def _frame_crc(kind: int, seq: int, term: int, payload: bytes) -> int:
    """crc32 over the header prefix (magic, kind, seq, term, length) AND
    the payload, so header corruption is detected, not silently replayed."""
    return zlib.crc32(payload,
                      zlib.crc32(_PREFIX.pack(_MAGIC, kind, seq, term,
                                              len(payload))))


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One decoded WAL record: the mutation kind, its global sequence
    number, the primary term that wrote it, and the payload arrays
    (``pack_arrays`` names)."""
    seq: int
    kind: int
    arrays: dict
    term: int = 1


def _segment_path(wal_dir: str, first_seq: int) -> str:
    return os.path.join(wal_dir, f"{_SEG_PREFIX}{first_seq:020d}{_SEG_SUFFIX}")


def _segment_first_seq(name: str) -> int | None:
    if not (name.startswith(_SEG_PREFIX) and name.endswith(_SEG_SUFFIX)):
        return None
    try:
        return int(name[len(_SEG_PREFIX):-len(_SEG_SUFFIX)])
    except ValueError:
        return None


def _scan_segment(path: str):
    """Decode one segment file.  Returns ``(records, valid_bytes, clean)``:
    every complete record in order, the byte offset of the first anomaly
    (== file size when clean), and whether the file ended exactly on a
    record boundary."""
    with open(path, "rb") as f:
        buf = f.read()
    records, off = [], 0
    while True:
        header = buf[off:off + _HEADER.size]
        if len(header) < _HEADER.size:
            return records, off, len(header) == 0
        magic, kind, seq, term, length, crc = _HEADER.unpack(header)
        if magic != _MAGIC:
            return records, off, False
        payload = buf[off + _HEADER.size:off + _HEADER.size + length]
        if len(payload) < length or \
                _frame_crc(kind, seq, term, payload) != crc:
            return records, off, False
        records.append(WalRecord(seq=seq, kind=kind, term=term,
                                 arrays=unpack_arrays(payload)))
        off += _HEADER.size + length


def _has_valid_frame_after(buf: bytes, start: int) -> bool:
    """True if a crc-valid frame decodes anywhere past ``start`` — the
    torn-tail / bitrot discriminator: a crash leaves garbage with nothing
    decodable after it, mid-log corruption leaves acked records stranded
    past the damage (and truncating those would silently lose them)."""
    i = buf.find(_MAGIC, start)
    while i != -1:
        header = buf[i:i + _HEADER.size]
        if len(header) == _HEADER.size:
            magic, kind, seq, term, length, crc = _HEADER.unpack(header)
            payload = buf[i + _HEADER.size:i + _HEADER.size + length]
            if len(payload) == length and _frame_crc(kind, seq, term,
                                                     payload) == crc:
                return True
        i = buf.find(_MAGIC, i + 1)
    return False


class MutationWAL:
    """Append-only, segmented, checksummed mutation log.

    Opening an existing directory scans the ACTIVE (last) segment — the
    only one a crash can tear — truncates any torn tail, and resumes the
    sequence counter after its last complete record, so
    append-after-recovery continues the same log.

    Durability is GROUP COMMIT (DESIGN.md §7.6): ``append`` frames and
    flushes the record under the internal append lock and returns its
    sequence number; the ack is ``sync_to(seq)``, which fsyncs AT MOST
    once for every batch of writes that raced in before it — one disk
    sync covers (and acks) all of them.  ``append(..., sync=True)`` /
    the default ``sync=None`` with ``self.sync`` keep the old
    one-call-one-ack behavior on top of the shared machinery, and
    ``append_many`` amortizes framing + flush + fsync over a whole batch
    explicitly."""

    def __init__(self, wal_dir: str, *, sync: bool = True,
                 start_seq: int = 1, metrics=None):
        self.wal_dir = wal_dir
        self.sync = sync
        # durability instruments (DESIGN.md §9.1): optional registry-backed
        # histograms/gauge, plus always-on plain attributes so
        # ``QueryService.stats()`` can report fsync health even when no
        # registry was threaded through.
        from repro.obs.metrics import NULL_REGISTRY
        reg = NULL_REGISTRY if metrics is None else metrics
        self._h_fsync = reg.histogram("wal.fsync_s")
        self._h_batch = reg.histogram(
            "wal.group_commit_batch",
            bounds=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024))
        self._g_backlog = reg.gauge("wal.unsynced_backlog")
        self.last_fsync_s = 0.0      # duration of the most recent fsync
        self.last_group_batch = 0    # records that fsync covered (acked)
        # _append_lock orders frame bytes + next_seq; _sync_lock serializes
        # fsyncs and guards _synced_seq.  Lock order: _sync_lock BEFORE
        # _append_lock (sync_to, rotate); append takes only _append_lock.
        self._append_lock = threading.Lock()
        self._sync_lock = threading.Lock()
        os.makedirs(wal_dir, exist_ok=True)
        self.term = self._read_term_file()
        self._segments = sorted(
            s for s in (_segment_first_seq(n) for n in os.listdir(wal_dir))
            if s is not None)
        # ``start_seq``: first sequence number of a BRAND-NEW log (ignored
        # when segments already exist).  A follower bootstrapping from a
        # fetched snapshot has no WAL files, but the snapshot's replay
        # horizon is ``replay_from_seq`` — its log must continue THERE, or
        # the first shipped frame after a compaction would look like a gap.
        self.next_seq = start_seq
        if not self._segments:
            self._segments = [start_seq]
            self._file = open(_segment_path(wal_dir, start_seq), "ab")
        else:
            active = _segment_path(wal_dir, self._segments[-1])
            records, valid, clean = _scan_segment(active)
            if not clean:
                with open(active, "rb") as f:
                    buf = f.read()
                if _has_valid_frame_after(buf, valid + 1):
                    raise ValueError(
                        f"{active}: corruption at byte {valid} with intact "
                        "records after it — this is bitrot, not a torn "
                        "tail; refusing to truncate acked mutations "
                        "(restore the file or cut a fresh snapshot)")
                with open(active, "r+b") as f:     # drop the torn tail
                    f.truncate(valid)
            self.next_seq = (records[-1].seq + 1 if records
                             else self._segments[-1])
            # a durably written record proves its term was adopted, even
            # if the crash beat the TERM-file write
            if records:
                self.term = max(self.term,
                                max(r.term for r in records))
            self._file = open(active, "ab")
        # nothing is pending at open: everything on disk counts as synced
        self._synced_seq = self.next_seq - 1

    # -- term fencing (DESIGN.md §8.7) -------------------------------------

    def _read_term_file(self) -> int:
        path = os.path.join(self.wal_dir, _TERM_FILE)
        if not os.path.exists(path):
            return 1
        with open(path) as f:
            return int(f.read().strip())

    def set_term(self, term: int) -> None:
        """Adopt a HIGHER primary term (promotion, or learning of one from
        shipped frames) and persist it durably before any record can be
        stamped with it.  A term can never go backwards: lowering it would
        re-admit a fenced-off zombie primary's writes."""
        with self._append_lock:
            self._adopt_term(int(term))

    def _adopt_term(self, term: int) -> None:
        """Persist + adopt a term (caller holds ``_append_lock``)."""
        if term < self.term:
            raise ValueError(
                f"term {term} < current term {self.term} — terms are "
                "monotone; a lowered term would unfence a zombie primary")
        if term == self.term:
            return
        path = os.path.join(self.wal_dir, _TERM_FILE)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(term))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        fsync_dir(self.wal_dir)
        self.term = term

    # -- append -----------------------------------------------------------

    def _write_frame(self, kind: int, arrays: dict) -> int:
        """Frame + buffer one record (caller holds ``_append_lock``);
        returns its sequence number.  No flush — the caller batches.
        Records are stamped with the log's current term."""
        seq = self.next_seq
        payload = pack_arrays(arrays)
        frame = _HEADER.pack(_MAGIC, kind, seq, self.term, len(payload),
                             _frame_crc(kind, seq, self.term,
                                        payload)) + payload
        self._file.write(frame)
        self.next_seq = seq + 1
        return seq

    def append(self, kind: int, arrays: dict, *, sync: bool | None = None) -> int:
        """Frame + append one record (flushed to the OS before returning)
        and return its sequence number.  ``sync=None`` (default) fsyncs per
        ``self.sync`` — the one-call-one-ack form; ``sync=False`` defers
        the disk sync to a later ``sync_to`` (group commit: the caller
        acks only after some fsync covers this sequence number)."""
        with self._append_lock:
            seq = self._write_frame(kind, arrays)
            self._file.flush()
        if self.sync if sync is None else sync:
            self.sync_to(seq)
        return seq

    def append_many(self, entries: list[tuple[int, dict]]) -> list[int]:
        """Append a batch of ``(kind, arrays)`` records under ONE lock hold,
        one flush, and (when ``self.sync``) one shared fsync — the explicit
        group-commit form benchmarks use to measure the amortization.
        Returns the assigned sequence numbers."""
        if not entries:
            return []
        with self._append_lock:
            seqs = [self._write_frame(kind, arrays)
                    for kind, arrays in entries]
            self._file.flush()
        self.sync_to(seqs[-1])
        return seqs

    def sync_to(self, seq: int) -> None:
        """Make every record up to (at least) ``seq`` durable: no-op if a
        previous group fsync already covered it, otherwise ONE fsync that
        covers every record flushed so far — concurrent callers piggyback
        on it instead of queueing their own (DESIGN.md §7.6).  No-op when
        the log was opened with ``sync=False``."""
        if not self.sync:
            return
        with self._sync_lock:
            if self._synced_seq >= seq:
                return                   # a shared fsync already covered it
            with self._append_lock:
                # everything flushed so far lands in this fsync; holding
                # _sync_lock keeps rotate() from closing the handle under us
                target = self.next_seq - 1
                fileno = self._file.fileno()
            synced_before = self._synced_seq
            t0 = time.perf_counter()
            os.fsync(fileno)
            dt = time.perf_counter() - t0
            self._synced_seq = max(self._synced_seq, target)
            batch = max(0, target - synced_before)
            self.last_fsync_s = dt
            self.last_group_batch = batch
            self._h_fsync.observe(dt)
            if batch:
                self._h_batch.observe(batch)
            self._g_backlog.set(self.unsynced_backlog)

    def append_insert(self, x_sparse, x_dense, ids, *,
                      sync: bool | None = None) -> int:
        """Log one normalized insert batch (CSR parts + dense + ids)."""
        xs = x_sparse.tocsr()
        return self.append(RECORD_INSERT, {
            "data": xs.data, "indices": xs.indices, "indptr": xs.indptr,
            "shape": np.asarray(xs.shape, np.int64),
            "dense": np.asarray(x_dense, np.float32),
            "ids": np.asarray(ids, np.int64)}, sync=sync)

    def append_delete(self, ids, *, sync: bool | None = None) -> int:
        """Log one delete (the requested external ids, live or not —
        replaying a no-op delete is itself a no-op)."""
        return self.append(RECORD_DELETE,
                           {"ids": np.atleast_1d(np.asarray(ids, np.int64))},
                           sync=sync)

    def append_noop(self, *, sync: bool | None = None) -> int:
        """Log a TERM BARRIER: a record with no state effect whose only job
        is to carry the log's current term (DESIGN.md §8.7).  A freshly
        promoted primary appends one immediately, so the first frame it
        ships proves the new term to every follower — after a follower
        applies it, a deposed primary's same-seq frames are refused by the
        term fence instead of racing the real ones."""
        return self.append(RECORD_NOOP, {}, sync=sync)

    # -- segmentation -----------------------------------------------------

    def rotate(self) -> int:
        """Close the active segment and start a new one at ``next_seq`` —
        the snapshot/compaction cut point.  Returns the new segment's first
        sequence number (the snapshot's ``replay_from_seq``).

        Takes BOTH locks (sync before append, the global order): the old
        segment is fsync'd before it is sealed — a flushed-but-unsynced
        group-commit record must not end up in a closed file no
        ``sync_to`` can reach — and an in-flight ``sync_to`` can never see
        the handle close under its fsync."""
        with self._sync_lock:
            with self._append_lock:
                self._file.flush()
                if self.sync:
                    os.fsync(self._file.fileno())
                self._file.close()
                first = self.next_seq
                self._segments.append(first)
                self._file = open(_segment_path(self.wal_dir, first), "ab")
                self._synced_seq = first - 1
            fsync_dir(self.wal_dir)
        return first

    def truncate_before(self, seq: int) -> int:
        """Delete whole segments every record of which is ``< seq`` (i.e.
        fully covered by a committed snapshot).  The active segment is never
        deleted.  Returns how many segments were removed."""
        removed = 0
        while len(self._segments) > 1 and self._segments[1] <= seq:
            os.remove(_segment_path(self.wal_dir, self._segments.pop(0)))
            removed += 1
        if removed:
            fsync_dir(self.wal_dir)
        return removed

    # -- replication shipping (DESIGN.md §8.3) ----------------------------

    def read_frames(self, from_seq: int, *, limit: int = 256,
                    max_bytes: int = 1 << 24) -> tuple[bytes, list[int]]:
        """Raw framed records with ``seq >= from_seq``, oldest first, capped
        at ``limit`` records / ``max_bytes`` payload — the WAL-shipping read
        a primary serves to its replicas.  Whole segments strictly below
        ``from_seq`` are skipped without being read (the segment-streaming
        point of the ``wal-<first_seq>`` naming), so a caught-up replica's
        poll costs one scan of the active tail, not the full log.

        Ships the frames BYTE-IDENTICAL (header + crc + payload): the
        replica re-validates each crc and appends the same bytes to its own
        log (``append_frames``), so primary and replica logs are the same
        file content record-for-record.  Returns ``(buf, seqs)``.
        """
        out, seqs = [], []
        with self._append_lock:
            self._file.flush()       # ship through the OS-visible tail
            segments = list(self._segments)
        for i, first in enumerate(segments):
            nxt = segments[i + 1] if i + 1 < len(segments) else None
            if nxt is not None and nxt <= from_seq:
                continue             # fully below the ship horizon: skip
            with open(_segment_path(self.wal_dir, first), "rb") as f:
                buf = f.read()
            off = 0
            while len(seqs) < limit and sum(map(len, out)) < max_bytes:
                header = buf[off:off + _HEADER.size]
                if len(header) < _HEADER.size:
                    break
                magic, kind, seq, term, length, crc = _HEADER.unpack(header)
                payload = buf[off + _HEADER.size:off + _HEADER.size + length]
                if (magic != _MAGIC or len(payload) < length
                        or _frame_crc(kind, seq, term, payload) != crc):
                    break            # torn/unflushed tail: stop shipping
                if seq >= from_seq:
                    out.append(buf[off:off + _HEADER.size + length])
                    seqs.append(seq)
                off += _HEADER.size + length
            if len(seqs) >= limit or sum(map(len, out)) >= max_bytes:
                break
        return b"".join(out), seqs

    def append_frames(self, buf: bytes) -> list[WalRecord]:
        """Validate and append SHIPPED frames, preserving their sequence
        numbers — the replica-side half of WAL shipping.  Each frame's crc
        is re-checked and its seq must continue this log exactly at
        ``next_seq`` (shipping is resumable but never leaves a gap: a
        restarted replica recovers to its exact applied seq and re-requests
        from there).  Frames the log already holds (seq < next_seq) are
        skipped, so an overlapping re-ship is idempotent.  A frame stamped
        with a term LOWER than this log's current term is REFUSED — the
        zombie fence (DESIGN.md §8.7): once a follower has learned of term
        T (promotion, or a shipped term-T record), nothing the deposed
        term-(T-1) primary keeps writing can enter its log.  A higher term
        is adopted (and persisted) before the frame lands.  Durability
        follows the log's sync policy.  Returns the decoded records that
        were appended, in order, for the caller to apply."""
        appended: list[WalRecord] = []
        with self._append_lock:
            off = 0
            while off < len(buf):
                header = buf[off:off + _HEADER.size]
                if len(header) < _HEADER.size:
                    raise ValueError("shipped WAL buffer ends mid-header")
                magic, kind, seq, term, length, crc = _HEADER.unpack(header)
                payload = buf[off + _HEADER.size:off + _HEADER.size + length]
                if (magic != _MAGIC or len(payload) < length
                        or _frame_crc(kind, seq, term, payload) != crc):
                    raise ValueError(
                        f"shipped WAL frame at offset {off} failed its "
                        "checksum — refusing to persist garbage")
                frame_end = off + _HEADER.size + length
                if seq < self.next_seq:
                    off = frame_end          # already have it: idempotent
                    continue
                if term < self.term:
                    raise ValueError(
                        f"shipped WAL frame seq {seq} carries term {term} "
                        f"< this log's term {self.term} — refusing a "
                        "deposed (zombie) primary's write")
                if seq != self.next_seq:
                    raise ValueError(
                        f"shipped WAL frame seq {seq} does not continue "
                        f"this log (expected {self.next_seq}) — a gap "
                        "would silently lose mutations")
                if term > self.term:
                    self._adopt_term(term)
                self._file.write(buf[off:frame_end])
                appended.append(WalRecord(seq=seq, kind=kind, term=term,
                                          arrays=unpack_arrays(payload)))
                self.next_seq = seq + 1
                off = frame_end
            self._file.flush()
        if appended:
            self.sync_to(appended[-1].seq)
        return appended

    # -- replay -----------------------------------------------------------

    def records(self, from_seq: int = 0) -> list[WalRecord]:
        """Every complete record with ``seq >= from_seq``, in order, across
        all segments — stopping at the torn tail of the ACTIVE segment.
        An unclean NON-active segment is never a crash artifact (only the
        last segment was being appended to), so it raises instead of
        silently recovering a partial prefix of acked mutations."""
        out = []
        for i, first in enumerate(self._segments):
            path = _segment_path(self.wal_dir, first)
            records, valid, clean = _scan_segment(path)
            if not clean and i + 1 < len(self._segments):
                raise ValueError(
                    f"{path}: corruption at byte {valid} in a sealed "
                    "(non-active) WAL segment — acked mutations would be "
                    "lost; refusing to recover past it")
            out.extend(r for r in records if r.seq >= from_seq)
        return out

    @property
    def unsynced_backlog(self) -> int:
        """Records appended (and OS-flushed) but not yet covered by a
        disk sync — the group-commit exposure window.  Always 0 right
        after a covering ``sync_to`` returns."""
        return max(0, self.next_seq - 1 - self._synced_seq)

    @property
    def segment_paths(self) -> list[str]:
        """Current segment files, oldest first (the active one is last)."""
        return [_segment_path(self.wal_dir, s) for s in self._segments]

    def close(self) -> None:
        """Flush (and, in sync mode, fsync — deferred group-commit records
        must not die with the handle) then close the append handle
        (idempotent)."""
        with self._sync_lock, self._append_lock:
            if not self._file.closed:
                self._file.flush()
                if self.sync:
                    os.fsync(self._file.fileno())
                self._file.close()
