"""Shared leaf-serialization helpers for durable on-disk artifacts.

Two consumers, one format discipline:

* ``repro/persist`` (index snapshots + mutation WAL) stores every array leaf
  as raw C-contiguous bytes with the dtype/shape/checksum carried OUT OF BAND
  (a JSON manifest for snapshot blobs, a framed header for WAL payloads) —
  no pickling, so a snapshot written by one process version loads in another,
  and a flipped bit is a detected error instead of a silently wrong score;
* ``repro/checkpoint`` (training state) keeps its npz container but shares
  the checksum/atomic-commit conventions.

Contracts:

* round trips are BIT-EXACT: ``read_array_blob(write_array_blob(x)) == x``
  including dtype — persistence bit-identity (tests/test_persist.py) rests
  on this layer;
* blob files carry no header; the manifest entry from ``write_array_blob``
  is the only way to decode one, and ``read_array_blob`` verifies the
  recorded sha256 before returning (opt-out for benchmarks);
* ``pack_arrays``/``unpack_arrays`` give the same exactness for an in-memory
  dict of named arrays (the WAL payload unit): a JSON header line + the
  concatenated raw bytes, deterministic for identical inputs.
"""

from __future__ import annotations

import hashlib
import json
import os

import numpy as np

__all__ = ["write_array_blob", "read_array_blob", "pack_arrays",
           "unpack_arrays", "array_sha256", "fsync_dir"]


def _contiguous(arr: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(arr))


def array_sha256(arr: np.ndarray) -> str:
    """Hex sha256 of an array's raw C-order bytes (dtype/shape not mixed in —
    the manifest records those separately, so the hash pins content only)."""
    return hashlib.sha256(_contiguous(arr).tobytes()).hexdigest()


def write_array_blob(path: str, arr: np.ndarray) -> dict:
    """Write one array as raw bytes; return its manifest entry
    ``{file, dtype, shape, nbytes, sha256}`` (file = basename of ``path``).

    The write goes through a same-directory temp file + atomic rename so a
    crash mid-write never leaves a half-length blob under the final name."""
    a = _contiguous(arr)
    buf = a.tobytes()          # serialize ONCE: written and hashed below
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(buf)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return {"file": os.path.basename(path), "dtype": a.dtype.str,
            "shape": list(a.shape), "nbytes": int(a.nbytes),
            "sha256": hashlib.sha256(buf).hexdigest()}


def read_array_blob(path: str, meta: dict, *, verify: bool = True) -> np.ndarray:
    """Read a blob written by ``write_array_blob`` back into an array.

    ``meta`` is the manifest entry; with ``verify`` (the default) the
    recorded sha256 is recomputed and a mismatch raises ``ValueError`` —
    a corrupt snapshot must fail recovery loudly, never score queries."""
    with open(path, "rb") as f:
        buf = f.read()
    if len(buf) != int(meta["nbytes"]):
        raise ValueError(f"{path}: expected {meta['nbytes']} bytes, "
                         f"found {len(buf)}")
    arr = np.frombuffer(buf, dtype=np.dtype(meta["dtype"]))
    arr = arr.reshape(tuple(meta["shape"])).copy()
    if verify:
        got = array_sha256(arr)
        if got != meta["sha256"]:
            raise ValueError(f"{path}: checksum mismatch "
                             f"(manifest {meta['sha256'][:12]}…, "
                             f"file {got[:12]}…)")
    return arr


def pack_arrays(arrays: dict[str, np.ndarray]) -> bytes:
    """Serialize named arrays to one deterministic byte string (the WAL
    payload unit): a JSON header line describing every array's dtype, shape
    and byte extent, then the concatenated raw C-order bytes."""
    metas, blobs = [], []
    off = 0
    for name, arr in arrays.items():
        a = _contiguous(arr)
        metas.append({"name": name, "dtype": a.dtype.str,
                      "shape": list(a.shape), "offset": off,
                      "nbytes": int(a.nbytes)})
        blobs.append(a.tobytes())
        off += a.nbytes
    header = json.dumps({"v": 1, "arrays": metas},
                        separators=(",", ":")).encode()
    return header + b"\n" + b"".join(blobs)


def unpack_arrays(buf: bytes) -> dict[str, np.ndarray]:
    """Inverse of ``pack_arrays``; bit-exact including dtypes."""
    nl = buf.index(b"\n")
    header = json.loads(buf[:nl].decode())
    body = buf[nl + 1:]
    out = {}
    for m in header["arrays"]:
        lo = int(m["offset"])
        raw = body[lo:lo + int(m["nbytes"])]
        if len(raw) != int(m["nbytes"]):
            raise ValueError(f"payload truncated inside array {m['name']!r}")
        arr = np.frombuffer(raw, dtype=np.dtype(m["dtype"]))
        out[m["name"]] = arr.reshape(tuple(m["shape"])).copy()
    return out


def fsync_dir(path: str) -> None:
    """Best-effort fsync of a DIRECTORY so a just-committed rename survives
    power loss (no-op on platforms that refuse O_RDONLY dir fds)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:          # pragma: no cover - platform-dependent
        return
    try:
        os.fsync(fd)
    except OSError:          # pragma: no cover - platform-dependent
        pass
    finally:
        os.close(fd)
