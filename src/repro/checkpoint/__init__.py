from .checkpoint import (save_checkpoint, restore_checkpoint,  # noqa: F401
                         latest_step, CheckpointManager)
from .leaves import (write_array_blob, read_array_blob,  # noqa: F401
                     pack_arrays, unpack_arrays, array_sha256, fsync_dir)
