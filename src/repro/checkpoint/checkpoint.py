"""Checkpointing: sharded-friendly npz save/restore with async writes,
a manifest for atomicity, and elastic re-mesh restore.

Design points for 1000+ node operation:
  * arrays are saved as *logical global* arrays (gathered per-leaf);
    restore re-shards onto whatever mesh is active — elastic scaling
    (checkpoint at 512 chips, restore at 256 or 1024) needs no conversion;
  * writes go to a temp dir + atomic rename, manifest written last, so a
    node failure mid-write never corrupts the latest checkpoint;
  * an async writer thread overlaps serialization with the next train steps
    (step data is snapshotted to host first — correctness over overlap);
  * keep_last garbage collection.

On a real multi-host cluster the np.asarray gather becomes
jax.experimental.multihost_utils / array serialization; single-controller
semantics here are identical.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]

_MANIFEST = "manifest.json"


def _flatten_with_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[name] = np.asarray(leaf)
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, *, keep_last: int = 3):
    """Atomic checkpoint of an arbitrary pytree at `step`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten_with_names(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(tmp, "treedef.txt"), "w") as f:
        f.write(str(treedef))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, _MANIFEST), "w") as f:
        json.dump({"latest_step": step}, f)
    _gc(ckpt_dir, keep_last)


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_"))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    man = os.path.join(ckpt_dir, _MANIFEST)
    if not os.path.exists(man):
        return None
    with open(man) as f:
        return json.load(f)["latest_step"]


def restore_checkpoint(ckpt_dir: str, step: int, like_tree, *, mesh=None,
                       pspec_tree=None):
    """Restore into the structure of `like_tree`.  If (mesh, pspec_tree) are
    given, leaves are placed with those shardings — elastic re-mesh restore."""
    path = os.path.join(ckpt_dir, f"step_{step}", "arrays.npz")
    data = np.load(path)
    names = list(_flatten_with_names(like_tree).keys())
    flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(names) == len(flat_like)
    leaves = []
    if mesh is not None and pspec_tree is not None:
        flat_spec = treedef.flatten_up_to(pspec_tree)
    else:
        flat_spec = [None] * len(flat_like)
    for name, like, spec in zip(names, flat_like, flat_spec):
        arr = data[name]
        if spec is not None:
            sharding = jax.sharding.NamedSharding(mesh, spec)
            leaves.append(jax.device_put(arr, sharding))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(leaves)


class CheckpointManager:
    """Async checkpointing: snapshot to host, write in a background thread."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot before async
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.ckpt_dir, step, host_tree),
            kwargs=dict(keep_last=self.keep_last), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
