"""Batched serving session: prefill -> decode loop with either the exact
full-vocab head or the PQ hybrid head (paper technique).

Tracks per-sequence token counts so the hybrid head's sparse penalty term
(repetition penalty) exercises the paper's sparse+dense decomposition on a
real serving signal.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import Model
from .hybrid_head import HybridLMHead


@dataclasses.dataclass
class ServeSession:
    """One serving deployment: model + params + optional PQ hybrid head.

    ``head_buckets`` (DESIGN.md §5): when set, decode-time head calls pad
    the batch up to these static sizes so sessions joining/leaving the batch
    cannot grow the head's jit cache beyond ``len(head_buckets)`` entries."""
    model: Model
    params: dict
    max_len: int
    pq_head: HybridLMHead | None = None
    pq_params: object = None
    head_buckets: tuple[int, ...] | None = None

    @classmethod
    def create(cls, model: Model, params: dict, max_len: int,
               use_pq_head: bool | None = None, use_kernel: bool = False,
               head_backend: str | None = None,
               head_buckets: tuple[int, ...] | None = None):
        """head_backend: engine backend name for the PQ head (ref,
        onehot-mxu, pallas, pallas-packed); overrides use_kernel.
        head_buckets: static decode-batch buckets for the PQ head (None
        keeps the exact batch size, one compile per size)."""
        cfg = model.cfg
        use_pq = cfg.pq_head if use_pq_head is None else use_pq_head
        head = hp = None
        if use_pq:
            head = HybridLMHead(cfg, use_kernel=use_kernel,
                                backend=head_backend)
            hp = head.build(params["lm_head"])
        return cls(model=model, params=params, max_len=max_len,
                   pq_head=head, pq_params=hp, head_buckets=head_buckets)

    def prefill(self, batch):
        """Jitted prefill of a prompt batch up to ``max_len``."""
        return jax.jit(self.model.prefill, static_argnums=2)(
            self.params, batch, self.max_len)

    def next_token(self, logits_or_hidden, counts, *, penalty: float = 0.0):
        """Greedy next token from logits (exact head) or hidden states
        (PQ head), with the sparse repetition-penalty term."""
        if self.pq_head is not None:
            # h=1 needs a deep overfetch (paper Prop. 4: recall tracks the
            # (h, alpha*h) gap; top-1 margins are the tightest)
            if self.head_buckets is not None:
                vals, ids = self.pq_head.approx_topk_bucketed(
                    self.pq_params, logits_or_hidden, counts, 1, 128,
                    penalty, buckets=self.head_buckets)
            else:
                vals, ids = self.pq_head.approx_topk(
                    self.pq_params, logits_or_hidden, counts, 1, 128, penalty)
            return ids[:, 0]
        logits = logits_or_hidden
        if penalty != 0.0 and counts is not None:
            logits = logits - penalty * counts
        return jnp.argmax(logits, axis=-1)


def greedy_generate(model: Model, params: dict, prompt_tokens, num_steps: int,
                    max_len: int, *, use_pq_head: bool = False,
                    penalty: float = 0.0, cond=None):
    """Greedy decode `num_steps` tokens after a prompt.  Returns (B, T) ids.

    With use_pq_head, the final hidden state feeds the paper's PQ+residual
    head instead of the full-vocab matmul; outputs should agree except where
    the top-1 margin is below PQ error (tests measure this agreement)."""
    cfg = model.cfg
    b, s = prompt_tokens.shape
    sess = ServeSession.create(model, params, max_len, use_pq_head)
    batch = {"tokens": prompt_tokens}
    if cond is not None:
        batch["cond"] = cond
    logits, state = jax.jit(model.prefill, static_argnums=2)(
        params, batch, max_len)
    counts = jnp.zeros((b, cfg.vocab_size), jnp.float32)
    counts = _bump(counts, prompt_tokens)

    decode = jax.jit(model.decode_step, static_argnums=3)

    out = []
    if use_pq_head:
        # re-derive hidden for the prompt's last position
        hidden = jax.jit(_last_hidden, static_argnums=0)(model, params, batch)
        tok = sess.next_token(hidden, counts, penalty=penalty)
    else:
        tok = sess.next_token(logits, counts, penalty=penalty)
    out.append(tok)
    counts = _bump(counts, tok[:, None])
    for _ in range(num_steps - 1):
        if use_pq_head:
            hidden, state = decode(params, state, tok, True)
            tok = sess.next_token(hidden, counts, penalty=penalty)
        else:
            logits, state = decode(params, state, tok, False)
            tok = sess.next_token(logits, counts, penalty=penalty)
        out.append(tok)
        counts = _bump(counts, tok[:, None])
    return jnp.stack(out, axis=1)


def _bump(counts, tokens):
    b = counts.shape[0]
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], tokens.shape)
    return counts.at[bidx, tokens].add(1.0)


def _last_hidden(model, params, batch):
    hidden, _ = model.forward(params, batch, return_hidden=True)
    return hidden[:, -1].astype(jnp.float32)
