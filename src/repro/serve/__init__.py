"""Serving layer (DESIGN.md §5): the batched QueryService request path,
the PQ-approximated LM head, and the decode loop that consumes it."""
from .hybrid_head import HybridLMHead, HybridHeadParams          # noqa: F401
from .query_service import (QueryService, CacheInfo,             # noqa: F401
                            JitCacheInfo)
from .serving import ServeSession, greedy_generate               # noqa: F401
