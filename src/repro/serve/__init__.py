from .hybrid_head import HybridLMHead, HybridHeadParams     # noqa: F401
from .serving import ServeSession, greedy_generate          # noqa: F401
