"""Serving layer (DESIGN.md §5, §8): the batched QueryService request
path, the PQ-approximated LM head, the decode loop that consumes it, and
the cross-host cluster tier (``repro.serve.cluster``: RPC shard fan-out +
snapshot/WAL replication)."""
from .hybrid_head import HybridLMHead, HybridHeadParams          # noqa: F401
from .query_service import (QueryService, CacheInfo,             # noqa: F401
                            JitCacheInfo)
from .serving import ServeSession, greedy_generate               # noqa: F401
