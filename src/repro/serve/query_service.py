"""Batched query service — the serving request path over the ScoringEngine
(DESIGN.md §5; the layer that turns the paper's per-server scorer into the
§7.2 online system under production query load).

``QueryService`` owns everything between "a client hands us hybrid queries"
and "refined top-h ids come back":

* **micro-batching into bucketed static shapes** — incoming
  ``(q_dims, q_vals, q_dense)`` batches are padded up to a small fixed set
  of batch-size buckets (default 1/8/32), so the jit cache of the underlying
  ``three_pass_search`` stays bounded by ``len(buckets)`` entries per
  parameter combination no matter how ragged the request stream is
  (``jit_cache_info()`` exposes the observed entries and the declared bound);

* **an LRU result cache** — results are cached per *query row* under a
  content fingerprint (``core.engine.query_fingerprint`` over the padded
  sparse query, the dense query, the search params, and the index
  generation), with exact hit/miss/eviction counters (``cache_info()``).
  Repeats in a warm stream never touch the device;

* **async shard fan-out** — with ``num_shards > 1`` the index is row-sliced
  once (``core.distributed.split_index_arrays``) into per-shard engines;
  a request dispatches the FULL three-pass search on every shard
  back-to-back (JAX async dispatch overlaps them — the in-process analogue
  of the paper's RPC fan-out) and the per-shard top-h sets are merged on the
  host, the same merge the ``shard_map`` path does with ``all_gather``;

* **double-buffered index refresh** — ``refresh(new_arrays)`` installs a
  rebuilt index without blocking in-flight searches: generations are
  refcounted, a search runs to completion against the generation it
  acquired, and the retired copy's device buffers are donated back
  (``core.engine.release_index_arrays``) once its last in-flight search
  drops the reference.

Results are positions in cache-sorted row order, exactly like
``ScoringEngine.search`` (pass ``id_map=HybridIndex.pi`` to get original
ids).  ``benchmarks/serve_bench.py`` measures the QPS/caching/refresh
claims and writes ``BENCH_serve.json``.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor

import jax.numpy as jnp
import numpy as np

from repro.core.distributed import split_index_arrays
from repro.core.engine import (Backend, IndexArrays, ScoringEngine,
                               query_fingerprint, release_index_arrays)

__all__ = ["QueryService", "CacheInfo", "JitCacheInfo", "bucket_for",
           "pad_rows"]

DEFAULT_BUCKETS = (1, 8, 32)


def bucket_for(q: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket >= q; q above the largest bucket gets the largest
    bucket (the caller chunks oversized batches)."""
    for b in buckets:
        if q <= b:
            return b
    return buckets[-1]


def pad_rows(x: np.ndarray, rows: int, fill=0) -> np.ndarray:
    """Pad axis 0 of host array ``x`` up to ``rows`` with ``fill`` — the
    static-shape bucketing primitive (the PQ LM head's decode batching does
    the same with ``jnp.pad``, device-side)."""
    if x.shape[0] >= rows:
        return x
    widths = [(0, rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, widths, constant_values=fill)


@dataclasses.dataclass(frozen=True)
class CacheInfo:
    """Exact LRU result-cache counters (``QueryService.cache_info()``)."""
    hits: int
    misses: int
    evictions: int
    size: int
    capacity: int

    @property
    def hit_rate(self) -> float:
        """Hits over total lookups (0.0 on an untouched cache)."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclasses.dataclass(frozen=True)
class JitCacheInfo:
    """Observed jit-cache pressure (``QueryService.jit_cache_info()``).

    ``batch_shapes`` is every padded batch size that reached the engine —
    bucketing guarantees ``len(batch_shapes) <= len(buckets)``.  ``entries``
    counts distinct (bucket, params) compilation keys; ``bound`` is the
    declared ceiling ``len(buckets) * <distinct param combos seen>``."""
    batch_shapes: tuple[int, ...]
    entries: int
    bound: int


@dataclasses.dataclass(eq=False)
class _Generation:
    """One installed index copy: the single-device engine, optional per-shard
    engines, and the refcount that gates donation of retired buffers.
    eq=False: identity semantics for the service's generation registry."""
    engine: ScoringEngine
    shards: list[ScoringEngine] | None
    offsets: np.ndarray | None
    id_map: np.ndarray | None
    version: int
    refs: int = 0
    retired: bool = False
    donate: bool = True


class QueryService:
    """The request path end to end: bucketed micro-batching, LRU result
    caching, (optionally sharded) three-pass search, double-buffered index
    swaps.  Thread-safe; ``submit`` gives the async client API.

    Parameters
    ----------
    engine:
        The ``ScoringEngine`` to serve (e.g. ``HybridIndex.build(...).engine``).
        Alternatively pass ``arrays`` (+ ``backend``) and the service builds
        the engine itself.
    h, alpha, beta:
        Default search parameters; per-call overrides are allowed but each
        distinct combination adds its own jit-cache entries.
    buckets:
        Allowed padded batch sizes, ascending.  Bigger request batches are
        chunked to the largest bucket.
    cache_size:
        LRU result-cache capacity in query rows (0 disables caching).
    num_shards:
        Row-shard the index into this many per-shard engines and fan out
        (requires ``num_points % num_shards == 0``).
    id_map:
        Optional position -> external id mapping (``HybridIndex.pi``)
        applied to returned ids.
    """

    def __init__(self, engine: ScoringEngine | None = None, *,
                 arrays: IndexArrays | None = None,
                 backend: Backend | str | None = None,
                 h: int = 10, alpha: int = 20, beta: int = 5,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 cache_size: int = 1024, num_shards: int = 1,
                 id_map: np.ndarray | None = None, max_workers: int = 2):
        if engine is None:
            if arrays is None:
                raise ValueError("pass either an engine or arrays")
            engine = ScoringEngine(arrays=arrays,
                                   backend=Backend.from_name(backend))
        if not buckets:
            raise ValueError("buckets must be non-empty")
        self.h, self.alpha, self.beta = h, alpha, beta
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.num_shards = num_shards
        self._lock = threading.Lock()
        self._version = 0
        self._next_version = 0      # monotonic; unique even across races
        self._gens: set[_Generation] = set()   # every not-yet-donated copy
        self._gen = self._make_generation(engine, id_map, self._version)
        self._cache: OrderedDict[str, tuple[np.ndarray, np.ndarray]] = \
            OrderedDict()
        self._cache_cap = cache_size
        self._hits = self._misses = self._evictions = 0
        self._jit_keys: set[tuple] = set()
        self._requests = self._batches = self._refreshes = 0
        self._executor: ThreadPoolExecutor | None = None
        self._max_workers = max_workers

    # -- generations ------------------------------------------------------

    def _make_generation(self, engine: ScoringEngine,
                         id_map: np.ndarray | None,
                         version: int) -> _Generation:
        shards = offsets = None
        if self.num_shards > 1:
            parts, offsets = split_index_arrays(engine.arrays,
                                                self.num_shards)
            shards = [ScoringEngine(arrays=a, backend=engine.backend)
                      for a in parts]
        gen = _Generation(engine=engine, shards=shards, offsets=offsets,
                          id_map=id_map, version=version)
        with self._lock:
            self._gens.add(gen)
        return gen

    def _acquire(self) -> _Generation:
        with self._lock:
            gen = self._gen
            gen.refs += 1
            return gen

    def _release(self, gen: _Generation) -> None:
        with self._lock:
            gen.refs -= 1
            dead = gen.retired and gen.refs == 0
        if dead:
            self._donate(gen)

    def _donate(self, gen: _Generation) -> None:
        """Free the retired generation's device buffers (DESIGN.md §5
        double-buffering: the swap itself never blocks; HBM of the old copy
        is reclaimed the moment its last in-flight search finishes).

        The keep set spans EVERY generation still registered — the live one
        AND any other retired copy that hasn't been donated yet (it may
        still have in-flight readers, or be externally owned via
        ``donate=False``) — so leaves shared across generations (codebooks,
        ``head_pos``) survive until their last owner goes."""
        with self._lock:
            keep = []
            for g in self._gens:
                if g is gen:
                    continue
                keep.append(g.engine.arrays)
                if g.shards is not None:
                    keep += [s.arrays for s in g.shards]
            if gen.donate:
                self._gens.discard(gen)
        if not gen.donate:
            return
        release_index_arrays(gen.engine.arrays, keep=keep)
        if gen.shards is not None:
            for s in gen.shards:
                release_index_arrays(s.arrays, keep=keep)

    def refresh(self, arrays: IndexArrays | ScoringEngine, *,
                id_map: np.ndarray | None = None,
                donate: bool = True) -> int:
        """Install a rebuilt index without blocking in-flight searches.

        Builds the new generation (including shard slices) OFF the serving
        lock, then swaps the pointer; searches already running keep the old
        generation alive via refcount and complete against it, so every
        result is consistent with exactly one index version.  Version
        numbers come from a monotonic counter read under the lock, so
        concurrent refreshes never mint duplicate cache-key generations.
        With ``donate=True`` (the default) the service owns the retired
        copy's buffers and deletes them once the last in-flight reference
        drops — callers must not reuse the old ``IndexArrays`` afterwards.
        Returns the new generation's version number."""
        with self._lock:
            backend = self._gen.engine.backend
            self._next_version += 1
            version = self._next_version
        if isinstance(arrays, ScoringEngine):
            engine = arrays
        else:
            engine = ScoringEngine(arrays=arrays, backend=backend)
        new = self._make_generation(engine, id_map, version)
        with self._lock:
            old = self._gen
            self._gen = new
            self._version = new.version
            self._refreshes += 1
            old.retired = True
            old.donate = donate and old.engine.arrays is not engine.arrays
            dead = old.refs == 0
        if dead:
            self._donate(old)
        return new.version

    # -- request path -----------------------------------------------------

    def search(self, q_dims, q_vals, q_dense, *, h: int | None = None,
               alpha: int | None = None, beta: int | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
        """Serve a batch of hybrid queries through cache + bucketed engine.

        q_dims/q_vals: (Q, nq) padded sparse queries (compact dim ids /
        values, 1-D accepted for a single query); q_dense: (Q, d_dense).
        Returns ``(scores (Q, h), ids (Q, h))`` numpy arrays; ids are
        cache-sorted positions, or external ids when the service was built
        with an ``id_map``.  Duplicate rows within one call are each counted
        as their own cache lookup."""
        h = self.h if h is None else h
        alpha = self.alpha if alpha is None else alpha
        beta = self.beta if beta is None else beta
        q_dims = np.atleast_2d(np.asarray(q_dims, np.int32))
        q_vals = np.atleast_2d(np.asarray(q_vals, np.float32))
        q_dense = np.atleast_2d(np.asarray(q_dense, np.float32))
        qn = q_dims.shape[0]

        gen = self._acquire()
        try:
            # fingerprints only exist to key the cache: with caching off the
            # hot path skips the per-row hashing entirely
            use_cache = self._cache_cap > 0
            keys = [query_fingerprint(q_dims[i], q_vals[i], q_dense[i],
                                      h, alpha, beta, gen.version)
                    for i in range(qn)] if use_cache else None
            out_s = np.empty((qn, h), np.float32)
            out_i = np.empty((qn, h), np.int64)
            with self._lock:
                self._requests += qn
                if not use_cache:
                    self._misses += qn
                    miss = list(range(qn))
                else:
                    miss = []
                    for i, key in enumerate(keys):
                        hit = self._cache.get(key)
                        if hit is not None:
                            self._cache.move_to_end(key)
                            self._hits += 1
                            out_s[i], out_i[i] = hit
                        else:
                            self._misses += 1
                            miss.append(i)

            max_bucket = self.buckets[-1]
            for lo in range(0, len(miss), max_bucket):
                rows = miss[lo:lo + max_bucket]
                s, ids = self._run_batch(gen, q_dims[rows], q_vals[rows],
                                         q_dense[rows], h, alpha, beta)
                with self._lock:
                    for j, i in enumerate(rows):
                        out_s[i], out_i[i] = s[j], ids[j]
                        if use_cache:
                            self._cache[keys[i]] = (s[j].copy(),
                                                    ids[j].copy())
                            self._cache.move_to_end(keys[i])
                            while len(self._cache) > self._cache_cap:
                                self._cache.popitem(last=False)
                                self._evictions += 1
            return out_s, out_i
        finally:
            self._release(gen)

    def submit(self, q_dims, q_vals, q_dense, **kw) -> Future:
        """Async client API: enqueue a search, get a Future of (scores, ids).

        Dispatch order is submission order on a small worker pool; the shard
        fan-out inside each search already overlaps device work via JAX
        async dispatch."""
        with self._lock:
            if self._executor is None:
                self._executor = ThreadPoolExecutor(
                    max_workers=self._max_workers,
                    thread_name_prefix="query-service")
            ex = self._executor
        return ex.submit(self.search, q_dims, q_vals, q_dense, **kw)

    def _run_batch(self, gen: _Generation, q_dims: np.ndarray,
                   q_vals: np.ndarray, q_dense: np.ndarray,
                   h: int, alpha: int, beta: int
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Pad one miss-batch to its bucket, run the (sharded) engine, trim."""
        qn = q_dims.shape[0]
        bucket = bucket_for(qn, self.buckets)
        d_active = gen.engine.arrays.d_active
        qd = jnp.asarray(pad_rows(q_dims, bucket, fill=d_active))
        qv = jnp.asarray(pad_rows(q_vals, bucket))
        qe = jnp.asarray(pad_rows(q_dense, bucket))

        engines = gen.shards if gen.shards is not None else [gen.engine]
        with self._lock:
            self._batches += 1
            c1, c2 = engines[0].candidate_counts(h, alpha, beta)
            self._jit_keys.add((bucket, q_dims.shape[1], q_dense.shape[1],
                                engines[0].num_points, h, c1, c2,
                                gen.shards is not None))

        if gen.shards is None:
            s, ids, _ = gen.engine.search(qd, qv, qe,
                                          h=h, alpha=alpha, beta=beta)
            s = np.asarray(s)[:qn]
            ids = np.asarray(ids)[:qn].astype(np.int64)
        else:
            # fan-out: dispatch EVERY shard before syncing any (JAX async
            # dispatch overlaps the per-shard searches), then merge top-h
            # on host — the in-process form of the paper's §7.2 RPC fan-out.
            parts = [e.search(qd, qv, qe, h=h, alpha=alpha, beta=beta)
                     for e in engines]
            ss = np.concatenate([np.asarray(p[0]) for p in parts], axis=1)
            ii = np.concatenate(
                [np.asarray(p[1]).astype(np.int64) + int(off)
                 for p, off in zip(parts, gen.offsets)], axis=1)
            # stable sort + shards concatenated in row order => ties break
            # by lowest global id, matching lax.top_k on the unsharded array
            order = np.argsort(-ss, axis=1, kind="stable")[:, :h]
            s = np.take_along_axis(ss, order, axis=1)[:qn]
            ids = np.take_along_axis(ii, order, axis=1)[:qn]
        if gen.id_map is not None:
            ids = np.asarray(gen.id_map)[ids]
        return s, ids

    # -- introspection ----------------------------------------------------

    def cache_info(self) -> CacheInfo:
        """Exact LRU counters: hits, misses, evictions, size, capacity."""
        with self._lock:
            return CacheInfo(hits=self._hits, misses=self._misses,
                             evictions=self._evictions,
                             size=len(self._cache),
                             capacity=self._cache_cap)

    def jit_cache_info(self) -> JitCacheInfo:
        """Observed engine compilation keys vs the declared bucketing bound."""
        with self._lock:
            shapes = tuple(sorted({k[0] for k in self._jit_keys}))
            combos = {k[1:] for k in self._jit_keys}
            return JitCacheInfo(batch_shapes=shapes,
                                entries=len(self._jit_keys),
                                bound=len(self.buckets) * max(1, len(combos)))

    def stats(self) -> dict:
        """Service counters for dashboards/benchmarks (plain dict)."""
        with self._lock:
            return {"requests": self._requests, "batches": self._batches,
                    "refreshes": self._refreshes, "version": self._version,
                    "cache_hits": self._hits, "cache_misses": self._misses,
                    "cache_evictions": self._evictions,
                    "num_shards": self.num_shards, "buckets": self.buckets}

    @property
    def version(self) -> int:
        """Version number of the currently installed index generation."""
        with self._lock:
            return self._version

    def close(self) -> None:
        """Shut down the async submit pool (idempotent)."""
        with self._lock:
            ex, self._executor = self._executor, None
        if ex is not None:
            ex.shutdown(wait=True)
