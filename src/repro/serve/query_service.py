"""Batched query service — the serving request path over the ScoringEngine
(DESIGN.md §5; the layer that turns the paper's per-server scorer into the
§7.2 online system under production query load).

``QueryService`` owns everything between "a client hands us hybrid queries"
and "refined top-h ids come back":

* **micro-batching into bucketed static shapes** — incoming
  ``(q_dims, q_vals, q_dense)`` batches are padded up to a small fixed set
  of batch-size buckets (default 1/8/32), so the jit cache of the underlying
  ``three_pass_search`` stays bounded by ``len(buckets)`` entries per
  parameter combination no matter how ragged the request stream is
  (``jit_cache_info()`` exposes the observed entries and the declared bound);

* **an LRU result cache** — results are cached per *query row* under a
  content fingerprint (``core.engine.query_fingerprint`` over the padded
  sparse query, the dense query, the search params, and the index
  generation), with exact hit/miss/eviction counters (``cache_info()``).
  Repeats in a warm stream never touch the device;

* **async shard fan-out** — with ``num_shards > 1`` the index is row-sliced
  once (``core.distributed.split_index_arrays``) into per-shard engines;
  a request dispatches the FULL three-pass search on every shard
  back-to-back (JAX async dispatch overlaps them — the in-process analogue
  of the paper's RPC fan-out) and the per-shard top-h sets are merged on the
  host, the same merge the ``shard_map`` path does with ``all_gather``;

* **double-buffered index refresh** — ``refresh(new_arrays)`` installs a
  rebuilt index without blocking in-flight searches: generations are
  refcounted, a search runs to completion against the generation it
  acquired, and the retired copy's device buffers are donated back
  (``core.engine.release_index_arrays``) once its last in-flight search
  drops the reference;

* **streaming mutation** (DESIGN.md §6) — constructed with a *mutable*
  ``HybridIndex`` (``index=``), the service gains ``insert()``/``delete()``:
  inserts land in the index's device-resident delta shard
  (``core.streaming.DeltaShard``) which is served as ONE MORE engine in the
  fan-out above; deletes tombstone either a delta slot (device-side -inf
  mask) or a main-generation row (dropped at the host merge, with the main
  engines overfetching by the tombstone count so results never come up
  short).  Every mutation bumps a version that the result-cache fingerprint
  incorporates, so a cached hit can never return pre-mutation results.
  Once the delta outgrows ``compact_min_rows`` / ``compact_ratio``, a
  background compaction rebuilds the main index from the surviving rows and
  swaps it through the same refcounted ``refresh()`` double-buffer;

* **durability** (DESIGN.md §7) — ``persist_dir=`` attaches a snapshot
  store + mutation WAL (``repro/persist``): every acked mutation is
  WAL-logged before the call returns, each compaction cuts a snapshot and
  truncates the log, and ``QueryService(restore_from=…)`` resumes after a
  crash bit-identical to the state at the last durably-acked mutation.

Results are positions in cache-sorted row order, exactly like
``ScoringEngine.search`` (pass ``id_map=HybridIndex.pi`` to get original
ids); a mutable service maps to external ids automatically.
``benchmarks/serve_bench.py`` measures the QPS/caching/refresh claims and
writes ``BENCH_serve.json`` (``--stream`` adds ``BENCH_stream.json``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor

import jax.numpy as jnp
import numpy as np

from repro.core.distributed import split_index_arrays
from repro.core.engine import (Backend, IndexArrays, ScoringEngine,
                               query_fingerprint, release_index_arrays)
from repro.core.sparse_index import sparse_queries_to_padded
from repro.core.streaming import fanout_search, plan_overfetch
from repro.obs import Observability

__all__ = ["QueryService", "CacheInfo", "JitCacheInfo", "bucket_for",
           "pad_rows"]

DEFAULT_BUCKETS = (1, 8, 32)


def bucket_for(q: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket >= q; q above the largest bucket gets the largest
    bucket (the caller chunks oversized batches)."""
    for b in buckets:
        if q <= b:
            return b
    return buckets[-1]


def pad_rows(x: np.ndarray, rows: int, fill=0) -> np.ndarray:
    """Pad axis 0 of host array ``x`` up to ``rows`` with ``fill`` — the
    static-shape bucketing primitive (the PQ LM head's decode batching does
    the same with ``jnp.pad``, device-side)."""
    if x.shape[0] >= rows:
        return x
    widths = [(0, rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, widths, constant_values=fill)


@dataclasses.dataclass(frozen=True)
class CacheInfo:
    """Exact LRU result-cache counters (``QueryService.cache_info()``)."""
    hits: int
    misses: int
    evictions: int
    size: int
    capacity: int

    @property
    def hit_rate(self) -> float:
        """Hits over total lookups (0.0 on an untouched cache)."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclasses.dataclass(frozen=True)
class JitCacheInfo:
    """Observed jit-cache pressure (``QueryService.jit_cache_info()``).

    ``batch_shapes`` is every padded batch size that reached the engine —
    bucketing guarantees ``len(batch_shapes) <= len(buckets)``.  ``entries``
    counts distinct (bucket, params) compilation keys; ``bound`` is the
    declared ceiling ``len(buckets) * <distinct param combos seen>``."""
    batch_shapes: tuple[int, ...]
    entries: int
    bound: int


@dataclasses.dataclass(eq=False)
class _Generation:
    """One installed index copy: the single-device engine, optional per-shard
    engines, and the refcount that gates donation of retired buffers.
    eq=False: identity semantics for the service's generation registry."""
    engine: ScoringEngine
    shards: list[ScoringEngine] | None
    offsets: np.ndarray | None
    id_map: np.ndarray | None
    version: int
    refs: int = 0
    retired: bool = False
    donate: bool = True


@dataclasses.dataclass(frozen=True)
class _DeltaView:
    """Immutable snapshot of the mutable side-state a search pairs with the
    generation it acquired (DESIGN.md §6): the delta-shard engine (None when
    the delta is empty), the slot -> external-id map, and the main-row
    tombstones dropped at the host merge.  Swapped atomically under the
    serving lock; in-flight searches keep the view they started with (the
    delta arrays stay alive through the Python reference)."""
    engine: ScoringEngine | None
    ids: np.ndarray | None            # (capacity,) int64 slot -> external id
    live: int
    capacity: int
    deleted: frozenset                # main-generation tombstoned ids


class QueryService:
    """The request path end to end: bucketed micro-batching, LRU result
    caching, (optionally sharded) three-pass search, double-buffered index
    swaps.  Thread-safe; ``submit`` gives the async client API.

    Parameters
    ----------
    engine:
        The ``ScoringEngine`` to serve (e.g. ``HybridIndex.build(...).engine``).
        Alternatively pass ``arrays`` (+ ``backend``) and the service builds
        the engine itself.
    h, alpha, beta:
        Default search parameters; per-call overrides are allowed but each
        distinct combination adds its own jit-cache entries.
    buckets:
        Allowed padded batch sizes, ascending.  Bigger request batches are
        chunked to the largest bucket.
    cache_size:
        LRU result-cache capacity in query rows (0 disables caching).
    num_shards:
        Row-shard the index into this many per-shard engines and fan out
        (requires ``num_points % num_shards == 0``).
    id_map:
        Optional position -> external id mapping (``HybridIndex.pi``)
        applied to returned ids.
    index:
        A MUTABLE ``HybridIndex`` (built with ``mutable=True``) enabling
        ``insert()``/``delete()``/``compact()``.  Supplies the engine and
        the external-id map when those aren't passed explicitly.
    auto_compact, compact_min_rows, compact_ratio:
        Compaction policy (DESIGN.md §6.3): when the pending mutation count
        (delta live rows + main tombstones) reaches
        ``max(compact_min_rows, compact_ratio * main_rows)``, a background
        thread rebuilds the index from the surviving rows and swaps it via
        ``refresh()``.  ``auto_compact=False`` leaves compaction to explicit
        ``compact()`` calls.
    persist_dir:
        Make a mutable service DURABLE (DESIGN.md §7): bootstrap a snapshot
        store + mutation WAL at this path for the freshly built ``index=``.
        Every acked ``insert``/``delete`` is WAL-logged before the call
        returns; each ``compact()`` cuts a new snapshot and truncates the
        log.  Refuses a path that already holds a store (use
        ``restore_from``).
    restore_from:
        Resume a durable service after a crash/restart: recover the index
        from this store (snapshot load + WAL-tail replay, bit-identical to
        the state at the last durably-acked mutation) and keep persisting
        into it.  Mutually exclusive with ``index=``/``persist_dir``.
    persist_sync:
        fsync each WAL append before acking (the default).  ``False`` trades
        the power-loss guarantee for append latency (process-crash safety
        is retained — the bytes are flushed to the OS).  Acks use GROUP
        COMMIT (DESIGN.md §7.6): records are framed + flushed under the
        mutation lock, but the fsync happens outside it and is shared —
        a mutation returns as soon as SOME fsync covers its sequence
        number, so concurrent writers amortize one disk sync.
    compact_retrain:
        Compaction policy for this service's ``compact()`` calls (explicit
        and background): ``False`` forces merge-compaction into the frozen
        build artifacts, ``True`` forces the full batch rebuild, ``None``
        (default) auto-routes per ``MutableState.compact`` (merge unless
        out-of-column-space entries require a retrain).  A per-call
        ``compact(retrain=…)`` overrides it.
    delta_snapshot_records:
        Cut a DELTA-STATE snapshot (DESIGN.md §7.6) + truncate the WAL
        after every this-many logged mutations, bounding replay length
        under sustained ingest without waiting for a compaction.  ``None``
        (default) disables automatic checkpoints; ``checkpoint()`` is the
        explicit form.
    obs:
        The :class:`repro.obs.Observability` bundle (DESIGN.md §9): its
        registry backs ``cache_info()``/``stats()``/``metrics()`` and the
        WAL durability instruments; its tracer (off by default) emits one
        ``serve.search`` root span per request with ``serve.batch``
        children.  ``Observability.off()`` nulls everything — the no-obs
        baseline for overhead measurement (§9.4).
    """

    def __init__(self, engine: ScoringEngine | None = None, *,
                 arrays: IndexArrays | None = None,
                 backend: Backend | str | None = None,
                 index=None,
                 h: int = 10, alpha: int = 20, beta: int = 5,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 cache_size: int = 1024, num_shards: int = 1,
                 id_map: np.ndarray | None = None, max_workers: int = 2,
                 auto_compact: bool = True, compact_min_rows: int = 256,
                 compact_ratio: float = 0.25,
                 persist_dir: str | None = None,
                 restore_from: str | None = None,
                 persist_sync: bool = True,
                 compact_retrain: bool | None = None,
                 delta_snapshot_records: int | None = None,
                 obs: Observability | None = None):
        # one observability bundle for the whole service (DESIGN.md §9):
        # default keeps the metrics registry ON (cache_info()/stats() read
        # its counters) with tracing OFF; Observability.off() nulls both.
        self.obs = obs if obs is not None else Observability()
        m = self.obs.metrics
        self._c_hits = m.counter("serve.cache.hits")
        self._c_misses = m.counter("serve.cache.misses")
        self._c_evictions = m.counter("serve.cache.evictions")
        self._c_requests = m.counter("serve.requests")
        self._c_batches = m.counter("serve.batches")
        self._c_refreshes = m.counter("serve.refreshes")
        self._h_compact = m.histogram("serve.compact_s")
        self._g_delta = m.gauge("serve.delta_rows")
        self._durability = None
        self._recovery = None
        if restore_from is not None:
            if index is not None or persist_dir is not None:
                raise ValueError("restore_from= recovers the index from the "
                                 "store; don't also pass index=/persist_dir=")
            from repro import persist
            rec = persist.recover(restore_from, sync=persist_sync,
                                  metrics=m)
            index, self._durability, self._recovery = \
                rec.index, rec.durability, rec
        elif persist_dir is not None:
            if index is None:
                raise ValueError("persist_dir= bootstraps a NEW store for a "
                                 "mutable index=; pass restore_from= to "
                                 "resume an existing one")
            from repro import persist
            self._durability = persist.bootstrap(persist_dir, index,
                                                 sync=persist_sync,
                                                 metrics=m)
        if index is not None:
            if index.mutable_state is None:
                raise ValueError("index= needs HybridIndex.build(..., "
                                 "mutable=True)")
            if engine is None:
                engine = index.engine
            if id_map is None:
                id_map = index.mutable_state.id_map
        if engine is None:
            if arrays is None:
                raise ValueError("pass an engine, arrays, or a mutable index")
            engine = ScoringEngine(arrays=arrays,
                                   backend=Backend.from_name(backend))
        if not buckets:
            raise ValueError("buckets must be non-empty")
        self.h, self.alpha, self.beta = h, alpha, beta
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.num_shards = num_shards
        self._lock = threading.Lock()
        self._version = 0
        self._next_version = 0      # monotonic; unique even across races
        self._gens: set[_Generation] = set()   # every not-yet-donated copy
        self._gen = self._make_generation(engine, id_map, self._version)
        self._cache: OrderedDict[str, tuple[np.ndarray, np.ndarray]] = \
            OrderedDict()
        self._cache_cap = cache_size
        self._jit_keys: set[tuple] = set()
        self._executor: ThreadPoolExecutor | None = None
        self._max_workers = max_workers
        # streaming mutation state (all guarded by _mut_lock except the
        # view/version, which searches read under _lock)
        self._index = index
        self._mut_lock = threading.RLock()
        self._delta_view: _DeltaView | None = None
        self._mutation_version = 0
        self._auto_compact = auto_compact
        self._compact_min_rows = compact_min_rows
        self._compact_ratio = compact_ratio
        self._compact_retrain = compact_retrain
        self._delta_snapshot_records = delta_snapshot_records
        self._records_since_checkpoint = 0
        self._compactions = 0
        self._last_compaction_s: float | None = None
        self._compact_thread: threading.Thread | None = None
        self._closed = False
        if index is not None:
            with self._mut_lock:
                self._install_view()

    # -- generations ------------------------------------------------------

    def _make_generation(self, engine: ScoringEngine,
                         id_map: np.ndarray | None,
                         version: int) -> _Generation:
        shards = offsets = None
        if self.num_shards > 1:
            parts, offsets = split_index_arrays(engine.arrays,
                                                self.num_shards)
            shards = [ScoringEngine(arrays=a, backend=engine.backend)
                      for a in parts]
        gen = _Generation(engine=engine, shards=shards, offsets=offsets,
                          id_map=id_map, version=version)
        with self._lock:
            self._gens.add(gen)
        return gen

    def _acquire(self) -> _Generation:
        with self._lock:
            gen = self._gen
            gen.refs += 1
            return gen

    def _acquire_view(self):
        """Atomically pin (generation, delta view, mutation version, index):
        a search must never pair a new main with an old delta or vice versa
        — the stress test's no-mixed-generation invariant.  The index handle
        rides along because compaction swaps it in the same critical section
        as the generation pointer: query encoding against ``index.cols``
        (search_sparse) is only valid for THIS generation."""
        with self._lock:
            gen = self._gen
            gen.refs += 1
            return gen, self._delta_view, self._mutation_version, self._index

    def _release(self, gen: _Generation) -> None:
        with self._lock:
            gen.refs -= 1
            dead = gen.retired and gen.refs == 0
        if dead:
            self._donate(gen)

    def _donate(self, gen: _Generation) -> None:
        """Free the retired generation's device buffers (DESIGN.md §5
        double-buffering: the swap itself never blocks; HBM of the old copy
        is reclaimed the moment its last in-flight search finishes).

        The keep set spans EVERY generation still registered — the live one
        AND any other retired copy that hasn't been donated yet (it may
        still have in-flight readers, or be externally owned via
        ``donate=False``) — so leaves shared across generations (codebooks,
        ``head_pos``) survive until their last owner goes."""
        with self._lock:
            keep = []
            for g in self._gens:
                if g is gen:
                    continue
                keep.append(g.engine.arrays)
                if g.shards is not None:
                    keep += [s.arrays for s in g.shards]
            if gen.donate:
                self._gens.discard(gen)
        if not gen.donate:
            return
        release_index_arrays(gen.engine.arrays, keep=keep)
        if gen.shards is not None:
            for s in gen.shards:
                release_index_arrays(s.arrays, keep=keep)

    def refresh(self, arrays: IndexArrays | ScoringEngine, *,
                id_map: np.ndarray | None = None,
                donate: bool = True) -> int:
        """Install a rebuilt index without blocking in-flight searches.

        Builds the new generation (including shard slices) OFF the serving
        lock, then swaps the pointer; searches already running keep the old
        generation alive via refcount and complete against it, so every
        result is consistent with exactly one index version.  Version
        numbers come from a monotonic counter read under the lock, so
        concurrent refreshes never mint duplicate cache-key generations.
        With ``donate=True`` (the default) the service owns the retired
        copy's buffers and deletes them once the last in-flight reference
        drops — callers must not reuse the old ``IndexArrays`` afterwards.
        Returns the new generation's version number.

        Not available on a mutable service: an external swap would leave the
        delta shard encoded against (and sharing device buffers with) the
        retired generation — ``compact()`` is the mutable path's refresh."""
        if self._index is not None:
            raise ValueError(
                "refresh() would desync the delta shard and compact column "
                "space of a mutable service; use insert()/delete()/compact()")
        with self._lock:
            backend = self._gen.engine.backend
            self._next_version += 1
            version = self._next_version
        if isinstance(arrays, ScoringEngine):
            engine = arrays
        else:
            engine = ScoringEngine(arrays=arrays, backend=backend)
        new = self._make_generation(engine, id_map, version)
        return self._swap(new, donate)

    def _swap(self, new: _Generation, donate: bool, on_swap=None) -> int:
        """Install a built generation; ``on_swap`` runs under the serving
        lock in the same critical section as the pointer swap (compaction
        uses it to retire the delta view atomically with the new main)."""
        with self._lock:
            old = self._gen
            self._gen = new
            self._version = new.version
            self._c_refreshes.inc()
            old.retired = True
            old.donate = donate and \
                old.engine.arrays is not new.engine.arrays
            if on_swap is not None:
                on_swap()
            dead = old.refs == 0
        if dead:
            self._donate(old)
        return new.version

    # -- streaming mutation (DESIGN.md §6) --------------------------------

    def _require_index(self):
        if self._index is None:
            raise ValueError("service has no mutable index; construct with "
                             "QueryService(index=HybridIndex.build(..., "
                             "mutable=True))")

    def _install_view(self) -> None:
        """Snapshot the index's delta + tombstones into an immutable view and
        swap it in under the serving lock (callers hold _mut_lock).  The
        mutation version bump is what invalidates result-cache entries."""
        st = self._index.mutable_state
        snap = st.delta.snapshot()
        engine = None
        if snap.live:
            engine = ScoringEngine(arrays=snap.arrays,
                                   backend=self._index.engine.backend)
        view = _DeltaView(engine=engine, ids=snap.ids, live=snap.live,
                          capacity=snap.capacity,
                          deleted=frozenset(st.main_tombstones))
        with self._lock:
            self._delta_view = view
            self._mutation_version += 1
        self._g_delta.set(view.live)

    def insert(self, x_sparse, x_dense, ids=None) -> np.ndarray:
        """Insert (or upsert) rows into the delta shard; they are searchable
        as soon as this returns (encoded against the frozen main-index
        artifacts — see core/streaming.py).  Returns the external ids.
        On a durable service the batch is WAL-logged and fsync-covered
        before this returns — apply-then-log, so a crash mid-call loses at
        most this not-yet-acked batch (DESIGN.md §7.4).  The fsync itself
        is a GROUP COMMIT (§7.6): the record is framed + flushed under the
        mutation lock, the sync happens outside it, and one disk sync acks
        every record it covers — concurrent mutators share fsyncs instead
        of queueing one each.  If the append itself FAILS (disk full), the
        exception propagates (the batch was never acked, though it may
        stay visible until restart) and the durability handle is poisoned:
        further mutations are refused so recoverable and served state
        cannot silently diverge.
        May trigger background compaction per the service's policy."""
        self._require_index()
        seq = None
        with self._mut_lock:
            if self._durability is not None:
                self._durability.ensure_ok()
            assigned = self._index.insert(x_sparse, x_dense, ids=ids)
            if self._durability is not None and len(assigned):
                seq = self._durability.log_insert(x_sparse, x_dense,
                                                  assigned, sync=False)
                self._auto_checkpoint()
            self._install_view()
            due = self._auto_compact and self._compact_due()
        if seq is not None:
            self._durability.sync(seq)       # ack after the (shared) fsync
        if due:
            self._spawn_compaction()
        return assigned

    def delete(self, ids) -> int:
        """Tombstone rows by external id: delta slots die on device (-inf
        mask), main-generation rows at the host merge.  Searches dispatched
        after this returns never report the ids.  On a durable service the
        delete is WAL-logged and fsync-covered (group commit, §7.6) before
        this returns (no-op deletes are not logged — nothing changed); a
        failed append poisons the durability handle exactly like
        ``insert``.  Returns #rows killed."""
        self._require_index()
        seq = None
        with self._mut_lock:
            if self._durability is not None:
                self._durability.ensure_ok()
            killed = self._index.delete(ids)
            if killed:
                if self._durability is not None:
                    seq = self._durability.log_delete(ids, sync=False)
                    self._auto_checkpoint()
                self._install_view()
                due = self._auto_compact and self._compact_due()
            else:
                due = False
        if seq is not None:
            self._durability.sync(seq)       # ack after the (shared) fsync
        if due:
            self._spawn_compaction()
        return killed

    def _auto_checkpoint(self) -> None:
        """Count one logged mutation toward ``delta_snapshot_records`` and
        cut a delta-state checkpoint when the threshold is hit (caller
        holds ``_mut_lock``; the just-logged record is flushed, and the
        checkpoint's rotation fsyncs it before sealing the segment)."""
        self._records_since_checkpoint += 1
        if (self._delta_snapshot_records is not None
                and self._records_since_checkpoint
                >= self._delta_snapshot_records):
            self._durability.delta_checkpoint(self._index)
            self._records_since_checkpoint = 0

    def checkpoint(self) -> None:
        """Cut a DELTA-STATE snapshot of the live mutable index (delta rows
        and tombstones included, no compaction) and truncate the WAL behind
        it (DESIGN.md §7.6): recovery becomes snapshot-load + small tail
        replay even under sustained ingest.  ``delta_snapshot_records``
        does this automatically every N mutations."""
        self._require_index()
        with self._mut_lock:
            if self._durability is None:
                raise ValueError("checkpoint() needs a durable service "
                                 "(persist_dir= or restore_from=)")
            self._durability.ensure_ok()
            self._durability.delta_checkpoint(self._index)
            self._records_since_checkpoint = 0

    def _compact_due(self) -> bool:
        st = self._index.mutable_state
        if st.live_rows == 0:
            return False        # batch build needs >= 1 surviving row
        pending = st.delta.live_count + len(st.main_tombstones)
        floor = max(self._compact_min_rows,
                    int(self._compact_ratio * self._gen.engine.num_points))
        return pending >= floor

    def _spawn_compaction(self) -> None:
        with self._lock:
            if self._closed or (self._compact_thread is not None
                                and self._compact_thread.is_alive()):
                return
            t = threading.Thread(target=self._compact_bg,
                                 name="query-service-compact", daemon=True)
            self._compact_thread = t
            # start INSIDE the lock: an unstarted thread reads as not-alive,
            # so starting outside would let a second spawner overwrite the
            # slot and leave a rebuild running that close() never joins
            t.start()

    def _compact_bg(self) -> None:
        with self._lock:
            # closes the spawn/close race: a thread created before close()
            # but started after it must not begin a rebuild
            if self._closed:
                return
        try:
            self.compact()
        except Exception:                     # pragma: no cover - diagnostic
            import traceback
            traceback.print_exc()

    def compact(self, retrain: bool | None = None) -> int:
        """Fold the delta + tombstones into a compacted index and swap it
        through the double-buffered refresh (DESIGN.md §6.3).  The fold is
        either a merge into the frozen build artifacts or a full batch
        rebuild — ``retrain`` overrides the service's ``compact_retrain``
        policy for this call (see ``MutableState.compact``).  Mutations are
        serialized with the fold (they'd be lost otherwise); searches keep
        serving the old generation + delta throughout and flip atomically
        at the swap, so no result ever mixes the old delta with the new
        main.  On a durable service the compacted generation is snapshotted
        and the WAL truncated right after the swap (DESIGN.md §7.4 covers
        the crash window between the two).  Returns the installed
        generation's version."""
        self._require_index()
        t0 = time.perf_counter()
        if retrain is None:
            retrain = self._compact_retrain
        with self._mut_lock:
            st = self._index.mutable_state
            if st.delta.count == 0 and not st.main_tombstones:
                return self.version              # nothing to fold
            # heavy; off serving lock
            new_idx = self._index.compact(retrain=retrain)
            new_state = new_idx.mutable_state
            engine = new_idx.engine
            with self._lock:
                self._next_version += 1
                version = self._next_version
            new_gen = self._make_generation(engine, new_state.id_map,
                                            version)

            def on_swap():
                self._index = new_idx
                self._delta_view = _DeltaView(
                    engine=None, ids=None, live=0, capacity=0,
                    deleted=frozenset())
                self._mutation_version += 1
                self._compactions += 1
                self._last_compaction_s = time.perf_counter() - t0
                self._h_compact.observe(self._last_compaction_s)
                self._g_delta.set(0)

            out = self._swap(new_gen, donate=True, on_swap=on_swap)
            if self._durability is not None:
                # snapshot = compaction output: cut it while still holding
                # the mutation lock so no WAL record lands between the swap
                # and the log rotation it anchors
                self._durability.checkpoint(new_idx)
                self._records_since_checkpoint = 0
            return out

    def search_sparse(self, q_sparse, q_dense, *, h: int | None = None,
                      alpha: int | None = None, beta: int | None = None):
        """Entry point for RAW scipy sparse queries: encode against the
        pinned generation's compact column space, then serve.  Mutable
        services need this across compactions — the compact space changes
        with each rebuild, so pre-padded ``q_dims`` are generation-bound;
        the generation is held for the WHOLE encode+search so a concurrent
        compaction can never score old-space dim ids against a new index."""
        self._require_index()
        gen, view, mut_version, idx = self._acquire_view()
        try:
            q_dims, q_vals = sparse_queries_to_padded(q_sparse, idx.cols,
                                                      nq_max=idx.params.nq_max)
            return self._serve(gen, view, mut_version,
                               np.atleast_2d(np.asarray(q_dims, np.int32)),
                               np.atleast_2d(np.asarray(q_vals, np.float32)),
                               np.atleast_2d(np.asarray(q_dense, np.float32)),
                               h, alpha, beta)
        finally:
            self._release(gen)

    # -- request path -----------------------------------------------------

    def search(self, q_dims, q_vals, q_dense, *, h: int | None = None,
               alpha: int | None = None, beta: int | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
        """Serve a batch of hybrid queries through cache + bucketed engine.

        q_dims/q_vals: (Q, nq) padded sparse queries (compact dim ids /
        values, 1-D accepted for a single query); q_dense: (Q, d_dense).
        Returns ``(scores (Q, h), ids (Q, h))`` numpy arrays; ids are
        cache-sorted positions, or external ids when the service was built
        with an ``id_map``.  Duplicate rows within one call are each counted
        as their own cache lookup.

        NOTE (mutable services): pre-padded ``q_dims`` are bound to the
        compact column space of the generation they were encoded against,
        which changes at every compaction — streaming clients should call
        ``search_sparse`` (raw queries, per-generation encoding) instead of
        caching padded queries across mutations."""
        q_dims = np.atleast_2d(np.asarray(q_dims, np.int32))
        q_vals = np.atleast_2d(np.asarray(q_vals, np.float32))
        q_dense = np.atleast_2d(np.asarray(q_dense, np.float32))
        gen, view, mut_version, _ = self._acquire_view()
        try:
            return self._serve(gen, view, mut_version, q_dims, q_vals,
                               q_dense, h, alpha, beta)
        finally:
            self._release(gen)

    def _serve(self, gen: _Generation, view: "_DeltaView | None",
               mut_version: int, q_dims: np.ndarray, q_vals: np.ndarray,
               q_dense: np.ndarray, h: int | None, alpha: int | None,
               beta: int | None) -> tuple[np.ndarray, np.ndarray]:
        """Cache + batch + fan-out against an already-pinned generation
        (the caller holds the refcount)."""
        h = self.h if h is None else h
        alpha = self.alpha if alpha is None else alpha
        beta = self.beta if beta is None else beta
        qn = q_dims.shape[0]
        # fingerprints only exist to key the cache: with caching off the
        # hot path skips the per-row hashing entirely.  The key covers
        # BOTH the generation and the delta-shard mutation version —
        # a cached hit can never serve pre-insert/pre-delete results.
        use_cache = self._cache_cap > 0
        keys = [query_fingerprint(q_dims[i], q_vals[i], q_dense[i],
                                  h, alpha, beta, gen.version, mut_version)
                for i in range(qn)] if use_cache else None
        out_s = np.empty((qn, h), np.float32)
        out_i = np.empty((qn, h), np.int64)
        sp = self.obs.tracer.root("serve.search", qn=qn, h=h,
                                  gen=gen.version)
        hits = evictions = 0
        with sp:
            with self._lock:
                if not use_cache:
                    miss = list(range(qn))
                else:
                    miss = []
                    for i, key in enumerate(keys):
                        hit = self._cache.get(key)
                        if hit is not None:
                            self._cache.move_to_end(key)
                            hits += 1
                            out_s[i], out_i[i] = hit
                        else:
                            miss.append(i)
            max_bucket = self.buckets[-1]
            for lo in range(0, len(miss), max_bucket):
                rows = miss[lo:lo + max_bucket]
                with sp.child("serve.batch", rows=len(rows),
                              bucket=bucket_for(len(rows),
                                                self.buckets)) as bs:
                    s, ids = self._run_batch(gen, view, q_dims[rows],
                                             q_vals[rows], q_dense[rows],
                                             h, alpha, beta, span=bs)
                with self._lock:
                    for j, i in enumerate(rows):
                        out_s[i], out_i[i] = s[j], ids[j]
                        if use_cache:
                            self._cache[keys[i]] = (s[j].copy(),
                                                    ids[j].copy())
                            self._cache.move_to_end(keys[i])
                            while len(self._cache) > self._cache_cap:
                                self._cache.popitem(last=False)
                                evictions += 1
            sp.set("cache_hits", hits)
            sp.set("cache_misses", len(miss))
        # counters fold ONCE per request, not per row — exact totals with
        # a bounded number of instrument-lock round-trips (DESIGN.md §9.4)
        self._c_requests.inc(qn)
        if hits:
            self._c_hits.inc(hits)
        if miss:
            self._c_misses.inc(len(miss))
        if evictions:
            self._c_evictions.inc(evictions)
        return out_s, out_i

    def submit(self, q_dims, q_vals, q_dense, **kw) -> Future:
        """Async client API: enqueue a search, get a Future of (scores, ids).

        Dispatch order is submission order on a small worker pool; the shard
        fan-out inside each search already overlaps device work via JAX
        async dispatch."""
        with self._lock:
            if self._executor is None:
                self._executor = ThreadPoolExecutor(
                    max_workers=self._max_workers,
                    thread_name_prefix="query-service")
            ex = self._executor
        return ex.submit(self.search, q_dims, q_vals, q_dense, **kw)

    def _run_batch(self, gen: _Generation, view: _DeltaView | None,
                   q_dims: np.ndarray, q_vals: np.ndarray,
                   q_dense: np.ndarray, h: int, alpha: int, beta: int,
                   span=None) -> tuple[np.ndarray, np.ndarray]:
        """Pad one miss-batch to its bucket, fan out over the main engine(s)
        plus the delta shard, merge on host.

        The delta is literally one more engine in the fan-out (DESIGN.md
        §6.2); its tombstoned slots score -inf on device, main-generation
        tombstones are dropped by the host merge.  With tombstones pending,
        every main engine overfetches by the (16-bucketed, so the jit cache
        stays bounded) tombstone count — overfetch-then-truncate of a
        deterministic top-k is exact, so the mutation-free path returns the
        very same bits as before."""
        qn = q_dims.shape[0]
        bucket = bucket_for(qn, self.buckets)
        d_active = gen.engine.arrays.d_active
        qd = jnp.asarray(pad_rows(q_dims, bucket, fill=d_active))
        qv = jnp.asarray(pad_rows(q_vals, bucket))
        qe = jnp.asarray(pad_rows(q_dense, bucket))

        deleted = view.deleted if view is not None else frozenset()
        engines = gen.shards if gen.shards is not None else [gen.engine]
        offsets = (gen.offsets if gen.shards is not None
                   else np.zeros(1, np.int64))
        h_fetch = plan_overfetch(engines, h, deleted)
        delta_engine = view.engine if view is not None else None

        self._c_batches.inc()
        with self._lock:
            c1, c2 = engines[0].candidate_counts(h_fetch[0], alpha, beta)
            self._jit_keys.add((bucket, q_dims.shape[1], q_dense.shape[1],
                                engines[0].num_points, h_fetch[0], c1, c2,
                                gen.shards is not None))
            if delta_engine is not None:
                hd = delta_engine.num_points        # fetch every delta slot
                cd1, cd2 = delta_engine.candidate_counts(hd, alpha, beta)
                self._jit_keys.add((bucket, q_dims.shape[1],
                                    q_dense.shape[1], hd, hd, cd1, cd2,
                                    "delta"))

        # the shared fan-out merge (core/streaming.py::fanout_search — the
        # same helper search_mutable uses): dispatch every engine before
        # syncing any, assemble in the common id space, merge on host.
        timing = {} if span else None
        out = fanout_search(engines, h_fetch, offsets, gen.id_map,
                            delta_engine,
                            view.ids if view is not None else None,
                            deleted, qd, qv, qe, h=h, alpha=alpha,
                            beta=beta, qn=qn, timing=timing)
        if timing:
            span.set("dispatch_s", timing["dispatch_s"])
            span.set("merge_s", timing["merge_s"])
        return out

    # -- introspection ----------------------------------------------------

    def cache_info(self) -> CacheInfo:
        """Exact LRU counters: hits, misses, evictions, size, capacity.
        (Registry-backed — reads 0 under ``Observability.off()``.)"""
        with self._lock:
            return CacheInfo(hits=self._c_hits.value,
                             misses=self._c_misses.value,
                             evictions=self._c_evictions.value,
                             size=len(self._cache),
                             capacity=self._cache_cap)

    def jit_cache_info(self) -> JitCacheInfo:
        """Observed engine compilation keys vs the declared bucketing bound."""
        with self._lock:
            shapes = tuple(sorted({k[0] for k in self._jit_keys}))
            combos = {k[1:] for k in self._jit_keys}
            return JitCacheInfo(batch_shapes=shapes,
                                entries=len(self._jit_keys),
                                bound=len(self.buckets) * max(1, len(combos)))

    def stats(self) -> dict:
        """Service counters for dashboards/benchmarks (plain dict).  On a
        durable service this includes the WAL durability gauges (DESIGN.md
        §9.1): the most recent fsync latency, the number of records that
        fsync covered (group-commit batch size), and the current
        flushed-but-unsynced backlog."""
        wal = self._durability.wal if self._durability is not None else None
        with self._lock:
            view = self._delta_view
            return {"requests": self._c_requests.value,
                    "batches": self._c_batches.value,
                    "refreshes": self._c_refreshes.value,
                    "version": self._version,
                    "cache_hits": self._c_hits.value,
                    "cache_misses": self._c_misses.value,
                    "cache_evictions": self._c_evictions.value,
                    "num_shards": self.num_shards, "buckets": self.buckets,
                    "mutation_version": self._mutation_version,
                    "delta_rows": view.live if view is not None else 0,
                    "delta_capacity":
                        view.capacity if view is not None else 0,
                    "deleted_pending":
                        len(view.deleted) if view is not None else 0,
                    "compactions": self._compactions,
                    "last_compaction_s": self._last_compaction_s,
                    "durable": wal is not None,
                    "wal_next_seq": wal.next_seq if wal is not None else 0,
                    "wal_last_fsync_s":
                        wal.last_fsync_s if wal is not None else None,
                    "wal_group_commit_batch":
                        wal.last_group_batch if wal is not None else 0,
                    "wal_unsynced_backlog":
                        wal.unsynced_backlog if wal is not None else 0,
                    "recovered_replayed":
                        (self._recovery.replayed
                         if self._recovery is not None else 0)}

    def metrics(self) -> dict:
        """JSON-ready snapshot of every registry instrument this service
        (and its WAL, when durable) feeds — the in-process analogue of the
        ``--metrics-port`` endpoint (DESIGN.md §9.1)."""
        return self.obs.metrics.snapshot()

    @property
    def version(self) -> int:
        """Version number of the currently installed index generation."""
        with self._lock:
            return self._version

    def close(self) -> None:
        """Shut down the async submit pool and wait out any in-flight
        background compaction (idempotent).  The closed flag is set in the
        same critical section that reads the compaction thread, and
        _spawn_compaction refuses once it's set — so no compaction can
        start after close() returns."""
        with self._lock:
            ex, self._executor = self._executor, None
            self._closed = True
            ct = self._compact_thread
        if ex is not None:
            ex.shutdown(wait=True)
        if ct is not None and ct.is_alive():
            ct.join()
        with self._mut_lock:
            if self._durability is not None:
                self._durability.close()
