"""PQ-approximated LM head — the paper's technique as a serving feature.

Next-token scoring over a 100k–256k vocabulary is a MIPS problem over the
output embedding table (DESIGN.md §4; the paper's own "extreme
classification" use case).  We apply the full paper pipeline:

  dense data index     PQ over the columns of lm_head (K = d/2, l = 16),
                       scanned with the LUT16 kernel (or its jnp oracle);
  sparse component     per-sequence token statistics (repetition counts) —
                       a genuinely sparse query-side term, scored exactly
                       like the paper's sparse inverted side;
  residual reorder     top alpha*k candidates re-scored with the int8 dense
                       residual (paper pass 2) and exact lm_head columns for
                       the final k (pass 3 analogue).

Result: full-vocab logits never materialize — the decode-time head cost
drops from O(V·d) to O(V·K/2 bytes + alpha·k·d), the paper's >10x regime
for 152k-256k vocabularies.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import residual as res
from repro.core.engine import Backend
from repro.core.pq import (PQCodebooks, ScalarQuant, adc_lut, pq_decode,
                           pq_encode, scalar_quantize, train_codebooks)

__all__ = ["HybridHeadParams", "HybridLMHead"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HybridHeadParams:
    """Device-resident PQ head: codebooks + codes + residual + exact head."""
    codebooks: PQCodebooks
    codes: jax.Array            # (V, K) uint8; (V, ceil(K/2)) when packed
    residual: ScalarQuant       # int8 residual of embedding columns
    head: jax.Array             # (d, V) exact head (pass-3 rerank)
    codes_packed: bool = dataclasses.field(
        default=False, metadata=dict(static=True))


class HybridLMHead:
    """Build once per checkpoint; serve per decode step."""

    def __init__(self, cfg, use_kernel: bool = False,
                 backend: Backend | str | None = None):
        """backend: engine backend name for the pass-1 code scan (ref,
        onehot-mxu, pallas, pallas-packed); overrides the legacy use_kernel."""
        self.cfg = cfg
        if backend is None:
            backend = Backend.PALLAS if use_kernel else Backend.REF
        self.backend = Backend.from_name(backend)

    def build(self, lm_head: jax.Array, *, subspaces: int | None = None,
              iters: int = 8, seed: int = 0) -> HybridHeadParams:
        """lm_head: (d, V) — token vectors are columns.

        With the pallas-packed backend the vocab-side codes are stored
        two-per-byte (V·K/2 bytes): the decode-time pass-1 scan — the V·K
        byte stream the head cost model is built on — streams half as much."""
        import numpy as np

        d, v = lm_head.shape
        table = lm_head.T.astype(jnp.float32)              # (V, d)
        k = subspaces or max(d // 2, 1)
        cb = train_codebooks(table, k, 16, iters=iters, seed=seed)
        codes = pq_encode(table, cb)
        recon = pq_decode(codes, cb)
        residual = scalar_quantize(table - recon)
        packed = self.backend is Backend.PALLAS_PACKED
        if packed:
            from repro.core.pq import pack_codes
            codes = jnp.asarray(pack_codes(np.asarray(codes)))
        return HybridHeadParams(codebooks=cb, codes=codes, residual=residual,
                                head=lm_head.astype(jnp.float32),
                                codes_packed=packed)

    @partial(jax.jit, static_argnums=(0, 4, 5, 6))
    def approx_topk(self, hp: HybridHeadParams, hidden: jax.Array,
                    token_counts: jax.Array | None, k: int = 50,
                    alpha: int = 8, penalty: float = 0.0):
        """hidden: (B, d) final hidden states; token_counts: (B, V) sparse
        per-sequence counts (may be None).  Returns (values (B,k), ids (B,k)).

        Pass 1: engine ADC over PQ codes (+ sparse penalty);
        Pass 2: + int8 residual for alpha*k candidates (engine pass-2 math);
        Pass 3: exact head columns for the k survivors."""
        h = hidden.astype(jnp.float32)
        lut = adc_lut(h, hp.codebooks)                     # (B, K, 16)
        scores = eng.adc_scores(hp.codes, lut, self.backend,
                                packed=hp.codes_packed)    # (B, V)
        if token_counts is not None and penalty != 0.0:
            scores = scores - penalty * token_counts       # hybrid sparse term
        c1 = min(alpha * k, scores.shape[1])
        s1, ids1 = res.topk_candidates(scores, c1)

        # pass 2: int8 residual correction (the engine's dense reorder pass).
        # Keep at least 16 survivors: pass 3 reranks them with EXACT columns,
        # so a deeper (still tiny) pool pins down top-1 decode fidelity.
        corr = res.dense_residual_scores(hp.residual, ids1, h)
        s2v, ids2 = res.reorder_pass(s1, ids1, corr, min(max(2 * k, 16), c1))

        # pass 3: exact columns for final ranking, in the MODEL's compute
        # dtype — the same arithmetic as the full-vocab head this replaces,
        # so near-tie top-1 decisions agree with the exact decode path.
        cd = jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32
        cols = jnp.take(hp.head, ids2, axis=1)             # (d, B, C)
        exact = jnp.einsum("bd,dbc->bc", h.astype(cd),
                           cols.astype(cd)).astype(jnp.float32)
        if token_counts is not None and penalty != 0.0:
            pen = jnp.take_along_axis(token_counts, ids2, axis=1)
            exact = exact - penalty * pen
        # rank by (score desc, vocab id asc): argmax over the full vocab
        # breaks exact ties by lowest id, and so must we
        pos3 = jnp.lexsort((ids2, -exact), axis=-1)[:, :k]
        s3 = jnp.take_along_axis(exact, pos3, axis=1)
        ids3 = jnp.take_along_axis(ids2, pos3, axis=1)
        return s3, ids3

    def approx_topk_bucketed(self, hp: HybridHeadParams, hidden: jax.Array,
                             token_counts: jax.Array | None, k: int = 50,
                             alpha: int = 8, penalty: float = 0.0,
                             buckets: tuple[int, ...] = (1, 8, 32)):
        """``approx_topk`` behind decode-batch bucketing (DESIGN.md §5).

        ``approx_topk`` recompiles for every distinct decode batch size; a
        serving loop whose sessions join and leave would melt the jit cache.
        This wrapper pads the batch up to the same static bucket set the
        QueryService uses (padded rows are zero hidden states, sliced off)
        and chunks batches above the largest bucket, so the head compiles
        at most ``len(buckets)`` times per (k, alpha, penalty) combination.
        Padding runs device-side (``jnp.pad``) — no host round-trip in the
        per-token decode path."""
        from .query_service import bucket_for
        bks = tuple(sorted(set(buckets)))
        b = hidden.shape[0]
        if b > bks[-1]:
            cap = bks[-1]
            outs = [self.approx_topk_bucketed(
                hp, hidden[lo:lo + cap],
                None if token_counts is None else token_counts[lo:lo + cap],
                k, alpha, penalty, bks) for lo in range(0, b, cap)]
            return (jnp.concatenate([o[0] for o in outs]),
                    jnp.concatenate([o[1] for o in outs]))
        bucket = bucket_for(b, bks)
        hid = jnp.pad(jnp.asarray(hidden), ((0, bucket - b), (0, 0)))
        tc = token_counts
        if tc is not None:
            tc = jnp.pad(jnp.asarray(tc), ((0, bucket - b), (0, 0)))
        vals, ids = self.approx_topk(hp, hid, tc, k, alpha, penalty)
        return vals[:b], ids[:b]

    def exact_topk(self, hp: HybridHeadParams, hidden: jax.Array,
                   token_counts: jax.Array | None, k: int = 50,
                   penalty: float = 0.0):
        """Oracle: full-vocab matmul (the thing the paper avoids)."""
        logits = hidden.astype(jnp.float32) @ hp.head
        if token_counts is not None and penalty != 0.0:
            logits = logits - penalty * token_counts
        return jax.lax.top_k(logits, k)
