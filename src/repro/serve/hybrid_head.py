"""PQ-approximated LM head — the paper's technique as a serving feature.

Next-token scoring over a 100k–256k vocabulary is a MIPS problem over the
output embedding table (DESIGN.md §4; the paper's own "extreme
classification" use case).  We apply the full paper pipeline:

  dense data index     PQ over the columns of lm_head (K = d/2, l = 16),
                       scanned with the LUT16 kernel (or its jnp oracle);
  sparse component     per-sequence token statistics (repetition counts) —
                       a genuinely sparse query-side term, scored exactly
                       like the paper's sparse inverted side;
  residual reorder     top alpha*k candidates re-scored with the int8 dense
                       residual (paper pass 2) and exact lm_head columns for
                       the final k (pass 3 analogue).

Result: full-vocab logits never materialize — the decode-time head cost
drops from O(V·d) to O(V·K/2 bytes + alpha·k·d), the paper's >10x regime
for 152k-256k vocabularies.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.pq import (PQCodebooks, ScalarQuant, adc_lut, adc_scores_ref,
                           pq_decode, pq_encode, scalar_quantize,
                           train_codebooks)

__all__ = ["HybridHeadParams", "HybridLMHead"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HybridHeadParams:
    codebooks: PQCodebooks
    codes: jax.Array            # (V, K) uint8
    residual: ScalarQuant       # int8 residual of embedding columns
    head: jax.Array             # (d, V) exact head (pass-3 rerank)


class HybridLMHead:
    """Build once per checkpoint; serve per decode step."""

    def __init__(self, cfg, use_kernel: bool = False):
        self.cfg = cfg
        self.use_kernel = use_kernel

    def build(self, lm_head: jax.Array, *, subspaces: int | None = None,
              iters: int = 8, seed: int = 0) -> HybridHeadParams:
        """lm_head: (d, V) — token vectors are columns."""
        d, v = lm_head.shape
        table = lm_head.T.astype(jnp.float32)              # (V, d)
        k = subspaces or max(d // 2, 1)
        cb = train_codebooks(table, k, 16, iters=iters, seed=seed)
        codes = pq_encode(table, cb)
        recon = pq_decode(codes, cb)
        residual = scalar_quantize(table - recon)
        return HybridHeadParams(codebooks=cb, codes=codes, residual=residual,
                                head=lm_head.astype(jnp.float32))

    @partial(jax.jit, static_argnums=(0, 4, 5, 6))
    def approx_topk(self, hp: HybridHeadParams, hidden: jax.Array,
                    token_counts: jax.Array | None, k: int = 50,
                    alpha: int = 8, penalty: float = 0.0):
        """hidden: (B, d) final hidden states; token_counts: (B, V) sparse
        per-sequence counts (may be None).  Returns (values (B,k), ids (B,k)).

        Pass 1: LUT16 ADC over PQ codes (+ sparse penalty);
        Pass 2: + int8 residual for alpha*k candidates;
        Pass 3: exact head columns for the k survivors."""
        h = hidden.astype(jnp.float32)
        lut = adc_lut(h, hp.codebooks)                     # (B, K, 16)
        if self.use_kernel:
            from repro.kernels.ops import lut16_adc
            scores = lut16_adc(hp.codes, lut)
        else:
            scores = adc_scores_ref(hp.codes, lut)         # (B, V)
        if token_counts is not None and penalty != 0.0:
            scores = scores - penalty * token_counts       # hybrid sparse term
        c1 = min(alpha * k, scores.shape[1])
        s1, ids1 = jax.lax.top_k(scores, c1)

        # pass 2: int8 residual correction
        rows = jnp.take(hp.residual.q, ids1, axis=0).astype(jnp.float32)
        qs = h * hp.residual.scale[None, :]
        base = 128.0 * qs.sum(-1) + h @ hp.residual.zero
        corr = jnp.einsum("bcd,bd->bc", rows, qs) + base[:, None]
        s2 = s1 + corr
        s2v, pos2 = jax.lax.top_k(s2, min(2 * k, c1))
        ids2 = jnp.take_along_axis(ids1, pos2, axis=1)

        # pass 3: exact columns for final ranking
        cols = jnp.take(hp.head, ids2, axis=1)             # (d, B, 2k)
        exact = jnp.einsum("bd,dbc->bc", h, cols)
        if token_counts is not None and penalty != 0.0:
            pen = jnp.take_along_axis(token_counts, ids2, axis=1)
            exact = exact - penalty * pen
        s3, pos3 = jax.lax.top_k(exact, k)
        ids3 = jnp.take_along_axis(ids2, pos3, axis=1)
        return s3, ids3

    def exact_topk(self, hp: HybridHeadParams, hidden: jax.Array,
                   token_counts: jax.Array | None, k: int = 50,
                   penalty: float = 0.0):
        """Oracle: full-vocab matmul (the thing the paper avoids)."""
        logits = hidden.astype(jnp.float32) @ hp.head
        if token_counts is not None and penalty != 0.0:
            logits = logits - penalty * token_counts
        return jax.lax.top_k(logits, k)
