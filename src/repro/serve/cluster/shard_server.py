"""Cluster shard server: one process, one role, one framed socket endpoint
(DESIGN.md §8.2–§8.3).

Three roles share the server shell (accept loop, dispatch, fault hooks):

* ``primary`` — owns the ONE mutable ``HybridIndex`` and its persist store
  (``persist.recover``): applies + WAL-logs every mutation, serves the
  DELTA search part, distributes its snapshot store to bootstrapping
  peers, and serves the WAL tail to replicas (``wal_fetch``).  Compaction
  happens here, cut as a durable checkpoint the other roles reload from.
* ``scorer`` — serves the MAIN search part for one row slice: bootstraps
  by copying the primary's store, loads the snapshot, keeps
  ``split_index_arrays(..., ragged=True)[shard]`` plus that slice's
  external ids.  The frozen artifacts (codebooks, column space) are the
  primary's own, which is what makes the RPC fan-out bit-identical to the
  in-process one: there is ONE build, row-sliced — never N builds.
* ``replica`` — a full follower: bootstraps from the store, then ships the
  WAL tail (``MutationWAL.append_frames`` into its OWN local log, then
  ``persist.apply_record`` through the normal mutation path), so a replica
  restarted mid-ingest recovers from its local snapshot + shipped log to
  the exact applied seq.  Serves whole-query (main + delta) parts tagged
  with ``applied_seq`` for the router's watermark rule (DESIGN.md §8.4).

Every search request carries the router's generation tag; a request
against a generation this process does not hold raises
``StaleGenerationError`` back across the wire — the router re-syncs and
retries rather than merging parts from mixed generations.
"""

from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import time

import numpy as np

from repro.core.distributed import split_index_arrays
from repro.core.engine import ScoringEngine

from .client import ShardClient
from .protocol import MSG_ERROR, MSG_RESPONSE, recv_msg, send_msg

__all__ = ["ShardServer", "StaleGenerationError", "main"]


class StaleGenerationError(RuntimeError):
    """The request's generation tag is not one this server holds (a
    compaction moved the cluster on, or the caller is ahead of a server
    that has not reloaded yet).  The router treats it as retriable after a
    state re-sync — never as data."""
    kind = "StaleGeneration"


def _jnp(x):
    import jax.numpy as jnp
    return jnp.asarray(x)


class _Gen:
    """One generation a scorer serves: the slice engine, its external ids,
    and the slice's global row extent."""

    def __init__(self, engine, ext_ids, num_points_total):
        self.engine = engine
        self.ext_ids = ext_ids
        self.num_points_total = num_points_total


class ShardServer:
    """The process behind one cluster endpoint; see the module docstring
    for the role split.  ``start()`` binds (port 0 = ephemeral), spawns the
    accept loop, and returns the bound port; ``__main__`` prints
    ``READY <port>`` on stdout so a launcher can scrape it."""

    def __init__(self, role: str, *, store: str | None = None,
                 peer: str | None = None, shard: int = 0,
                 num_shards: int = 1, workdir: str | None = None,
                 backend: str | None = None, poll_interval: float = 0.02):
        if role not in ("primary", "scorer", "replica"):
            raise ValueError(f"unknown role {role!r}")
        self.role = role
        self.store = store
        self.peer = peer
        self.shard = shard
        self.num_shards = num_shards
        self.workdir = workdir
        self.backend = backend
        self.poll_interval = poll_interval
        self.generation = 1
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._listener: socket.socket | None = None
        self._faults: set[str] = set()
        self._ship_paused = threading.Event()
        self._ship_thread: threading.Thread | None = None
        self.shipped_records = 0
        # primary / replica
        self.index = None
        self.durability = None
        self._applied_seq = 0
        self._prev_index = None          # (gen, index) kept across a flip
        self._delta_engine_cache: dict[tuple, ScoringEngine] = {}
        # scorer
        self._gens: dict[int, _Gen] = {}

    # -- bootstrap --------------------------------------------------------

    def _peer_client(self) -> ShardClient:
        host, port = self.peer.rsplit(":", 1)
        return ShardClient(host, int(port))

    def bootstrap(self) -> None:
        """Bring this role to serving state (blocking; run before
        ``start``): primary recovers its store; scorer/replica fetch the
        primary's store first when they have none (snapshot
        distribution)."""
        from repro import persist
        if self.role == "primary":
            rec = persist.recover(self.store, backend=self.backend)
            self.index, self.durability = rec.index, rec.durability
            self._applied_seq = self.durability.wal.next_seq - 1
        elif self.role == "scorer":
            self._load_slice(self.generation)
        else:                            # replica
            if persist.read_current(self.store) is None:
                self._peer_client().fetch_store(self.store)
            rec = persist.recover(self.store, backend=self.backend)
            self.index, self.durability = rec.index, rec.durability
            self._applied_seq = self.durability.wal.next_seq - 1
            peer_status, _ = self._peer_client().call("status")
            self.generation = int(peer_status["gen"])
            self._start_shipping()

    def _load_slice(self, gen: int) -> None:
        """Scorer: fetch the primary's current store into a per-generation
        directory, load the snapshot, keep only this shard's row slice
        (plus its external ids) — and at most the last two generations, so
        in-flight old-generation requests drain during a flip."""
        from repro import persist
        root = os.path.join(self.workdir, f"gen-{gen:04d}")
        self._peer_client().fetch_store(root)
        index, _ = persist.load_snapshot(root, backend=self.backend)
        parts, offsets = split_index_arrays(index.engine.arrays,
                                            self.num_shards, ragged=True)
        lo = int(offsets[self.shard])
        hi = lo + parts[self.shard].num_points
        g = _Gen(engine=ScoringEngine(arrays=parts[self.shard],
                                      backend=index.engine.backend),
                 ext_ids=np.asarray(index.mutable_state.id_map[lo:hi]),
                 num_points_total=index.engine.arrays.num_points)
        with self._lock:
            self._gens[gen] = g
            self.generation = gen
            for old in sorted(self._gens)[:-2]:
                del self._gens[old]

    # -- replication shipping (replica role) ------------------------------

    def _start_shipping(self) -> None:
        self._ship_thread = threading.Thread(target=self._ship_loop,
                                             daemon=True,
                                             name="wal-shipping")
        self._ship_thread.start()

    def applied_seq(self) -> int:
        """Last WAL seq whose effects are VISIBLE in this process's
        serving state.  On the primary that is the log's high-water mark
        (apply-then-log); on a replica it advances only after
        ``apply_record`` returns (log-then-apply) — the distinction the
        watermark rule (DESIGN.md §8.4) depends on: a replica must never
        advertise a seq whose mutation a read could still miss.  Recovery
        re-establishes it exactly (the replica-restart test pins this)."""
        if self.role == "primary":
            return self.durability.wal.next_seq - 1
        return self._applied_seq

    def _ship_loop(self) -> None:
        """Replica tail loop: poll the primary for frames past our applied
        seq, append them BYTE-IDENTICAL to the local log, then apply each
        through the normal mutation path — log-then-apply, so a crash
        between the two replays the record on restart instead of losing
        it."""
        from repro.persist import apply_record
        peer = self._peer_client()
        while not self._stop.is_set():
            if self._ship_paused.is_set():
                time.sleep(self.poll_interval)
                continue
            try:
                meta, arrays = peer.call(
                    "wal_fetch", {"from_seq": self.applied_seq() + 1})
            except ConnectionError:
                time.sleep(self.poll_interval)
                continue
            frames = arrays["frames"].tobytes()
            if not frames:
                time.sleep(self.poll_interval)
                continue
            with self._lock:
                for rec in self.durability.wal.append_frames(frames):
                    apply_record(self.index, rec)
                    self._applied_seq = rec.seq
                    self.shipped_records += 1

    # -- op handlers ------------------------------------------------------

    def _check_gen(self, meta: dict) -> int:
        gen = int(meta["gen"])
        ok = gen in self._gens if self.role == "scorer" else \
            gen == self.generation or (
                self._prev_index is not None and gen == self._prev_index[0])
        if not ok:
            raise StaleGenerationError(
                f"{self.role} holds generation {self.generation}, "
                f"request wants {gen}")
        return gen

    def _delta_engine(self, index, snap) -> ScoringEngine:
        key = (id(index), snap.version, snap.capacity)
        eng = self._delta_engine_cache.get(key)
        if eng is None:
            self._delta_engine_cache.clear()      # one live snapshot view
            eng = ScoringEngine(arrays=snap.arrays,
                                backend=index.engine.backend)
            self._delta_engine_cache[key] = eng
        return eng

    def _op_search(self, meta, arrays):
        qd, qv = _jnp(arrays["q_dims"]), _jnp(arrays["q_vals"])
        qe = _jnp(arrays["q_dense"])
        h = int(meta["h"])
        alpha, beta = int(meta["alpha"]), int(meta["beta"])
        part = meta["part"]
        t0 = time.perf_counter()
        if part == "main":                       # scorer row slice
            with self._lock:
                gen_no = self._check_gen(meta)
                gen = self._gens[gen_no]
            s, ids, _ = gen.engine.search(qd, qv, qe, h=h,
                                          alpha=alpha, beta=beta)
            # local slice positions -> external ids; -1 sentinels wrap to
            # the slice's last id exactly like the in-process
            # ``id_map[off + ids]`` (their scores are non-finite, so the
            # merge rewrites them to -1 either way)
            out = {"scores": np.asarray(s),
                   "ids": gen.ext_ids[np.asarray(ids)]}
            rmeta = {"gen": gen_no}
        elif part == "delta":                    # primary delta shard
            with self._lock:
                gen = self._check_gen(meta)
                index = (self.index if gen == self.generation
                         else self._prev_index[1])
                st = index.mutable_state
                snap = st.delta.snapshot() if st.delta.live_count else None
                eng = (self._delta_engine(index, snap)
                       if snap is not None else None)
            if snap is None:
                q = int(np.asarray(arrays["q_dims"]).shape[0])
                out = {"scores": np.zeros((q, 0), np.float32),
                       "ids": np.zeros((q, 0), np.int64)}
                rmeta = {"gen": gen, "live": 0}
            else:
                s, ids, _ = eng.search(qd, qv, qe, h=snap.capacity,
                                       alpha=alpha, beta=beta)
                out = {"scores": np.asarray(s),
                       "ids": snap.ids[np.asarray(ids)]}
                rmeta = {"gen": gen, "live": snap.live}
        elif part == "full":                     # replica: main + delta
            with self._lock:
                self._check_gen(meta)
                st = self.index.mutable_state
                snap = st.delta.snapshot() if st.delta.live_count else None
                eng = (self._delta_engine(self.index, snap)
                       if snap is not None else None)
                tombs = np.asarray(sorted(st.main_tombstones), np.int64)
                applied = self.applied_seq()
            ms, mi, _ = self.index.engine.search(qd, qv, qe, h=h,
                                                 alpha=alpha, beta=beta)
            out = {"ms": np.asarray(ms),
                   "mi": np.asarray(st.id_map)[np.asarray(mi)],
                   "main_tombstones": tombs}
            if snap is not None:
                ds, di, _ = eng.search(qd, qv, qe, h=snap.capacity,
                                       alpha=alpha, beta=beta)
                out["ds"], out["di"] = np.asarray(ds), snap.ids[np.asarray(di)]
            rmeta = {"gen": self.generation, "applied_seq": applied,
                     "delta_live": snap.live if snap is not None else 0}
        else:
            raise ValueError(f"unknown search part {part!r}")
        rmeta["score_s"] = time.perf_counter() - t0
        return rmeta, out

    def _op_insert(self, meta, arrays):
        import scipy.sparse as sp
        xs = sp.csr_matrix((arrays["data"], arrays["indices"],
                            arrays["indptr"]),
                           shape=tuple(np.asarray(arrays["shape"])))
        ids = arrays["ids"] if "ids" in arrays else None
        with self._lock:
            self.durability.ensure_ok()
            st = self.index.mutable_state
            before = set(st.main_tombstones)
            assigned = self.index.insert(xs, arrays["dense"], ids=ids)
            seq = self.durability.log_insert(xs, arrays["dense"], assigned,
                                             sync=False)
            main_killed = sorted(st.main_tombstones - before)
            delta_live = st.delta.live_count
        self.durability.sync(seq)                # group-commit ack
        return ({"seq": seq, "gen": self.generation,
                 "delta_live": delta_live},
                {"ids": np.asarray(assigned, np.int64),
                 "main_killed": np.asarray(main_killed, np.int64)})

    def _op_delete(self, meta, arrays):
        req = np.atleast_1d(np.asarray(arrays["ids"], np.int64))
        with self._lock:
            self.durability.ensure_ok()
            st = self.index.mutable_state
            before = set(st.main_tombstones)
            was_live = [int(e) for e in req if int(e) in st._loc]
            killed = self.index.delete(req)
            seq = (self.durability.log_delete(req, sync=False)
                   if killed else 0)
            main_killed = sorted(st.main_tombstones - before)
            delta_live = st.delta.live_count
        if seq:
            self.durability.sync(seq)
        return ({"seq": seq, "gen": self.generation, "killed": killed,
                 "delta_live": delta_live},
                {"killed_ids": np.asarray(sorted(was_live), np.int64),
                 "main_killed": np.asarray(main_killed, np.int64)})

    def _op_compact(self, meta, arrays):
        retrain = meta.get("retrain")
        with self._lock:
            self.durability.ensure_ok()
            new_index = self.index.compact(retrain=retrain)
            self.durability.checkpoint(new_index)
            self._prev_index = (self.generation, self.index)
            self.index = new_index
            self.generation += 1
            self._delta_engine_cache.clear()
            st = new_index.mutable_state
            return ({"gen": self.generation,
                     "num_points": new_index.engine.arrays.num_points,
                     "d_active": new_index.engine.arrays.d_active,
                     "next_seq": self.durability.wal.next_seq},
                    {"cols_global_ids":
                     np.asarray(new_index.cols.global_ids)})

    def _op_wal_fetch(self, meta, arrays):
        buf, seqs = self.durability.wal.read_frames(
            int(meta["from_seq"]), limit=int(meta.get("limit", 256)))
        return ({"seqs": seqs, "next_seq": self.durability.wal.next_seq},
                {"frames": np.frombuffer(buf, np.uint8)})

    def _op_store_manifest(self, meta, arrays):
        from repro import persist
        return {"files": persist.store_files(self.store),
                "gen": self.generation}, {}

    def _op_store_file(self, meta, arrays):
        with open(os.path.join(self.store, meta["path"]), "rb") as f:
            data = f.read()
        return {}, {"data": np.frombuffer(data, np.uint8)}

    def _op_reload(self, meta, arrays):
        gen = int(meta["gen"])
        if self.role == "scorer":
            self._load_slice(gen)
        elif self.role == "replica":
            # re-bootstrap onto the primary's post-compaction store: the
            # old local store describes a generation that no longer takes
            # writes, so wipe it and fetch fresh, then resume shipping
            # from the new snapshot's replay horizon
            import shutil
            from repro import persist
            self._ship_paused.set()      # quiesce the tail loop first
            with self._lock:
                self.durability.close()
                shutil.rmtree(self.store)
                self._peer_client().fetch_store(self.store)
                rec = persist.recover(self.store, backend=self.backend)
                self.index, self.durability = rec.index, rec.durability
                self._applied_seq = self.durability.wal.next_seq - 1
                self.generation = gen
                self._delta_engine_cache.clear()
            self._ship_paused.clear()
        else:
            raise ValueError("primary does not reload; it compacts")
        return {"gen": self.generation}, {}

    def _op_status(self, meta, arrays):
        out = {"role": self.role, "gen": self.generation}
        if self.role in ("primary", "replica"):
            st = self.index.mutable_state
            out.update(applied_seq=self.applied_seq(),
                       delta_live=st.delta.live_count,
                       num_points=self.index.engine.arrays.num_points,
                       shipping_paused=self._ship_paused.is_set())
        else:
            g = self._gens[self.generation]
            out.update(num_points_local=g.engine.num_points,
                       num_points=g.num_points_total, shard=self.shard)
        return out, {}

    def _op_info(self, meta, arrays):
        idx = self.index
        st = idx.mutable_state
        return ({"gen": self.generation,
                 "num_points": idx.engine.arrays.num_points,
                 "d_active": idx.engine.arrays.d_active,
                 "nq_max": idx.params.nq_max,
                 "backend": idx.engine.backend.value,
                 "h": 10, "alpha": idx.params.alpha,
                 "beta": idx.params.beta,
                 "delta_live": st.delta.live_count,
                 "applied_seq": self.applied_seq()},
                {"cols_global_ids": np.asarray(idx.cols.global_ids),
                 "main_tombstones":
                 np.asarray(sorted(st.main_tombstones), np.int64)})

    def _op_fault(self, meta, arrays):
        mode = meta["mode"]
        if mode == "pause_shipping":
            self._ship_paused.set()
        elif mode == "resume_shipping":
            self._ship_paused.clear()
        elif mode in ("corrupt_next", "close_next"):
            self._faults.add(mode)
        else:
            raise ValueError(f"unknown fault mode {mode!r}")
        return {"mode": mode}, {}

    def _op_ping(self, meta, arrays):
        return {"pong": True}, {}

    _OPS = {"search": _op_search, "insert": _op_insert,
            "delete": _op_delete, "compact": _op_compact,
            "wal_fetch": _op_wal_fetch, "store_manifest": _op_store_manifest,
            "store_file": _op_store_file, "reload": _op_reload,
            "status": _op_status, "info": _op_info, "fault": _op_fault,
            "ping": _op_ping}

    # -- server shell -----------------------------------------------------

    def _handle_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                try:
                    _, meta, arrays = recv_msg(conn)
                except (ConnectionError, OSError):
                    return
                cmd = meta.pop("cmd", None)
                handler = self._OPS.get(cmd)
                try:
                    if handler is None:
                        raise ValueError(f"unknown command {cmd!r}")
                    rmeta, rarr = handler(self, meta, arrays)
                    op = MSG_RESPONSE
                except Exception as e:           # ships as MSG_ERROR
                    rmeta = {"error": f"{type(e).__name__}: {e}",
                             "kind": getattr(e, "kind", type(e).__name__)}
                    rarr, op = {}, MSG_ERROR
                # fault injection never eats its OWN arming ack — the
                # armed fault fires on the NEXT (non-fault) exchange
                if cmd != "fault" and "close_next" in self._faults:
                    self._faults.discard("close_next")
                    return                       # drop mid-exchange
                corrupt = cmd != "fault" and "corrupt_next" in self._faults
                if corrupt:
                    self._faults.discard("corrupt_next")
                try:
                    send_msg(conn, "reply", rmeta, rarr, op=op,
                             corrupt=corrupt)
                except (ConnectionError, OSError):
                    return
        finally:
            conn.close()

    def start(self, port: int = 0, host: str = "127.0.0.1") -> int:
        """Bind + listen + spawn the accept loop (daemon thread); returns
        the bound port (``port=0`` picks an ephemeral one)."""
        self._listener = socket.create_server((host, port))
        self._listener.settimeout(0.2)
        threading.Thread(target=self._accept_loop, daemon=True,
                         name=f"{self.role}-accept").start()
        return self._listener.getsockname()[1]

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._handle_conn, args=(conn,),
                             daemon=True).start()

    def stop(self) -> None:
        """Stop accepting, close the listener, close the store handle."""
        self._stop.set()
        if self._listener is not None:
            self._listener.close()
        if self.durability is not None:
            self.durability.close()


def main(argv=None) -> int:
    """CLI entry (``python -m repro.serve.cluster.shard_server`` or
    ``repro.launch.serve --role shard``): bootstrap the role, bind, print
    ``READY <port>``, serve until killed."""
    ap = argparse.ArgumentParser(description="hybrid cluster shard server")
    ap.add_argument("--role", required=True,
                    choices=["primary", "scorer", "replica"])
    ap.add_argument("--store", help="persist store root (primary/replica)")
    ap.add_argument("--peer", help="primary host:port (scorer/replica)")
    ap.add_argument("--shard", type=int, default=0)
    ap.add_argument("--num-shards", type=int, default=1)
    ap.add_argument("--workdir", help="scratch dir (scorer store fetches)")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--port", type=int, default=0)
    args = ap.parse_args(argv)
    server = ShardServer(args.role, store=args.store, peer=args.peer,
                         shard=args.shard, num_shards=args.num_shards,
                         workdir=args.workdir, backend=args.backend)
    server.bootstrap()
    port = server.start(args.port)
    print(f"READY {port}", flush=True)
    try:
        while not server._stop.is_set():
            time.sleep(0.2)
    except KeyboardInterrupt:
        pass
    server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
