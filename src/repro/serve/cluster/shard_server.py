"""Cluster shard server: one process, one role, one framed socket endpoint
(DESIGN.md §8.2–§8.3, §8.7).

Three roles share the server shell (accept loop, dispatch, fault hooks):

* ``primary`` — owns the ONE mutable ``HybridIndex`` and its persist store
  (``persist.recover``): applies + WAL-logs every mutation, serves the
  DELTA search part, distributes its snapshot store to bootstrapping
  peers, and serves the WAL tail to replicas (``wal_fetch``).  Compaction
  happens here, cut as a durable checkpoint the other roles reload from.
* ``scorer`` — serves the MAIN search part for one row slice: bootstraps
  by copying the primary's store, loads the snapshot, keeps
  ``split_index_arrays(..., ragged=True)[shard]`` plus that slice's
  external ids.  The frozen artifacts (codebooks, column space) are the
  primary's own, which is what makes the RPC fan-out bit-identical to the
  in-process one: there is ONE build, row-sliced — never N builds.
* ``replica`` — a full follower: bootstraps from the store, then ships the
  WAL tail (``MutationWAL.append_frames`` into its OWN local log, then
  ``persist.apply_record`` through the normal mutation path), so a replica
  restarted mid-ingest recovers from its local snapshot + shipped log to
  the exact applied seq.  Serves whole-query (main + delta) parts tagged
  with ``applied_seq`` for the router's watermark rule (DESIGN.md §8.4).
  A caught-up replica can be PROMOTED to primary (``promote`` op), fenced
  by the WAL's monotonic term so the deposed primary's writes are refused
  everywhere (DESIGN.md §8.7).

AUTHORITY lives here, not in any router: the primary's liveness view —
tombstones, fully-deleted ids, delta live count — is versioned by a
``(term, epoch)`` tag that every mutation ack and delta response carries.
Routers keep only a cache keyed by that tag; a delta response whose tag
differs from the request's ``have_epoch``/``have_term`` piggybacks the
full authoritative sets (``state_sync`` serves the same payload on
demand), which is what makes N routers over one cluster bit-identical to
one router (DESIGN.md §8.4).

Every search request carries the router's generation tag; a request
against a generation this process does not hold raises
``StaleGenerationError`` back across the wire — the router re-syncs and
retries rather than merging parts from mixed generations.
"""

from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import time

import numpy as np

from repro.core.distributed import ceil16, split_index_arrays
from repro.core.engine import ScoringEngine
from repro.obs import Observability

from .client import ShardClient
from .protocol import MSG_ERROR, MSG_RESPONSE, recv_msg, send_msg

__all__ = ["ShardServer", "StaleGenerationError", "NotPrimaryError",
           "PromotionError", "main"]


class StaleGenerationError(RuntimeError):
    """The request's generation tag is not one this server holds (a
    compaction moved the cluster on, or the caller is ahead of a server
    that has not reloaded yet).  The router treats it as retriable after a
    state re-sync — never as data."""
    kind = "StaleGeneration"


class NotPrimaryError(RuntimeError):
    """A mutation (or compaction) was sent to a node that is not the
    primary.  Applying it locally would fork the replicated log — the
    exact divergence the single-writer discipline exists to prevent — so
    it is refused outright; the router re-discovers the primary and
    re-drives."""
    kind = "NotPrimary"


class PromotionError(RuntimeError):
    """A ``promote`` request failed its eligibility gate: the target is
    not a replica, has not applied every sealed (acked) seq, or the
    proposed term does not exceed its current one.  Promoting anyway would
    lose acked mutations or un-fence a zombie — the router must pick
    another candidate (DESIGN.md §8.7)."""
    kind = "Promotion"


class _Gen:
    """One generation a scorer serves: the slice engine, its external ids,
    and the slice's global row extent."""

    def __init__(self, engine, ext_ids, num_points_total):
        self.engine = engine
        self.ext_ids = ext_ids
        self.num_points_total = num_points_total


class ShardServer:
    """The process behind one cluster endpoint; see the module docstring
    for the role split.  ``start()`` binds (port 0 = ephemeral), spawns the
    accept loop, and returns the bound port; ``__main__`` prints
    ``READY <port>`` on stdout so a launcher can scrape it."""

    def __init__(self, role: str, *, store: str | None = None,
                 peer: str | None = None, shard: int = 0,
                 num_shards: int = 1, workdir: str | None = None,
                 backend: str | None = None, poll_interval: float = 0.02,
                 obs: Observability | None = None):
        if role not in ("primary", "scorer", "replica"):
            raise ValueError(f"unknown role {role!r}")
        self.role = role
        # server-side tracing is enabled but PER-REQUEST opt-in: a child
        # span is built only when the request meta carries a trace
        # context, so untraced routers cost this server nothing
        # (DESIGN.md §9.2)
        self.obs = obs if obs is not None else Observability(trace=True)
        self._h_score = self.obs.metrics.histogram("server.score_s")
        self.store = store
        self.peer = peer
        self.shard = shard
        self.num_shards = num_shards
        self.workdir = workdir
        self.backend = backend
        self.poll_interval = poll_interval
        self.generation = 1
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._listener: socket.socket | None = None
        self._faults: set[str] = set()
        self._ship_paused = threading.Event()
        self._ship_thread: threading.Thread | None = None
        self.shipped_records = 0
        # primary / replica
        self.index = None
        self.durability = None
        self._applied_seq = 0
        self._prev_index = None          # (gen, index) kept across a flip
        self._prev_auth = None           # frozen (main_dead, fully_deleted)
        self._delta_engine_cache: dict[tuple, ScoringEngine] = {}
        # liveness-state version: bumped under _lock on every op that can
        # change what a merge must drop (mutation, shipped record, flip,
        # promotion).  Paired with the WAL term it orders authoritative
        # state ACROSS primaries: terms only grow, so (term, epoch)
        # compares lexicographically even though a promoted replica's
        # epoch counter is unrelated to the deposed primary's.
        self._state_epoch = 1
        # scorer
        self._gens: dict[int, _Gen] = {}

    # -- bootstrap --------------------------------------------------------

    def _peer_client(self) -> ShardClient:
        host, port = self.peer.rsplit(":", 1)
        return ShardClient(host, int(port))

    def bootstrap(self) -> None:
        """Bring this role to serving state (blocking; run before
        ``start``): primary recovers its store; scorer/replica fetch the
        primary's store first when they have none (snapshot
        distribution)."""
        from repro import persist
        if self.role == "primary":
            rec = persist.recover(self.store, backend=self.backend,
                                      metrics=self.obs.metrics)
            self.index, self.durability = rec.index, rec.durability
            self._applied_seq = self.durability.wal.next_seq - 1
        elif self.role == "scorer":
            self._load_slice(self.generation)
        else:                            # replica
            if persist.read_current(self.store) is None:
                self._peer_client().fetch_store(self.store)
            rec = persist.recover(self.store, backend=self.backend,
                                      metrics=self.obs.metrics)
            self.index, self.durability = rec.index, rec.durability
            self._applied_seq = self.durability.wal.next_seq - 1
            peer_status, _ = self._peer_client().call("status")
            self.generation = int(peer_status["gen"])
            self._start_shipping()

    def _load_slice(self, gen: int) -> None:
        """Scorer: fetch the primary's current store into a per-generation
        directory, load the snapshot, keep only this shard's row slice
        (plus its external ids) — and at most the last two generations, so
        in-flight old-generation requests drain during a flip."""
        from repro import persist
        root = os.path.join(self.workdir, f"gen-{gen:04d}")
        self._peer_client().fetch_store(root)
        index, _ = persist.load_snapshot(root, backend=self.backend)
        parts, offsets = split_index_arrays(index.engine.arrays,
                                            self.num_shards, ragged=True)
        lo = int(offsets[self.shard])
        hi = lo + parts[self.shard].num_points
        g = _Gen(engine=ScoringEngine(arrays=parts[self.shard],
                                      backend=index.engine.backend),
                 ext_ids=np.asarray(index.mutable_state.id_map[lo:hi]),
                 num_points_total=index.engine.arrays.num_points)
        with self._lock:
            self._gens[gen] = g
            self.generation = gen
            for old in sorted(self._gens)[:-2]:
                del self._gens[old]

    # -- replication shipping (replica role) ------------------------------

    def _start_shipping(self) -> None:
        self._ship_thread = threading.Thread(target=self._ship_loop,
                                             daemon=True,
                                             name="wal-shipping")
        self._ship_thread.start()

    def applied_seq(self) -> int:
        """Last WAL seq whose effects are VISIBLE in this process's
        serving state.  On the primary that is the log's high-water mark
        (apply-then-log); on a replica it advances only after
        ``apply_record`` returns (log-then-apply) — the distinction the
        watermark rule (DESIGN.md §8.4) depends on: a replica must never
        advertise a seq whose mutation a read could still miss.  Recovery
        re-establishes it exactly (the replica-restart test pins this)."""
        if self.role == "primary":
            return self.durability.wal.next_seq - 1
        return self._applied_seq

    def term(self) -> int:
        """The WAL's fencing term (DESIGN.md §8.7); 0 for scorers, which
        hold no log and take no part in fencing."""
        return self.durability.wal.term if self.durability is not None else 0

    def _ship_loop(self) -> None:
        """Replica tail loop: poll the (current) primary for frames past
        our applied seq, append them BYTE-IDENTICAL to the local log, then
        apply each through the normal mutation path — log-then-apply, so a
        crash between the two replays the record on restart instead of
        losing it.  Follows ``set_peer`` re-pointing (failover moves the
        tail source to the promoted primary) and exits the moment this
        process is itself promoted."""
        from repro.persist import apply_record
        peer_addr = self.peer
        peer = self._peer_client()
        while not self._stop.is_set():
            if self.role != "replica":
                peer.close()
                return                   # promoted: this process leads now
            if self.peer != peer_addr:   # re-pointed at a new primary
                peer.close()
                peer_addr = self.peer
                peer = self._peer_client()
            if self._ship_paused.is_set():
                time.sleep(self.poll_interval)
                continue
            try:
                meta, arrays = peer.call(
                    "wal_fetch", {"from_seq": self.applied_seq() + 1})
            except ConnectionError:
                time.sleep(self.poll_interval)
                continue
            frames = arrays["frames"].tobytes()
            if not frames:
                time.sleep(self.poll_interval)
                continue
            try:
                with self._lock:
                    if self.role != "replica":
                        peer.close()
                        return
                    for rec in self.durability.wal.append_frames(frames):
                        apply_record(self.index, rec)
                        self._applied_seq = rec.seq
                        self.shipped_records += 1
                        self._state_epoch += 1
            except ValueError:
                # the term fence refused the frames — a deposed primary is
                # still talking; drop the batch and re-poll (a set_peer /
                # promote is racing this fetch)
                time.sleep(self.poll_interval)

    # -- authoritative liveness state (DESIGN.md §8.4) --------------------

    def _auth_state(self, index) -> tuple[np.ndarray, np.ndarray]:
        """The two dead-id sets every merge must drop, from THIS node's
        applied state (caller holds ``_lock``): ``main_dead`` (tombstoned
        main rows — upserts and deletes both) and ``fully_deleted`` (ids
        with no live copy anywhere — the overlay that stops a lagging
        follower resurrecting them)."""
        st = index.mutable_state
        main_dead = np.asarray(sorted(st.main_tombstones), np.int64)
        fully = (st.main_tombstones | set(st.extra_ids)) - st._loc.keys()
        return main_dead, np.asarray(sorted(fully), np.int64)

    def _ensure_primary(self) -> None:
        if self.role != "primary":
            raise NotPrimaryError(
                f"this node is a {self.role}; mutations go to the primary")

    # -- op handlers ------------------------------------------------------

    def _check_gen(self, meta: dict) -> int:
        gen = int(meta["gen"])
        ok = gen in self._gens if self.role == "scorer" else \
            gen == self.generation or (
                self._prev_index is not None and gen == self._prev_index[0])
        if not ok:
            raise StaleGenerationError(
                f"{self.role} holds generation {self.generation}, "
                f"request wants {gen}")
        return gen

    def _delta_engine(self, index, snap) -> ScoringEngine:
        key = (id(index), snap.version, snap.capacity)
        eng = self._delta_engine_cache.get(key)
        if eng is None:
            self._delta_engine_cache.clear()      # one live snapshot view
            eng = ScoringEngine(arrays=snap.arrays,
                                backend=index.engine.backend)
            self._delta_engine_cache[key] = eng
        return eng

    def _op_search(self, meta, arrays):
        # queries stay host numpy: the engine accepts numpy for EVERY
        # backend (the in-process QueryService always feeds it numpy), and
        # a per-request device put costs ~0.4ms of pure overhead on the
        # hot path — the backend moves data only if its kernels need to
        qd, qv = arrays["q_dims"], arrays["q_vals"]
        qe = arrays["q_dense"]
        h = int(meta["h"])
        alpha, beta = int(meta["alpha"]), int(meta["beta"])
        part = meta["part"]
        # per-request opt-in child span: NULL_SPAN unless the request
        # meta carries the router's trace context (DESIGN.md §9.2)
        sp = self.obs.tracer.from_wire(meta.get("trace"), "shard.search",
                                       role=self.role, part=part)
        t0 = time.perf_counter()
        if part == "main":                       # scorer row slice
            with self._lock:
                gen_no = self._check_gen(meta)
                gen = self._gens[gen_no]
            s, ids, _ = gen.engine.search(qd, qv, qe, h=h,
                                          alpha=alpha, beta=beta)
            # local slice positions -> external ids; -1 sentinels wrap to
            # the slice's last id exactly like the in-process
            # ``id_map[off + ids]`` (their scores are non-finite, so the
            # merge rewrites them to -1 either way)
            out = {"scores": np.asarray(s),
                   "ids": gen.ext_ids[np.asarray(ids)]}
            rmeta = {"gen": gen_no}
        elif part == "delta":                    # primary delta shard
            with self._lock:
                gen = self._check_gen(meta)
                current = gen == self.generation
                index = self.index if current else self._prev_index[1]
                st = index.mutable_state
                snap = st.delta.snapshot() if st.delta.live_count else None
                eng = (self._delta_engine(index, snap)
                       if snap is not None else None)
                # the delta response doubles as the router's state
                # validation channel: tag it, and when the caller's cached
                # (term, epoch) is not exactly ours — or it asked about a
                # frozen previous generation (epoch 0 sentinel) — piggyback
                # the full authoritative sets, captured under the SAME lock
                # as the delta snapshot so both describe one state
                epoch = self._state_epoch if current else 0
                term = self.term()
                # ``current_gen`` lets a router that pinned a frozen
                # generation discover the flip from the wire (another
                # router may have compacted) instead of silently serving
                # pre-compaction state that misses newer mutations
                rmeta = {"gen": gen, "epoch": epoch, "term": term,
                         "current_gen": self.generation,
                         "applied_seq": self.applied_seq(),
                         "live": snap.live if snap is not None else 0}
                sync = (not current
                        or int(meta.get("have_epoch", -1)) != epoch
                        or int(meta.get("have_term", -1)) != term)
                if sync:
                    md, fd = self._auth_state(index)
            if snap is None:
                q = int(np.asarray(arrays["q_dims"]).shape[0])
                out = {"scores": np.zeros((q, 0), np.float32),
                       "ids": np.zeros((q, 0), np.int64)}
            else:
                s, ids, _ = eng.search(qd, qv, qe, h=snap.capacity,
                                       alpha=alpha, beta=beta)
                out = {"scores": np.asarray(s),
                       "ids": snap.ids[np.asarray(ids)]}
            if sync:
                rmeta["sync"] = True
                out["sync_main_dead"] = md
                out["sync_fully_deleted"] = fd
        elif part == "full":                     # replica OR primary direct
            with self._lock:
                # strictly current-generation: this branch scores
                # ``self.index``, so a frozen prev-gen pin must get the
                # StaleGeneration signal (and re-pin), never current rows
                # budgeted under old-generation geometry
                if int(meta["gen"]) != self.generation:
                    raise StaleGenerationError(
                        f"{self.role} serves part='full' only at its "
                        f"current generation {self.generation}, request "
                        f"wants {meta['gen']}")
                st = self.index.mutable_state
                snap = st.delta.snapshot() if st.delta.live_count else None
                eng = (self._delta_engine(self.index, snap)
                       if snap is not None else None)
                tombs = np.asarray(sorted(st.main_tombstones), np.int64)
                applied = self.applied_seq()
            # self-slack: the caller budgeted overfetch from ITS dead-id
            # view, which cannot know kills this node applied that the
            # caller has not seen acked — deepen the fetch by our own
            # tombstone count so dropping them can never truncate below
            # the requested k (overfetch depth cannot change the merged
            # top-k, only guarantee it)
            n = self.index.engine.arrays.num_points
            h_eff = min(h + (ceil16(len(tombs)) if len(tombs) else 0), n)
            ms, mi, _ = self.index.engine.search(qd, qv, qe, h=h_eff,
                                                 alpha=alpha, beta=beta)
            out = {"ms": np.asarray(ms),
                   "mi": np.asarray(st.id_map)[np.asarray(mi)],
                   "main_tombstones": tombs}
            if snap is not None:
                ds, di, _ = eng.search(qd, qv, qe, h=snap.capacity,
                                       alpha=alpha, beta=beta)
                out["ds"], out["di"] = np.asarray(ds), snap.ids[np.asarray(di)]
            rmeta = {"gen": self.generation, "applied_seq": applied,
                     "term": self.term(),
                     "delta_live": snap.live if snap is not None else 0}
        else:
            raise ValueError(f"unknown search part {part!r}")
        score_s = time.perf_counter() - t0
        rmeta["score_s"] = score_s
        self._h_score.observe(score_s)
        if sp:
            # the serialized child span the router folds into its hop
            # span; queue_s 0 here — ``msearch`` overwrites it with the
            # sub's measured dispatch wait
            sp.set("score_s", score_s)
            sp.set("queue_s", 0.0)
            rmeta["trace"] = sp.to_wire()
        return rmeta, out

    def _op_msearch(self, meta, arrays):
        """Coalesced searches: ``subs`` is a list of search metas, arrays
        are keyed ``"<i>:<name>"``.  Each sub runs independently; a sub
        that fails reports ``error``/``kind`` in ITS slot of the reply's
        ``subs`` instead of failing the frame — the batch is a transport
        artifact, not a transaction (DESIGN.md §8.8).  Subs run
        sequentially, so sub i waits behind subs 0..i-1; that wait is
        the server-side ``queue_s`` stamped into each traced sub's child
        span — the coalesced-pipelined path's per-request timing that
        previously had no home (DESIGN.md §9.2)."""
        rsubs: list[dict] = []
        out: dict = {}
        t_start = time.perf_counter()
        for i, sub in enumerate(meta["subs"]):
            prefix = f"{i}:"
            sub_arrays = {k[len(prefix):]: v for k, v in arrays.items()
                          if k.startswith(prefix)}
            waited = time.perf_counter() - t_start
            try:
                rm, ra = self._op_search(dict(sub), sub_arrays)
            except Exception as e:
                rm, ra = {"error": f"{type(e).__name__}: {e}",
                          "kind": getattr(e, "kind", type(e).__name__)}, {}
            tr = rm.get("trace")
            if tr is not None:
                tr["queue_s"] = waited
            rsubs.append(rm)
            for k, v in ra.items():
                out[f"{i}:{k}"] = v
        return {"subs": rsubs}, out

    def _op_state_sync(self, meta, arrays):
        """The authoritative liveness snapshot on demand (routers call it
        at attach, after failover, and whenever their cache tag went
        stale): the full dead-id sets plus the (term, epoch) tag and seq /
        corpus scalars, all captured under one lock."""
        if self.index is None:
            raise ValueError("scorers hold no authoritative state; "
                             "state_sync is a primary/replica op")
        with self._lock:
            st = self.index.mutable_state
            md, fd = self._auth_state(self.index)
            return ({"gen": self.generation, "epoch": self._state_epoch,
                     "term": self.term(), "role": self.role,
                     "applied_seq": self.applied_seq(),
                     "delta_live": st.delta.live_count,
                     "num_points": self.index.engine.arrays.num_points,
                     "d_active": self.index.engine.arrays.d_active},
                    {"main_dead": md, "fully_deleted": fd})

    def _op_insert(self, meta, arrays):
        import scipy.sparse as sp
        self._ensure_primary()
        xs = sp.csr_matrix((arrays["data"], arrays["indices"],
                            arrays["indptr"]),
                           shape=tuple(np.asarray(arrays["shape"])))
        ids = arrays["ids"] if "ids" in arrays else None
        with self._lock:
            self.durability.ensure_ok()
            st = self.index.mutable_state
            before = set(st.main_tombstones)
            assigned = self.index.insert(xs, arrays["dense"], ids=ids)
            seq = self.durability.log_insert(xs, arrays["dense"], assigned,
                                             sync=False)
            main_killed = sorted(st.main_tombstones - before)
            delta_live = st.delta.live_count
            self._state_epoch += 1
            epoch, term = self._state_epoch, self.term()
        self.durability.sync(seq)                # group-commit ack
        return ({"seq": seq, "gen": self.generation, "epoch": epoch,
                 "term": term, "delta_live": delta_live},
                {"ids": np.asarray(assigned, np.int64),
                 "main_killed": np.asarray(main_killed, np.int64)})

    def _op_delete(self, meta, arrays):
        self._ensure_primary()
        req = np.atleast_1d(np.asarray(arrays["ids"], np.int64))
        with self._lock:
            self.durability.ensure_ok()
            st = self.index.mutable_state
            before = set(st.main_tombstones)
            was_live = [int(e) for e in req if int(e) in st._loc]
            killed = self.index.delete(req)
            # seq is None — not 0 — when nothing was logged: 0 is never a
            # real WAL seq, but callers folding watermarks must be able to
            # test "was anything acked" without a falsy-zero trap
            seq = (self.durability.log_delete(req, sync=False)
                   if killed else None)
            main_killed = sorted(st.main_tombstones - before)
            delta_live = st.delta.live_count
            if killed:
                self._state_epoch += 1
            epoch, term = self._state_epoch, self.term()
        if seq is not None:
            self.durability.sync(seq)
        return ({"seq": seq, "gen": self.generation, "killed": killed,
                 "epoch": epoch, "term": term, "delta_live": delta_live},
                {"killed_ids": np.asarray(sorted(was_live), np.int64),
                 "main_killed": np.asarray(main_killed, np.int64)})

    def _op_compact(self, meta, arrays):
        retrain = meta.get("retrain")
        self._ensure_primary()
        with self._lock:
            self.durability.ensure_ok()
            new_index = self.index.compact(retrain=retrain)
            self.durability.checkpoint(new_index)
            self._prev_index = (self.generation, self.index)
            self.index = new_index
            self.generation += 1
            self._delta_engine_cache.clear()
            self._state_epoch += 1
            return ({"gen": self.generation,
                     "epoch": self._state_epoch, "term": self.term(),
                     "num_points": new_index.engine.arrays.num_points,
                     "d_active": new_index.engine.arrays.d_active,
                     "next_seq": self.durability.wal.next_seq},
                    {"cols_global_ids":
                     np.asarray(new_index.cols.global_ids)})

    # -- failover (DESIGN.md §8.7) ----------------------------------------

    def _op_promote(self, meta, arrays):
        """Promote this replica to primary — the router-driven election's
        commit point.  Gated under the SAME lock that serializes shipped-
        record application, so the eligibility check is exact: a replica
        that passes ``applied_seq >= sealed_seq`` here has applied every
        mutation any router ever acked.  The new term is persisted BEFORE
        the role flips, and a no-op term barrier is logged immediately:
        the first record the new primary ships proves the new term to
        every follower, closing the window where a zombie's same-seq frame
        could still look current."""
        sealed = int(meta["sealed_seq"])
        new_term = int(meta["new_term"])
        with self._lock:
            if self.role != "replica":
                raise PromotionError(
                    f"cannot promote a {self.role}; promotion targets a "
                    "replica")
            if self._applied_seq < sealed:
                raise PromotionError(
                    f"replica applied seq {self._applied_seq} < sealed "
                    f"seq {sealed}: promoting it would lose acked "
                    "mutations")
            if new_term <= self.durability.wal.term:
                raise PromotionError(
                    f"proposed term {new_term} does not exceed current "
                    f"term {self.durability.wal.term}")
            self.durability.wal.set_term(new_term)
            self.role = "primary"        # the ship loop sees this and exits
            barrier = self.durability.log_noop()
            self._state_epoch += 1
            return ({"term": new_term, "seq": barrier,
                     "gen": self.generation, "epoch": self._state_epoch,
                     "applied_seq": self.applied_seq()}, {})

    def _op_set_peer(self, meta, arrays):
        """Re-point this node's upstream (failover moved the primary): a
        replica's ship loop re-targets its WAL tail fetches, a scorer's
        next reload fetches the store from the new address."""
        self.peer = str(meta["peer"])
        return {"peer": self.peer}, {}

    def _op_wal_fetch(self, meta, arrays):
        buf, seqs = self.durability.wal.read_frames(
            int(meta["from_seq"]), limit=int(meta.get("limit", 256)))
        return ({"seqs": seqs, "next_seq": self.durability.wal.next_seq},
                {"frames": np.frombuffer(buf, np.uint8)})

    def _op_store_manifest(self, meta, arrays):
        from repro import persist
        return {"files": persist.store_files(self.store),
                "gen": self.generation}, {}

    def _op_store_file(self, meta, arrays):
        with open(os.path.join(self.store, meta["path"]), "rb") as f:
            data = f.read()
        return {}, {"data": np.frombuffer(data, np.uint8)}

    def _op_reload(self, meta, arrays):
        gen = int(meta["gen"])
        if self.role == "scorer":
            self._load_slice(gen)
        elif self.role == "replica":
            # re-bootstrap onto the primary's post-compaction store: the
            # old local store describes a generation that no longer takes
            # writes, so wipe it and fetch fresh, then resume shipping
            # from the new snapshot's replay horizon
            import shutil
            from repro import persist
            self._ship_paused.set()      # quiesce the tail loop first
            with self._lock:
                self.durability.close()
                shutil.rmtree(self.store)
                self._peer_client().fetch_store(self.store)
                rec = persist.recover(self.store, backend=self.backend,
                                      metrics=self.obs.metrics)
                self.index, self.durability = rec.index, rec.durability
                self._applied_seq = self.durability.wal.next_seq - 1
                self.generation = gen
                self._delta_engine_cache.clear()
                self._state_epoch += 1
            self._ship_paused.clear()
        else:
            raise ValueError("primary does not reload; it compacts")
        return {"gen": self.generation}, {}

    def _op_status(self, meta, arrays):
        out = {"role": self.role, "gen": self.generation,
               "term": self.term()}
        if self.role in ("primary", "replica"):
            st = self.index.mutable_state
            out.update(applied_seq=self.applied_seq(),
                       delta_live=st.delta.live_count,
                       num_points=self.index.engine.arrays.num_points,
                       epoch=self._state_epoch,
                       shipping_paused=self._ship_paused.is_set())
        else:
            g = self._gens[self.generation]
            out.update(num_points_local=g.engine.num_points,
                       num_points=g.num_points_total, shard=self.shard)
        return out, {}

    def _op_info(self, meta, arrays):
        with self._lock:
            idx = self.index
            st = idx.mutable_state
            md, fd = self._auth_state(idx)
            return ({"gen": self.generation,
                     "num_points": idx.engine.arrays.num_points,
                     "d_active": idx.engine.arrays.d_active,
                     "nq_max": idx.params.nq_max,
                     "backend": idx.engine.backend.value,
                     "h": 10, "alpha": idx.params.alpha,
                     "beta": idx.params.beta,
                     "delta_live": st.delta.live_count,
                     "applied_seq": self.applied_seq(),
                     "epoch": self._state_epoch, "term": self.term(),
                     "role": self.role},
                    {"cols_global_ids": np.asarray(idx.cols.global_ids),
                     "main_tombstones": md, "fully_deleted": fd})

    def _op_fault(self, meta, arrays):
        mode = meta["mode"]
        if mode == "pause_shipping":
            self._ship_paused.set()
        elif mode == "resume_shipping":
            self._ship_paused.clear()
        elif mode in ("corrupt_next", "close_next"):
            self._faults.add(mode)
        else:
            raise ValueError(f"unknown fault mode {mode!r}")
        return {"mode": mode}, {}

    def _op_ping(self, meta, arrays):
        return {"pong": True}, {}

    def _op_stats(self, meta, arrays):
        """Observability RPC: this node's full metrics registry snapshot
        (per-op counters, score-time histogram, WAL durability gauges on
        primary/replica) plus role/generation — how routers and the
        benches read server-side numbers (DESIGN.md §9.1)."""
        return ({"role": self.role, "gen": self.generation,
                 "applied_seq": self.applied_seq(),
                 "metrics": self.obs.metrics.snapshot()}, {})

    _OPS = {"search": _op_search, "msearch": _op_msearch,
            "insert": _op_insert, "delete": _op_delete,
            "compact": _op_compact, "state_sync": _op_state_sync,
            "promote": _op_promote, "set_peer": _op_set_peer,
            "wal_fetch": _op_wal_fetch, "store_manifest": _op_store_manifest,
            "store_file": _op_store_file, "reload": _op_reload,
            "status": _op_status, "info": _op_info, "fault": _op_fault,
            "ping": _op_ping, "stats": _op_stats}

    # -- server shell -----------------------------------------------------

    def _handle_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                try:
                    _, meta, arrays = recv_msg(conn)
                except (ConnectionError, OSError):
                    return
                cmd = meta.pop("cmd", None)
                handler = self._OPS.get(cmd)
                try:
                    if handler is None:
                        raise ValueError(f"unknown command {cmd!r}")
                    rmeta, rarr = handler(self, meta, arrays)
                    op = MSG_RESPONSE
                    self.obs.metrics.counter(f"server.op.{cmd}").inc()
                except Exception as e:           # ships as MSG_ERROR
                    rmeta = {"error": f"{type(e).__name__}: {e}",
                             "kind": getattr(e, "kind", type(e).__name__)}
                    rarr, op = {}, MSG_ERROR
                    self.obs.metrics.counter("server.op.errors").inc()
                # fault injection never eats its OWN arming ack — the
                # armed fault fires on the NEXT (non-fault) exchange
                if cmd != "fault" and "close_next" in self._faults:
                    self._faults.discard("close_next")
                    return                       # drop mid-exchange
                corrupt = cmd != "fault" and "corrupt_next" in self._faults
                if corrupt:
                    self._faults.discard("corrupt_next")
                try:
                    send_msg(conn, "reply", rmeta, rarr, op=op,
                             corrupt=corrupt)
                except (ConnectionError, OSError):
                    return
        finally:
            conn.close()

    def start(self, port: int = 0, host: str = "127.0.0.1") -> int:
        """Bind + listen + spawn the accept loop (daemon thread); returns
        the bound port (``port=0`` picks an ephemeral one)."""
        self._listener = socket.create_server((host, port))
        self._listener.settimeout(0.2)
        threading.Thread(target=self._accept_loop, daemon=True,
                         name=f"{self.role}-accept").start()
        return self._listener.getsockname()[1]

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._handle_conn, args=(conn,),
                             daemon=True).start()

    def stop(self) -> None:
        """Stop accepting, close the listener, close the store handle."""
        self._stop.set()
        if self._listener is not None:
            self._listener.close()
        if self.durability is not None:
            self.durability.close()


def main(argv=None) -> int:
    """CLI entry (``python -m repro.serve.cluster.shard_server`` or
    ``repro.launch.serve --role shard``): bootstrap the role, bind, print
    ``READY <port>``, serve until killed."""
    ap = argparse.ArgumentParser(description="hybrid cluster shard server")
    ap.add_argument("--role", required=True,
                    choices=["primary", "scorer", "replica"])
    ap.add_argument("--store", help="persist store root (primary/replica)")
    ap.add_argument("--peer", help="primary host:port (scorer/replica)")
    ap.add_argument("--shard", type=int, default=0)
    ap.add_argument("--num-shards", type=int, default=1)
    ap.add_argument("--workdir", help="scratch dir (scorer store fetches)")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve this node's metrics registry as a text "
                         "endpoint on the given port (0 = ephemeral)")
    args = ap.parse_args(argv)
    server = ShardServer(args.role, store=args.store, peer=args.peer,
                         shard=args.shard, num_shards=args.num_shards,
                         workdir=args.workdir, backend=args.backend)
    server.bootstrap()
    port = server.start(args.port)
    if args.metrics_port is not None:
        from repro.obs import start_metrics_server
        ms = start_metrics_server(server.obs.metrics, args.metrics_port)
        print(f"METRICS {ms.port}", flush=True)
    print(f"READY {port}", flush=True)
    try:
        while not server._stop.is_set():
            time.sleep(0.2)
    except KeyboardInterrupt:
        pass
    server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
