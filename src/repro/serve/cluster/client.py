"""Cluster client: one socket per shard server, transparent reconnect,
request PIPELINING, and same-shard request COALESCING (DESIGN.md §8.2,
§8.8).

``ShardClient`` is the transport half.  Two request modes share one
socket:

* ``call`` — blocking request/response, with torn frames and dropped
  connections healed by ONE reconnect-and-retry (the retried read is
  idempotent; mutations pass ``retry=False`` and are re-driven by the
  caller, which knows their semantics);
* ``submit`` — PIPELINED: the frame goes out immediately and a
  ``PendingReply`` comes back; replies are matched to requests in FIFO
  order (the server answers one connection strictly in order).  The
  router's fan-out submits to every shard back-to-back and only then
  collects, so S shards cost one round trip, not S — and a frame built
  once (``protocol.build_frame``) is reused byte-identical across shards.

``submit_search`` adds COALESCING on top: while one frame is in flight,
searches from other router threads queue up and the next flush ships them
as ONE ``msearch`` frame (amortizing per-request framing + syscalls —
DESIGN.md §8.8).  With no concurrency it degenerates to exactly one
``search`` frame per request, adding zero latency.

``RemoteMainEngine`` / ``RemoteDeltaEngine`` are the duck-typed
``ShardSearcher`` handles: they expose exactly the
``.search(...)/.num_points`` surface an in-process ``ScoringEngine`` does,
which keeps the transport swappable where the merge contract is not.
"""

from __future__ import annotations

import collections
import hashlib
import json
import os
import socket
import threading
import time

import numpy as np

from ...obs.trace import NULL_SPAN
from .protocol import MSG_ERROR, RemoteError, build_frame, recv_msg

__all__ = ["ShardClient", "PendingReply", "RemoteMainEngine",
           "RemoteDeltaEngine", "ShardUnavailableError", "wait_ready"]


class ShardUnavailableError(ConnectionError):
    """The shard could not be reached even after a reconnect attempt — the
    router's signal to fail over to a replica or raise an explicit
    degraded-result error (never to merge a silently truncated top-k)."""


class PendingReply:
    """One in-flight pipelined request (``ShardClient.submit``).

    ``wait()`` blocks until THIS request's reply arrives, reading replies
    off the shared socket as needed — whichever waiter holds the receive
    lock completes earlier pendings in FIFO order on the way to its own.
    A transport failure fails every in-flight pending on the connection
    (framing is lost for all of them); the raised error is the original
    ``ConnectionError``/``TornFrameError`` so callers keep their existing
    retry semantics.  ``send_s``/``wall_s`` carry PER-REQUEST timing (one
    ``PendingReply`` per submit — never shared across requests, so
    concurrent fan-outs can't overwrite each other's numbers; the router
    folds them into hop spans, DESIGN.md §9.2)."""

    def __init__(self, client: "ShardClient", cmd: str):
        self.client = client
        self.cmd = cmd
        self.send_s = 0.0
        self.wall_s = 0.0
        self._t0 = 0.0
        self._event = threading.Event()
        self._value: tuple | None = None
        self._exc: BaseException | None = None

    def _complete(self, op: int, meta: dict, arrays: dict) -> None:
        self.wall_s = time.perf_counter() - self._t0
        self._value = (op, meta, arrays)
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._event.set()

    def wait(self) -> tuple[int, dict, dict]:
        """Block until the reply is in; returns raw ``(op, meta, arrays)``
        (``MSG_ERROR`` frames are returned, not raised — ``result`` is the
        raising form).  Raises the transport error that killed the
        connection if one did."""
        while not self._event.is_set():
            # whoever gets the receive lock drains replies FIFO until its
            # own arrives; everyone else wakes on their event
            if self.client._recv_lock.acquire(timeout=0.0005):
                try:
                    if not self._event.is_set():
                        self.client._drain_one()
                finally:
                    self.client._recv_lock.release()
        if self._exc is not None:
            raise self._exc
        return self._value

    def result(self) -> tuple[dict, dict]:
        """``wait()`` + unwrap: pops the protocol's ``cmd`` echo and raises
        ``RemoteError`` for ``MSG_ERROR`` replies; returns
        ``(meta, arrays)``."""
        op, meta, arrays = self.wait()
        meta.pop("cmd", None)
        if op == MSG_ERROR:
            raise RemoteError(f"shard {self.client.addr} failed "
                              f"{self.cmd!r}: {meta.get('error')}")
        return meta, arrays


class _CoalescedReply:
    """One search enrolled in a coalescing batch (``submit_search``): holds
    its slot in the (eventual) ``msearch`` frame and demuxes its own
    sub-result out of the shared reply.

    Timing lives ON THE ENTRY, not on the client or the shared pending:
    ``queue_s`` (enqueue → flush, the client-side coalescer wait),
    ``wall_s`` (enqueue → this entry's reply collected) and ``send_s``
    (the shared frame's send duration) are written once per entry, so
    overlapping coalesced requests keep independent numbers — the race
    the old shared ``last_*`` fields had (DESIGN.md §9.2)."""

    def __init__(self, meta: dict, arrays: dict,
                 frame: bytes | None = None):
        self.meta = meta
        self.arrays = arrays
        self.frame = frame
        self.slot = 0
        self.width = 1
        self.t_enq = time.perf_counter()
        self.queue_s = 0.0
        self.send_s = 0.0
        self.wall_s = 0.0
        self._ready = threading.Event()
        self._pending: PendingReply | None = None
        self._exc: BaseException | None = None
        self._batch: "_CoalescedBatch | None" = None

    def result(self) -> tuple[dict, dict]:
        """Block for this search's own ``(meta, arrays)``; per-sub remote
        failures raise ``RemoteError``, transport failures raise what the
        connection raised."""
        self._ready.wait()
        if self._exc is not None:
            raise self._exc
        try:
            op, meta, arrays = self._pending.wait()
            self.wall_s = time.perf_counter() - self.t_enq
            self.send_s = self._pending.send_s
        finally:
            self._batch.on_complete()      # kick the next queued flush
        meta.pop("cmd", None)
        if op == MSG_ERROR:
            raise RemoteError(f"shard {self._pending.client.addr} failed "
                              f"'search': {meta.get('error')}")
        if self.width == 1:
            return meta, arrays
        sub = meta["subs"][self.slot]
        if "error" in sub:
            raise RemoteError(f"shard {self._pending.client.addr} failed "
                              f"'search': {sub['error']}")
        prefix = f"{self.slot}:"
        return sub, {k[len(prefix):]: v for k, v in arrays.items()
                     if k.startswith(prefix)}


class _CoalescedBatch:
    """One flushed group of coalesced searches sharing a single pipelined
    frame; completing it (once) releases the client's in-flight slot and
    flushes whatever queued up behind it."""

    def __init__(self, client: "ShardClient", entries: list[_CoalescedReply]):
        self.client = client
        self.entries = entries
        self._done = False
        self._lock = threading.Lock()

    def on_complete(self) -> None:
        with self._lock:
            if self._done:
                return
            self._done = True
        self.client._coalesce_next()


class ShardClient:
    """Pipelining request client for one shard server (module docstring
    for the call/submit/submit_search split).  Thread-safe: a send lock
    orders frames onto the wire (and pendings into the FIFO), a receive
    lock orders replies off it.  ``reconnects`` counts healed transport
    failures (the torn-frame tests pin it)."""

    def __init__(self, host: str, port: int, *, timeout: float = 30.0):
        self.host, self.port = host, port
        self.timeout = timeout
        self.reconnects = 0
        self.bytes_sent = 0
        self.bytes_recv = 0
        self._sock: socket.socket | None = None
        self._send_lock = threading.Lock()
        self._recv_lock = threading.Lock()
        self._pending: collections.deque[PendingReply] = collections.deque()
        # coalescer state: queued searches + whether a frame is in flight
        self._co_lock = threading.Lock()
        self._co_queue: list[_CoalescedReply] = []
        self._co_inflight = False

    @property
    def addr(self) -> str:
        """``host:port`` of the peer (log/error labels)."""
        return f"{self.host}:{self.port}"

    def _connect(self) -> socket.socket:
        s = socket.create_connection((self.host, self.port),
                                     timeout=self.timeout)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return s

    # -- pipelined transport ----------------------------------------------

    def submit(self, cmd: str, meta: dict | None = None,
               arrays: dict | None = None, *,
               frame: bytes | None = None) -> PendingReply:
        """Send one request WITHOUT waiting for its reply; returns the
        ``PendingReply`` to collect it from.  ``frame`` short-circuits
        serialization with a pre-built ``protocol.build_frame`` result (the
        fan-out's build-once-send-everywhere path).  Raises the transport
        error on send failure — nothing is retried here."""
        if frame is None:
            frame = build_frame(cmd, meta, arrays)
        p = PendingReply(self, cmd)
        with self._send_lock:
            try:
                if self._sock is None:
                    self._sock = self._connect()
                p._t0 = time.perf_counter()
                self._pending.append(p)
                self._sock.sendall(frame)
                p.send_s = time.perf_counter() - p._t0
                self.bytes_sent += len(frame)
            except (OSError, ConnectionError) as e:
                self._fail_all(e)
                raise
        return p

    def _drain_one(self) -> None:
        """Read ONE reply off the socket and complete the oldest pending
        (caller holds ``_recv_lock``).  The protocol is strictly FIFO per
        connection, so reply N belongs to request N; any transport anomaly
        loses framing for every in-flight request, so all of them fail."""
        sock = self._sock
        if sock is None or not self._pending:
            return
        try:
            op, meta, arrays = recv_msg(sock)
        except (OSError, ConnectionError) as e:
            with self._send_lock:
                self._fail_all(e)
            return
        p = self._pending.popleft()
        p._complete(op, meta, arrays)

    def _fail_all(self, exc: BaseException) -> None:
        """Fail every in-flight pending and drop the socket (caller holds
        ``_send_lock``)."""
        while self._pending:
            self._pending.popleft()._fail(exc)
        if self._sock is not None:
            try:
                self._sock.close()
            finally:
                self._sock = None

    # -- blocking call (with the one-reconnect heal) ----------------------

    def call(self, cmd: str, meta: dict | None = None,
             arrays: dict | None = None, *, retry: bool = True,
             span=NULL_SPAN) -> tuple[dict, dict]:
        """Send one request, read its reply; returns ``(meta, arrays)``.
        Transport failures (torn frame, dead socket) are healed by one
        reconnect + resend when ``retry`` (callers disable it for
        non-idempotent mutations and re-drive at their own layer);
        ``MSG_ERROR`` replies raise ``RemoteError``.  ``span`` receives
        this call's timing tags (``serialize_s`` accumulated across the
        heal, ``wall_s`` of the attempt that answered) plus a
        ``reconnect_resend`` annotation when the heal fired — per-request
        hop accounting with no shared client fields (DESIGN.md §9.2)."""
        frame = build_frame(cmd, meta, arrays)
        attempts = 2 if retry else 1
        for attempt in range(attempts):
            try:
                p = self.submit(cmd, frame=frame)
                op, rmeta, rarrays = p.wait()
                break
            except (OSError, ConnectionError) as e:
                # TornFrameError is a ConnectionError: framing is lost
                # either way, so the socket was dropped — (maybe) retry
                if attempt + 1 >= attempts:
                    raise ShardUnavailableError(
                        f"shard {self.addr} unreachable for "
                        f"{cmd!r}: {e}") from e
                self.reconnects += 1
                span.annotate(f"reconnect_resend cmd={cmd}")
        span.add("serialize_s", p.send_s)
        span.set("wall_s", p.wall_s)
        rmeta.pop("cmd", None)
        if op == MSG_ERROR:
            raise RemoteError(
                f"shard {self.addr} failed {cmd!r}: {rmeta.get('error')}")
        return rmeta, rarrays

    # -- search coalescing (DESIGN.md §8.8) -------------------------------

    def submit_search(self, meta: dict, arrays: dict, *,
                      frame: bytes | None = None) -> _CoalescedReply:
        """Enqueue one search for COALESCED dispatch: if no frame is in
        flight it goes out immediately (alone — zero added latency); while
        one IS in flight, searches pile up and the next flush ships the
        whole pile as one ``msearch`` frame.  ``frame`` is an optional
        pre-built ``build_frame`` result used for the ships-alone case
        (the fan-out's serialize-once path); a coalesced flush rebuilds
        from meta/arrays.  Returns a handle whose ``result()`` yields
        this search's own ``(meta, arrays)``."""
        e = _CoalescedReply(meta, arrays, frame)
        with self._co_lock:
            self._co_queue.append(e)
            if self._co_inflight:
                return e
            self._co_inflight = True
            batch = self._co_queue
            self._co_queue = []
        self._flush(batch)
        return e

    def _coalesce_next(self) -> None:
        """Release the in-flight slot and flush whatever coalesced behind
        the batch that just completed."""
        with self._co_lock:
            if not self._co_queue:
                self._co_inflight = False
                return
            batch = self._co_queue
            self._co_queue = []
        self._flush(batch)

    def _flush(self, batch: list[_CoalescedReply]) -> None:
        """Ship one batch as a single pipelined frame: a plain ``search``
        for a batch of one, an ``msearch`` (sub-metas under ``subs``,
        arrays keyed ``"<i>:<name>"``) otherwise."""
        now = time.perf_counter()
        for e in batch:
            e.queue_s = now - e.t_enq      # client-side coalescer wait
        try:
            if len(batch) == 1:
                p = self.submit("search", batch[0].meta, batch[0].arrays,
                                frame=batch[0].frame)
            else:
                subs = [e.meta for e in batch]
                arrays = {f"{i}:{k}": v
                          for i, e in enumerate(batch)
                          for k, v in e.arrays.items()}
                p = self.submit("msearch", {"subs": subs}, arrays)
        except BaseException as exc:
            shared = _CoalescedBatch(self, batch)
            shared._done = True           # nothing in flight to complete
            for e in batch:
                e._batch = shared
                e._exc = exc
                e._ready.set()
            self._coalesce_next()
            return
        shared = _CoalescedBatch(self, batch)
        for i, e in enumerate(batch):
            e.slot, e.width = i, len(batch)
            e._pending = p
            e._batch = shared
            e._ready.set()

    # -- snapshot distribution (DESIGN.md §8.3) ---------------------------

    def fetch_store(self, dst_root: str) -> list[str]:
        """Copy the peer's committed snapshot store into ``dst_root`` —
        snapshot distribution (DESIGN.md §8.3).  The CURRENT pointer is
        written LAST, and only after every data file is verified against
        the manifest's recorded sha256 and fsync'd (file + containing
        dir): an interrupted or bit-flipped fetch can never leave a
        committed-looking but torn local store — the exact guarantee the
        CURRENT-last ordering claims.  Returns the copied relative
        paths."""
        meta, _ = self.call("store_manifest")
        digests: dict[str, str] = {}
        dirs: set[str] = set()
        deferred: list[str] = []
        for rel in meta["files"]:
            if os.path.basename(rel) == "CURRENT":
                deferred.append(rel)       # commit pointers strictly last
                continue
            fmeta, farr = self.call("store_file", {"path": rel})
            data = farr["data"].tobytes()
            digests[rel] = hashlib.sha256(data).hexdigest()
            path = os.path.join(dst_root, rel)
            os.makedirs(os.path.dirname(path) or dst_root, exist_ok=True)
            with open(path, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            dirs.add(os.path.dirname(path) or dst_root)
        self._verify_manifests(dst_root, digests)
        from repro.checkpoint.leaves import fsync_dir
        for d in sorted(dirs):
            fsync_dir(d)
        for rel in deferred:
            fmeta, farr = self.call("store_file", {"path": rel})
            path = os.path.join(dst_root, rel)
            with open(path, "wb") as f:
                f.write(farr["data"].tobytes())
                f.flush()
                os.fsync(f.fileno())
            fsync_dir(os.path.dirname(path) or dst_root)
        return list(meta["files"])

    @staticmethod
    def _verify_manifests(dst_root: str, digests: dict[str, str]) -> None:
        """Check every fetched blob against the sha256 its snapshot
        manifest recorded at write time — a bitrotted source file (or a
        wire layer that lied) fails the fetch instead of becoming a
        committed follower store."""
        for rel, digest in digests.items():
            if os.path.basename(rel) != "manifest.json":
                continue
            with open(os.path.join(dst_root, rel)) as f:
                manifest = json.load(f)
            snap_dir = os.path.dirname(rel)
            for leaf in manifest.get("leaves", {}).values():
                blob_rel = f"{snap_dir}/{leaf['file']}" if snap_dir \
                    else leaf["file"]
                got = digests.get(blob_rel)
                if got is not None and got != leaf["sha256"]:
                    raise ValueError(
                        f"fetched blob {blob_rel!r} sha256 {got[:12]}… "
                        f"does not match the manifest's recorded "
                        f"{leaf['sha256'][:12]}… — refusing to commit a "
                        "corrupt follower store")

    def close(self) -> None:
        """Close the socket (idempotent); the next call reconnects."""
        with self._send_lock:
            if self._sock is not None:
                try:
                    self._sock.close()
                finally:
                    self._sock = None


class _RemoteEngineBase:
    """Shared half of the remote ``ShardSearcher`` duck-type: ships the
    padded query batch, returns ``(scores, ids)`` with ids ALREADY in the
    external id space (the server maps through its row slice / delta
    slots), and surfaces the response's replication tags to the router."""

    def __init__(self, client: ShardClient, *, generation: int,
                 num_points: int, part: str):
        self.client = client
        self.generation = generation
        self.num_points = num_points
        self.part = part
        self.last_meta: dict = {}

    def search(self, qd, qv, qe, *, h: int, alpha: int, beta: int):
        meta, arrays = self.client.call(
            "search", {"part": self.part, "gen": self.generation,
                       "h": int(h), "alpha": int(alpha), "beta": int(beta)},
            {"q_dims": np.asarray(qd, np.int32),
             "q_vals": np.asarray(qv, np.float32),
             "q_dense": np.asarray(qe, np.float32)})
        self.last_meta = meta
        return arrays["scores"], arrays["ids"]


class RemoteMainEngine(_RemoteEngineBase):
    """RPC handle for one scoring shard's main row slice: ``num_points``
    is the slice size (so ``plan_overfetch`` budgets exactly like the
    in-process shard engine) and ``search`` returns the slice's top-k in
    external ids."""

    def __init__(self, client: ShardClient, *, generation: int,
                 num_points: int):
        super().__init__(client, generation=generation,
                         num_points=num_points, part="main")


class RemoteDeltaEngine(_RemoteEngineBase):
    """RPC handle for the primary's delta shard: like the in-process delta
    engine it fetches its WHOLE capacity (the server pins a snapshot and
    uses its capacity; ``num_points`` here is advisory), and tombstoned
    slots come back -inf so the merge semantics match bit for bit."""

    def __init__(self, client: ShardClient, *, generation: int,
                 num_points: int):
        super().__init__(client, generation=generation,
                         num_points=num_points, part="delta")


def wait_ready(client: ShardClient, *, timeout: float = 30.0,
               poll: float = 0.05) -> dict:
    """Poll ``status`` until the server answers (subprocess startup races);
    returns the first status meta.  Raises ``ShardUnavailableError`` after
    ``timeout`` seconds."""
    deadline = time.monotonic() + timeout
    while True:
        try:
            meta, _ = client.call("status")
            return meta
        except (ShardUnavailableError, ConnectionError):
            if time.monotonic() >= deadline:
                raise
            time.sleep(poll)
