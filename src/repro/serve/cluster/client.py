"""Cluster client: one socket per shard server, transparent reconnect, and
the remote engine handles the router plugs into ``fanout_search``
(DESIGN.md §8.2).

``ShardClient`` is the transport half: request/response over the framed
protocol, with torn frames and dropped connections healed by ONE
reconnect-and-retry (the protocol is one-reply-per-request, so a retried
idempotent read is safe; mutations are only retried by the caller, which
knows their semantics).  ``RemoteMainEngine`` / ``RemoteDeltaEngine`` are
the duck-typed ``ShardSearcher`` handles: they expose exactly the
``.search(...)/.num_points`` surface an in-process ``ScoringEngine`` does,
which is what lets the router reuse ``core/streaming.py::fanout_search``
unchanged — the transport is swappable, the merge contract is not.
"""

from __future__ import annotations

import socket
import threading
import time

import numpy as np

from .protocol import (MSG_ERROR, RemoteError, TornFrameError, recv_msg,
                       send_msg)

__all__ = ["ShardClient", "RemoteMainEngine", "RemoteDeltaEngine",
           "ShardUnavailableError", "wait_ready"]


class ShardUnavailableError(ConnectionError):
    """The shard could not be reached even after a reconnect attempt — the
    router's signal to fail over to a replica or raise an explicit
    degraded-result error (never to merge a silently truncated top-k)."""


class ShardClient:
    """Blocking request/response client for one shard server.

    Thread-safe (one lock around the socket — the router's executor may
    fan a batch's shards out concurrently, but each shard sees one request
    at a time).  A ``TornFrameError`` or dropped connection triggers one
    transparent reconnect + resend; the second failure surfaces as
    ``ShardUnavailableError``.  ``reconnects`` counts the healed failures
    (the torn-frame tests pin it)."""

    def __init__(self, host: str, port: int, *, timeout: float = 30.0):
        self.host, self.port = host, port
        self.timeout = timeout
        self.reconnects = 0
        self.bytes_sent = 0
        self.bytes_recv = 0
        # per-call timing of the LAST request (the router's per-hop
        # latency breakdown reads these right after each fan-out)
        self.last_send_s = 0.0
        self.last_wall_s = 0.0
        self._sock: socket.socket | None = None
        self._lock = threading.Lock()

    @property
    def addr(self) -> str:
        """``host:port`` of the peer (log/error labels)."""
        return f"{self.host}:{self.port}"

    def _connect(self) -> socket.socket:
        s = socket.create_connection((self.host, self.port),
                                     timeout=self.timeout)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return s

    def call(self, cmd: str, meta: dict | None = None,
             arrays: dict | None = None, *, retry: bool = True
             ) -> tuple[dict, dict]:
        """Send one request, read its reply; returns ``(meta, arrays)``.
        Transport failures (torn frame, dead socket) are healed by one
        reconnect + resend when ``retry`` (callers disable it for
        non-idempotent mutations and re-drive at their own layer);
        ``MSG_ERROR`` replies raise ``RemoteError``."""
        with self._lock:
            attempts = 2 if retry else 1
            for attempt in range(attempts):
                try:
                    if self._sock is None:
                        self._sock = self._connect()
                    t0 = time.perf_counter()
                    self.bytes_sent += send_msg(self._sock, cmd, meta,
                                                arrays)
                    t1 = time.perf_counter()
                    op, rmeta, rarrays = recv_msg(self._sock)
                    self.last_send_s = t1 - t0
                    self.last_wall_s = time.perf_counter() - t0
                    break
                except (OSError, ConnectionError) as e:
                    # TornFrameError is a ConnectionError: framing is lost
                    # either way, so drop the socket and (maybe) retry
                    if self._sock is not None:
                        try:
                            self._sock.close()
                        finally:
                            self._sock = None
                    if attempt + 1 >= attempts:
                        raise ShardUnavailableError(
                            f"shard {self.addr} unreachable for "
                            f"{cmd!r}: {e}") from e
                    self.reconnects += 1
        rmeta.pop("cmd", None)
        if op == MSG_ERROR:
            raise RemoteError(
                f"shard {self.addr} failed {cmd!r}: {rmeta.get('error')}")
        return rmeta, rarrays

    def fetch_store(self, dst_root: str) -> list[str]:
        """Copy the peer's committed snapshot store into ``dst_root`` —
        snapshot distribution (DESIGN.md §8.3).  The server lists files
        via ``persist.store_files`` with CURRENT last, and this writes
        them in that order, so an interrupted fetch never leaves a
        committed-looking store.  Returns the copied relative paths."""
        import os
        meta, _ = self.call("store_manifest")
        for rel in meta["files"]:
            fmeta, farr = self.call("store_file", {"path": rel})
            path = os.path.join(dst_root, rel)
            os.makedirs(os.path.dirname(path) or dst_root, exist_ok=True)
            with open(path, "wb") as f:
                f.write(farr["data"].tobytes())
        return list(meta["files"])

    def close(self) -> None:
        """Close the socket (idempotent); the next call reconnects."""
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.close()
                finally:
                    self._sock = None


class _RemoteEngineBase:
    """Shared half of the remote ``ShardSearcher`` duck-type: ships the
    padded query batch, returns ``(scores, ids)`` with ids ALREADY in the
    external id space (the server maps through its row slice / delta
    slots), and surfaces the response's replication tags to the router."""

    def __init__(self, client: ShardClient, *, generation: int,
                 num_points: int, part: str):
        self.client = client
        self.generation = generation
        self.num_points = num_points
        self.part = part
        self.last_meta: dict = {}

    def search(self, qd, qv, qe, *, h: int, alpha: int, beta: int):
        meta, arrays = self.client.call(
            "search", {"part": self.part, "gen": self.generation,
                       "h": int(h), "alpha": int(alpha), "beta": int(beta)},
            {"q_dims": np.asarray(qd, np.int32),
             "q_vals": np.asarray(qv, np.float32),
             "q_dense": np.asarray(qe, np.float32)})
        self.last_meta = meta
        return arrays["scores"], arrays["ids"]


class RemoteMainEngine(_RemoteEngineBase):
    """RPC handle for one scoring shard's main row slice: ``num_points``
    is the slice size (so ``plan_overfetch`` budgets exactly like the
    in-process shard engine) and ``search`` returns the slice's top-k in
    external ids."""

    def __init__(self, client: ShardClient, *, generation: int,
                 num_points: int):
        super().__init__(client, generation=generation,
                         num_points=num_points, part="main")


class RemoteDeltaEngine(_RemoteEngineBase):
    """RPC handle for the primary's delta shard: like the in-process delta
    engine it fetches its WHOLE capacity (the server pins a snapshot and
    uses its capacity; ``num_points`` here is advisory), and tombstoned
    slots come back -inf so the merge semantics match bit for bit."""

    def __init__(self, client: ShardClient, *, generation: int,
                 num_points: int):
        super().__init__(client, generation=generation,
                         num_points=num_points, part="delta")


def wait_ready(client: ShardClient, *, timeout: float = 30.0,
               poll: float = 0.05) -> dict:
    """Poll ``status`` until the server answers (subprocess startup races);
    returns the first status meta.  Raises ``ShardUnavailableError`` after
    ``timeout`` seconds."""
    deadline = time.monotonic() + timeout
    while True:
        try:
            meta, _ = client.call("status")
            return meta
        except (ShardUnavailableError, ConnectionError):
            if time.monotonic() >= deadline:
                raise
            time.sleep(poll)
