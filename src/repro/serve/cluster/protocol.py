"""Cluster wire protocol: length-prefixed, checksummed frames (DESIGN.md
§8.1).

One message = one frame::

    magic   2s   b"HC"
    op      u8   message class: 1 = request, 2 = response, 3 = error
    length  u32  payload byte count
    crc32   u32  zlib.crc32 of magic+op+length THEN the payload — header
                 fields are covered too (the WAL's framing discipline,
                 persist/wal.py), so a flipped bit anywhere in the frame is
                 a detected ``TornFrameError``, never a silently wrong
                 tensor
    payload      one JSON meta line (command name + scalar fields), b"\\n",
                 then ``checkpoint.leaves.pack_arrays`` of the named
                 tensors — the same deterministic bit-exact encoding the
                 WAL and snapshot store use, so a tensor that round-trips
                 the wire is the tensor that round-trips disk

The framing is deliberately the smallest thing that can carry named numpy
arrays with end-to-end integrity; request/response matching is one-per-
connection (a client sends a request and reads exactly one reply), which
keeps failure handling trivial: any anomaly kills the connection and the
client re-establishes it (``client.ShardClient``).
"""

from __future__ import annotations

import json
import socket
import struct
import zlib

from repro.checkpoint.leaves import pack_arrays, unpack_arrays

__all__ = ["TornFrameError", "RemoteError", "send_msg", "recv_msg",
           "build_frame", "MSG_REQUEST", "MSG_RESPONSE", "MSG_ERROR"]

MSG_REQUEST = 1
MSG_RESPONSE = 2
MSG_ERROR = 3

_MAGIC = b"HC"
_HEADER = struct.Struct("<2sBII")       # magic, op, length, crc32
_PREFIX = struct.Struct("<2sBI")        # the crc-covered header fields


class TornFrameError(ConnectionError):
    """A frame failed its integrity check — short read, bad magic, or crc
    mismatch.  The connection is unusable (framing is lost): the only safe
    recovery is to drop it and reconnect, which ``client.ShardClient``
    does transparently."""


class RemoteError(RuntimeError):
    """The peer executed the request and reported an application-level
    failure (its message is the remote traceback summary).  Distinct from
    ``TornFrameError``: the wire worked, the command did not — retrying on
    a fresh connection will not help."""


def _frame_crc(op: int, payload: bytes) -> int:
    return zlib.crc32(payload,
                      zlib.crc32(_PREFIX.pack(_MAGIC, op, len(payload))))


def build_frame(cmd: str, meta: dict | None = None,
                arrays: dict | None = None, *,
                op: int = MSG_REQUEST) -> bytes:
    """Serialize one message to its complete wire frame WITHOUT sending it.
    The router's fan-out uses this to pack a query batch ONCE and send the
    identical bytes to every scorer (the per-shard re-serialization was a
    measurable slice of the Q=1 RPC overhead); ``ShardClient.submit``
    accepts the pre-built frame directly."""
    head = dict(meta or {})
    head["cmd"] = cmd
    payload = json.dumps(head).encode() + b"\n" + pack_arrays(arrays or {})
    return _HEADER.pack(_MAGIC, op, len(payload),
                        _frame_crc(op, payload)) + payload


def send_msg(sock: socket.socket, cmd: str, meta: dict | None = None,
             arrays: dict | None = None, *, op: int = MSG_REQUEST,
             corrupt: bool = False) -> int:
    """Frame and send one message; returns the bytes written.  ``cmd`` and
    the JSON-scalar ``meta`` fields form the header line, ``arrays`` are
    named numpy tensors (bit-exact via ``pack_arrays``).  ``corrupt=True``
    flips a payload bit AFTER the crc is computed — the server-side fault
    hook the torn-frame tests drive; a real sender never sets it."""
    frame = bytearray(build_frame(cmd, meta, arrays, op=op))
    if corrupt:
        frame[-1] ^= 0x40
    sock.sendall(frame)
    return len(frame)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly n bytes or raise: ``ConnectionError`` on a clean EOF at
    a frame boundary (peer went away), ``TornFrameError`` mid-frame."""
    chunks, got = [], 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                raise ConnectionError("peer closed the connection")
            raise TornFrameError(
                f"connection closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_msg(sock: socket.socket) -> tuple[int, dict, dict]:
    """Receive one frame; returns ``(op, meta, arrays)``.  Integrity
    failures raise ``TornFrameError``; an ``op == MSG_ERROR`` frame is
    returned like any other (the client raises ``RemoteError`` from it —
    the transport layer only vouches for the bytes)."""
    header = _recv_exact(sock, _HEADER.size)
    magic, op, length, crc = _HEADER.unpack(header)
    if magic != _MAGIC:
        raise TornFrameError(f"bad frame magic {magic!r}")
    payload = _recv_exact(sock, length)
    if _frame_crc(op, payload) != crc:
        raise TornFrameError("frame checksum mismatch")
    nl = payload.index(b"\n")
    meta = json.loads(payload[:nl].decode())
    arrays = unpack_arrays(payload[nl + 1:])
    return op, meta, arrays
