"""Cross-host serving tier (DESIGN.md §8): RPC shard fan-out + snapshot/WAL
replication — the paper's §7.2 many-server deployment, made concrete.

* ``protocol`` — length-prefixed, crc-checksummed frames carrying a JSON
  meta line + bit-exact packed tensors (§8.1);
* ``shard_server`` — one process per role: ``primary`` (mutations + delta
  + persist store), ``scorer`` (one ragged row slice of the ONE build),
  ``replica`` (full follower via snapshot distribution + WAL shipping,
  §8.3);
* ``client`` — reconnecting ``ShardClient`` + the remote ``ShardSearcher``
  handles ``fanout_search`` dispatches like in-process engines;
* ``router`` — bucketed fan-out, authoritative per-generation tombstone
  overlay at the merge, read-your-writes watermarks, explicit
  ``DegradedResultError`` instead of silently truncated top-k (§8.2,
  §8.4);
* ``local`` — subprocess launcher for tests/benchmarks/demos.

The contract the test harness (tests/test_cluster.py) pins: RPC results
are bit-identical — ids AND scores — to the in-process ``QueryService``
fan-out on the same state, across backends, odd/even K, and every
mutation interleaving.
"""

from .client import (RemoteDeltaEngine, RemoteMainEngine,  # noqa: F401
                     ShardClient, ShardUnavailableError, wait_ready)
from .local import LocalCluster, NodeHandle                # noqa: F401
from .protocol import RemoteError, TornFrameError          # noqa: F401
from .router import (ClusterRouter, DegradedResultError,   # noqa: F401
                     Session)
from .shard_server import ShardServer, StaleGenerationError  # noqa: F401
