"""Cross-host serving tier (DESIGN.md §8): RPC shard fan-out + snapshot/WAL
replication — the paper's §7.2 many-server deployment, made concrete.

* ``protocol`` — length-prefixed, crc-checksummed frames carrying a JSON
  meta line + bit-exact packed tensors (§8.1);
* ``shard_server`` — one process per role: ``primary`` (mutations + delta
  + persist store + the AUTHORITATIVE (term, epoch)-tagged liveness
  state), ``scorer`` (one ragged row slice of the ONE build), ``replica``
  (full follower via snapshot distribution + WAL shipping, promotable to
  primary under term fencing, §8.3, §8.7);
* ``client`` — pipelining ``ShardClient`` (submit/PendingReply +
  same-shard request coalescing into ``msearch`` frames, §8.8) + the
  remote ``ShardSearcher`` handles that dispatch like in-process engines;
* ``router`` — bucketed fan-out merging under server-side authority
  (epoch-validated cache), read-your-writes watermarks, deterministic
  ``failover()`` election, explicit ``DegradedResultError`` instead of
  silently truncated top-k (§8.2, §8.4, §8.7);
* ``local`` — subprocess launcher for tests/benchmarks/demos.

The contract the test harness (tests/test_cluster.py) pins: RPC results
are bit-identical — ids AND scores — to the in-process ``QueryService``
fan-out on the same state, for ANY number of routers sharing the cluster,
across backends, odd/even K, every mutation interleaving, and across a
primary failover.
"""

from .client import (PendingReply, RemoteDeltaEngine,      # noqa: F401
                     RemoteMainEngine, ShardClient,
                     ShardUnavailableError, wait_ready)
from .local import LocalCluster, NodeHandle                # noqa: F401
from .protocol import (RemoteError, TornFrameError,        # noqa: F401
                       build_frame)
from .router import (ClusterRouter, DegradedResultError,   # noqa: F401
                     FailoverError, Session, StaleTermError)
from .shard_server import (NotPrimaryError, PromotionError,  # noqa: F401
                           ShardServer, StaleGenerationError)
