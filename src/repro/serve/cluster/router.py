"""Cluster router: bucketed fan-out over RPC shards + host-side merge
under SERVER-SIDE authority (DESIGN.md §8.2, §8.4) — the cross-host form
of ``QueryService``'s in-process fan-out, sharing its actual machinery:
``bucket_for``/``pad_rows`` for micro-batching, the ``plan_overfetch``
budget formula for tombstone slack, ``merge_topk_host`` for the merge.

Topology: N ``scorer`` servers each hold one contiguous row slice of the
ONE build (bit-identity depends on that — frozen artifacts are global,
rows are sliced); the ``primary`` owns mutations and serves the delta
part; ``replica`` followers serve whole-query parts for follower reads
and failover.  The merge order is ``[scorer 0 … scorer S-1, delta]`` —
exactly the in-process ``[main shards…, delta]`` — so stable-sort
tie-breaking, and therefore every bit of every result, matches the
single-process service.

AUTHORITY IS SERVER-SIDE: the primary versions its liveness state
(tombstones, fully-deleted overlay, delta live count) with a
``(term, epoch)`` tag; this router keeps only a CACHE of it.  Every chunk
dispatches the delta request as a validation channel carrying the cached
tag — a mismatched response piggybacks the authoritative sets, and the
merge always uses the authoritative view, re-deepening main fetches when
the cache under-budgeted the overfetch.  That is what makes N routers
over one cluster bit-identical to one router: no router ever merges from
private state another router cannot see (DESIGN.md §8.4).

Failover (DESIGN.md §8.7): ``failover()`` runs a deterministic election
over the replica set (most-applied wins, ties to the lowest index),
promotes the winner via the ``promote`` op — gated server-side on having
applied every sealed seq — and re-points every node at it.  The promoted
term fences the deposed primary: any response carrying a lower term
raises ``StaleTermError`` instead of being folded into state.

Read-your-writes: every mutation ack carries its WAL seq; a ``Session``
records the max as its watermark, and follower reads are only served by a
replica whose ``applied_seq`` covers it — otherwise the router falls back
to the primary path.  A replica behind ``last acked seq - replica_max_lag``
is excluded from routing entirely until it catches up.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.distributed import ceil16, merge_topk_host
from repro.core.sparse_index import (CompactColumns,
                                     sparse_queries_to_padded)
from repro.obs import Observability
from repro.obs.trace import NULL_SPAN
from repro.serve.query_service import DEFAULT_BUCKETS, bucket_for, pad_rows

from .client import ShardClient, ShardUnavailableError
from .protocol import RemoteError, build_frame

__all__ = ["ClusterRouter", "Session", "DegradedResultError",
           "StaleTermError", "FailoverError"]


class DegradedResultError(RuntimeError):
    """A shard needed for a full-fidelity answer is unreachable and no
    caught-up replica can stand in.  Raised INSTEAD of merging whatever
    parts survived: a silently truncated top-k is a wrong answer that
    looks right, which the fault-injection suite forbids."""


class StaleTermError(RuntimeError):
    """A response carried a fencing term LOWER than one this router has
    already observed: it came from a deposed (zombie) primary.  Its ack is
    refused — the mutation may sit in the zombie's log, but the promoted
    primary's log will never contain it, so folding it into watermarks or
    tombstone state would invent durability (DESIGN.md §8.7)."""


class FailoverError(RuntimeError):
    """No promotion candidate survives the eligibility gate (applied every
    sealed seq, same generation, reachable).  Promoting anything else
    would lose acked mutations, so the election refuses instead."""


@dataclasses.dataclass
class Session:
    """Read-your-writes handle: ``watermark`` is the WAL seq of this
    session's last acked write (-1 = no writes observed yet; real seqs
    start at 1, and seq 0 never occurs); reads made with the session are
    only served by state that has applied at least that seq."""
    watermark: int = -1

    def observe(self, seq: int) -> None:
        """Fold an acked write's seq into the watermark."""
        self.watermark = max(self.watermark, int(seq))


@dataclasses.dataclass(frozen=True)
class _PinnedState:
    """One consistent router-state snapshot for a chunk's lifetime (the
    cross-host analogue of ``QueryService._acquire_view``): generation +
    its corpus geometry, the CACHED liveness sets with their validating
    ``(term, epoch)`` tag, and the last acked seq.  ``epoch == -1`` means
    no cache — the delta response will carry the authoritative sets."""
    gen: int
    num_points: int
    d_active: int
    cols: CompactColumns
    main_dead: frozenset
    fully_deleted: frozenset
    delta_live: int
    last_seq: int
    epoch: int
    term: int


@dataclasses.dataclass
class _Auth:
    """Cached authoritative liveness state for one generation, valid
    exactly at ``(term, epoch)``."""
    epoch: int
    term: int
    main_dead: set
    fully_deleted: set
    delta_live: int


def _addr(spec: str) -> tuple[str, int]:
    host, port = spec.rsplit(":", 1)
    return host, int(port)


class ClusterRouter:
    """Client-side coordinator for one shard cluster.

    ``primary``/``scorers``/``replicas`` are ``host:port`` endpoints (see
    ``local.LocalCluster`` for a one-call launcher).  Searches take raw
    scipy sparse queries (``search_sparse``) or pre-padded compact-space
    batches (``search``); mutations go to the primary and their acks feed
    the router's cache + watermark state; ``compact()`` orchestrates the
    cluster-wide generation flip; ``failover()`` promotes a replica when
    the primary dies.  ``lockstep=True`` disables request pipelining,
    coalescing, AND the adaptive fan-out cutoff (one blocking call per
    shard via the thread pool — the pre-batching wire discipline, kept
    for the benchmark's before/after comparison).

    ``direct_q_max`` is the adaptive fan-out cutoff (DESIGN.md §8.8):
    chunks whose padded bucket is at most this many queries skip the
    S-scorer scatter-gather and get served by ONE ``part="full"`` request
    to the primary — the same main+delta read (and the same
    bit-identical merge) a replica serves, against the node that is
    trivially caught-up.  A single query through S scorers pays S+1 RPCs
    of fixed dispatch cost to do one process worth of scoring; the
    scatter-gather only earns its overhead at batch sizes that fill the
    slices.  ``0`` disables the cutoff (every chunk fans out)."""

    def __init__(self, primary: str, scorers: list[str],
                 replicas: list[str] = (), *, h: int = 10,
                 alpha: int | None = None, beta: int | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 prefer_replica: bool = False, replica_max_lag: int = 0,
                 lockstep: bool = False, direct_q_max: int = 1,
                 timeout: float = 60.0, obs: Observability | None = None):
        # tracing defaults ON for the router: per-chunk span trees are
        # the hop breakdown's only source (DESIGN.md §9.2), and their
        # cost is microseconds against millisecond RPCs
        self.obs = obs if obs is not None else Observability(trace=True)
        self.primary = ShardClient(*_addr(primary), timeout=timeout)
        self.scorers = [ShardClient(*_addr(a), timeout=timeout)
                        for a in scorers]
        self.replicas = [ShardClient(*_addr(a), timeout=timeout)
                         for a in replicas]
        self.buckets = buckets
        self.prefer_replica = prefer_replica
        self.replica_max_lag = replica_max_lag
        self.lockstep = lockstep
        self.direct_q_max = int(direct_q_max)
        self._lock = threading.RLock()
        self._pool = ThreadPoolExecutor(
            max_workers=max(2, len(self.scorers) + 1),
            thread_name_prefix="router-fanout")
        info, arrays = self.primary.call("info")
        self.gen = int(info["gen"])
        self.h = h
        self.alpha = int(info["alpha"] if alpha is None else alpha)
        self.beta = int(info["beta"] if beta is None else beta)
        self._num_points = int(info["num_points"])
        self._d_active = int(info["d_active"])
        self._nq_max = int(info["nq_max"])
        self._cols = CompactColumns(global_ids=arrays["cols_global_ids"])
        self.term = int(info.get("term", 0))
        self._auth = {self.gen: _Auth(
            epoch=int(info.get("epoch", 0)), term=self.term,
            main_dead=set(arrays["main_tombstones"].tolist()),
            fully_deleted=set(arrays["fully_deleted"].tolist()),
            delta_live=int(info["delta_live"]))}
        self._last_seq = int(info["applied_seq"])
        self._replica_seq = [(-1) for _ in self.replicas]
        self.stats = {"primary_reads": 0, "replica_reads": 0,
                      "direct_reads": 0, "failovers": 0, "degraded": 0,
                      "stale_retries": 0, "excluded_stale": 0,
                      "queries": 0, "resyncs": 0, "promotions": 0}
        # cumulative per-stage hop counters, folded from finished chunk
        # spans (``_fold_stages``) — the span-sourced replacement for the
        # old ad-hoc ``hop_s`` field scraping (DESIGN.md §9.2)
        m = self.obs.metrics
        self._hop_c = {k: m.counter(f"cluster.hop.{k}")
                       for k in ("serialize_s", "wire_s", "queue_s",
                                 "score_s", "merge_s")}

    # -- sessions ---------------------------------------------------------

    def session(self) -> Session:
        """A fresh read-your-writes session (watermark -1 = any state)."""
        return Session()

    # -- term fencing + state cache ---------------------------------------

    def _fence_term(self, term: int) -> None:
        """Refuse a deposed primary's response (caller holds ``_lock``):
        terms only grow, so anything below the highest one this router has
        seen is a zombie talking (DESIGN.md §8.7)."""
        if term and term < self.term:
            raise StaleTermError(
                f"response carries term {term} but this router has seen "
                f"term {self.term}: a deposed primary is still answering; "
                "refusing its state")
        if term > self.term:
            self.term = term

    def _adopt_auth(self, gen: int, term: int, epoch: int, main_dead: set,
                    fully_deleted: set, delta_live: int) -> None:
        """Install a synced authoritative view as the cache for ``gen``
        (caller holds ``_lock``); never replaces a newer tag."""
        a = self._auth.get(gen)
        if a is None or (term, epoch) >= (a.term, a.epoch):
            self._auth[gen] = _Auth(epoch=epoch, term=term,
                                    main_dead=main_dead,
                                    fully_deleted=fully_deleted,
                                    delta_live=delta_live)

    def _resync(self) -> None:
        """Re-learn generation, corpus geometry, column space and the
        authoritative liveness state from the primary — another router may
        have compacted, mutated, or failed the cluster over since this
        router last looked."""
        info, arrays = self.primary.call("info")
        with self._lock:
            self._fence_term(int(info.get("term", 0)))
            g = int(info["gen"])
            self.gen = g
            self._num_points = int(info["num_points"])
            self._d_active = int(info["d_active"])
            self._cols = CompactColumns(
                global_ids=arrays["cols_global_ids"])
            self._adopt_auth(g, int(info.get("term", 0)),
                             int(info.get("epoch", 0)),
                             set(arrays["main_tombstones"].tolist()),
                             set(arrays["fully_deleted"].tolist()),
                             int(info["delta_live"]))
            self._auth = {gg: aa for gg, aa in self._auth.items()
                          if gg == g}
            self._last_seq = max(self._last_seq, int(info["applied_seq"]))
            self.stats["resyncs"] += 1

    # -- mutations (primary only) -----------------------------------------

    def _ack(self, meta: dict, *, main_killed, resurrected=(),
             fully_killed=(), session: Session | None,
             span=NULL_SPAN) -> None:
        """Fold one mutation ack into the watermark state and — when the
        ack extends the cache's exact ``(term, epoch)`` tag — the cached
        liveness view.  An ack that does NOT extend the tag (another
        router mutated in between) invalidates the cache instead: the
        next read's delta response re-syncs it from authority.  A stale
        term raises ``StaleTermError`` BEFORE anything is folded — a
        zombie's ack must not move watermarks (and the refusal is
        recorded as a ``term_fenced`` annotation on the mutation's
        span)."""
        seq = meta["seq"]
        term = int(meta.get("term", 0))
        with self._lock:
            try:
                self._fence_term(term)
            except StaleTermError:
                span.annotate(f"term_fenced: ack term {term} < "
                              f"router term {self.term}, refused")
                raise
            g = int(meta["gen"])
            e = int(meta.get("epoch", 0))
            a = self._auth.get(g)
            if a is not None:
                if a.term == term and e in (a.epoch, a.epoch + 1):
                    a.main_dead.update(int(x) for x in main_killed)
                    a.fully_deleted.update(int(x) for x in fully_killed)
                    a.fully_deleted.difference_update(
                        int(x) for x in resurrected)
                    a.delta_live = int(meta["delta_live"])
                    a.epoch = e
                else:
                    del self._auth[g]
            if seq is not None:
                self._last_seq = max(self._last_seq, int(seq))
        # ``is not None``, not truthiness: only a no-op mutation acks with
        # seq None, and a session must observe every REAL seq it was acked
        if session is not None and seq is not None:
            session.observe(seq)

    def insert(self, x_sparse, x_dense, ids=None,
               session: Session | None = None) -> np.ndarray:
        """Insert (or upsert) rows via the primary; returns the assigned
        external ids.  Acked only after the primary's WAL covers the batch
        (its group-commit discipline); the ack's ``main_killed`` ids feed
        the router's cached liveness view and its seq the session
        watermark."""
        import scipy.sparse as sp
        xs = sp.csr_matrix(x_sparse)
        arrays = {"data": xs.data, "indices": xs.indices,
                  "indptr": xs.indptr,
                  "shape": np.asarray(xs.shape, np.int64),
                  "dense": np.atleast_2d(np.asarray(x_dense, np.float32))}
        if ids is not None:
            arrays["ids"] = np.atleast_1d(np.asarray(ids, np.int64))
        with self.obs.tracer.root("cluster.insert") as sp:
            hs = sp.child("rpc", peer=self.primary.addr, part="insert")
            ctx = sp.wire_context()
            meta, arr = self.primary.call(
                "insert", {"trace": ctx} if ctx else None, arrays,
                retry=False, span=hs)
            self._finish_hop(hs, meta)
            assigned = arr["ids"]
            self._ack(meta, main_killed=arr["main_killed"],
                      resurrected=assigned.tolist(), session=session,
                      span=sp)
        return assigned

    def delete(self, ids, session: Session | None = None) -> int:
        """Tombstone rows by external id via the primary; returns #killed.
        The ack's killed ids join BOTH cached sets: ``main_dead`` (drop
        from scorer parts) and ``fully_deleted`` (the overlay that stops a
        lagging replica resurrecting them, DESIGN.md §8.4)."""
        with self.obs.tracer.root("cluster.delete") as sp:
            hs = sp.child("rpc", peer=self.primary.addr, part="delete")
            ctx = sp.wire_context()
            meta, arr = self.primary.call(
                "delete", {"trace": ctx} if ctx else None,
                {"ids": np.atleast_1d(np.asarray(ids, np.int64))},
                retry=False, span=hs)
            self._finish_hop(hs, meta)
            self._ack(meta, main_killed=arr["main_killed"],
                      fully_killed=arr["killed_ids"].tolist(),
                      session=session, span=sp)
        return int(meta["killed"])

    # -- compaction (cluster-wide generation flip) ------------------------

    def compact(self, retrain: bool | None = None) -> int:
        """Orchestrate a cluster compaction: pause replica shipping, fold
        delta + tombstones at the primary (cut as a durable checkpoint),
        have every scorer/replica reload the new store, then atomically
        flip the router's generation + seed the new epoch's cache from the
        compact ack's tag.  Old-generation searches keep working mid-flip
        (servers hold the last two generations).  Returns the new
        generation number."""
        for r in self.replicas:
            r.call("fault", {"mode": "pause_shipping"})
        meta, arrays = self.primary.call("compact", {"retrain": retrain},
                                         retry=False)
        gen = int(meta["gen"])
        for s in self.scorers:
            s.call("reload", {"gen": gen})
        for r in self.replicas:
            r.call("reload", {"gen": gen})
        with self._lock:
            self._fence_term(int(meta.get("term", 0)))
            self.gen = gen
            self._num_points = int(meta["num_points"])
            self._d_active = int(meta["d_active"])
            self._cols = CompactColumns(
                global_ids=arrays["cols_global_ids"])
            # a fresh generation starts with empty liveness sets, valid at
            # the compact ack's tag; a mutation racing the flip bumps the
            # server epoch past it, so the tag validation catches it
            self._auth = {gen: _Auth(epoch=int(meta.get("epoch", 0)),
                                     term=int(meta.get("term", 0)),
                                     main_dead=set(), fully_deleted=set(),
                                     delta_live=0)}
        return gen

    # -- failover (DESIGN.md §8.7) ----------------------------------------

    def failover(self, new_primary: int | None = None) -> int:
        """Promote a replica to primary after the primary died: a
        deterministic election (every router over the same replica set
        picks the same winner: most applied seqs first, ties to the lowest
        index), committed by the ``promote`` op whose server-side gate
        re-checks eligibility under the apply lock.  The new term fences
        the deposed primary everywhere.  Re-points every surviving node's
        upstream, then re-syncs state from the new primary.  Returns the
        new term; raises ``FailoverError`` when no candidate has applied
        every sealed (acked) seq."""
        with self._lock:
            sealed = self._last_seq
            gen = self.gen
            known_term = self.term
        with self.obs.tracer.root("cluster.failover", gen=gen,
                                  sealed_seq=sealed) as sp:
            candidates = []
            for i, rep in enumerate(self.replicas):
                try:
                    st, _ = rep.call("status")
                except (ShardUnavailableError, ConnectionError):
                    sp.annotate(f"candidate {rep.addr} unreachable")
                    continue
                known_term = max(known_term, int(st.get("term", 0)))
                if st.get("role") != "replica" or int(st["gen"]) != gen:
                    continue
                candidates.append((int(st["applied_seq"]), i))
                sp.annotate(f"candidate {rep.addr} "
                            f"applied={int(st['applied_seq'])}")
            eligible = [(a, i) for a, i in candidates if a >= sealed]
            if new_primary is not None:
                eligible = [(a, i) for a, i in eligible
                            if i == new_primary]
            if not eligible:
                sp.annotate("election_failed: no caught-up candidate")
                raise FailoverError(
                    f"no eligible promotion candidate: need applied_seq "
                    f">= sealed seq {sealed} at gen {gen}, saw "
                    f"{sorted(candidates)}; promoting a lagging replica "
                    "would lose acked mutations")
            eligible.sort(key=lambda t: (-t[0], t[1]))
            win = eligible[0][1]
            new_term = known_term + 1
            target = self.replicas[win]
            sp.annotate(f"promote winner={target.addr} "
                        f"new_term={new_term}")
            meta, _ = target.call("promote", {"sealed_seq": sealed,
                                              "new_term": new_term},
                                  retry=False)
            old = self.primary
            with self._lock:
                self.primary = target
                del self.replicas[win]
                del self._replica_seq[win]
                self.term = new_term
                self._last_seq = max(self._last_seq,
                                     int(meta["applied_seq"]))
                # the new primary's state IS the authority now — drop the
                # cache and re-sync below rather than trusting anything
                # folded from the deposed primary's acks
                self._auth.pop(gen, None)
                self.stats["promotions"] += 1
            sp.set("term", new_term)
            new_addr = f"{target.host}:{target.port}"
            for c in [*self.scorers, *self.replicas]:
                try:
                    c.call("set_peer", {"peer": new_addr})
                except (ShardUnavailableError, ConnectionError):
                    pass             # unreachable now; it re-learns on
                                     # restart or the next reload
            old.close()
            self._resync()
        return new_term

    # -- search -----------------------------------------------------------

    def _slice_sizes(self, n: int) -> list[int]:
        """Row counts per scorer under the ragged ceil-split — must mirror
        ``split_index_arrays(..., ragged=True)`` exactly, since the
        overfetch budget computes per-slice fetch depths from them."""
        s = len(self.scorers)
        base, rem = divmod(n, s)
        return [base + 1 if i < rem else base for i in range(s)]

    def _pin(self) -> _PinnedState:
        """Snapshot the router's view for one chunk: generation + corpus
        geometry pinned TOGETHER (a compaction racing the chunk cannot
        re-budget old-generation fetch depths from the new generation's
        row count), plus the cached liveness sets and their validating
        tag."""
        with self._lock:
            g = self.gen
            a = self._auth.get(g)
            return _PinnedState(
                gen=g, num_points=self._num_points,
                d_active=self._d_active, cols=self._cols,
                main_dead=frozenset(a.main_dead) if a else frozenset(),
                fully_deleted=(frozenset(a.fully_deleted) if a
                               else frozenset()),
                delta_live=a.delta_live if a else 0,
                last_seq=self._last_seq,
                epoch=a.epoch if a else -1,
                term=a.term if a else -1)

    def search_sparse(self, q_sparse, q_dense, *, h: int | None = None,
                      alpha: int | None = None, beta: int | None = None,
                      session: Session | None = None):
        """Serve RAW scipy sparse queries: encode against the pinned
        generation's compact column space (generation-bound, like
        ``QueryService.search_sparse``), then fan out.  Returns
        ``(scores (Q, h), ids (Q, h))`` in external ids."""
        pin = self._pin()
        q_dims, q_vals = sparse_queries_to_padded(q_sparse, pin.cols,
                                                  nq_max=self._nq_max)
        return self._search_pinned(pin,
                                   np.atleast_2d(np.asarray(q_dims,
                                                            np.int32)),
                                   np.atleast_2d(np.asarray(q_vals,
                                                            np.float32)),
                                   np.atleast_2d(np.asarray(q_dense,
                                                            np.float32)),
                                   h, alpha, beta, session)

    def search(self, q_dims, q_vals, q_dense, *, h: int | None = None,
               alpha: int | None = None, beta: int | None = None,
               session: Session | None = None):
        """Serve pre-padded compact-space query batches (generation-bound
        — streaming clients should prefer ``search_sparse``).  Returns
        ``(scores (Q, h), ids (Q, h))`` numpy arrays, bit-identical to the
        in-process ``QueryService`` fan-out on the same state."""
        return self._search_pinned(
            self._pin(),
            np.atleast_2d(np.asarray(q_dims, np.int32)),
            np.atleast_2d(np.asarray(q_vals, np.float32)),
            np.atleast_2d(np.asarray(q_dense, np.float32)),
            h, alpha, beta, session)

    def _search_pinned(self, pin, q_dims, q_vals, q_dense,
                       h, alpha, beta, session, _retries: int = 8):
        h = self.h if h is None else h
        alpha = self.alpha if alpha is None else alpha
        beta = self.beta if beta is None else beta
        qn_total = q_dims.shape[0]
        out_s = np.empty((qn_total, h), np.float32)
        out_i = np.empty((qn_total, h), np.int64)
        max_bucket = self.buckets[-1]
        for lo in range(0, qn_total, max_bucket):
            hi = min(lo + max_bucket, qn_total)
            # one root span per chunk, covering its whole retry loop —
            # the trace tree the hop breakdown is sourced from
            with self.obs.tracer.root("cluster.search",
                                      qn=hi - lo, gen=pin.gen) as span:
                for attempt in range(_retries):
                    try:
                        s, ids = self._run_chunk(
                            pin, q_dims[lo:hi], q_vals[lo:hi],
                            q_dense[lo:hi], h, alpha, beta, session,
                            span)
                        break
                    except RemoteError as e:
                        if "StaleGeneration" not in str(e) \
                                or attempt + 1 >= _retries:
                            raise
                        # a compaction flipped generations mid-flight
                        # (possibly driven by ANOTHER router): re-learn
                        # the cluster state from the primary, re-pin,
                        # retry against the new epoch
                        with self._lock:
                            self.stats["stale_retries"] += 1
                        span.annotate("stale_generation_resync "
                                      f"attempt={attempt + 1}")
                        # mid-flip the scorers lag the primary's new
                        # generation by a store fetch + reload — back off
                        # so the retry budget spans the whole flip
                        time.sleep(0.05 * (attempt + 1))
                        try:
                            self._resync()
                        except (ShardUnavailableError, ConnectionError):
                            pass
                        pin = self._pin()
                        span.set("gen", pin.gen)
            out_s[lo:hi], out_i[lo:hi] = s, ids
        with self._lock:
            self.stats["queries"] += qn_total
        return out_s, out_i

    def _run_chunk(self, pin, q_dims, q_vals, q_dense, h, alpha,
                   beta, session, span=NULL_SPAN):
        qn = q_dims.shape[0]
        bucket = bucket_for(qn, self.buckets)
        qd = pad_rows(q_dims, bucket, fill=pin.d_active)
        qv = pad_rows(q_vals, bucket)
        qe = pad_rows(q_dense, bucket)
        required = session.watermark if session is not None else -1
        floor = max(required, pin.last_seq - self.replica_max_lag)

        if self.prefer_replica and self.replicas:
            res = self._try_replicas(pin, qd, qv, qe, qn, h, alpha, beta,
                                     floor, span)
            if res is not None:
                return res
        try:
            if bucket <= self.direct_q_max and not self.lockstep:
                return self._primary_full(pin, qd, qv, qe, qn, h,
                                          alpha, beta, span)
            return self._fanout(pin, qd, qv, qe, qn, h, alpha, beta,
                                span)
        except (ShardUnavailableError, ConnectionError):
            with self._lock:
                self.stats["failovers"] += 1
            span.annotate("shard_unreachable: replica failover")
            res = self._try_replicas(pin, qd, qv, qe, qn, h, alpha, beta,
                                     floor, span)
            if res is not None:
                return res
            with self._lock:
                self.stats["degraded"] += 1
            span.annotate("degraded: no caught-up replica")
            raise DegradedResultError(
                "a scoring shard is unreachable and no replica has "
                f"applied seq >= {floor}; refusing to return a silently "
                "truncated top-k") from None

    def _collect(self, client, entry, cmd, meta, arrays, span=NULL_SPAN):
        """Collect one pipelined reply, healing a transport failure (torn
        frame, dropped socket) with ONE fresh-connection resend — the same
        discipline and ``reconnects`` accounting as ``ShardClient.call``;
        searches are idempotent, so the resend is safe.  Returns
        ``(rmeta, rarrays)``; the entry's PER-REQUEST timing (wall /
        serialize / coalescer queue — _CoalescedReply fields, never
        shared across requests) is folded into ``span``, and a healed
        resend both re-times through ``call(span=…)`` and annotates the
        span, so the trace survives the reconnect (DESIGN.md §9.2)."""
        try:
            rmeta, rarr = entry.result()
            span.add("serialize_s", entry.send_s)
            span.add("queue_s", entry.queue_s)
            span.set("wall_s", entry.wall_s)
            return rmeta, rarr
        except RemoteError:
            raise
        except ShardUnavailableError:
            raise
        except (ConnectionError, OSError):
            client.reconnects += 1
            span.annotate(f"reconnect_resend cmd={cmd}")
            return client.call(cmd, meta, arrays, retry=False, span=span)

    def _finish_hop(self, hs, rmeta: dict) -> None:
        """Finish one hop span: attach the shard's serialized child span
        (``rmeta["trace"]``, present iff the request carried a trace
        context), fold its server-measured ``queue_s``/``score_s`` into
        the hop's stage tags, and set ``wire_s`` as the residual so the
        stages sum exactly to the hop's measured ``wall_s``
        (serialize + queue + score + wire == wall, DESIGN.md §9.2)."""
        rt = rmeta.get("trace")
        # every hop carries the full stage vocabulary (queue_s is 0.0
        # for replies without a server span, e.g. mutations)
        hs.add("queue_s", float(rt.get("queue_s", 0.0)) if rt else 0.0)
        if rt:
            # score/queue live as hop stage tags; don't duplicate them on
            # the attached child or stage totals would double-count
            hs.attach_remote({k: v for k, v in rt.items()
                              if k not in ("queue_s", "score_s")})
        hs.add("score_s", float(rmeta.get("score_s", 0.0)))
        wall = hs.tags.get("wall_s", 0.0)
        measured = (hs.tags.get("serialize_s", 0.0)
                    + hs.tags.get("queue_s", 0.0)
                    + hs.tags.get("score_s", 0.0))
        hs.set("wire_s", max(0.0, wall - measured))
        hs.end()
        # fold this hop into the cumulative counters exactly once (per
        # hop span, so chunk retries never double-count)
        for k in ("serialize_s", "wire_s", "queue_s", "score_s"):
            v = hs.tags.get(k)
            if v:
                self._hop_c[k].inc(v)

    def _merge_timed(self, span, t_m: float) -> None:
        """Tag the chunk span with the host-merge duration measured from
        ``t_m`` and fold it into the cumulative merge counter."""
        dt = time.perf_counter() - t_m
        span.add("merge_s", dt)
        self._hop_c["merge_s"].inc(dt)

    def _primary_full(self, pin, qd, qv, qe, qn, h, alpha, beta,
                      span=NULL_SPAN):
        """The adaptive fan-out cutoff: serve one small chunk with ONE
        ``part="full"`` request to the primary (DESIGN.md §8.8).  The
        primary scores its whole main engine plus the live delta — the
        exact read a replica serves, merged with the exact same per-part
        drop construction, against the one node whose applied prefix is
        the cluster's truth (read-your-writes floors hold trivially).
        The response's ``main_tombstones`` are the CURRENT authoritative
        kills and the server self-slacks its fetch depth by them, so a
        stale pinned cache can neither truncate nor resurrect; a frozen
        pinned generation gets the server's StaleGeneration refusal and
        re-pins through ``_search_pinned``'s retry loop."""
        t0 = time.perf_counter()
        span.set("path", "direct")
        dead = pin.main_dead | pin.fully_deleted
        h_fetch = min(h + (ceil16(len(dead)) if dead else 0),
                      pin.num_points)
        req = {"part": "full", "gen": pin.gen, "h": int(h_fetch),
               "alpha": int(alpha), "beta": int(beta)}
        ctx = span.wire_context()
        if ctx:
            req["trace"] = ctx
        hs = span.child("rpc", peer=self.primary.addr, part="full")
        meta, arrays = self.primary.call(
            "search", req, {"q_dims": qd, "q_vals": qv, "q_dense": qe},
            span=hs)
        self._finish_hop(hs, meta)
        with self._lock:
            self._fence_term(int(meta.get("term", 0)))
            self._last_seq = max(self._last_seq,
                                 int(meta.get("applied_seq", -1)))
        drop_main = set(arrays["main_tombstones"].tolist())
        drop_main.update(pin.fully_deleted)
        parts = [(arrays["ms"][:qn], arrays["mi"][:qn],
                  np.asarray(sorted(drop_main), np.int64))]
        if "ds" in arrays:
            parts.append((arrays["ds"][:qn], arrays["di"][:qn],
                          np.asarray(sorted(pin.fully_deleted),
                                     np.int64)))
        t_m = time.perf_counter()
        s, ids = merge_topk_host(parts, h)
        self._merge_timed(span, t_m)
        span.set("wall_s", time.perf_counter() - t0)
        with self._lock:
            self.stats["primary_reads"] += qn
            self.stats["direct_reads"] += qn
        return s, ids

    def _fanout(self, pin, qd, qv, qe, qn, h, alpha, beta,
                span=NULL_SPAN):
        """The S-scorer + primary-delta path.  The delta request is ALWAYS
        dispatched — it is the chunk's state-validation channel: its
        response either confirms the pinned cache tag or carries the
        authoritative liveness sets, and the merge uses whichever is
        authoritative.  Main fetches are re-deepened (once, only the
        under-budgeted slices) when the authoritative dead set needs more
        overfetch slack than the cache predicted — main parts are pure
        functions of (generation, depth, query), so a re-fetch merges
        exactly as a first fetch would have.

        Per-hop timing is a child span per shard RPC; the SAME chunk
        trace context rides every request meta (one shared value keeps
        the build-once frame sharing intact), and each shard's reply
        carries its server child span back (DESIGN.md §9.2)."""
        t0 = time.perf_counter()
        span.set("path", "fanout")
        sizes = self._slice_sizes(pin.num_points)
        # the plan_overfetch budget formula over pinned slice sizes
        slack = ceil16(len(pin.main_dead)) if pin.main_dead else 0
        h_fetch = [min(h + slack, sz) for sz in sizes]
        q_arrays = {"q_dims": qd, "q_vals": qv, "q_dense": qe}
        ctx = span.wire_context()
        dmeta_req = {"part": "delta", "gen": pin.gen, "h": int(h),
                     "alpha": int(alpha), "beta": int(beta),
                     "have_epoch": pin.epoch, "have_term": pin.term}
        metas = [{"part": "main", "gen": pin.gen, "h": int(hf),
                  "alpha": int(alpha), "beta": int(beta)}
                 for hf in h_fetch]
        if ctx:
            dmeta_req["trace"] = ctx
            for m in metas:
                m["trace"] = ctx
        if self.lockstep:
            hspans = [span.child("rpc", peer=c.addr, part="main")
                      for c in self.scorers]
            dspan = span.child("rpc", peer=self.primary.addr,
                               part="delta")
            futs = [self._pool.submit(c.call, "search", m, q_arrays,
                                      span=hs)
                    for c, m, hs in zip(self.scorers, metas, hspans)]
            dfut = self._pool.submit(self.primary.call, "search",
                                     dmeta_req, q_arrays, span=dspan)
            mains = [f.result() for f in futs]
            dmeta, darr = dfut.result()
            for (rm, _), hs in zip(mains, hspans):
                self._finish_hop(hs, rm)
            self._finish_hop(dspan, dmeta)
        else:
            # pipelined: every request on the wire before any reply is
            # read; one pre-built frame shared by every scorer with the
            # same fetch depth (serialize the query batch ONCE); the
            # per-client coalescer may fold concurrent chunks' requests
            # into msearch frames
            frames: dict[int, bytes] = {}
            entries, hspans = [], []
            for c, m, hf in zip(self.scorers, metas, h_fetch):
                fr = frames.get(hf)
                if fr is None:
                    fr = frames[hf] = build_frame("search", m, q_arrays)
                hspans.append(span.child("rpc", peer=c.addr,
                                         part="main"))
                entries.append(c.submit_search(m, q_arrays, frame=fr))
            dspan = span.child("rpc", peer=self.primary.addr,
                               part="delta")
            dentry = self.primary.submit_search(dmeta_req, q_arrays)
            mains = []
            for c, m, en, hs in zip(self.scorers, metas, entries,
                                    hspans):
                rm, ra = self._collect(c, en, "search", m, q_arrays,
                                       span=hs)
                mains.append((rm, ra))
                self._finish_hop(hs, rm)
            dmeta, darr = self._collect(self.primary, dentry, "search",
                                        dmeta_req, q_arrays, span=dspan)
            self._finish_hop(dspan, dmeta)

        # adopt / confirm the authoritative liveness state
        with self._lock:
            self._fence_term(int(dmeta.get("term", 0)))
        # a frozen-generation reply means another router compacted since
        # this chunk pinned: the frozen state misses every post-flip
        # mutation, so re-learn the cluster and retry instead of serving
        # it (the StaleGeneration retry loop in ``_search_pinned``)
        cur_g = int(dmeta.get("current_gen", pin.gen))
        if cur_g != pin.gen:
            raise RemoteError(
                f"StaleGeneration: generation {pin.gen} is frozen — the "
                f"cluster has compacted to generation {cur_g}")
        live = int(dmeta["live"])
        if dmeta.get("sync"):
            auth_md = frozenset(
                int(x) for x in darr["sync_main_dead"].tolist())
            auth_fd = frozenset(
                int(x) for x in darr["sync_fully_deleted"].tolist())
            if int(dmeta.get("epoch", 0)) > 0:    # 0 = frozen prev-gen
                with self._lock:
                    self._adopt_auth(pin.gen, int(dmeta["term"]),
                                     int(dmeta["epoch"]), set(auth_md),
                                     set(auth_fd), live)
        else:
            auth_md, auth_fd = pin.main_dead, pin.fully_deleted

        # re-deepen under-budgeted main fetches against the authoritative
        # dead set
        need = ceil16(len(auth_md)) if auth_md else 0
        if need > slack:
            for k, sz in enumerate(sizes):
                hf2 = min(h + need, sz)
                if hf2 > h_fetch[k]:
                    m2 = dict(metas[k], h=int(hf2))
                    hs2 = span.child("rpc", peer=self.scorers[k].addr,
                                     part="main-redeepen")
                    rm, ra = self.scorers[k].call("search", m2, q_arrays,
                                                  span=hs2)
                    self._finish_hop(hs2, rm)
                    mains[k] = (rm, ra)

        # assemble parts exactly as the in-process fanout_search does:
        # scorer slices in row order (filtered), delta last (unfiltered)
        parts = []
        for rm, ra in mains:
            parts.append((np.asarray(ra["scores"])[:qn],
                          np.asarray(ra["ids"]).astype(np.int64)[:qn],
                          True))
        if live > 0:
            parts.append((np.asarray(darr["scores"])[:qn],
                          np.asarray(darr["ids"]).astype(np.int64)[:qn],
                          False))
        t_m = time.perf_counter()
        s, ids = merge_topk_host(parts, h, drop_ids=auth_md,
                                 dedup_upserts=True)
        self._merge_timed(span, t_m)
        span.set("wall_s", time.perf_counter() - t0)
        with self._lock:
            self.stats["primary_reads"] += qn
        return s, ids

    def _try_replicas(self, pin, qd, qv, qe, qn, h, alpha, beta, floor,
                      span=NULL_SPAN):
        """Serve the chunk from the first eligible replica, or None.
        Eligibility is checked from the cached applied seq (refreshing
        via a status poll when stale) BEFORE the search RPC, and enforced
        again on the response tag — a replica below the floor never
        serves the read (DESIGN.md §8.4).  The overfetch budget covers
        the UNION of both cached dead sets: the merge drops the
        ``fully_deleted`` overlay from the replica's parts too, so
        budgeting from ``main_dead`` alone could truncate the merged
        top-k below h (the replica adds its own self-slack for kills this
        router has not seen)."""
        dead = pin.main_dead | pin.fully_deleted
        h_fetch = min(h + (ceil16(len(dead)) if dead else 0),
                      pin.num_points)
        ctx = span.wire_context()
        for i, rep in enumerate(self.replicas):
            hs = span.child("rpc", peer=rep.addr, part="full",
                            replica=i)
            try:
                if self._replica_seq[i] < floor:
                    st, _ = rep.call("status")
                    with self._lock:
                        self._replica_seq[i] = int(st["applied_seq"])
                    if self._replica_seq[i] < floor or \
                            int(st["gen"]) != pin.gen:
                        with self._lock:
                            self.stats["excluded_stale"] += 1
                        hs.annotate("excluded_stale")
                        hs.end()
                        continue
                req = {"part": "full", "gen": pin.gen,
                       "h": int(h_fetch), "alpha": int(alpha),
                       "beta": int(beta)}
                if ctx:
                    req["trace"] = ctx
                meta, arrays = rep.call(
                    "search", req,
                    {"q_dims": qd, "q_vals": qv, "q_dense": qe},
                    span=hs)
            except (ShardUnavailableError, ConnectionError, RemoteError):
                hs.annotate("replica_unreachable")
                hs.end()
                continue
            with self._lock:
                self._replica_seq[i] = int(meta["applied_seq"])
                # a lagging replica legitimately reports an old term —
                # adopt newer terms, never refuse follower reads over it
                self.term = max(self.term, int(meta.get("term", 0)))
            if int(meta["applied_seq"]) < floor or \
                    int(meta["gen"]) != pin.gen:
                with self._lock:
                    self.stats["excluded_stale"] += 1
                hs.annotate("excluded_stale")
                hs.end()
                continue
            # merge the replica's consistent-prefix parts under the
            # router's view: its own main tombstones (its prefix's
            # upsert/delete kills) plus fully_deleted on BOTH parts — a
            # stale tombstone view can hide nothing and resurrect nothing
            self._finish_hop(hs, meta)
            span.set("path", "replica")
            drop_main = set(arrays["main_tombstones"].tolist())
            drop_main.update(pin.fully_deleted)
            parts = [(arrays["ms"][:qn], arrays["mi"][:qn],
                      np.asarray(sorted(drop_main), np.int64))]
            if "ds" in arrays:
                parts.append((arrays["ds"][:qn], arrays["di"][:qn],
                              np.asarray(sorted(pin.fully_deleted),
                                         np.int64)))
            t_m = time.perf_counter()
            s, ids = merge_topk_host(parts, h)
            self._merge_timed(span, t_m)
            with self._lock:
                self.stats["replica_reads"] += qn
            return s, ids
        return None

    # -- introspection ----------------------------------------------------

    def hops(self) -> dict:
        """Cumulative per-stage hop seconds — ``{"serialize_s",
        "wire_s", "queue_s", "score_s", "merge_s"}`` — folded from every
        finished hop span (searches AND mutations).  Span-sourced: the
        registry counters behind this are only written by
        ``_finish_hop``/``_merge_timed`` (DESIGN.md §9.2)."""
        return {k: c.value for k, c in self._hop_c.items()}

    def metrics(self) -> dict:
        """JSON-ready snapshot of the router's metrics registry."""
        return self.obs.metrics.snapshot()

    def status(self) -> dict:
        """Router-side cluster view: generation, corpus size, cached
        liveness-set sizes + their validating tag, last acked seq,
        per-replica applied seqs, and the read/failover counters."""
        with self._lock:
            g = self.gen
            a = self._auth.get(g)
            return {"gen": g, "num_points": self._num_points,
                    "term": self.term,
                    "epoch": a.epoch if a else -1,
                    "main_dead": len(a.main_dead) if a else 0,
                    "fully_deleted": len(a.fully_deleted) if a else 0,
                    "delta_live": a.delta_live if a else 0,
                    "last_seq": self._last_seq,
                    "replica_seq": list(self._replica_seq),
                    **self.stats}

    def close(self) -> None:
        """Close every client socket and the fan-out pool (idempotent)."""
        self._pool.shutdown(wait=False)
        for c in [self.primary, *self.scorers, *self.replicas]:
            c.close()
