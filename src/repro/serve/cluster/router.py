"""Cluster router: bucketed fan-out over RPC shards + authoritative
host-side merge (DESIGN.md §8.2, §8.4) — the cross-host form of
``QueryService``'s in-process fan-out, sharing its actual machinery:
``bucket_for``/``pad_rows`` for micro-batching, ``plan_overfetch`` for
tombstone slack, ``fanout_search``/``merge_topk_host`` for the merge.

Topology: N ``scorer`` servers each hold one contiguous row slice of the
ONE build (bit-identity depends on that — frozen artifacts are global,
rows are sliced); the ``primary`` owns mutations and serves the delta
part; ``replica`` followers serve whole-query parts for follower reads
and failover.  The merge order is ``[scorer 0 … scorer S-1, delta]`` —
exactly the in-process ``[main shards…, delta]`` — so stable-sort
tie-breaking, and therefore every bit of every result, matches the
single-process service.

Tombstones are filtered HERE, from the router's authoritative per-
generation view (accumulated from mutation acks), never from a shard's
possibly-stale view — the ``merge_topk_host`` per-part drop fix this PR
pins: a lagging replica cannot resurrect a deleted id because the router
overlays ``fully_deleted`` on the replica's parts at merge time
(DESIGN.md §8.4).

Read-your-writes: every mutation ack carries its WAL seq; a ``Session``
records the max as its watermark, and follower reads are only served by a
replica whose ``applied_seq`` covers it — otherwise the router falls back
to the primary path.  A replica behind ``last acked seq - replica_max_lag``
is excluded from routing entirely until it catches up.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.distributed import ceil16, merge_topk_host
from repro.core.sparse_index import (CompactColumns,
                                     sparse_queries_to_padded)
from repro.core.streaming import fanout_search, plan_overfetch
from repro.serve.query_service import DEFAULT_BUCKETS, bucket_for, pad_rows

from .client import (RemoteDeltaEngine, RemoteMainEngine, ShardClient,
                     ShardUnavailableError)
from .protocol import RemoteError

__all__ = ["ClusterRouter", "Session", "DegradedResultError"]


class DegradedResultError(RuntimeError):
    """A shard needed for a full-fidelity answer is unreachable and no
    caught-up replica can stand in.  Raised INSTEAD of merging whatever
    parts survived: a silently truncated top-k is a wrong answer that
    looks right, which the fault-injection suite forbids."""


@dataclasses.dataclass
class Session:
    """Read-your-writes handle: ``watermark`` is the WAL seq of this
    session's last acked write; reads made with the session are only
    served by state that has applied at least that seq."""
    watermark: int = 0

    def observe(self, seq: int) -> None:
        """Fold an acked write's seq into the watermark."""
        self.watermark = max(self.watermark, int(seq))


def _addr(spec: str) -> tuple[str, int]:
    host, port = spec.rsplit(":", 1)
    return host, int(port)


class ClusterRouter:
    """Client-side coordinator for one shard cluster.

    ``primary``/``scorers``/``replicas`` are ``host:port`` endpoints (see
    ``local.LocalCluster`` for a one-call launcher).  Searches take raw
    scipy sparse queries (``search_sparse``) or pre-padded compact-space
    batches (``search``); mutations go to the primary and their acks feed
    the router's authoritative tombstone/watermark state; ``compact()``
    orchestrates the cluster-wide generation flip."""

    def __init__(self, primary: str, scorers: list[str],
                 replicas: list[str] = (), *, h: int = 10,
                 alpha: int | None = None, beta: int | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 prefer_replica: bool = False, replica_max_lag: int = 0,
                 timeout: float = 60.0):
        self.primary = ShardClient(*_addr(primary), timeout=timeout)
        self.scorers = [ShardClient(*_addr(a), timeout=timeout)
                        for a in scorers]
        self.replicas = [ShardClient(*_addr(a), timeout=timeout)
                         for a in replicas]
        self.buckets = buckets
        self.prefer_replica = prefer_replica
        self.replica_max_lag = replica_max_lag
        self._lock = threading.RLock()
        self._pool = ThreadPoolExecutor(
            max_workers=max(2, len(self.scorers) + 1),
            thread_name_prefix="router-fanout")
        info, arrays = self.primary.call("info")
        self.gen = int(info["gen"])
        self.h = h
        self.alpha = int(info["alpha"] if alpha is None else alpha)
        self.beta = int(info["beta"] if beta is None else beta)
        self._num_points = int(info["num_points"])
        self._d_active = int(info["d_active"])
        self._nq_max = int(info["nq_max"])
        self._cols = CompactColumns(global_ids=arrays["cols_global_ids"])
        self._main_dead = {self.gen: set(arrays["main_tombstones"].tolist())}
        self._fully_deleted = {self.gen: set()}
        self._delta_live = {self.gen: int(info["delta_live"])}
        self._last_seq = int(info["applied_seq"])
        self._replica_seq = [(-1) for _ in self.replicas]
        self.stats = {"primary_reads": 0, "replica_reads": 0,
                      "failovers": 0, "degraded": 0, "stale_retries": 0,
                      "excluded_stale": 0, "queries": 0}
        self.hop_s = {"serialize": 0.0, "wire": 0.0, "score": 0.0,
                      "merge": 0.0}

    # -- sessions ---------------------------------------------------------

    def session(self) -> Session:
        """A fresh read-your-writes session (watermark 0 = any state)."""
        return Session()

    # -- mutations (primary only) -----------------------------------------

    def _ack(self, meta: dict, *, main_killed, resurrected=(),
             fully_killed=(), session: Session | None) -> None:
        """Fold one mutation ack into the authoritative per-generation
        tombstone view + watermark state.  Acks are generation-tagged by
        the primary, so one racing a compaction lands in the right
        epoch's sets (the flip preserves already-accumulated entries)."""
        with self._lock:
            g = int(meta["gen"])
            self._main_dead.setdefault(g, set()).update(
                int(e) for e in main_killed)
            fd = self._fully_deleted.setdefault(g, set())
            fd.update(int(e) for e in fully_killed)
            fd.difference_update(int(e) for e in resurrected)
            self._delta_live[g] = int(meta["delta_live"])
            self._last_seq = max(self._last_seq, int(meta["seq"]))
        if session is not None and meta["seq"]:
            session.observe(meta["seq"])

    def insert(self, x_sparse, x_dense, ids=None,
               session: Session | None = None) -> np.ndarray:
        """Insert (or upsert) rows via the primary; returns the assigned
        external ids.  Acked only after the primary's WAL covers the batch
        (its group-commit discipline); the ack's ``main_killed`` ids feed
        the router's tombstone view and its seq the session watermark."""
        import scipy.sparse as sp
        xs = sp.csr_matrix(x_sparse)
        arrays = {"data": xs.data, "indices": xs.indices,
                  "indptr": xs.indptr,
                  "shape": np.asarray(xs.shape, np.int64),
                  "dense": np.atleast_2d(np.asarray(x_dense, np.float32))}
        if ids is not None:
            arrays["ids"] = np.atleast_1d(np.asarray(ids, np.int64))
        meta, arr = self.primary.call("insert", arrays=arrays, retry=False)
        assigned = arr["ids"]
        self._ack(meta, main_killed=arr["main_killed"],
                  resurrected=assigned.tolist(), session=session)
        return assigned

    def delete(self, ids, session: Session | None = None) -> int:
        """Tombstone rows by external id via the primary; returns #killed.
        The ack's killed ids join BOTH router sets: ``main_dead`` (drop
        from scorer parts) and ``fully_deleted`` (the overlay that stops a
        lagging replica resurrecting them, DESIGN.md §8.4)."""
        meta, arr = self.primary.call(
            "delete", arrays={"ids": np.atleast_1d(np.asarray(ids,
                                                              np.int64))},
            retry=False)
        self._ack(meta, main_killed=arr["main_killed"],
                  fully_killed=arr["killed_ids"].tolist(), session=session)
        return int(meta["killed"])

    # -- compaction (cluster-wide generation flip) ------------------------

    def compact(self, retrain: bool | None = None) -> int:
        """Orchestrate a cluster compaction: pause replica shipping, fold
        delta + tombstones at the primary (cut as a durable checkpoint),
        have every scorer/replica reload the new store, then atomically
        flip the router's generation + reset its tombstone epoch.  Old-
        generation searches keep working mid-flip (servers hold the last
        two generations); new-generation state starts clean.  Returns the
        new generation number."""
        for r in self.replicas:
            r.call("fault", {"mode": "pause_shipping"})
        meta, arrays = self.primary.call("compact", {"retrain": retrain},
                                         retry=False)
        gen = int(meta["gen"])
        for s in self.scorers:
            s.call("reload", {"gen": gen})
        for r in self.replicas:
            r.call("reload", {"gen": gen})
        with self._lock:
            self.gen = gen
            self._num_points = int(meta["num_points"])
            self._d_active = int(meta["d_active"])
            self._cols = CompactColumns(
                global_ids=arrays["cols_global_ids"])
            # keep entries acks already accumulated FOR this generation
            # (a mutation can race the flip), drop every older epoch
            self._main_dead = {gen: self._main_dead.get(gen, set())}
            self._fully_deleted = {gen: self._fully_deleted.get(gen, set())}
            self._delta_live = {gen: self._delta_live.get(gen, 0)}
        return gen

    # -- search -----------------------------------------------------------

    def _slice_sizes(self, n: int) -> list[int]:
        """Row counts per scorer under the ragged ceil-split — must mirror
        ``split_index_arrays(..., ragged=True)`` exactly, since
        ``plan_overfetch`` budgets per-slice fetch depths from them."""
        s = len(self.scorers)
        base, rem = divmod(n, s)
        return [base + 1 if i < rem else base for i in range(s)]

    def _pin(self):
        """One consistent router-state snapshot (the cross-host analogue
        of ``QueryService._acquire_view``): generation, corpus size,
        column space, tombstone sets, delta liveness, last acked seq."""
        with self._lock:
            g = self.gen
            return (g, self._num_points, self._d_active, self._cols,
                    frozenset(self._main_dead.get(g, ())),
                    frozenset(self._fully_deleted.get(g, ())),
                    self._delta_live.get(g, 0), self._last_seq)

    def search_sparse(self, q_sparse, q_dense, *, h: int | None = None,
                      alpha: int | None = None, beta: int | None = None,
                      session: Session | None = None):
        """Serve RAW scipy sparse queries: encode against the pinned
        generation's compact column space (generation-bound, like
        ``QueryService.search_sparse``), then fan out.  Returns
        ``(scores (Q, h), ids (Q, h))`` in external ids."""
        gen_state = self._pin()
        cols, nq_max = gen_state[3], self._nq_max
        q_dims, q_vals = sparse_queries_to_padded(q_sparse, cols,
                                                  nq_max=nq_max)
        return self._search_pinned(gen_state,
                                   np.atleast_2d(np.asarray(q_dims,
                                                            np.int32)),
                                   np.atleast_2d(np.asarray(q_vals,
                                                            np.float32)),
                                   np.atleast_2d(np.asarray(q_dense,
                                                            np.float32)),
                                   h, alpha, beta, session)

    def search(self, q_dims, q_vals, q_dense, *, h: int | None = None,
               alpha: int | None = None, beta: int | None = None,
               session: Session | None = None):
        """Serve pre-padded compact-space query batches (generation-bound
        — streaming clients should prefer ``search_sparse``).  Returns
        ``(scores (Q, h), ids (Q, h))`` numpy arrays, bit-identical to the
        in-process ``QueryService`` fan-out on the same state."""
        return self._search_pinned(
            self._pin(),
            np.atleast_2d(np.asarray(q_dims, np.int32)),
            np.atleast_2d(np.asarray(q_vals, np.float32)),
            np.atleast_2d(np.asarray(q_dense, np.float32)),
            h, alpha, beta, session)

    def _search_pinned(self, gen_state, q_dims, q_vals, q_dense,
                       h, alpha, beta, session, _retries: int = 8):
        h = self.h if h is None else h
        alpha = self.alpha if alpha is None else alpha
        beta = self.beta if beta is None else beta
        qn_total = q_dims.shape[0]
        out_s = np.empty((qn_total, h), np.float32)
        out_i = np.empty((qn_total, h), np.int64)
        max_bucket = self.buckets[-1]
        for lo in range(0, qn_total, max_bucket):
            hi = min(lo + max_bucket, qn_total)
            for attempt in range(_retries):
                try:
                    s, ids = self._run_chunk(gen_state, q_dims[lo:hi],
                                             q_vals[lo:hi], q_dense[lo:hi],
                                             h, alpha, beta, session)
                    break
                except RemoteError as e:
                    if "StaleGeneration" not in str(e) \
                            or attempt + 1 >= _retries:
                        raise
                    # a compaction flipped generations mid-flight:
                    # re-pin and retry against the new epoch
                    with self._lock:
                        self.stats["stale_retries"] += 1
                    time.sleep(0.05)
                    gen_state = self._pin()
            out_s[lo:hi], out_i[lo:hi] = s, ids
        with self._lock:
            self.stats["queries"] += qn_total
        return out_s, out_i

    def _run_chunk(self, gen_state, q_dims, q_vals, q_dense, h, alpha,
                   beta, session):
        (gen, n, d_active, _cols, main_dead, fully_deleted, delta_live,
         last_seq) = gen_state
        qn = q_dims.shape[0]
        bucket = bucket_for(qn, self.buckets)
        qd = pad_rows(q_dims, bucket, fill=d_active)
        qv = pad_rows(q_vals, bucket)
        qe = pad_rows(q_dense, bucket)
        required = session.watermark if session is not None else 0
        floor = max(required, last_seq - self.replica_max_lag)

        if self.prefer_replica and self.replicas:
            res = self._try_replicas(gen, qd, qv, qe, qn, h, alpha, beta,
                                     main_dead, fully_deleted, floor)
            if res is not None:
                return res
        try:
            return self._primary_fanout(gen, qd, qv, qe, qn, h, alpha,
                                        beta, main_dead, delta_live)
        except (ShardUnavailableError, ConnectionError):
            with self._lock:
                self.stats["failovers"] += 1
            res = self._try_replicas(gen, qd, qv, qe, qn, h, alpha, beta,
                                     main_dead, fully_deleted, floor)
            if res is not None:
                return res
            with self._lock:
                self.stats["degraded"] += 1
            raise DegradedResultError(
                "a scoring shard is unreachable and no replica has "
                f"applied seq >= {floor}; refusing to return a silently "
                "truncated top-k") from None

    def _primary_fanout(self, gen, qd, qv, qe, qn, h, alpha, beta,
                        main_dead, delta_live):
        """The S-scorer + primary-delta path: the literal in-process merge
        (``plan_overfetch`` + ``fanout_search``) over remote engines."""
        t0 = time.perf_counter()
        engines = [RemoteMainEngine(c, generation=gen, num_points=sz)
                   for c, sz in zip(self.scorers,
                                    self._slice_sizes(self._pin_n(gen)))]
        h_fetch = plan_overfetch(engines, h, main_dead)
        delta = (RemoteDeltaEngine(self.primary, generation=gen,
                                   num_points=delta_live)
                 if delta_live > 0 else None)
        s, ids = fanout_search(
            engines, h_fetch, np.zeros(len(engines), np.int64), None,
            delta, None, main_dead, qd, qv, qe, h=h, alpha=alpha,
            beta=beta, qn=qn, executor=self._pool, dedup_upserts=True)
        self._account_hops([e for e in engines + ([delta] if delta else [])],
                           time.perf_counter() - t0, qn)
        with self._lock:
            self.stats["primary_reads"] += qn
        return s, ids

    def _pin_n(self, gen: int) -> int:
        with self._lock:
            return self._num_points

    def _try_replicas(self, gen, qd, qv, qe, qn, h, alpha, beta,
                      main_dead, fully_deleted, floor):
        """Serve the chunk from the first eligible replica, or None.
        Eligibility is checked from the cached applied seq (refreshing
        via a status poll when stale) BEFORE the search RPC, and enforced
        again on the response tag — a replica below the floor never
        serves the read (DESIGN.md §8.4)."""
        h_fetch = min(h + (ceil16(len(main_dead)) if main_dead else 0),
                      self._pin_n(gen))
        for i, rep in enumerate(self.replicas):
            try:
                if self._replica_seq[i] < floor:
                    st, _ = rep.call("status")
                    with self._lock:
                        self._replica_seq[i] = int(st["applied_seq"])
                    if self._replica_seq[i] < floor or \
                            int(st["gen"]) != gen:
                        with self._lock:
                            self.stats["excluded_stale"] += 1
                        continue
                meta, arrays = rep.call(
                    "search", {"part": "full", "gen": gen, "h": h_fetch,
                               "alpha": int(alpha), "beta": int(beta)},
                    {"q_dims": qd, "q_vals": qv, "q_dense": qe})
            except (ShardUnavailableError, ConnectionError, RemoteError):
                continue
            with self._lock:
                self._replica_seq[i] = int(meta["applied_seq"])
            if int(meta["applied_seq"]) < floor or int(meta["gen"]) != gen:
                with self._lock:
                    self.stats["excluded_stale"] += 1
                continue
            # merge the replica's consistent-prefix parts under the
            # router's AUTHORITATIVE overlay: its own main tombstones
            # (its prefix's upsert/delete kills) plus fully_deleted on
            # BOTH parts — a stale tombstone view can hide nothing and
            # resurrect nothing
            drop_main = set(arrays["main_tombstones"].tolist())
            drop_main.update(fully_deleted)
            parts = [(arrays["ms"][:qn], arrays["mi"][:qn],
                      np.asarray(sorted(drop_main), np.int64))]
            if "ds" in arrays:
                parts.append((arrays["ds"][:qn], arrays["di"][:qn],
                              np.asarray(sorted(fully_deleted), np.int64)))
            s, ids = merge_topk_host(parts, h)
            with self._lock:
                self.stats["replica_reads"] += qn
            return s, ids
        return None

    # -- introspection ----------------------------------------------------

    def _account_hops(self, engines, chunk_wall: float, qn: int) -> None:
        walls, sends, scores = [], [], []
        for e in engines:
            walls.append(getattr(e.client, "last_wall_s", 0.0))
            sends.append(getattr(e.client, "last_send_s", 0.0))
            scores.append(float(e.last_meta.get("score_s", 0.0)))
        with self._lock:
            self.hop_s["serialize"] += sum(sends)
            self.hop_s["score"] += sum(scores)
            self.hop_s["wire"] += max(
                0.0, sum(walls) - sum(sends) - sum(scores))
            self.hop_s["merge"] += max(0.0, chunk_wall - max(walls,
                                                             default=0.0))

    def status(self) -> dict:
        """Router-side cluster view: generation, corpus size, tombstone
        counts, delta liveness, last acked seq, per-replica applied seqs,
        and the read/failover counters."""
        with self._lock:
            g = self.gen
            return {"gen": g, "num_points": self._num_points,
                    "main_dead": len(self._main_dead.get(g, ())),
                    "fully_deleted": len(self._fully_deleted.get(g, ())),
                    "delta_live": self._delta_live.get(g, 0),
                    "last_seq": self._last_seq,
                    "replica_seq": list(self._replica_seq),
                    **self.stats}

    def close(self) -> None:
        """Close every client socket and the fan-out pool (idempotent)."""
        self._pool.shutdown(wait=False)
        for c in [self.primary, *self.scorers, *self.replicas]:
            c.close()
