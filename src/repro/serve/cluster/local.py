"""Local cluster launcher: real subprocess shard servers on loopback
sockets (DESIGN.md §8.2) — what the equivalence/fault tests, the cluster
benchmark, and ``repro.launch.serve --role router`` all stand on.

``LocalCluster.launch(index, root)`` bootstraps a durable store from a
built index, spawns one primary + N scorers (+ optional replicas) as
separate Python processes, scrapes each child's ``READY <port>`` line,
and hands out ``ClusterRouter``s.  Processes are REAL processes on
purpose: kill -9 in the fault suite must kill an OS process mid-stream,
not a thread pretending to be one.
"""

from __future__ import annotations

import os
import signal
import subprocess
import sys
import time

__all__ = ["LocalCluster", "NodeHandle"]

_READY_TIMEOUT_S = 180.0


def _src_path() -> str:
    import repro
    pkg_dir = (os.path.dirname(repro.__file__) if repro.__file__
               else list(repro.__path__)[0])   # namespace package
    return os.path.dirname(os.path.abspath(pkg_dir))


class NodeHandle:
    """One spawned shard-server process: its role, bound port, and the
    Popen handle (``kill()`` delivers SIGKILL — the fault suite's
    mid-stream crash)."""

    def __init__(self, name: str, role: str, proc: subprocess.Popen,
                 port: int, log_path: str):
        self.name = name
        self.role = role
        self.proc = proc
        self.port = port
        self.log_path = log_path

    @property
    def addr(self) -> str:
        """Loopback ``host:port`` endpoint of this node."""
        return f"127.0.0.1:{self.port}"

    def kill(self) -> None:
        """SIGKILL the process (no shutdown handshake — the crash the
        fault-injection tests need) and reap it."""
        if self.proc.poll() is None:
            self.proc.send_signal(signal.SIGKILL)
        self.proc.wait()

    def alive(self) -> bool:
        """True while the process has not exited."""
        return self.proc.poll() is None


class LocalCluster:
    """Owner of one locally spawned cluster (primary + scorers +
    replicas).  Use as a context manager — ``close()`` SIGKILLs whatever
    is still running.  ``launch`` is the one-call path from a built
    mutable index; ``__init__`` attaches to an existing store root."""

    def __init__(self, root: str, *, num_scorers: int = 2,
                 num_replicas: int = 0, backend: str | None = None):
        self.root = root
        self.backend = backend
        self.num_scorers = num_scorers
        self.primary: NodeHandle | None = None
        self.scorers: list[NodeHandle] = []
        self.replicas: list[NodeHandle] = []
        os.makedirs(os.path.join(root, "logs"), exist_ok=True)
        self.primary = self._spawn("primary", "primary",
                                   store=os.path.join(root, "store"))
        for s in range(num_scorers):
            self.scorers.append(self._spawn(
                f"scorer-{s}", "scorer", shard=s,
                workdir=os.path.join(root, f"scorer-{s}")))
        for r in range(num_replicas):
            self.replicas.append(self._spawn(
                f"replica-{r}", "replica",
                store=os.path.join(root, f"replica-{r}", "store")))

    @classmethod
    def launch(cls, index, root: str, *, num_scorers: int = 2,
               num_replicas: int = 0,
               backend: str | None = None) -> "LocalCluster":
        """Bootstrap ``root/store`` from a freshly built mutable index
        (initial snapshot + empty WAL, handle closed so the primary
        subprocess owns the log), then spawn the cluster."""
        index.save(os.path.join(root, "store"))
        return cls(root, num_scorers=num_scorers,
                   num_replicas=num_replicas, backend=backend)

    def _spawn(self, name: str, role: str, *, store: str | None = None,
               workdir: str | None = None, shard: int = 0) -> NodeHandle:
        cmd = [sys.executable, "-m", "repro.serve.cluster.shard_server",
               "--role", role, "--port", "0"]
        if role == "primary":
            cmd += ["--store", store]
        elif role == "scorer":
            os.makedirs(workdir, exist_ok=True)
            cmd += ["--peer", self.primary.addr, "--shard", str(shard),
                    "--num-shards", str(self.num_scorers),
                    "--workdir", workdir]
        else:
            os.makedirs(os.path.dirname(store), exist_ok=True)
            cmd += ["--peer", self.primary.addr, "--store", store]
        if self.backend:
            cmd += ["--backend", self.backend]
        env = dict(os.environ)
        env["PYTHONPATH"] = _src_path() + os.pathsep + \
            env.get("PYTHONPATH", "")
        env["PYTHONUNBUFFERED"] = "1"
        log_path = os.path.join(self.root, "logs", f"{name}.log")
        log = open(log_path, "ab")
        proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, stderr=log,
                                env=env, text=True)
        port = self._wait_ready(name, proc, log_path)
        return NodeHandle(name, role, proc, port, log_path)

    @staticmethod
    def _wait_ready(name: str, proc: subprocess.Popen,
                    log_path: str) -> int:
        deadline = time.monotonic() + _READY_TIMEOUT_S
        while True:
            line = proc.stdout.readline()
            if line.startswith("READY "):
                return int(line.split()[1])
            if proc.poll() is not None or not line:
                with open(log_path) as f:
                    tail = f.read()[-2000:]
                raise RuntimeError(
                    f"shard server {name} died during startup:\n{tail}")
            if time.monotonic() > deadline:
                proc.kill()
                raise RuntimeError(f"shard server {name} never reported "
                                   "READY")

    # -- topology ---------------------------------------------------------

    def router(self, **kw):
        """A fresh ``ClusterRouter`` over this cluster's endpoints."""
        from .router import ClusterRouter
        return ClusterRouter(self.primary.addr,
                             [s.addr for s in self.scorers],
                             [r.addr for r in self.replicas], **kw)

    def kill_primary(self) -> None:
        """SIGKILL the primary mid-whatever-it-was-doing — the failover
        suite's inciting incident.  The handle stays in the topology (a
        router holding its address gets ``ShardUnavailableError``); use
        ``ClusterRouter.failover()`` to promote a replica in its place."""
        self.primary.kill()

    def kill_scorer(self, i: int) -> None:
        """SIGKILL scorer ``i`` (it stays in the topology — routers that
        contact it get ``ShardUnavailableError`` and fail over)."""
        self.scorers[i].kill()

    def kill_replica(self, i: int) -> None:
        """SIGKILL replica ``i`` mid-whatever-it-was-doing."""
        self.replicas[i].kill()

    def restart_replica(self, i: int) -> NodeHandle:
        """Respawn replica ``i`` on its EXISTING store directory — the
        restart-mid-ingest recovery path: local snapshot + shipped WAL
        tail, then shipping resumes from the exact applied seq."""
        old = self.replicas[i]
        old.kill()
        self.replicas[i] = self._spawn(
            old.name, "replica",
            store=os.path.join(self.root, old.name, "store"))
        return self.replicas[i]

    def close(self) -> None:
        """SIGKILL every node still running (idempotent)."""
        for h in [*self.scorers, *self.replicas,
                  *([self.primary] if self.primary else [])]:
            try:
                h.kill()
            except Exception:
                pass

    def __enter__(self) -> "LocalCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
