"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Training uses the chunked SSD algorithm: within-chunk terms are dense
"attention-like" matmuls (MXU-friendly), across-chunk terms are a linear
recurrence over chunk summary states (lax.scan, O(S/chunk) steps).  Decode
is the O(1) recurrent update.

Layout (n_groups = 1):
  in_proj : D -> [z (d_in), xBC (d_in + 2N), dt (H)]
  conv1d  : causal depthwise width-4 over xBC
  SSD     : x (B,S,H,P), dt (B,S,H), A (H,) neg., b,c (B,S,N)
  out     : y * silu(z) -> RMSNorm -> out_proj (d_in -> D)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, logical_constraint, rms_norm


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_headdim
    return d_in, heads, cfg.ssm_state, cfg.ssm_headdim


def init_ssd(key, cfg) -> dict:
    d = cfg.d_model
    d_in, h, n, p = _dims(cfg)
    ks = jax.random.split(key, 4)
    conv_ch = d_in + 2 * n
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * n + h)),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch),
                                          jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": dense_init(ks[3], (d_in, d)) / (2.0 * cfg.num_layers) ** 0.5,
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x (B,S,C); w (K,C).  state (B,K-1,C) for decode.
    Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
            for i in range(k))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad
    return y, new_state


def _split_proj(proj, cfg):
    d_in, h, n, p = _dims(cfg)
    z = proj[..., :d_in]
    xbc = proj[..., d_in:2 * d_in + 2 * n]
    dt = proj[..., 2 * d_in + 2 * n:]
    return z, xbc, dt


def ssd_chunked(x, dt, a, b, c, chunk: int):
    """SSD scan.  x (B,S,H,P); dt (B,S,H); a (H,) negative; b,c (B,S,N).
    Returns (B,S,H,P) and final state (B,H,P,N)."""
    bt, s, h, p = x.shape
    n = b.shape[-1]
    lc = min(chunk, s)
    s_orig = s
    if s % lc:
        # right-pad with dt = 0 tokens: zero state contribution, decay 1 —
        # outputs for real positions and the final state are unchanged.
        pad = lc - s % lc
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // lc

    xd = x * dt[..., None]                                  # dt-weighted input
    la = a[None, None, :] * dt                              # log-decay per token
    xc = xd.reshape(bt, nc, lc, h, p)
    lac = la.reshape(bt, nc, lc, h)
    bc = b.reshape(bt, nc, lc, n)
    cc = c.reshape(bt, nc, lc, n)

    cum = jnp.cumsum(lac, axis=2)                           # (B,nc,Lc,H)

    # ---- intra-chunk (quadratic, masked decay kernel) ----------------------
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,Li,Lj,H)
    iq = jax.lax.broadcasted_iota(jnp.int32, (1, 1, lc, lc, 1), 2)
    ik = jax.lax.broadcasted_iota(jnp.int32, (1, 1, lc, lc, 1), 3)
    decay = jnp.where(iq >= ik, jnp.exp(diff), 0.0)         # (B,nc,Li,Lj,H)
    scores = jnp.einsum("bkin,bkjn->bkij", cc, bc)          # (B,nc,Li,Lj)
    y_intra = jnp.einsum("bkij,bkijh,bkjhp->bkihp",
                         scores, decay.astype(scores.dtype), xc)

    # ---- chunk summary states ----------------------------------------------
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                 # decay to chunk end
    state_k = jnp.einsum("bkjn,bkjh,bkjhp->bkhpn",
                         bc, tail.astype(bc.dtype), xc)     # (B,nc,H,P,N)
    total = jnp.exp(cum[:, :, -1, :])                       # (B,nc,H)

    # ---- inter-chunk recurrence --------------------------------------------
    def step(s_prev, inp):
        st, tot = inp                                       # (B,H,P,N), (B,H)
        s_new = s_prev * tot[:, :, None, None] + st
        return s_new, s_prev                                # emit state BEFORE

    s0 = jnp.zeros((bt, h, p, n), x.dtype)
    s_last, s_before = jax.lax.scan(
        step, s0, (state_k.transpose(1, 0, 2, 3, 4),
                   total.transpose(1, 0, 2).astype(x.dtype)))
    s_before = s_before.transpose(1, 0, 2, 3, 4)            # (B,nc,H,P,N)

    pre = jnp.exp(cum)                                      # decay from start
    y_inter = jnp.einsum("bkin,bkih,bkhpn->bkihp",
                         cc, pre.astype(cc.dtype), s_before)
    y = (y_intra + y_inter).reshape(bt, s, h, p)
    return y[:, :s_orig], s_last


def ssd_block(x, p, cfg, return_state: bool = False):
    """Full Mamba2 block (train/prefill).  x (B,S,D) -> (B,S,D)."""
    dtype = x.dtype
    d_in, h, n, hd = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dtype))
    z, xbc_raw, dt = _split_proj(proj, cfg)
    xbc, _ = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    conv_tail = xbc_raw[:, -(cfg.ssm_conv - 1):, :]   # pre-activation stream
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(*x.shape[:2], h, hd)
    b = xbc[..., d_in:d_in + n]
    c = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"]).astype(dtype)       # (B,S,H)
    dt = logical_constraint(dt, "batch", "seq", "heads")
    a = -jnp.exp(p["a_log"]).astype(dtype)                   # (H,) negative
    xs = logical_constraint(xs, "batch", "seq", "heads", None)
    y, s_last = ssd_chunked(xs, dt, a, b, c, cfg.ssm_chunk)
    y = y + xs * p["d_skip"].astype(dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:2], d_in)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dtype))
    out = logical_constraint(out, "batch", "seq", "embed")
    if return_state:
        return out, {"conv": conv_tail, "ssm": s_last}
    return out


def ssd_decode_init(cfg, batch: int, dtype) -> dict:
    d_in, h, n, hd = _dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, h, hd, n), dtype),
    }


def ssd_decode_step(x, p, cfg, state):
    """x (B,1,D) -> (B,1,D); O(1) state update."""
    dtype = x.dtype
    d_in, h, n, hd = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dtype))
    z, xbc, dt = _split_proj(proj, cfg)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state=state["conv"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(x.shape[0], h, hd)          # (B,H,P)
    b = xbc[:, 0, d_in:d_in + n]                             # (B,N)
    c = xbc[:, 0, d_in + n:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"]).astype(dtype)       # (B,H)
    a = -jnp.exp(p["a_log"]).astype(dtype)
    decay = jnp.exp(a[None] * dt)                            # (B,H)
    s_new = (state["ssm"] * decay[:, :, None, None]
             + jnp.einsum("bhp,bn,bh->bhpn", xs, b, dt))
    y = jnp.einsum("bhpn,bn->bhp", s_new, c)
    y = y + xs * p["d_skip"].astype(dtype)[None, :, None]
    y = y.reshape(x.shape[0], 1, d_in)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dtype))
    return out, {"conv": conv_state, "ssm": s_new}
