"""Shared model plumbing: logical-axis sharding, norms, RoPE, init helpers.

Sharding follows the MaxText pattern: model code annotates tensors with
*logical* axis names; a context-installed rule set maps them to mesh axes.
With no rules installed (single-device CPU tests) every annotation is a no-op.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_STATE = threading.local()

# logical axis -> mesh axis (or tuple). Installed by launch/mesh.py.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",          # demoted to None when heads % shards != 0
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "capacity": None,
    "fsdp": "data",               # parameter sharding axis
    "kv_seq": "model",            # decode-time KV cache sequence sharding
    "state": "model",             # recurrent state width
    "cond": None,
    "moe_tokens": "model",        # MoE dispatch token axis (EP all-to-all)
}


@contextlib.contextmanager
def sharding_rules(mesh, rules: dict | None = None):
    """Install (mesh, rules) so logical_constraint becomes effective."""
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, dict(DEFAULT_RULES, **(rules or {})))
    try:
        yield
    finally:
        _STATE.ctx = prev


def resolve_spec(mesh, rules, names, shape) -> P:
    """Map logical axis names -> PartitionSpec, claiming each mesh axis at
    most once and *only* when it divides the dimension (so fallbacks like
    28 heads on a 16-way model axis degrade to replication, and a later
    logical axis may claim the freed mesh axis)."""
    axes = []
    used: set[str] = set()
    for nm, dim in zip(names, shape):
        ax = rules.get(nm) if nm is not None else None
        if ax is None:
            axes.append(None)
            continue
        cand = []
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            if a in mesh.axis_names and a not in used:
                cand.append(a)
                size *= mesh.shape[a]
        # greedy shrink until it divides
        while cand and (dim % size != 0 or dim < size):
            size //= mesh.shape[cand.pop()]
        used.update(cand)
        axes.append(tuple(cand) if len(cand) > 1 else
                    (cand[0] if cand else None))
    return P(*axes)


def current_mesh():
    """Mesh installed by sharding_rules (None outside a lowering context)."""
    ctx = getattr(_STATE, "ctx", None)
    return ctx[0] if ctx else None


def logical_constraint(x: jax.Array, *names: str | None) -> jax.Array:
    """with_sharding_constraint through the logical rule table (no-op without
    an installed mesh, or when a dim doesn't divide)."""
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_spec(mesh, rules, names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def logical_spec(mesh, shape, *names, rules: dict | None = None):
    """PartitionSpec for in_shardings/ShapeDtypeStruct (launch-side)."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    return resolve_spec(mesh, rules, names, shape)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, kind: str):
    if kind == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def init_norm(d: int, kind: str):
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}   # rms stored as (1+scale)


def rope(x: jax.Array, positions: jax.Array, theta: float,
         fraction: float = 1.0) -> jax.Array:
    """Rotary embedding on the leading `fraction` of head dims.

    x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None, None].astype(jnp.float32) * freq  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half], xr[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def dense_init(key, shape, in_axis: int = 0) -> jax.Array:
    """Truncated-normal fan-in init, fp32 master weights."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)


def activation(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)
