"""Model orchestration: layer patterns, scan-over-layers, embeddings, loss,
and decode-state threading for every architecture family.

A config's layer stack is a repeating *pattern* of layer types (e.g. Griffin:
(rglru, rglru, lattn)); parameters for each pattern position are stacked over
repeats and the stack is traversed with lax.scan (keeps HLO size O(pattern),
essential for 512-device dry-run compiles).  Remainder layers (when
num_layers % len(pattern) != 0) run unscanned after the scan body.

Layer types:
  self       GQA self-attention + gated MLP        (dense / vlm backbone)
  lattn      local-window GQA (+MLP)               (griffin attention layers)
  self_cross self-attn + cross-attn + MLP          (vlm image layers, musicgen)
  moe        self-attn + mixture-of-experts        (qwen-moe family)
  ssd        Mamba2 SSD block (no MLP)             (mamba2)
  rglru      RG-LRU recurrent block + MLP          (griffin recurrent layers)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mlp as mlp_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .common import (apply_norm, dense_init, init_norm, logical_constraint)

Params = Any


# ---------------------------------------------------------------------------
# patterns
# ---------------------------------------------------------------------------

def pattern_for(cfg) -> tuple[str, ...]:
    if cfg.family == "ssm":
        return ("ssd",)
    if cfg.family == "hybrid":
        return ("rglru", "rglru", "lattn")[: max(cfg.rglru_pattern, 1)]
    if cfg.family == "moe":
        return ("moe",)
    if cfg.family == "vlm":
        k = cfg.cross_attn_every
        return ("self",) * (k - 1) + ("self_cross",) if k > 1 else ("self_cross",)
    if cfg.family == "audio":
        return ("self_cross",)
    return ("self",)


def _uses_cond(cfg) -> bool:
    return any(t == "self_cross" for t in pattern_for(cfg))


# ---------------------------------------------------------------------------
# per-type init / apply / decode
# ---------------------------------------------------------------------------

def _init_layer(key, cfg, typ: str) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if typ == "ssd":
        return {"ln1": init_norm(d, cfg.norm),
                "ssd": ssm_mod.init_ssd(ks[0], cfg)}
    if typ == "rglru":
        return {"ln1": init_norm(d, cfg.norm),
                "rec": rglru_mod.init_rglru(ks[0], cfg),
                "ln2": init_norm(d, cfg.norm),
                "mlp": mlp_mod.init_mlp(ks[1], cfg)}
    p = {"ln1": init_norm(d, cfg.norm),
         "attn": attn.init_attention(ks[0], cfg)}
    if typ == "moe":
        p["ln2"] = init_norm(d, cfg.norm)
        p["moe"] = mlp_mod.init_moe(ks[1], cfg)
    else:
        p["ln2"] = init_norm(d, cfg.norm)
        p["mlp"] = mlp_mod.init_mlp(ks[1], cfg)
    if typ == "self_cross":
        p["lnx"] = init_norm(d, cfg.norm)
        p["xattn"] = attn.init_attention(ks[2], cfg, cross=True)
    return p


def _apply_layer(x, p, cfg, typ: str, cond_embed):
    aux = jnp.zeros((), jnp.float32)
    if typ == "ssd":
        return x + ssm_mod.ssd_block(apply_norm(x, p["ln1"], cfg.norm), cfg=cfg,
                                     p=p["ssd"]), aux
    if typ == "rglru":
        x = x + rglru_mod.rglru_block(apply_norm(x, p["ln1"], cfg.norm),
                                      p["rec"], cfg)
        x = x + mlp_mod.mlp(apply_norm(x, p["ln2"], cfg.norm), p["mlp"], cfg)
        return x, aux
    window = cfg.local_window if typ == "lattn" else 0
    a_out, _ = attn.self_attention(apply_norm(x, p["ln1"], cfg.norm),
                                   p["attn"], cfg, window=window,
                                   chunk=cfg.attn_chunk)
    x = x + a_out
    if typ == "self_cross":
        ckv = attn.cond_kv(cond_embed, p["xattn"], cfg)
        x = x + attn.cross_attention(apply_norm(x, p["lnx"], cfg.norm), ckv,
                                     p["xattn"], cfg)
    if typ == "moe":
        m_out, aux = mlp_mod.moe(apply_norm(x, p["ln2"], cfg.norm), p["moe"],
                                 cfg)
        x = x + m_out
    else:
        x = x + mlp_mod.mlp(apply_norm(x, p["ln2"], cfg.norm), p["mlp"], cfg)
    return x, aux


def _apply_layer_prefill(x, p, cfg, typ: str, cond_embed, max_len: int):
    """Forward one layer AND produce its decode state (teacher-forced
    prefill).  Matches _state_init_layer's structure exactly."""
    dtype = x.dtype
    b, s, _ = x.shape

    def pad_cache(t):
        out = jnp.zeros((b, max_len) + t.shape[2:], dtype)
        out = jax.lax.dynamic_update_slice(out, t.astype(dtype),
                                           (0, 0, 0, 0))
        return logical_constraint(out, "batch", "kv_seq", None, None)

    if typ == "ssd":
        out, st = ssm_mod.ssd_block(apply_norm(x, p["ln1"], cfg.norm),
                                    p["ssd"], cfg, return_state=True)
        return x + out, st
    if typ == "rglru":
        out, st = rglru_mod.rglru_block(apply_norm(x, p["ln1"], cfg.norm),
                                        p["rec"], cfg, return_state=True)
        x = x + out
        x = x + mlp_mod.mlp(apply_norm(x, p["ln2"], cfg.norm), p["mlp"], cfg)
        return x, st
    window = cfg.local_window if typ == "lattn" else 0
    a_out, (k, v) = attn.self_attention(apply_norm(x, p["ln1"], cfg.norm),
                                        p["attn"], cfg, window=window,
                                        chunk=cfg.attn_chunk)
    x = x + a_out
    if typ == "lattn":
        w = min(cfg.local_window, max_len)
        # ring layout: position p lives in slot p % w
        if s >= w:
            shift = (s - w) % w
            st = {"k": jnp.roll(k[:, -w:], shift, axis=1).astype(dtype),
                  "v": jnp.roll(v[:, -w:], shift, axis=1).astype(dtype),
                  "pos": jnp.roll(jnp.arange(s - w, s, dtype=jnp.int32),
                                  shift)}
        else:
            kp = jnp.zeros((b, w) + k.shape[2:], dtype)
            vp = jnp.zeros((b, w) + v.shape[2:], dtype)
            st = {"k": jax.lax.dynamic_update_slice(
                      kp, k.astype(dtype), (0, 0, 0, 0)),
                  "v": jax.lax.dynamic_update_slice(
                      vp, v.astype(dtype), (0, 0, 0, 0)),
                  "pos": jnp.where(jnp.arange(w) < s, jnp.arange(w), -1)}
        x = x + mlp_mod.mlp(apply_norm(x, p["ln2"], cfg.norm), p["mlp"], cfg)
        return x, st
    st = {"k": pad_cache(k), "v": pad_cache(v)}
    if typ == "self_cross":
        ck, cv = attn.cond_kv(cond_embed, p["xattn"], cfg)
        st["ck"], st["cv"] = ck, cv
        x = x + attn.cross_attention(apply_norm(x, p["lnx"], cfg.norm),
                                     (ck, cv), p["xattn"], cfg)
    if typ == "moe":
        m_out, _ = mlp_mod.moe(apply_norm(x, p["ln2"], cfg.norm), p["moe"],
                               cfg)
        x = x + m_out
    else:
        x = x + mlp_mod.mlp(apply_norm(x, p["ln2"], cfg.norm), p["mlp"], cfg)
    return x, st


def _state_init_layer(cfg, typ: str, batch: int, max_len: int, dtype):
    hkv, hd = cfg.effective_kv_heads, cfg.resolved_head_dim
    if typ == "ssd":
        return ssm_mod.ssd_decode_init(cfg, batch, dtype)
    if typ == "rglru":
        return rglru_mod.rglru_decode_init(cfg, batch, dtype)
    if typ == "lattn":
        w = min(cfg.local_window, max_len)
        return {"k": jnp.zeros((batch, w, hkv, hd), dtype),
                "v": jnp.zeros((batch, w, hkv, hd), dtype),
                "pos": jnp.full((w,), -1, jnp.int32)}
    st = {"k": jnp.zeros((batch, max_len, hkv, hd), dtype),
          "v": jnp.zeros((batch, max_len, hkv, hd), dtype)}
    if typ == "self_cross":
        tc = cfg.num_cond_tokens
        st["ck"] = jnp.zeros((batch, tc, hkv, hd), dtype)
        st["cv"] = jnp.zeros((batch, tc, hkv, hd), dtype)
    return st


def _decode_layer(x, p, cfg, typ: str, state, cur_index):
    if typ == "ssd":
        out, st = ssm_mod.ssd_decode_step(apply_norm(x, p["ln1"], cfg.norm),
                                          p["ssd"], cfg, state)
        return x + out, st
    if typ == "rglru":
        out, st = rglru_mod.rglru_decode_step(apply_norm(x, p["ln1"], cfg.norm),
                                              p["rec"], cfg, state)
        x = x + out
        x = x + mlp_mod.mlp(apply_norm(x, p["ln2"], cfg.norm), p["mlp"], cfg)
        return x, st
    if typ == "lattn":
        out, k, v, pos = attn.decode_local_attention(
            apply_norm(x, p["ln1"], cfg.norm), p["attn"], cfg,
            state["k"], state["v"], state["pos"], cur_index,
            window=cfg.local_window)
        x = x + out
        x = x + mlp_mod.mlp(apply_norm(x, p["ln2"], cfg.norm), p["mlp"], cfg)
        return x, {"k": k, "v": v, "pos": pos}
    out, k, v = attn.decode_self_attention(
        apply_norm(x, p["ln1"], cfg.norm), p["attn"], cfg,
        state["k"], state["v"], cur_index)
    x = x + out
    st = {"k": k, "v": v}
    if typ == "self_cross":
        st["ck"], st["cv"] = state["ck"], state["cv"]
        x = x + attn.cross_attention(apply_norm(x, p["lnx"], cfg.norm),
                                     (state["ck"], state["cv"]), p["xattn"],
                                     cfg)
    if typ == "moe":
        m_out, _ = mlp_mod.moe(apply_norm(x, p["ln2"], cfg.norm), p["moe"], cfg)
        x = x + m_out
    else:
        x = x + mlp_mod.mlp(apply_norm(x, p["ln2"], cfg.norm), p["mlp"], cfg)
    return x, st


# ---------------------------------------------------------------------------
# the Model
# ---------------------------------------------------------------------------

class Model:
    """Functional model wrapper: init / forward / loss / decode."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.pattern = pattern_for(cfg)
        self.repeats = cfg.num_layers // len(self.pattern)
        self.remainder = self.pattern[: cfg.num_layers % len(self.pattern)]

    # -- parameters ----------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        k_embed, k_head, k_layers, k_tail = jax.random.split(key, 4)
        params: dict = {"final_norm": init_norm(cfg.d_model, cfg.norm)}
        if cfg.frontend == "tokens":
            params["embed"] = dense_init(k_embed, (cfg.vocab_size, cfg.d_model))
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size))

        blocks = []
        for pos, typ in enumerate(self.pattern):
            keys = jax.random.split(jax.random.fold_in(k_layers, pos),
                                    self.repeats)
            blocks.append(jax.vmap(
                functools.partial(_init_layer, cfg=self.cfg, typ=typ))(keys))
        params["blocks"] = blocks
        params["tail"] = [
            _init_layer(jax.random.fold_in(k_tail, i), cfg, typ)
            for i, typ in enumerate(self.remainder)]
        return params

    # -- embedding / head ------------------------------------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        if cfg.frontend == "tokens":
            x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dtype)
        else:
            x = batch["embeds"].astype(dtype)
        x = logical_constraint(x, "batch", "seq", "embed")
        cond = batch.get("cond")
        if cond is not None:
            cond = cond.astype(dtype)
        return x, cond

    def _head(self, params, x):
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype))
        return logical_constraint(logits, "batch", "seq", "vocab")

    # -- forward (train / prefill teacher-forced) ------------------------------
    def forward(self, params, batch, return_hidden: bool = False):
        cfg = self.cfg
        x, cond = self._embed(params, batch)

        def body(carry, block_params):
            h, aux = carry
            for pos, typ in enumerate(self.pattern):
                h, a = _apply_layer(h, block_params[pos], cfg, typ, cond)
                aux = aux + a
            return (h, aux), None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        carry = (x, jnp.zeros((), jnp.float32))
        if cfg.unroll:
            for r in range(self.repeats):
                carry, _ = body(carry, jax.tree.map(
                    lambda t: t[r], tuple(params["blocks"])))
            x, aux = carry
        else:
            (x, aux), _ = jax.lax.scan(body, carry, tuple(params["blocks"]))
        for i, typ in enumerate(self.remainder):
            x, a = _apply_layer(x, params["tail"][i], cfg, typ, cond)
            aux = aux + a
        x = apply_norm(x, params["final_norm"], cfg.norm)
        if return_hidden:
            return x, aux
        return self._head(params, x), aux

    def loss(self, params, batch):
        """Mean token cross-entropy (+ MoE aux), computed in sequence chunks
        so the (B, S, V) float32 logits never materialize (the f32 logit
        pipeline of a 152k vocab would otherwise dominate peak memory)."""
        cfg = self.cfg
        hidden, aux = self.forward(params, batch, return_hidden=True)
        labels = batch["labels"]
        b, s, d = hidden.shape
        cs = min(cfg.loss_chunk, s)
        if s % cs:
            cs = s                        # fallback: single chunk
        nch = s // cs
        head = params["lm_head"]

        def chunk_sums(h_c, l_c):
            logits = jnp.einsum("bsd,dv->bsv", h_c, head.astype(h_c.dtype))
            logits = logical_constraint(logits, "batch", "seq", "vocab")
            lf = logits.astype(jnp.float32)
            logz = jax.scipy.special.logsumexp(lf, axis=-1)
            gold = jnp.take_along_axis(lf, l_c[..., None], axis=-1)[..., 0]
            return jnp.stack([(logz - gold).sum(), (logz ** 2).sum()])

        h_ch = hidden.reshape(b, nch, cs, d).swapaxes(0, 1)
        l_ch = labels.reshape(b, nch, cs).swapaxes(0, 1)
        if cfg.unroll or nch == 1:
            sums = sum(chunk_sums(h_ch[i], l_ch[i]) for i in range(nch))
        else:
            body = jax.checkpoint(
                lambda acc, inp: (acc + chunk_sums(*inp), None),
                prevent_cse=False)
            sums, _ = jax.lax.scan(body, jnp.zeros((2,), jnp.float32),
                                   (h_ch, l_ch))
        denom = float(b * s)
        nll = sums[0] / denom
        zloss = 1e-4 * sums[1] / denom
        return nll + zloss + aux, {"nll": nll, "aux": aux, "zloss": zloss}

    # -- prefill ---------------------------------------------------------------
    def prefill(self, params, batch, max_len: int):
        """Teacher-forced forward that also builds the decode state.

        Returns (last_position_logits (B, V), decode_state) — the state is
        structurally identical to init_decode_state, with index = S."""
        cfg = self.cfg
        x, cond = self._embed(params, batch)
        s = x.shape[1]

        def body(h, block_params):
            states = []
            for pos, typ in enumerate(self.pattern):
                h, st = _apply_layer_prefill(h, block_params[pos], cfg, typ,
                                             cond, max_len)
                states.append(st)
            return h, tuple(states)

        if cfg.unroll:
            per_rep = []
            for r in range(self.repeats):
                x, st = body(x, jax.tree.map(lambda t: t[r],
                                             tuple(params["blocks"])))
                per_rep.append(st)
            block_states = jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep) \
                if per_rep else tuple(
                    {} for _ in self.pattern)
        else:
            x, block_states = jax.lax.scan(body, x, tuple(params["blocks"]))
        tail_states = []
        for i, typ in enumerate(self.remainder):
            x, st = _apply_layer_prefill(x, params["tail"][i], cfg, typ, cond,
                                         max_len)
            tail_states.append(st)
        x = apply_norm(x, params["final_norm"], cfg.norm)
        logits = self._head(params, x[:, -1:, :])[:, 0]
        state = {"blocks": list(block_states), "tail": tail_states,
                 "index": jnp.asarray(s, jnp.int32)}
        return logits, state

    # -- decode ----------------------------------------------------------------
    def init_decode_state(self, params, batch_size: int, max_len: int,
                          cond=None):
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        states = []
        for pos, typ in enumerate(self.pattern):
            one = _state_init_layer(cfg, typ, batch_size, max_len, dtype)
            stacked = jax.tree.map(
                lambda s: jnp.broadcast_to(s[None], (self.repeats,) + s.shape),
                one)
            states.append(stacked)
        tail = [_state_init_layer(cfg, typ, batch_size, max_len, dtype)
                for typ in self.remainder]
        state = {"blocks": states, "tail": tail,
                 "index": jnp.zeros((), jnp.int32)}
        if cond is not None and _uses_cond(cfg):
            state = self._precompute_cond(params, state, cond)
        return state

    def _precompute_cond(self, params, state, cond):
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        cond = cond.astype(dtype)
        for pos, typ in enumerate(self.pattern):
            if typ != "self_cross":
                continue
            xp = params["blocks"][pos]["xattn"]
            ck, cv = jax.vmap(
                lambda p: attn.cond_kv(cond, p, cfg))(xp)
            state["blocks"][pos]["ck"] = ck
            state["blocks"][pos]["cv"] = cv
        for i, typ in enumerate(self.remainder):
            if typ != "self_cross":
                continue
            xp = params["tail"][i]["xattn"]
            ck, cv = attn.cond_kv(cond, xp, cfg)
            state["tail"][i]["ck"] = ck
            state["tail"][i]["cv"] = cv
        return state

    def decode_step(self, params, state, token_or_embed,
                    return_hidden: bool = False):
        """One token for the whole batch.  token_or_embed: (B,) int32 tokens
        or (B, 1, D) embeddings (stub frontends).  Returns (logits, state);
        with return_hidden, (hidden (B, D), state) — the PQ hybrid head
        (serve/hybrid_head.py) consumes the hidden state directly and the
        full-vocab matmul never runs."""
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        cur = state["index"]
        if cfg.frontend == "tokens":
            x = jnp.take(params["embed"], token_or_embed[:, None],
                         axis=0).astype(dtype)
        else:
            x = token_or_embed.astype(dtype)
        x = logical_constraint(x, "batch", None, "embed")

        def body(h, inp):
            block_params, block_state = inp
            new_states = []
            for pos, typ in enumerate(self.pattern):
                h, st = _decode_layer(h, block_params[pos], cfg, typ,
                                      block_state[pos], cur)
                new_states.append(st)
            return h, tuple(new_states)

        if cfg.unroll:
            per_rep = []
            for r in range(self.repeats):
                sel = jax.tree.map(lambda t: t[r], (tuple(params["blocks"]),
                                                    tuple(state["blocks"])))
                x, st = body(x, sel)
                per_rep.append(st)
            new_block_states = jax.tree.map(lambda *xs: jnp.stack(xs),
                                            *per_rep)
        else:
            x, new_block_states = jax.lax.scan(
                body, x, (tuple(params["blocks"]), tuple(state["blocks"])))
        new_tail = []
        for i, typ in enumerate(self.remainder):
            x, st = _decode_layer(x, params["tail"][i], cfg, typ,
                                  state["tail"][i], cur)
            new_tail.append(st)
        x = apply_norm(x, params["final_norm"], cfg.norm)
        new_state = {"blocks": list(new_block_states), "tail": new_tail,
                     "index": cur + 1}
        if return_hidden:
            return x[:, 0].astype(jnp.float32), new_state
        logits = self._head(params, x)[:, 0]
        return logits, new_state
