"""Feed-forward layers: gated dense MLP and GShard-style capacity MoE.

MoE (qwen3-moe / qwen2-moe): top-k routing with per-sequence groups and a
fixed expert capacity C = ceil(k*S/E * capacity_factor).  Dispatch is
sort-free scatter/gather (no (T,E,C) one-hot tensor is ever materialized):

  router -> top-k expert ids -> position-in-expert via masked cumulative
  count -> scatter tokens into the (B, E, C, D) buffer -> batched expert
  einsum (E-sharded => all-to-all at the scatter, expert parallelism) ->
  gather back, gate-weighted combine.

Shared experts (qwen2-moe) run as a dense gated MLP on every token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import activation, dense_init, logical_constraint


# ---------------------------------------------------------------------------
# dense gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f)),
        "w_up": dense_init(ks[1], (d, f)),
        "w_down": dense_init(ks[2], (f, d)) / (2.0 * cfg.num_layers) ** 0.5,
    }


def mlp(x, p, cfg):
    dtype = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dtype))
    h = logical_constraint(h, "batch", "seq", "mlp")
    u = logical_constraint(u, "batch", "seq", "mlp")
    h = activation(h, cfg.act) * u
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dtype))
    return logical_constraint(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------

def init_moe(key, cfg) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e)),
        "w_gate": dense_init(ks[1], (e, d, f)),
        "w_up": dense_init(ks[2], (e, d, f)),
        "w_down": dense_init(ks[3], (e, f, d)) / (2.0 * cfg.num_layers) ** 0.5,
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = init_mlp(
            ks[4], cfg, d_ff=(cfg.moe_d_ff or cfg.d_ff) * cfg.num_shared_experts)
    return p


def _shardmap_combine(y_pad, slot, gates, b, s, k, d, e, c, dtype):
    """Expert->token combine with an explicit shard_map.

    GSPMD resolves the cross-sharding gather (expert-sharded y_pad ->
    token-space output) as a masked f32 (B, A, D) all-reduce *before* the
    k-sum (measured: 3×8.6 GB/layer/device on qwen3-moe).  Doing the masked
    local gather + gate-weight + k-sum inside shard_map and psum-ing the
    (B, S, D) partial moves 8×k fewer bytes."""
    from jax.sharding import PartitionSpec as P
    from .common import current_mesh

    mesh = current_mesh()
    n_model = mesh.shape["model"] if (mesh and "model" in mesh.axis_names) \
        else 1
    c1 = c + 1
    if mesh is None or n_model == 1 or e % n_model or (b % _batch_size(mesh)):
        y_assign = jnp.take_along_axis(y_pad, slot[:, :, None], axis=1)
        return (y_assign * gates[..., None]).reshape(b, s, k, d).sum(axis=2)

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) != 1 else batch_axes[0]

    def local(y_pad_l, slot_l, gates_l):
        shard = jax.lax.axis_index("model")
        base = shard * (e // n_model) * c1
        sl = slot_l - base
        valid = (sl >= 0) & (sl < y_pad_l.shape[1])
        sl = jnp.clip(sl, 0, y_pad_l.shape[1] - 1)
        ya = jnp.take_along_axis(y_pad_l, sl[:, :, None], axis=1)
        ya = ya * valid[:, :, None].astype(ya.dtype) * gates_l[..., None]
        y_tok = ya.reshape(ya.shape[0], s, k, d).sum(axis=2)
        return jax.lax.psum(y_tok, "model")

    from repro import compat
    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, "model", None), P(bspec, None), P(bspec, None)),
        out_specs=P(bspec, None, None), check_vma=False)
    return fn(y_pad, slot, gates)


def _batch_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _capacity(cfg, seq: int) -> int:
    k, e = cfg.num_experts_per_tok, cfg.num_experts
    c = int(k * seq / e * cfg.capacity_factor)
    return max(-(-c // 4) * 4, 4)                 # round up to a lane multiple


def moe(x, p, cfg):
    """x (B, S, D) -> (B, S, D), plus the router aux loss.

    Groups = sequences (GShard): capacity is per sequence, dispatch tensors
    are (B, E, C, D) sharded batch->data, expert->model.
    """
    dtype = x.dtype
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    c = _capacity(cfg, s)

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)        # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)            # renormalize top-k

    # --- position-in-expert via stable sort (no (A,E) one-hot materialized)
    flat_e = expert_ids.reshape(b, s * k)                  # (B, A)
    a = s * k
    flat_e = logical_constraint(flat_e, "batch", None)
    order = jnp.argsort(flat_e, axis=1)                    # stable
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    idx = jnp.broadcast_to(jnp.arange(a)[None, :], (b, a))
    is_start = jnp.concatenate(
        [jnp.ones((b, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(is_start, idx, 0), axis=1)
    pos_sorted = idx - run_start                           # rank within expert
    inv = jnp.argsort(order, axis=1)
    pos = jnp.take_along_axis(pos_sorted, inv, axis=1)     # (B, A)
    in_cap = pos < c

    # --- dispatch: scatter token *indices*, then one batched gather --------
    # (token-index plumbing is (B, A) int32 — bytes are negligible; the
    # big (·, D) tensors below are sharded batch×expert / batch×seq so the
    # cross-device exchange is the canonical EP all-to-all volume)
    tok = jnp.broadcast_to((jnp.arange(a) // k)[None, :], (b, a))
    bidx = jnp.arange(b)[:, None]
    pos_c = jnp.where(in_cap, pos, c)                      # OOB -> dropped
    slot = flat_e * (c + 1) + pos_c                        # (B, A) flat slots
    buf_idx = jnp.full((b, e * (c + 1)), s, jnp.int32)     # sentinel = pad row
    buf_idx = buf_idx.at[bidx, slot].set(tok)
    buf_idx = logical_constraint(
        buf_idx.reshape(b, e, c + 1)[:, :, :c], "batch", "expert", None)
    x_pad = jnp.concatenate([x, jnp.zeros((b, 1, d), dtype)], axis=1)
    buf = jnp.take_along_axis(
        x_pad[:, :, None, :].transpose(0, 2, 1, 3),        # (B, 1, S+1, D)
        buf_idx.reshape(b, 1, e * c, 1), axis=2
    ).reshape(b, e, c, d)
    buf = logical_constraint(buf, "batch", "expert", None, None)

    # --- expert computation (E batched einsum; E sharded => EP) ------------
    h = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dtype))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dtype))
    h = logical_constraint(h, "batch", "expert", None, "expert_mlp")
    h = activation(h, cfg.act) * u
    y = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dtype))
    y = logical_constraint(y, "batch", "expert", None, None)

    # --- combine: batched gather back, gate-weight, sum over k -------------
    # y_assign is sharded (batch, moe_tokens->model, -): each device pulls
    # only its A/|model| slice from the expert shards (all-to-all volume
    # ~ tokens*k*D/devices, not a replicated (B,A,D) monster).
    y_pad = jnp.concatenate([y, jnp.zeros((b, e, 1, d), dtype)],
                            axis=2).reshape(b, e * (c + 1), d)
    gates = gate_vals.reshape(b, s * k).astype(dtype) * in_cap.astype(dtype)
    if cfg.moe_shardmap_combine:
        y_tok = _shardmap_combine(y_pad, slot, gates, b, s, k, d, e, c, dtype)
        out = y_tok
        if "shared" in p:
            out = out + mlp(x, p["shared"], cfg)
        out = logical_constraint(out, "batch", "seq", "embed")
        me = probs.mean(axis=(0, 1))
        ce = jnp.zeros((e,), jnp.float32).at[flat_e.reshape(-1)].add(
            1.0 / (b * s * k))
        aux = e * jnp.sum(me * ce) * cfg.router_aux_weight
        return out, aux
    slot_g = logical_constraint(slot, "batch", "moe_tokens")
    y_assign = jnp.take_along_axis(y_pad, slot_g[:, :, None], axis=1)
    y_assign = logical_constraint(y_assign, "batch", "moe_tokens", None)
    if cfg.moe_seq_combine:
        # gate-weight and k-sum while still seq-sharded over 'model' (the
        # reshape keeps whole tokens per shard because k | A/shards), so the
        # final all-gather moves (B,S,D) bf16, not (B,S,k,D):
        y_bsk = (y_assign * gates[..., None]).reshape(b, s, k, d)
        y_bsk = logical_constraint(y_bsk, "batch", "moe_tokens", None, None)
        y_tok = y_bsk.sum(axis=2)
        y_tok = logical_constraint(y_tok, "batch", "moe_tokens", None)
    else:
        y_tok = (y_assign * gates[..., None]).reshape(b, s, k, d).sum(axis=2)
    y_tok = logical_constraint(y_tok, "batch", "seq", None)

    out = y_tok
    if "shared" in p:
        out = out + mlp(x, p["shared"], cfg)
    out = logical_constraint(out, "batch", "seq", "embed")

    # --- router aux load-balancing loss (Switch-style) ---------------------
    me = probs.mean(axis=(0, 1))                                   # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e.reshape(-1)].add(
        1.0 / (b * a))                                             # (E,)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight
    return out, aux
