"""Attention layers: GQA self-attention (full causal / local window), cross
attention, and single-token decode against a KV cache.

Projections are stored in *grouped* layout ``wq: (D, Hkv, G, hd)`` with
``G = Hq / Hkv`` so tensor parallelism lands on whichever of (kv-heads,
group) divides the model axis: kv-heads for MHA-ish configs (stablelm 32,
qwen2-moe 16), the group dim for wide-GQA configs (qwen3-moe kv=4 × G=16).
A plain ``(D, Hq, hd)`` layout cannot be GSPMD-sharded for either case
without a reshard at the GQA reshape (measured: compile failure at 16-way).
Configs where neither factor divides (e.g. deepseek kv=8 × G=8 on a 16-way
axis) fall back to replicated attention activations — recorded per-arch in
EXPERIMENTS.md, with KV-head replication (vLLM-style) as the hillclimb fix.

Large-S causal attention uses a *banded flash* schedule: the (S×S) score
matrix is processed one chunk-diagonal band at a time with an online
softmax.  Unlike a masked full matmul this does exact causal work, so HLO
FLOPs stay honest for the roofline, and peak memory is O(S·C) per band.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, logical_constraint, rope

NEG_INF = -1e30


def _cache_constraint(cache):
    """Decode KV caches live (batch, kv_seq-sharded, heads replicated) —
    the one layout that works for every kv_heads count (GQA kv=1..32);
    softmax stats over the sharded seq become two small all-reduces."""
    return logical_constraint(cache, "batch", "kv_seq", None, None)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_attention(key, cfg, cross: bool = False) -> dict:
    d, hq, hkv = cfg.d_model, cfg.num_heads, cfg.effective_kv_heads
    assert hq % hkv == 0, (
        f"kv_repeat={cfg.kv_repeat} must keep kv heads dividing "
        f"{cfg.num_heads} query heads")
    g = hq // hkv
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hkv, g, hd)),
        "wk": dense_init(ks[1], (d, hkv, hd)),
        "wv": dense_init(ks[2], (d, hkv, hd)),
        "wo": dense_init(ks[3], (hkv, g, hd, d),
                         in_axis=2) / (2.0 * cfg.num_layers) ** 0.5,
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hkv, g, hd), jnp.float32)
        p["bk"] = jnp.zeros((hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((hkv, hd), jnp.float32)
    return p


def _project_q(x, p, cfg, dtype):
    """-> (B, S, Hkv, G, hd), sharded on kv-heads or group (whichever fits)."""
    q = jnp.einsum("bsd,dhgk->bshgk", x, p["wq"].astype(dtype))
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
    return logical_constraint(q, "batch", "seq", "kv_heads", "heads", None)


def _project_kv(x, p, cfg, dtype):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if "bk" in p:
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    k = logical_constraint(k, "batch", "seq", "kv_heads", None)
    v = logical_constraint(v, "batch", "seq", "kv_heads", None)
    return k, v


def _out_proj(attn, p, dtype):
    """attn: (B, S, Hkv, G, hd) -> (B, S, D)."""
    out = jnp.einsum("bshgk,hgkd->bsd", attn, p["wo"].astype(dtype))
    return logical_constraint(out, "batch", "seq", "embed")


def _rope_grouped(q, positions, theta, fraction):
    """rope over (B, S, Hkv, G, hd) — flatten head dims for the helper."""
    b, s, hkv, g, hd = q.shape
    out = rope(q.reshape(b, s, hkv * g, hd), positions, theta, fraction)
    return out.reshape(b, s, hkv, g, hd)


# ---------------------------------------------------------------------------
# core softmax-attention over chunk-diagonal bands
# ---------------------------------------------------------------------------

def banded_causal_attention(q, k, v, *, chunk: int, window: int = 0,
                            dtype=jnp.bfloat16):
    """Exact-work causal (optionally windowed) attention.

    q: (B,S,Hkv,G,hd); k,v: (B,S,Hkv,hd).  Returns (B,S,Hkv,G,hd).
    """
    b, s, hkv, g, hd = q.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c
    scale = hd ** -0.5

    qc = q.reshape(b, nc, c, hkv, g, hd)
    kc = k.reshape(b, nc, c, hkv, hd)
    vc = v.reshape(b, nc, c, hkv, hd)

    acc = jnp.zeros((b, nc, c, hkv, g, hd), jnp.float32)
    m = jnp.full((b, nc, c, hkv, g), NEG_INF, jnp.float32)
    l = jnp.zeros((b, nc, c, hkv, g), jnp.float32)

    max_band = nc if window <= 0 else min(nc, -(-window // c) + 1)

    for band in range(max_band):
        nq = nc - band
        qs = qc[:, band:]                       # (B,nq,C,Hkv,G,hd)
        ks = kc[:, :nq]
        vs = vc[:, :nq]
        sc = jnp.einsum("bnchgk,bnmhk->bnchgm", qs, ks).astype(jnp.float32)
        sc = sc * scale                          # (B,nq,Cq,Hkv,G,Ck)
        iq = jax.lax.broadcasted_iota(jnp.int32, (1, 1, c, 1, 1, c), 2)
        ik = jax.lax.broadcasted_iota(jnp.int32, (1, 1, c, 1, 1, c), 5)
        dist = iq + band * c - ik                # query_pos - key_pos >= 0
        mask = dist >= 0
        if window > 0:
            mask &= dist < window
        sc = jnp.where(mask, sc, NEG_INF)

        m_band = jnp.maximum(m[:, band:], sc.max(axis=-1))
        alpha = jnp.exp(m[:, band:] - m_band)
        pr = jnp.exp(sc - m_band[..., None])
        l_band = l[:, band:] * alpha + pr.sum(axis=-1)
        acc_band = (acc[:, band:] * alpha[..., None]
                    + jnp.einsum("bnchgm,bnmhk->bnchgk",
                                 pr.astype(dtype), vs).astype(jnp.float32))
        m = m.at[:, band:].set(m_band)
        l = l.at[:, band:].set(l_band)
        acc = acc.at[:, band:].set(acc_band)

    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, s, hkv, g, hd).astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, dtype=jnp.bfloat16):
    """Plain masked attention (small S / cross-attention).

    q: (B,S,Hkv,G,hd); k,v: (B,M,Hkv,hd) -> (B,S,Hkv,G,hd)."""
    b, s, hkv, g, hd = q.shape
    scale = hd ** -0.5
    sc = jnp.einsum("bshgk,bmhk->bshgm", q, k).astype(jnp.float32) * scale
    if causal:
        iq = jax.lax.broadcasted_iota(jnp.int32, (1, s, 1, 1, k.shape[1]), 1)
        ik = jax.lax.broadcasted_iota(jnp.int32, (1, s, 1, 1, k.shape[1]), 4)
        sc = jnp.where(iq >= ik, sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bshgm,bmhk->bshgk", pr.astype(dtype), v)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# layer-level entry points
# ---------------------------------------------------------------------------

def self_attention(x, p, cfg, *, positions=None, window: int = 0,
                   chunk: int = 1024):
    """Causal self-attention over the full sequence (train / prefill)."""
    dtype = x.dtype
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = _project_q(x, p, cfg, dtype)
    k, v = _project_kv(x, p, cfg, dtype)
    q = _rope_grouped(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    if window > 0 and s > window:
        c = chunk if s % chunk == 0 else _largest_divisor_chunk(s, chunk)
        attn = banded_causal_attention(q, k, v, chunk=c, window=window,
                                       dtype=dtype)
    elif s > chunk and s % chunk == 0:
        attn = banded_causal_attention(q, k, v, chunk=chunk, window=window,
                                       dtype=dtype)
    else:
        attn = full_attention(q, k, v, causal=True, dtype=dtype)
    return _out_proj(attn, p, dtype), (k, v)


def _largest_divisor_chunk(s: int, chunk: int) -> int:
    for c in range(min(chunk, s), 0, -1):
        if s % c == 0:
            return c
    return s


def cross_attention(x, cond_kv, p, cfg):
    """x (B,S,D) attends over precomputed conditioning K/V (no mask)."""
    dtype = x.dtype
    q = _project_q(x, p, cfg, dtype)
    k, v = cond_kv
    attn = full_attention(q, k, v, causal=False, dtype=dtype)
    return _out_proj(attn, p, dtype)


def cond_kv(cond_embed, p, cfg):
    """Precompute cross-attention K/V from conditioning embeddings."""
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return _project_kv(cond_embed.astype(dt), p, cfg, dt)


def decode_local_attention(x, p, cfg, cache_k, cache_v, cache_pos, cur_index,
                           *, window: int):
    """Ring-buffer local-window decode (Griffin attention layers).

    cache_{k,v}: (B, W, Hkv, hd) with W = min(window, max_len); cache_pos (W,)
    holds the absolute position stored in each slot (-1 = empty).  RoPE is
    applied at the absolute position before caching, so slots never need
    re-rotation.  Memory stays O(window) regardless of generation length —
    this is what makes long_500k feasible for the hybrid family."""
    dtype = x.dtype
    b = x.shape[0]
    w = cache_k.shape[1]
    pos = jnp.full((b, 1), cur_index, jnp.int32)
    q = _project_q(x, p, cfg, dtype)
    k_new, v_new = _project_kv(x, p, cfg, dtype)
    q = _rope_grouped(q, pos, cfg.rope_theta, cfg.rope_fraction)
    k_new = rope(k_new, pos, cfg.rope_theta, cfg.rope_fraction)
    k_new = logical_constraint(k_new, "batch", None, None, None)
    v_new = logical_constraint(v_new, "batch", None, None, None)
    slot = jnp.mod(cur_index, w)
    cache_k = _cache_constraint(jax.lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0)))
    cache_v = _cache_constraint(jax.lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0)))
    cache_pos = jax.lax.dynamic_update_slice(cache_pos,
                                             cur_index[None].astype(jnp.int32),
                                             (slot,))
    hkv, g, hd = q.shape[2], q.shape[3], q.shape[4]
    qg = q[:, 0]                                              # (B,Hkv,G,hd)
    sc = jnp.einsum("bhgk,bmhk->bhgm", qg,
                    cache_k.astype(dtype)).astype(jnp.float32) * hd ** -0.5
    valid = (cache_pos >= 0) & (cache_pos > cur_index - window) \
        & (cache_pos <= cur_index)
    sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgm,bmhk->bhgk", pr.astype(dtype),
                     cache_v.astype(dtype))[:, None]
    return _out_proj(out, p, dtype), cache_k, cache_v, cache_pos


def decode_self_attention(x, p, cfg, cache_k, cache_v, cur_index, *,
                          window: int = 0):
    """One-token decode: x (B,1,D); cache (B,S_max,Hkv,hd); cur_index ()
    is the position being written.  Returns (out, new_k, new_v)."""
    dtype = x.dtype
    b = x.shape[0]
    s_max = cache_k.shape[1]
    pos = jnp.full((b, 1), cur_index, jnp.int32)
    q = _project_q(x, p, cfg, dtype)
    k_new, v_new = _project_kv(x, p, cfg, dtype)
    q = _rope_grouped(q, pos, cfg.rope_theta, cfg.rope_fraction)
    k_new = rope(k_new, pos, cfg.rope_theta, cfg.rope_fraction)
    k_new = logical_constraint(k_new, "batch", None, None, None)
    v_new = logical_constraint(v_new, "batch", None, None, None)
    cache_k = _cache_constraint(jax.lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (0, cur_index, 0, 0)))
    cache_v = _cache_constraint(jax.lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (0, cur_index, 0, 0)))
    hkv, g, hd = q.shape[2], q.shape[3], q.shape[4]
    qg = q[:, 0]                                              # (B,Hkv,G,hd)
    sc = jnp.einsum("bhgk,bmhk->bhgm", qg,
                    cache_k.astype(dtype)).astype(jnp.float32) * hd ** -0.5
    ik = jnp.arange(s_max)[None, None, None, :]
    valid = ik <= cur_index
    if window > 0:
        valid &= ik > cur_index - window
    sc = jnp.where(valid, sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgm,bmhk->bhgk", pr.astype(dtype),
                     cache_v.astype(dtype))[:, None]          # (B,1,Hkv,G,hd)
    return _out_proj(out, p, dtype), cache_k, cache_v
