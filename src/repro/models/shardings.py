"""Parameter / optimizer / decode-state PartitionSpec derivation.

Specs are derived from leaf *path names* (the param tree is our schema) via
the same logical-rule table the model's activation constraints use, so
params and activations always agree on which mesh axis means what.

FSDP convention: the non-tensor-parallel dimension of every matrix shards
over 'data' (+'pod'); XLA GSPMD inserts the all-gather at use and the
reduce-scatter in the backward pass (ZeRO-3 equivalent).  Moments in the
optimizer state inherit their parameter's spec (ZeRO-2 comes for free).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from .common import DEFAULT_RULES, resolve_spec

__all__ = ["param_pspecs", "state_pspecs", "batch_pspecs", "tree_pspecs"]

# leaf-name -> logical axes per rank (the stacked `blocks` axis is prepended
# automatically when the path passes through "blocks")
_PARAM_AXES = {
    "embed":    ("vocab", "fsdp"),
    "lm_head":  ("fsdp", "vocab"),
    "wq":       ("fsdp", "kv_heads", "heads", None),
    "wk":       ("fsdp", "kv_heads", None),
    "wv":       ("fsdp", "kv_heads", None),
    "wo":       ("kv_heads", "heads", None, "fsdp"),
    "bq":       ("kv_heads", "heads", None),
    "bk":       ("kv_heads", None),
    "bv":       ("kv_heads", None),
    "router":   ("fsdp", "expert"),
    "in_proj":  ("fsdp", "mlp"),
    "out_proj": ("mlp", "fsdp"),
    "conv_w":   (None, "mlp"),
    "conv_b":   ("mlp",),
    "a_log":    ("heads",),
    "dt_bias":  ("heads",),
    "d_skip":   ("heads",),
    "wa":       ("fsdp", "state"),
    "wx":       ("fsdp", "state"),
    "ba":       ("state",),
    "bx":       ("state",),
    "lam":      ("state",),
    "w_rec":    ("fsdp", "state"),
    "out":      ("state", "fsdp"),
    "norm":     ("mlp",),
    "scale":    (None,),
    "bias":     (None,),
}

_STATE_AXES = {
    "k":    ("batch", "kv_seq", "kv_heads", None),
    "v":    ("batch", "kv_seq", "kv_heads", None),
    "ck":   ("batch", None, "kv_heads", None),
    "cv":   ("batch", None, "kv_heads", None),
    "pos":  (None,),
    "conv": ("batch", None, "mlp"),
    "ssm":  ("batch", "heads", None, None),
    "h":    ("batch", "state"),
    "index": (),
}


def _mlp_axes(name: str, rank: int):
    # dense MLP w_gate/w_up (D,F) / w_down (F,D); MoE (E,D,F) / (E,F,D);
    # rglru w_gate (D,W)
    if name in ("w_gate", "w_up"):
        return ("expert", "fsdp", "mlp") if rank == 3 else ("fsdp", "mlp")
    if name == "w_down":
        return ("expert", "mlp", "fsdp") if rank == 3 else ("mlp", "fsdp")
    return None


def _leaf_name(path) -> tuple[str, bool]:
    parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    stacked = "blocks" in parts
    return parts[-1], stacked


def param_pspecs(params, mesh, rules: dict | None = None):
    """PartitionSpec tree matching `params` (works on ShapeDtypeStructs)."""
    rules = dict(DEFAULT_RULES, **(rules or {}))

    def spec_for(path, leaf):
        name, stacked = _leaf_name(path)
        axes = _mlp_axes(name, np.ndim(leaf) - (1 if stacked else 0))
        if axes is None:
            axes = _PARAM_AXES.get(name)
        if axes is None:
            axes = (None,) * (np.ndim(leaf) - (1 if stacked else 0))
        if stacked:
            axes = (None,) + tuple(axes)
        axes = axes[: np.ndim(leaf)]
        return resolve_spec(mesh, rules, axes, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def state_pspecs(state, mesh, rules: dict | None = None):
    """Decode-state spec tree (KV caches / recurrent states)."""
    rules = dict(DEFAULT_RULES, **(rules or {}))

    def spec_for(path, leaf):
        name, stacked = _leaf_name(path)
        axes = _STATE_AXES.get(name, (None,) * np.ndim(leaf))
        if stacked:
            axes = (None,) + tuple(axes)
        axes = axes[: np.ndim(leaf)]
        return resolve_spec(mesh, rules, axes, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, state)


def batch_pspecs(batch, mesh, rules: dict | None = None):
    """Input batch specs: leading dim is always the global batch."""
    rules = dict(DEFAULT_RULES, **(rules or {}))

    def spec_for(path, leaf):
        axes = ("batch",) + (None,) * (np.ndim(leaf) - 1)
        return resolve_spec(mesh, rules, axes, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def tree_pspecs(tree, mesh, params_like, rules: dict | None = None):
    """Optimizer-state specs: moments inherit parameter specs; scalars and
    int8-quantized moment blocks replicate."""
    pspecs = param_pspecs(params_like, mesh, rules)

    def build(subtree):
        return jax.tree.map(lambda _: P(), subtree)

    out = {}
    for key, sub in tree.items():
        if key in ("m", "v"):
            out[key] = jax.tree.map(
                lambda spec, leaf: spec if np.ndim(leaf) > 0 else P(),
                pspecs, sub)
        else:
            out[key] = build(sub) if isinstance(sub, dict) else P()
    return out
