"""Griffin / RecurrentGemma recurrent block (RG-LRU) — arXiv:2402.19427.

Block: x -> {gate branch: linear -> GeLU} ⊙ {recurrent branch: linear ->
causal conv1d (width 4) -> RG-LRU} -> linear out.

RG-LRU (Real-Gated LRU), c = 8:
  r_t = sigmoid(W_a x_t + b_a)          recurrence gate
  i_t = sigmoid(W_x x_t + b_x)          input gate
  log a_t = -c * softplus(lam) * r_t    per-channel decay (lam learnable)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t)

Training runs the diagonal recurrence with jax.lax.associative_scan
(log-depth over S — this is what makes long-context training feasible);
decode is the O(1) update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, logical_constraint

_C = 8.0


def init_rglru(key, cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_gate": dense_init(ks[0], (d, w)),           # GeLU branch
        "w_rec": dense_init(ks[1], (d, w)),            # recurrent branch
        "conv_w": 0.1 * jax.random.normal(ks[2], (4, w), jnp.float32),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "wa": dense_init(ks[3], (w, w)),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": dense_init(ks[4], (w, w)),
        "bx": jnp.zeros((w,), jnp.float32),
        # init so a^c in [0.9, 0.999] as in the paper
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w, dtype=jnp.float32)) / _C)),
        "out": dense_init(ks[5], (w, d)) / (2.0 * cfg.num_layers) ** 0.5,
    }


def _conv(x, w, b, state=None):
    k = w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
            for i in range(k))
    return y + b.astype(x.dtype), xp[:, -(k - 1):, :]


def _gates(xr, p, dtype):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xr, p["wa"].astype(dtype))
                       + p["ba"].astype(dtype))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xr, p["wx"].astype(dtype))
                       + p["bx"].astype(dtype))
    log_a = (-_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))                        # (B,S,W)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta, i


def rglru_block(x, p, cfg, return_state: bool = False):
    """Train/prefill path.  x (B,S,D) -> (B,S,D)."""
    dtype = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(dtype)))
    xr_raw = jnp.einsum("bsd,dw->bsw", x, p["w_rec"].astype(dtype))
    xr_raw = logical_constraint(xr_raw, "batch", "seq", "state")
    xr, _ = _conv(xr_raw, p["conv_w"], p["conv_b"])
    a, beta, i = _gates(xr, p, dtype)
    v = (beta * i.astype(jnp.float32) * xr.astype(jnp.float32))  # (B,S,W)

    # h_t = a_t h_{t-1} + v_t  — associative scan over S with pairs (a, v)
    def combine(c1, c2):
        a1, v1 = c1
        a2, v2 = c2
        return a1 * a2, v1 * a2 + v2

    _, h = jax.lax.associative_scan(combine, (a, v), axis=1)
    y = h.astype(dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["out"].astype(dtype))
    out = logical_constraint(out, "batch", "seq", "embed")
    if return_state:
        return out, {"conv": xr_raw[:, -3:, :], "h": h[:, -1]}
    return out


def rglru_decode_init(cfg, batch: int, dtype) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_decode_step(x, p, cfg, state):
    """x (B,1,D) -> (B,1,D) with O(1) state."""
    dtype = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(dtype)))
    xr = jnp.einsum("bsd,dw->bsw", x, p["w_rec"].astype(dtype))
    xr, conv_state = _conv(xr, p["conv_w"], p["conv_b"], state=state["conv"])
    a, beta, i = _gates(xr, p, dtype)
    v = beta * i.astype(jnp.float32) * xr.astype(jnp.float32)
    h = a[:, 0] * state["h"] + v[:, 0]                       # (B,W)
    y = h[:, None, :].astype(dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["out"].astype(dtype))
    return out, {"conv": conv_state, "h": h}
