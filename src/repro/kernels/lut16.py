"""LUT16 ADC scan as a Pallas TPU kernel (paper §4.1.2, TPU-adapted).

x86 lineage: AVX2 PSHUFB performs 32 parallel 16-way lookups of 8-bit LUT
values per instruction; accumulation needs the unsigned width-extension trick.

TPU re-derivation (DESIGN.md §2): the MXU *is* a register-bandwidth shuffle
engine — contracting a 0/1 one-hot matrix against the LUT performs 128-wide
16-way lookup-accumulate per cycle, with fp32 accumulation for free (so the
paper's bias/overflow fix-up is unnecessary).  Codes are kept uint8 in HBM
(the stream that bounds single-query throughput, §4.1.2) and expanded to
one-hot only inside VMEM.

Contract (matches kernels/ref.py::lut16_adc_ref):
  codes (N, K) uint8 in [0, l)   PQ codes, row-major over datapoints
  lut   (Q, K, l) float32        per-query per-subspace inner products
  out   (Q, N) float32           out[q, n] = sum_k lut[q, k, codes[n, k]]

Grid: (Q/bq, N/bn, K/bk); K innermost for output-block accumulation.
VMEM per step: bn*bk codes + bq*bk*l LUT + bq*bn out — defaults keep this
well under 16 MiB v5e VMEM (128,512,256,l=16: 128 KiB + 2 MiB + 256 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lut16_adc_pallas", "pack_codes", "unpack_codes",
           "default_interpret"]


def default_interpret() -> bool:
    """The one backend-detection rule for Pallas interpret fallback: compile
    on real TPU backends, interpret everywhere else (ops.py imports this
    too, so the rule lives in exactly one place)."""
    return jax.default_backend() != "tpu"


def _kernel(codes_ref, lut_ref, out_ref, *, compute_dtype,
            packed: bool = False):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                                  # (bn, bk) uint8
    bq, _, l = lut_ref.shape
    if packed:
        # two 4-bit codes per byte (paper §6.1.1's actual storage): unpack
        # with VPU shifts/masks in VMEM — HBM streams half the bytes.
        bn_c, bk_c = codes.shape
        lo = codes & 0x0F
        hi = codes >> 4
        codes = jnp.stack([lo, hi], axis=2).reshape(bn_c, bk_c * 2)
    # one-hot expansion in VMEM: (bn, K, l) — the "shuffle control" operand
    onehot = (codes[:, :, None] ==
              jax.lax.broadcasted_iota(jnp.uint8, (1, 1, l), 2))
    onehot = onehot.reshape(codes.shape[0], -1).astype(compute_dtype)
    lut = lut_ref[...].reshape(bq, -1).astype(compute_dtype)
    # MXU contraction: (bq, K*l) x (bn, K*l)^T -> (bq, bn)
    part = jax.lax.dot_general(
        lut, onehot, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] += part


@functools.partial(jax.jit,
                   static_argnames=("bq", "bn", "bk", "interpret",
                                    "compute_dtype", "packed"))
def lut16_adc_pallas(codes: jax.Array, lut: jax.Array, *, bq: int = 8,
                     bn: int = 512, bk: int = 32,
                     interpret: bool | None = None,
                     compute_dtype=jnp.float32,
                     packed: bool = False) -> jax.Array:
    """Pallas LUT16 ADC.  Shapes must be divisible by the block sizes
    (ops.py pads).  codes: (N, K) uint8; lut: (Q, K, l) f32 -> (Q, N) f32.

    interpret=None auto-detects: the kernel compiles for real TPU backends
    and falls back to Pallas interpret mode everywhere else.  Pass an
    explicit bool to override — CI pins interpret=True so kernel tests mean
    the same thing on a TPU host as on a CPU runner.

    compute_dtype=bfloat16 selects the fast MXU path on real TPUs (the LUT is
    bf16-rounded, matching the paper's 8-bit quantized LUT accuracy budget);
    float32 keeps the oracle comparison bit-tight for CI.

    packed=True: codes hold TWO 4-bit subspace codes per byte (shape
    (N, K/2); the paper's storage format) — HBM streams half the bytes and
    the kernel unpacks in VMEM.  Requires l == 16 and K even.  Callers
    should halve ``bk`` (ops.py does): the LUT block spans ``2*bk`` logical
    subspaces per code-byte block, so halving keeps the LUT VMEM footprint
    identical to the unpacked kernel's."""
    if interpret is None:
        interpret = default_interpret()
    n, k = codes.shape
    q, k2, l = lut.shape
    if packed:
        assert l == 16 and k2 == 2 * k, (codes.shape, lut.shape)
    else:
        assert k == k2, (codes.shape, lut.shape)
    assert n % bn == 0 and q % bq == 0 and k % bk == 0, (n, q, k, bq, bn, bk)

    lut_bk = 2 * bk if packed else bk
    grid = (q // bq, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, compute_dtype=compute_dtype,
                          packed=packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda iq, jn, kk: (jn, kk)),
            pl.BlockSpec((bq, lut_bk, l), lambda iq, jn, kk: (iq, kk, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda iq, jn, kk: (iq, jn)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.float32),
        interpret=interpret,
    )(codes, lut)


def pack_codes(codes):
    """(N, K) codes in [0, 16) -> (N, ceil(K/2)) uint8, two codes per byte.

    Subspace 2j sits in the low nibble of byte j, subspace 2j+1 in the high
    nibble (paper §6.1.1's storage).  Odd K is zero-padded with one phantom
    subspace in the last byte's high nibble; scoring wrappers
    (ops.lut16_adc(packed=True) / unpack_codes) zero the phantom LUT column
    or slice it off, so the pad contributes nothing.  Values outside [0, 16)
    would silently corrupt the neighbouring nibble, so they are rejected.
    Host-side (numpy): runs once at index-construction time."""
    import numpy as np
    codes = np.asarray(codes)
    if codes.ndim != 2:
        raise ValueError(f"codes must be 2-D (N, K), got shape {codes.shape}")
    if codes.size and (codes.min() < 0 or codes.max() > 15):
        raise ValueError(
            "pack_codes requires 4-bit codes in [0, 16); got range "
            f"[{int(codes.min())}, {int(codes.max())}]")
    if codes.shape[1] % 2:
        codes = np.pad(codes, ((0, 0), (0, 1)))
    lo = codes[:, 0::2].astype(np.uint8)
    hi = codes[:, 1::2].astype(np.uint8)
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_codes(packed, k: int):
    """(N, Kp) packed bytes -> (N, k) uint8 codes; inverse of pack_codes.

    k is the LOGICAL subspace count: 2*Kp, or 2*Kp - 1 when the trailing
    high nibble is odd-K padding (which is sliced off here).  jnp-traceable —
    the engine's unpack-then-score path runs it inside jit, so the non-Pallas
    backends score packed storage bit-for-bit like unpacked storage."""
    kp = packed.shape[1]
    if not 0 <= 2 * kp - k <= 1:
        raise ValueError(
            f"(N, {kp}) packed bytes cannot hold {k} subspace codes")
    lo = packed & 0x0F
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=2).reshape(packed.shape[0], 2 * kp)
    return out[:, :k].astype(jnp.uint8)
