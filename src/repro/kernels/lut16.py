"""LUT16 ADC scan as a Pallas TPU kernel (paper §4.1.2, TPU-adapted).

x86 lineage: AVX2 PSHUFB performs 32 parallel 16-way lookups of 8-bit LUT
values per instruction; accumulation needs the unsigned width-extension trick.

TPU re-derivation (DESIGN.md §2): the MXU *is* a register-bandwidth shuffle
engine — contracting a 0/1 one-hot matrix against the LUT performs 128-wide
16-way lookup-accumulate per cycle, with fp32 accumulation for free (so the
paper's bias/overflow fix-up is unnecessary).  Codes are kept uint8 in HBM
(the stream that bounds single-query throughput, §4.1.2) and expanded to
one-hot only inside VMEM.

Two kernels share one accumulation body (``_block_partial``):

* ``lut16_adc_pallas``      — materialize the full (Q, N) score matrix;
* ``lut16_adc_topk_pallas`` — fused scan-and-select (DESIGN.md §2.5): the
  same accumulation, but survivors are selected against a VMEM-resident
  candidate buffer in the same grid pass, so the (Q, N) matrix never exists
  in HBM.  Packed nibbles are unpacked in-register (two one-hot dots against
  the even/odd LUT halves — no interleaved ``jnp.stack`` materialization of
  the code block).

Contract (matches kernels/ref.py::lut16_adc_ref):
  codes (N, K) uint8 in [0, l)   PQ codes, row-major over datapoints
  lut   (Q, K, l) float32        per-query per-subspace inner products
  out   (Q, N) float32           out[q, n] = sum_k lut[q, k, codes[n, k]]

Grid: (Q/bq, N/bn, K/bk); K innermost for output-block accumulation.
VMEM per step: bn*bk codes + bq*bk*l LUT + bq*bn out — defaults keep this
well under 16 MiB v5e VMEM (128,512,256,l=16: 128 KiB + 2 MiB + 256 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["lut16_adc_pallas", "lut16_adc_topk_pallas", "candidate_buffer_width",
           "pack_codes", "unpack_codes", "default_interpret"]


def default_interpret() -> bool:
    """The one backend-detection rule for Pallas interpret fallback: compile
    on real TPU backends, interpret everywhere else (ops.py imports this
    too, so the rule lives in exactly one place)."""
    return jax.default_backend() != "tpu"


def candidate_buffer_width(k: int) -> int:
    """VMEM candidate-buffer width for a top-``k`` fused select: ``k``
    rounded up to the 128-lane granularity (DESIGN.md §2.5)."""
    return max(-(-k // 128) * 128, 128)


def _block_partial(codes, lut, *, compute_dtype, packed: bool):
    """One (bq, bn) partial sum: codes block × LUT block on the MXU.

    packed=True unpacks two 4-bit codes per byte IN-REGISTER: the low and
    high nibbles each get their own one-hot and their own dot against the
    even/odd half of the LUT (``lut.reshape(bq, bk, 2, l)``) — the unpacked
    (bn, 2*bk) code block is never materialized (no ``jnp.stack``/reshape of
    the code operand), so the VPU work is two masks instead of a cross-lane
    interleave."""
    bq, _, l = lut.shape
    bn_c, bk_c = codes.shape
    if packed:
        lut_pair = lut.reshape(bq, bk_c, 2, l)
        part = None
        for nib, half in ((codes & 0x0F, lut_pair[:, :, 0, :]),
                          (codes >> 4, lut_pair[:, :, 1, :])):
            onehot = (nib[:, :, None] ==
                      jax.lax.broadcasted_iota(jnp.uint8, (1, 1, l), 2))
            onehot = onehot.reshape(bn_c, -1).astype(compute_dtype)
            halff = half.reshape(bq, -1).astype(compute_dtype)
            p = jax.lax.dot_general(
                halff, onehot, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            part = p if part is None else part + p
        return part
    # one-hot expansion in VMEM: (bn, K, l) — the "shuffle control" operand
    onehot = (codes[:, :, None] ==
              jax.lax.broadcasted_iota(jnp.uint8, (1, 1, l), 2))
    onehot = onehot.reshape(bn_c, -1).astype(compute_dtype)
    lutf = lut.reshape(bq, -1).astype(compute_dtype)
    # MXU contraction: (bq, K*l) x (bn, K*l)^T -> (bq, bn)
    return jax.lax.dot_general(
        lutf, onehot, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _kernel(codes_ref, lut_ref, out_ref, *, compute_dtype,
            packed: bool = False):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += _block_partial(codes_ref[...], lut_ref[...],
                                   compute_dtype=compute_dtype, packed=packed)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bn", "bk", "interpret",
                                    "compute_dtype", "packed"))
def lut16_adc_pallas(codes: jax.Array, lut: jax.Array, *, bq: int = 8,
                     bn: int = 512, bk: int = 32,
                     interpret: bool | None = None,
                     compute_dtype=jnp.float32,
                     packed: bool = False) -> jax.Array:
    """Pallas LUT16 ADC.  Shapes must be divisible by the block sizes
    (ops.py pads).  codes: (N, K) uint8; lut: (Q, K, l) f32 -> (Q, N) f32.

    interpret=None auto-detects: the kernel compiles for real TPU backends
    and falls back to Pallas interpret mode everywhere else.  Pass an
    explicit bool to override — CI pins interpret=True so kernel tests mean
    the same thing on a TPU host as on a CPU runner.

    compute_dtype=bfloat16 selects the fast MXU path on real TPUs (the LUT is
    bf16-rounded, matching the paper's 8-bit quantized LUT accuracy budget);
    float32 keeps the oracle comparison bit-tight for CI.

    packed=True: codes hold TWO 4-bit subspace codes per byte (shape
    (N, K/2); the paper's storage format) — HBM streams half the bytes and
    the kernel unpacks in-register (see ``_block_partial``).  Requires
    l == 16 and K even.  Callers should halve ``bk`` (ops.py does): the LUT
    block spans ``2*bk`` logical subspaces per code-byte block, so halving
    keeps the LUT VMEM footprint identical to the unpacked kernel's."""
    if interpret is None:
        interpret = default_interpret()
    n, k = codes.shape
    q, k2, l = lut.shape
    if packed:
        assert l == 16 and k2 == 2 * k, (codes.shape, lut.shape)
    else:
        assert k == k2, (codes.shape, lut.shape)
    assert n % bn == 0 and q % bq == 0 and k % bk == 0, (n, q, k, bq, bn, bk)

    lut_bk = 2 * bk if packed else bk
    grid = (q // bq, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, compute_dtype=compute_dtype,
                          packed=packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda iq, jn, kk: (jn, kk)),
            pl.BlockSpec((bq, lut_bk, l), lambda iq, jn, kk: (iq, kk, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda iq, jn, kk: (iq, jn)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.float32),
        interpret=interpret,
    )(codes, lut)


# ---------------------------------------------------------------------------
# Fused scan-and-select (DESIGN.md §2.5)
# ---------------------------------------------------------------------------

def _fused_kernel(codes_ref, lut_ref, base_ref, out_s_ref, out_i_ref, acc_ref,
                  *, compute_dtype, packed: bool, cbuf: int, bn: int, nk: int):
    """Accumulate one (bq, bn) score block in VMEM scratch, then merge it
    into the per-query candidate buffer — the (Q, N) matrix never leaves
    VMEM.

    The buffer (out_s/out_i, shape (bq, cbuf)) is the OUTPUT block; its index
    map ignores (jn, kk), so Pallas keeps it VMEM-resident across the whole
    row sweep and writes it back to HBM once per query block.  The running
    threshold is the buffer's current minimum: a block whose best score
    cannot STRICTLY beat it is skipped entirely, which is exact under
    ``lax.top_k``'s lowest-index tie-break (an equal-scoring later row never
    displaces an earlier buffer entry)."""
    jn = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when((jn == 0) & (kk == 0))
    def _init_buffer():
        out_s_ref[...] = jnp.full_like(out_s_ref, -jnp.inf)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    @pl.when(kk == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _block_partial(codes_ref[...], lut_ref[...],
                                   compute_dtype=compute_dtype, packed=packed)

    @pl.when(kk == nk - 1)
    def _select():
        # bias is added HERE, once per row block, so the fp32 addition order
        # is exactly ``base + (partial_0 + ... + partial_nk)`` — bit-identical
        # to the materialize-then-topk path (ops.lut16_adc_topk fallback).
        total = base_ref[...] + acc_ref[...]                     # (bq, bn)
        ids = jn * bn + jax.lax.broadcasted_iota(jnp.int32, total.shape, 1)
        buf_s = out_s_ref[...]
        thresh = buf_s[:, cbuf - 1:cbuf]                         # (bq, 1)

        @pl.when(jnp.any(total > thresh))
        def _merge():
            # Buffer entries come FIRST in the concat: among equal scores
            # top_k keeps the lower concat index, i.e. the earlier (lower-id)
            # row — the same tie-break a full-row lax.top_k applies.
            cat_s = jnp.concatenate([buf_s, total], axis=1)
            cat_i = jnp.concatenate([out_i_ref[...], ids], axis=1)
            top_s, pos = jax.lax.top_k(cat_s, cbuf)
            out_s_ref[...] = top_s
            out_i_ref[...] = jnp.take_along_axis(cat_i, pos, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("k", "bq", "bn", "bk", "interpret",
                                    "compute_dtype", "packed"))
def lut16_adc_topk_pallas(codes: jax.Array, lut: jax.Array, base: jax.Array,
                          *, k: int, bq: int = 8, bn: int = 512, bk: int = 32,
                          interpret: bool | None = None,
                          compute_dtype=jnp.float32, packed: bool = False):
    """Fused LUT16 scan + top-k select (DESIGN.md §2.5).

    Scores ``base + codes·lut`` and returns the per-query top candidates
    WITHOUT materializing the (Q, N) score matrix: the only outputs are the
    (Q, cbuf) candidate score/id buffers, cbuf = ``candidate_buffer_width(k)``.
    Callers slice ``[:, :k]``.

    base: additive bias, broadcast against the score block — either (Q, N)
    f32 (sparse+head+tombstones, the engine's pass-1 bias) or (1, N) f32 (a
    row mask only, e.g. -inf padding).  -inf rows can never enter the buffer
    ahead of finite ones; never-filled buffer slots stay (-inf, -1).

    Shapes must be divisible by the block sizes (ops.lut16_adc_topk pads);
    ids are row indices into the PADDED n axis."""
    if interpret is None:
        interpret = default_interpret()
    n, kc = codes.shape
    q, k2, l = lut.shape
    if packed:
        assert l == 16 and k2 == 2 * kc, (codes.shape, lut.shape)
    else:
        assert kc == k2, (codes.shape, lut.shape)
    assert n % bn == 0 and q % bq == 0 and kc % bk == 0, (n, q, kc, bq, bn, bk)
    assert base.ndim == 2 and base.shape[1] == n and base.shape[0] in (1, q), \
        (base.shape, q, n)
    cbuf = candidate_buffer_width(k)
    assert 0 < k <= n, (k, n)

    lut_bk = 2 * bk if packed else bk
    base_rows = base.shape[0]
    nk = kc // bk
    grid = (q // bq, n // bn, nk)
    out_s, out_i = pl.pallas_call(
        functools.partial(_fused_kernel, compute_dtype=compute_dtype,
                          packed=packed, cbuf=cbuf, bn=bn, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda iq, jn, kk: (jn, kk)),
            pl.BlockSpec((bq, lut_bk, l), lambda iq, jn, kk: (iq, kk, 0)),
            pl.BlockSpec((base_rows if base_rows == 1 else bq, bn),
                         (lambda iq, jn, kk: (0, jn)) if base_rows == 1
                         else (lambda iq, jn, kk: (iq, jn))),
        ],
        out_specs=[
            pl.BlockSpec((bq, cbuf), lambda iq, jn, kk: (iq, 0)),
            pl.BlockSpec((bq, cbuf), lambda iq, jn, kk: (iq, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((q, cbuf), jnp.float32),
                   jax.ShapeDtypeStruct((q, cbuf), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bq, bn), jnp.float32)],
        interpret=interpret,
    )(codes, lut, base)
    return out_s, out_i


def pack_codes(codes):
    """(N, K) codes in [0, 16) -> (N, ceil(K/2)) uint8, two codes per byte.

    Subspace 2j sits in the low nibble of byte j, subspace 2j+1 in the high
    nibble (paper §6.1.1's storage).  Odd K is zero-padded with one phantom
    subspace in the last byte's high nibble; scoring wrappers
    (ops.lut16_adc(packed=True) / unpack_codes) zero the phantom LUT column
    or slice it off, so the pad contributes nothing.  Values outside [0, 16)
    would silently corrupt the neighbouring nibble, so they are rejected.
    Host-side (numpy): runs once at index-construction time."""
    import numpy as np
    codes = np.asarray(codes)
    if codes.ndim != 2:
        raise ValueError(f"codes must be 2-D (N, K), got shape {codes.shape}")
    if codes.size and (codes.min() < 0 or codes.max() > 15):
        raise ValueError(
            "pack_codes requires 4-bit codes in [0, 16); got range "
            f"[{int(codes.min())}, {int(codes.max())}]")
    if codes.shape[1] % 2:
        codes = np.pad(codes, ((0, 0), (0, 1)))
    lo = codes[:, 0::2].astype(np.uint8)
    hi = codes[:, 1::2].astype(np.uint8)
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_codes(packed, k: int):
    """(N, Kp) packed bytes -> (N, k) uint8 codes; inverse of pack_codes.

    k is the LOGICAL subspace count: 2*Kp, or 2*Kp - 1 when the trailing
    high nibble is odd-K padding (which is sliced off here).  jnp-traceable —
    the engine's unpack-then-score path runs it inside jit, so the non-Pallas
    backends score packed storage bit-for-bit like unpacked storage."""
    kp = packed.shape[1]
    if not 0 <= 2 * kp - k <= 1:
        raise ValueError(
            f"(N, {kp}) packed bytes cannot hold {k} subspace codes")
    lo = packed & 0x0F
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=2).reshape(packed.shape[0], 2 * kp)
    return out[:, :k].astype(jnp.uint8)
