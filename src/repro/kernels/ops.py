"""Jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, host-side BCSR conversion, and the
interpret-mode switch (interpret=True everywhere except a real TPU backend).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .block_sparse import (block_sparse_matmul_pallas, dense_to_bcsr,
                           inverted_value_forward_pallas)
from .lut16 import (candidate_buffer_width, default_interpret as _interpret,
                    lut16_adc_pallas, lut16_adc_topk_pallas, pack_codes,
                    unpack_codes)
from .ref import lut16_adc_ref

__all__ = ["lut16_adc", "lut16_adc_topk", "lut16_adc_onehot",
           "block_sparse_matmul", "block_sparse_matmul_bcsr",
           "bcsr_from_head", "pack_codes", "unpack_codes",
           "score_inverted_vf", "dense_scores_materialized",
           "MAX_FUSED_CANDIDATES"]

# Fused-select candidate-buffer cap (DESIGN.md §2.5): (bq, cbuf) score+id
# buffers must stay VMEM-resident next to the (bq, bn) accumulator, and the
# per-block merge is a top_k over (cbuf + bn) lanes — past ~1k candidates the
# merge dominates the scan and materialize-then-topk wins anyway, so
# lut16_adc_topk falls back above this.
MAX_FUSED_CANDIDATES = 1024


def _pad_to(x: np.ndarray | jax.Array, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), size


def _resolve_lut16_blocks(q: int, n: int, kc: int, bq: int, bn: int,
                          bk: int | None, packed: bool):
    """One block-size resolution for BOTH the materialize and the fused LUT16
    wrappers, so their per-block fp32 partial sums are bit-identical.

    bk=None picks the stored-axis block: 32 bytes unpacked, 16 packed (one
    packed byte is two subspaces, so this keeps the LUT VMEM block equal).
    bn clamps against the 128-lane-rounded row count so small inputs aren't
    padded to a full 512."""
    if bk is None:
        bk = 16 if packed else 32
    bq = min(bq, max(1, q))
    bk = min(bk, kc)
    bn = min(bn, max(-(-n // 128) * 128, 128))
    return bq, bn, bk


def _validate_packed(kc: int, k: int, l: int, lut: jax.Array,
                     packed: bool) -> jax.Array:
    """Shared packed-storage validation + odd-K phantom-subspace LUT pad."""
    if packed:
        if l != 16:
            raise ValueError(f"packed codes require l == 16, got l={l}")
        if not 0 <= 2 * kc - k <= 1:
            raise ValueError(
                f"packed codes (N, {kc}) cannot hold a {k}-subspace LUT")
        if k < 2 * kc:                  # odd K: phantom subspace scores zero
            lut = jnp.pad(lut, ((0, 0), (0, 2 * kc - k), (0, 0)))
    elif k != kc:
        raise ValueError(f"codes (N, {kc}) do not match a {k}-subspace LUT")
    return lut


def lut16_adc(codes: jax.Array, lut: jax.Array, *, bq: int = 8, bn: int = 512,
              bk: int | None = None, compute_dtype=jnp.float32,
              packed: bool = False) -> jax.Array:
    """LUT16 ADC: codes (N, K) uint8, lut (Q, K, l) or (K, l) -> (Q, N).

    Pads N/Q/K to block multiples and routes through the Pallas kernel.

    packed=True: codes hold TWO 4-bit subspace codes per byte, shape
    (N, ceil(K/2)) from pack_codes — HBM streams half the bytes; the kernel
    unpacks in VMEM.  Requires l == 16.  Odd K is handled here by padding the
    LUT with a zero phantom subspace so the pad nibble (code 0) scores 0.

    bk=None picks the stored-axis block size: 32 bytes unpacked, 16 bytes
    packed.  One packed byte is two logical subspaces, so the packed LUT
    block spans 2*bk subspaces — halving bk keeps the per-step LUT VMEM
    footprint (bq * 2*bk * l floats) identical to the unpacked kernel's
    instead of doubling it (BENCH_serve.json records the resulting
    packed-vs-unpacked QPS at Q in {1, 8, 32})."""
    single = lut.ndim == 2
    if single:
        lut = lut[None]
    lut = jnp.asarray(lut, jnp.float32)
    q, k, l = lut.shape
    n, kc = codes.shape                 # kc: stored (byte) subspace axis
    lut = _validate_packed(kc, k, l, lut, packed)
    bq, bn, bk = _resolve_lut16_blocks(q, n, kc, bq, bn, bk, packed)
    codes_p, n0 = _pad_to(jnp.asarray(codes), 0, bn)
    # pad K consistently on both operands: padded codes point at LUT slot 0 of
    # padded subspaces whose LUT is zero, contributing nothing.  (In packed
    # form one padded byte is TWO zero-code phantom subspaces, so the LUT K
    # axis pads by 2*bk per code byte.)
    codes_p, _ = _pad_to(codes_p, 1, bk)
    lut_p, _ = _pad_to(lut, 1, 2 * bk if packed else bk)
    lut_p, q0 = _pad_to(lut_p, 0, bq)
    out = lut16_adc_pallas(codes_p, lut_p, bq=bq, bn=bn, bk=bk,
                           interpret=_interpret(), compute_dtype=compute_dtype,
                           packed=packed)
    out = out[:q0, :n0]
    return out[0] if single else out


def lut16_adc_topk(codes: jax.Array, lut: jax.Array, k: int, *,
                   bias: jax.Array | None = None,
                   row_mask: jax.Array | None = None,
                   bq: int = 8, bn: int = 512, bk: int | None = None,
                   compute_dtype=jnp.float32, packed: bool = False,
                   fused: bool = True):
    """Pass-1 scan-and-select: top-k of ``bias + row_mask + codes·lut``
    (DESIGN.md §2.5).

    codes (N, Kc) uint8 (packed two-per-byte when packed=True), lut
    (Q, K, l) f32, bias optional (Q, N) f32 (the engine's sparse+head term),
    row_mask optional (N,) f32 additive mask (0 live / -inf tombstoned).
    Returns ``(scores (Q, k) f32, ids (Q, k) int32)``; entries whose score is
    non-finite get id -1, in BOTH paths, so tombstoned rows never surface as
    candidates.

    fused=True routes through the fused Pallas kernel: the (Q, N) score
    matrix is never materialized — the kernel's only outputs are the
    (Q, cbuf) candidate buffers.  The fallback (fused=False, or
    k > MAX_FUSED_CANDIDATES: the candidate buffer would not fit the select)
    materializes scores with the SAME block sizes and adds the bias in the
    SAME fp32 order, so the two paths return bit-identical (scores, ids)."""
    lut = jnp.asarray(lut, jnp.float32)
    q, kl, l = lut.shape
    n, kc = codes.shape
    if not 0 < k <= n:
        raise ValueError(f"top-k needs 0 < k <= N rows, got k={k}, N={n}")
    lut = _validate_packed(kc, kl, l, lut, packed)
    bq, bn, bk = _resolve_lut16_blocks(q, n, kc, bq, bn, bk, packed)

    def _normalize(s, ids):
        return s, jnp.where(jnp.isfinite(s), ids, -1)

    if not (fused and k <= MAX_FUSED_CANDIDATES):
        # materialize-then-topk fallback: bias-first addition order matches
        # the fused kernel's select step bit-for-bit.
        dense = lut16_adc(codes, lut[:, :kl], bq=bq, bn=bn, bk=bk,
                          compute_dtype=compute_dtype, packed=packed)
        base = bias
        if row_mask is not None:
            rm = jnp.asarray(row_mask, jnp.float32)[None, :]
            base = rm if base is None else base + rm
        total = dense if base is None else base + dense
        s, ids = jax.lax.top_k(total, k)
        return _normalize(s, ids)

    codes_p, _ = _pad_to(jnp.asarray(codes), 0, bn)
    codes_p, _ = _pad_to(codes_p, 1, bk)
    lut_p, _ = _pad_to(lut, 1, 2 * bk if packed else bk)
    lut_p, _ = _pad_to(lut_p, 0, bq)
    n_pad = codes_p.shape[0]
    neg_inf = jnp.float32(-jnp.inf)
    if bias is not None:
        base = jnp.asarray(bias, jnp.float32)
        if row_mask is not None:
            base = base + jnp.asarray(row_mask, jnp.float32)[None, :]
        # padded query rows get -inf too: their buffers stay (-inf, -1) and
        # are sliced off below.
        base = jnp.pad(base, ((0, lut_p.shape[0] - q), (0, n_pad - n)),
                       constant_values=neg_inf)
    else:
        # no per-query bias: a (1, N) row mask is enough — the fused jaxpr
        # then contains NO (Q, N)-shaped value at all (the structural claim
        # dense_scores_materialized checks).
        rm = (jnp.asarray(row_mask, jnp.float32) if row_mask is not None
              else jnp.zeros((n,), jnp.float32))
        base = jnp.pad(rm[None, :], ((0, 0), (0, n_pad - n)),
                       constant_values=neg_inf)
    s, ids = lut16_adc_topk_pallas(codes_p, lut_p, base, k=k, bq=bq, bn=bn,
                                   bk=bk, interpret=_interpret(),
                                   compute_dtype=compute_dtype, packed=packed)
    return _normalize(s[:q, :k], ids[:q, :k])


def _jaxpr_types():
    try:                               # newer jax
        from jax.extend import core as xcore
        return xcore.Jaxpr, xcore.ClosedJaxpr
    except (ImportError, AttributeError):
        from jax import core as jcore
        return jcore.Jaxpr, jcore.ClosedJaxpr


def _walk_jaxpr_eqns(jaxpr):
    Jaxpr, ClosedJaxpr = _jaxpr_types()
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            # a pallas_call's body jaxpr manipulates VMEM *blocks*; only its
            # outvars (checked above) land in HBM.  Descending would flag
            # per-block temporaries — e.g. the fused select's (bq, cbuf+bn)
            # concat — that never exist at HBM scale.
            continue
        for v in eqn.params.values():
            for sub in jax.tree.leaves(
                    v, is_leaf=lambda x: isinstance(x, (Jaxpr, ClosedJaxpr))):
                if isinstance(sub, ClosedJaxpr):
                    yield from _walk_jaxpr_eqns(sub.jaxpr)
                elif isinstance(sub, Jaxpr):
                    yield from _walk_jaxpr_eqns(sub)


def dense_scores_materialized(fn, *args) -> bool:
    """Structural check for the fused-select claim (DESIGN.md §2.5): trace
    ``fn(*args)`` and report whether any equation in the jaxpr (recursively
    through pjit sub-jaxprs; pallas_call bodies are VMEM block scale and
    skipped, their HBM outvars are checked) PRODUCES a float32 value of shape
    (Q > 1, >= N) — i.e. a full per-query score matrix.  N is taken from the
    first argument's leading dim (the codes row count).  A (1, N) row mask is
    allowed: it is O(N) storage, not the O(Q·N) matrix the fused path
    eliminates.  True for materialize-then-topk, False for the fused path."""
    n = args[0].shape[0]
    closed = jax.make_jaxpr(fn)(*args)
    for eqn in _walk_jaxpr_eqns(closed.jaxpr):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if (aval is not None and getattr(aval, "ndim", 0) == 2
                    and aval.dtype == jnp.float32
                    and aval.shape[0] > 1 and aval.shape[1] >= n):
                return True
    return False


def score_inverted_vf(index, q_dims, q_vals, *, bq: int = 8, bn: int = 512,
                      chunk: int = 128) -> jax.Array:
    """Value-forward inverted-index scoring (SINDI-style; DESIGN.md §2.5):
    host-plans a row-sorted (row, query, contribution) stream per
    (query-block, row-block) and consumes it with MXU one-hot dots — no
    (Q, nq, L_max) gather rectangle and no (Q, N) scatter-add.

    Matches ``core.sparse_index.score_inverted`` on the same
    ``PaddedInvertedIndex``.  The stream layout depends on the query batch's
    nonzeros, so this op is HOST-PLANNED: it cannot sit inside the jitted
    three-pass search and serves the benchmarks/offline scans instead."""
    from repro.core.sparse_index import build_value_forward_stream
    st = build_value_forward_stream(index, q_dims, q_vals, bq=bq, bn=bn,
                                    chunk=chunk)
    out = inverted_value_forward_pallas(
        st.ptr, st.rows, st.qidx, st.contrib, bq=st.bq, bn=st.bn,
        chunk=st.chunk, num_row_blocks=st.num_row_blocks,
        max_steps=st.max_steps, interpret=_interpret())
    return out[:st.num_queries, :st.num_points]


@jax.jit
def lut16_adc_onehot(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """MXU one-hot ADC: codes (N, K) uint8, lut (Q, K, l) or (K, l) -> (Q, N).

    The LUT16 kernel's contraction expressed in jnp: codes expand to one-hot
    and contract against the LUT as a single matmul — no (Q, N, K) gather
    intermediate, systolic-friendly on TPU (bf16 operands, f32 accumulate)."""
    single = lut.ndim == 2
    lut3 = lut[None] if single else lut                       # (Q, K, l)
    n = codes.shape[0]
    l = lut3.shape[-1]
    onehot = (codes[:, :, None] ==
              jnp.arange(l, dtype=codes.dtype)).astype(jnp.bfloat16)
    out = jax.lax.dot_general(
        lut3.reshape(lut3.shape[0], -1).astype(jnp.bfloat16),
        onehot.reshape(n, -1),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (Q, N)
    return out[0] if single else out


def bcsr_from_head(head) -> tuple[jax.Array, jax.Array, jax.Array, int]:
    """TileSparseHead -> (tiles, tile_ptr, tile_col, max_steps) host-side."""
    block = np.asarray(head.block, np.float32)
    tiles, ptr, col = dense_to_bcsr(block, head.block_rows, head.block_cols)
    max_steps = int(np.max(ptr[1:] - ptr[:-1], initial=1))
    return (jnp.asarray(tiles), jnp.asarray(ptr), jnp.asarray(col), max_steps)


def block_sparse_matmul_bcsr(q_head: jax.Array, tiles: jax.Array,
                             ptr: jax.Array, col: jax.Array, *,
                             max_steps: int, bq: int = 8) -> jax.Array:
    """Tile-skipping head scoring over prebuilt BCSR arrays: pads the query
    block, runs the Pallas kernel, trims the padding.  Jit-safe."""
    qp, q0 = _pad_to(jnp.asarray(q_head, jnp.float32), 0, bq)
    out = block_sparse_matmul_pallas(qp, tiles, ptr, col, bq=bq,
                                     max_steps=max_steps,
                                     interpret=_interpret())
    return out[:q0]


def block_sparse_matmul(q_head: jax.Array, head, *, bq: int = 8) -> jax.Array:
    """Tile-skipping head scoring: q_head (Q, D_pad) × TileSparseHead -> (Q, N).

    Matches sparse_index.score_head_ref on the stored block matrix."""
    tiles, ptr, col, max_steps = bcsr_from_head(head)
    return block_sparse_matmul_bcsr(q_head, tiles, ptr, col,
                                    max_steps=max_steps, bq=bq)
