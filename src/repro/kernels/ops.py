"""Jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, host-side BCSR conversion, and the
interpret-mode switch (interpret=True everywhere except a real TPU backend).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .block_sparse import block_sparse_matmul_pallas, dense_to_bcsr
from .lut16 import (default_interpret as _interpret, lut16_adc_pallas,
                    pack_codes, unpack_codes)
from .ref import lut16_adc_ref

__all__ = ["lut16_adc", "lut16_adc_onehot", "block_sparse_matmul",
           "block_sparse_matmul_bcsr", "bcsr_from_head", "pack_codes",
           "unpack_codes"]


def _pad_to(x: np.ndarray | jax.Array, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), size


def lut16_adc(codes: jax.Array, lut: jax.Array, *, bq: int = 8, bn: int = 512,
              bk: int | None = None, compute_dtype=jnp.float32,
              packed: bool = False) -> jax.Array:
    """LUT16 ADC: codes (N, K) uint8, lut (Q, K, l) or (K, l) -> (Q, N).

    Pads N/Q/K to block multiples and routes through the Pallas kernel.

    packed=True: codes hold TWO 4-bit subspace codes per byte, shape
    (N, ceil(K/2)) from pack_codes — HBM streams half the bytes; the kernel
    unpacks in VMEM.  Requires l == 16.  Odd K is handled here by padding the
    LUT with a zero phantom subspace so the pad nibble (code 0) scores 0.

    bk=None picks the stored-axis block size: 32 bytes unpacked, 16 bytes
    packed.  One packed byte is two logical subspaces, so the packed LUT
    block spans 2*bk subspaces — halving bk keeps the per-step LUT VMEM
    footprint (bq * 2*bk * l floats) identical to the unpacked kernel's
    instead of doubling it (BENCH_serve.json records the resulting
    packed-vs-unpacked QPS at Q in {1, 8, 32})."""
    single = lut.ndim == 2
    if single:
        lut = lut[None]
    lut = jnp.asarray(lut, jnp.float32)
    q, k, l = lut.shape
    n, kc = codes.shape                 # kc: stored (byte) subspace axis
    if bk is None:
        bk = 16 if packed else 32
    if packed:
        if l != 16:
            raise ValueError(f"packed codes require l == 16, got l={l}")
        if not 0 <= 2 * kc - k <= 1:
            raise ValueError(
                f"packed codes (N, {kc}) cannot hold a {k}-subspace LUT")
        if k < 2 * kc:                  # odd K: phantom subspace scores zero
            lut = jnp.pad(lut, ((0, 0), (0, 2 * kc - k), (0, 0)))
    elif k != kc:
        raise ValueError(f"codes (N, {kc}) do not match a {k}-subspace LUT")
    bq = min(bq, max(1, q))
    bk = min(bk, kc)
    # clamp the row block against the actual row count (rounded up to the
    # 128-lane granularity) so small inputs aren't padded to a full bn=512.
    bn = min(bn, max(-(-n // 128) * 128, 128))
    codes_p, n0 = _pad_to(jnp.asarray(codes), 0, bn)
    # pad K consistently on both operands: padded codes point at LUT slot 0 of
    # padded subspaces whose LUT is zero, contributing nothing.  (In packed
    # form one padded byte is TWO zero-code phantom subspaces, so the LUT K
    # axis pads by 2*bk per code byte.)
    codes_p, _ = _pad_to(codes_p, 1, bk)
    lut_p, _ = _pad_to(lut, 1, 2 * bk if packed else bk)
    lut_p, q0 = _pad_to(lut_p, 0, bq)
    out = lut16_adc_pallas(codes_p, lut_p, bq=bq, bn=bn, bk=bk,
                           interpret=_interpret(), compute_dtype=compute_dtype,
                           packed=packed)
    out = out[:q0, :n0]
    return out[0] if single else out


@jax.jit
def lut16_adc_onehot(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """MXU one-hot ADC: codes (N, K) uint8, lut (Q, K, l) or (K, l) -> (Q, N).

    The LUT16 kernel's contraction expressed in jnp: codes expand to one-hot
    and contract against the LUT as a single matmul — no (Q, N, K) gather
    intermediate, systolic-friendly on TPU (bf16 operands, f32 accumulate)."""
    single = lut.ndim == 2
    lut3 = lut[None] if single else lut                       # (Q, K, l)
    n = codes.shape[0]
    l = lut3.shape[-1]
    onehot = (codes[:, :, None] ==
              jnp.arange(l, dtype=codes.dtype)).astype(jnp.bfloat16)
    out = jax.lax.dot_general(
        lut3.reshape(lut3.shape[0], -1).astype(jnp.bfloat16),
        onehot.reshape(n, -1),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (Q, N)
    return out[0] if single else out


def bcsr_from_head(head) -> tuple[jax.Array, jax.Array, jax.Array, int]:
    """TileSparseHead -> (tiles, tile_ptr, tile_col, max_steps) host-side."""
    block = np.asarray(head.block, np.float32)
    tiles, ptr, col = dense_to_bcsr(block, head.block_rows, head.block_cols)
    max_steps = int(np.max(ptr[1:] - ptr[:-1], initial=1))
    return (jnp.asarray(tiles), jnp.asarray(ptr), jnp.asarray(col), max_steps)


def block_sparse_matmul_bcsr(q_head: jax.Array, tiles: jax.Array,
                             ptr: jax.Array, col: jax.Array, *,
                             max_steps: int, bq: int = 8) -> jax.Array:
    """Tile-skipping head scoring over prebuilt BCSR arrays: pads the query
    block, runs the Pallas kernel, trims the padding.  Jit-safe."""
    qp, q0 = _pad_to(jnp.asarray(q_head, jnp.float32), 0, bq)
    out = block_sparse_matmul_pallas(qp, tiles, ptr, col, bq=bq,
                                     max_steps=max_steps,
                                     interpret=_interpret())
    return out[:q0]


def block_sparse_matmul(q_head: jax.Array, head, *, bq: int = 8) -> jax.Array:
    """Tile-skipping head scoring: q_head (Q, D_pad) × TileSparseHead -> (Q, N).

    Matches sparse_index.score_head_ref on the stored block matrix."""
    tiles, ptr, col, max_steps = bcsr_from_head(head)
    return block_sparse_matmul_bcsr(q_head, tiles, ptr, col,
                                    max_steps=max_steps, bq=bq)
