"""Pure-jnp oracles for every Pallas kernel in this package.

Tests sweep shapes/dtypes and assert the kernels (interpret=True on CPU)
match these references.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lut16_adc_ref", "block_sparse_ref", "bcsr_to_dense_ref"]


@jax.jit
def lut16_adc_ref(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """out[q, n] = sum_k lut[q, k, codes[n, k]].

    codes (N, K) integer; lut (Q, K, l) float32 -> (Q, N) float32."""
    gathered = jnp.take_along_axis(
        lut[:, None],                                  # (Q, 1, K, l)
        codes[None, :, :, None].astype(jnp.int32),     # (1, N, K, 1)
        axis=3,
    )[..., 0]                                          # (Q, N, K)
    return gathered.sum(axis=-1).astype(jnp.float32)


def bcsr_to_dense_ref(tiles, tile_ptr, tile_col, d: int) -> jax.Array:
    """Reassemble the dense (N, D) head matrix from BCSR tiles (host/test
    helper; not jitted — tile_ptr drives python loops)."""
    import numpy as np
    tiles = np.asarray(tiles)
    tile_ptr = np.asarray(tile_ptr)
    tile_col = np.asarray(tile_col)
    t, br, bc = tiles.shape
    nb = len(tile_ptr) - 1
    out = np.zeros((nb * br, d), tiles.dtype)
    for i in range(nb):
        for tt in range(tile_ptr[i], tile_ptr[i + 1]):
            j = tile_col[tt]
            out[i * br:(i + 1) * br, j * bc:(j + 1) * bc] = tiles[tt]
    return jnp.asarray(out)


@jax.jit
def block_sparse_ref(q: jax.Array, x_head: jax.Array) -> jax.Array:
    """out = q @ x_head^T : (Q, D) x (N, D) -> (Q, N) float32."""
    return (q.astype(jnp.float32) @ x_head.astype(jnp.float32).T)
