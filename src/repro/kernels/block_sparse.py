"""Cache-sorted block-sparse scoring as a Pallas TPU kernel (paper §3.1-3.3,
TPU-adapted — see DESIGN.md §2).

The paper's cache-sorted inverted index minimizes 64-byte accumulator
cache-lines touched.  The TPU analogue: store the (N × d_head) head-dim
matrix as **BCSR over (block_rows × block_cols) VMEM tiles**, keeping *only
nonzero tiles* in HBM.  Cache sorting (Algorithm 1) is exactly the
permutation that minimizes the number of stored/streamed tiles, so the
paper's E[C_sort] cost model (Eq. 5 with B = tile rows) directly predicts
this kernel's DMA traffic.

Scalar-prefetch drives the gather: the grid walks (query-block, row-block,
step) and the per-row-block tile list is resolved through prefetched
``tile_ptr``/``tile_col`` arrays inside the BlockSpec index_maps — i.e. the
kernel *never touches* zero tiles, matching the paper's skipped cache-lines.

Contract (matches kernels/ref.py::block_sparse_ref):
  q       (Q, D) float32          dense query head-subvectors
  tiles   (T, Br, Bc) float32     nonzero tiles, row-block-major
  tile_ptr(NB + 1,) int32         CSR offsets over row-blocks
  tile_col(T,) int32              column-block index of each tile
  out     (Q, N) float32          q @ X_head^T  (X reassembled from tiles)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["block_sparse_matmul_pallas", "dense_to_bcsr",
           "inverted_value_forward_pallas"]


def dense_to_bcsr(x: np.ndarray, br: int, bc: int):
    """(N, D) -> (tiles (T,br,bc), tile_ptr (N/br+1,), tile_col (T,)).

    T == number of nonzero tiles == the object cache sorting minimizes."""
    n, d = x.shape
    assert n % br == 0 and d % bc == 0, (x.shape, br, bc)
    nb, db = n // br, d // bc
    view = x.reshape(nb, br, db, bc).transpose(0, 2, 1, 3)     # (nb, db, br, bc)
    nz = np.abs(view).max(axis=(2, 3)) > 0                     # (nb, db)
    tiles, cols, ptr = [], [], [0]
    for i in range(nb):
        for j in np.flatnonzero(nz[i]):
            tiles.append(view[i, j])
            cols.append(j)
        ptr.append(len(tiles))
    if not tiles:                                              # fully zero
        tiles = [np.zeros((br, bc), x.dtype)]
        cols = [0]
        ptr = [0] * (nb + 1)
    return (np.stack(tiles).astype(np.float32),
            np.asarray(ptr, np.int32), np.asarray(cols, np.int32))


def _kernel(ptr_ref, col_ref, q_ref, tiles_ref, out_ref):
    nb_idx = pl.program_id(1)
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    t = ptr_ref[nb_idx] + step
    valid = t < ptr_ref[nb_idx + 1]

    @pl.when(valid)
    def _acc():
        tile = tiles_ref[0]                                   # (Br, Bc)
        qv = q_ref[...]                                       # (bq, Bc)
        out_ref[...] += jax.lax.dot_general(
            qv, tile, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bq", "max_steps", "interpret"))
def block_sparse_matmul_pallas(q: jax.Array, tiles: jax.Array,
                               tile_ptr: jax.Array, tile_col: jax.Array,
                               *, bq: int = 8, max_steps: int = 1,
                               interpret: bool = True) -> jax.Array:
    """q (Q, D) × BCSR head matrix -> (Q, N).  Q % bq == 0 (ops.py pads).

    ``max_steps`` bounds the per-row-block tile count (grid dim 2); pass the
    true max (host-computed from tile_ptr) for a tight grid — extra steps are
    masked out, zero tiles are never fetched either way."""
    qn, d = q.shape
    t_total, br, bc = tiles.shape
    nb = tile_ptr.shape[0] - 1
    n = nb * br
    assert d % bc == 0 and qn % bq == 0
    max_steps = max(int(max_steps), 1)

    grid = (qn // bq, nb, max_steps)

    def q_map(iq, jn, s, ptr, col):
        t = jnp.minimum(ptr[jn] + s, t_total - 1)
        return (iq, col[t])

    def tiles_map(iq, jn, s, ptr, col):
        t = jnp.minimum(ptr[jn] + s, t_total - 1)
        return (t, 0, 0)

    def out_map(iq, jn, s, ptr, col):
        return (iq, jn)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bq, bc), q_map),
                pl.BlockSpec((1, br, bc), tiles_map),
            ],
            out_specs=pl.BlockSpec((bq, br), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((qn, n), jnp.float32),
        interpret=interpret,
    )(tile_ptr, tile_col, q, tiles)


# ---------------------------------------------------------------------------
# Value-forward inverted-index traversal (SINDI-motivated; DESIGN.md §2.5)
# ---------------------------------------------------------------------------

def _vf_kernel(ptr_ref, rows_ref, qidx_ref, contrib_ref, out_ref, *,
               bq: int, bn: int, chunk: int, nb1: int):
    """Consume one chunk of the (row, query, contribution) stream.

    The stream is row-sorted per (query-block, row-block), so each chunk
    lands entirely in the current (bq, bn) output tile: a query one-hot
    weighted by the contributions (bq, chunk) contracted against a local-row
    one-hot (chunk, bn) scatter-adds the whole chunk on the MXU — the
    value-forward replacement for the (Q, nq, L_max) gather + (Q, N)
    scatter-add of score_inverted."""
    b = pl.program_id(0)
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    start = ptr_ref[b * nb1 + j]
    end = ptr_ref[b * nb1 + j + 1]

    @pl.when(start + s < end)
    def _acc():
        rows = rows_ref[0]                                     # (chunk,) local
        qi = qidx_ref[0]                                       # (chunk,)
        cv = contrib_ref[0]                                    # (chunk,)
        qsel = (qi[None, :] ==
                jax.lax.broadcasted_iota(jnp.int32, (bq, chunk), 0)
                ).astype(jnp.float32) * cv[None, :]            # (bq, chunk)
        rsel = (rows[:, None] ==
                jax.lax.broadcasted_iota(jnp.int32, (chunk, bn), 1)
                ).astype(jnp.float32)                          # (chunk, bn)
        out_ref[...] += jax.lax.dot_general(
            qsel, rsel, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bn", "chunk", "num_row_blocks",
                                    "max_steps", "interpret"))
def inverted_value_forward_pallas(ptr: jax.Array, rows: jax.Array,
                                  qidx: jax.Array, contrib: jax.Array, *,
                                  bq: int, bn: int, chunk: int,
                                  num_row_blocks: int, max_steps: int,
                                  interpret: bool = True) -> jax.Array:
    """Value-forward inverted scoring over a host-planned stream.

    ptr (QB*(NB+1),) int32 chunk offsets per (query-block, row-block) —
    scalar-prefetched so the BlockSpec index maps stream exactly the chunks
    each tile owns; rows/qidx/contrib (QB, P_pad): block-LOCAL row ids
    (pad = bn, matches nothing), query index within the block (pad 0), and
    q_val*posting_val contributions (pad 0).  Returns
    (QB*bq, num_row_blocks*bn) f32 scores; callers slice to (Q, N).

    Built by ``core.sparse_index.build_value_forward_stream``; wrapped by
    ``kernels.ops.score_inverted_vf``."""
    qb, p_pad = rows.shape
    assert p_pad % chunk == 0 and p_pad > 0, (p_pad, chunk)
    total_chunks = p_pad // chunk
    nb1 = num_row_blocks + 1
    grid = (qb, num_row_blocks, max(int(max_steps), 1))

    def stream_map(b, j, s, ptr):
        c = jnp.minimum(ptr[b * nb1 + j] + s, total_chunks - 1)
        return (b, c)

    def out_map(b, j, s, ptr):
        return (b, j)

    return pl.pallas_call(
        functools.partial(_vf_kernel, bq=bq, bn=bn, chunk=chunk, nb1=nb1),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, chunk), stream_map),
                pl.BlockSpec((1, chunk), stream_map),
                pl.BlockSpec((1, chunk), stream_map),
            ],
            out_specs=pl.BlockSpec((bq, bn), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((qb * bq, num_row_blocks * bn),
                                       jnp.float32),
        interpret=interpret,
    )(ptr, rows, qidx, contrib)
