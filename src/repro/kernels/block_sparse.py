"""Cache-sorted block-sparse scoring as a Pallas TPU kernel (paper §3.1-3.3,
TPU-adapted — see DESIGN.md §2).

The paper's cache-sorted inverted index minimizes 64-byte accumulator
cache-lines touched.  The TPU analogue: store the (N × d_head) head-dim
matrix as **BCSR over (block_rows × block_cols) VMEM tiles**, keeping *only
nonzero tiles* in HBM.  Cache sorting (Algorithm 1) is exactly the
permutation that minimizes the number of stored/streamed tiles, so the
paper's E[C_sort] cost model (Eq. 5 with B = tile rows) directly predicts
this kernel's DMA traffic.

Scalar-prefetch drives the gather: the grid walks (query-block, row-block,
step) and the per-row-block tile list is resolved through prefetched
``tile_ptr``/``tile_col`` arrays inside the BlockSpec index_maps — i.e. the
kernel *never touches* zero tiles, matching the paper's skipped cache-lines.

Contract (matches kernels/ref.py::block_sparse_ref):
  q       (Q, D) float32          dense query head-subvectors
  tiles   (T, Br, Bc) float32     nonzero tiles, row-block-major
  tile_ptr(NB + 1,) int32         CSR offsets over row-blocks
  tile_col(T,) int32              column-block index of each tile
  out     (Q, N) float32          q @ X_head^T  (X reassembled from tiles)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["block_sparse_matmul_pallas", "dense_to_bcsr"]


def dense_to_bcsr(x: np.ndarray, br: int, bc: int):
    """(N, D) -> (tiles (T,br,bc), tile_ptr (N/br+1,), tile_col (T,)).

    T == number of nonzero tiles == the object cache sorting minimizes."""
    n, d = x.shape
    assert n % br == 0 and d % bc == 0, (x.shape, br, bc)
    nb, db = n // br, d // bc
    view = x.reshape(nb, br, db, bc).transpose(0, 2, 1, 3)     # (nb, db, br, bc)
    nz = np.abs(view).max(axis=(2, 3)) > 0                     # (nb, db)
    tiles, cols, ptr = [], [], [0]
    for i in range(nb):
        for j in np.flatnonzero(nz[i]):
            tiles.append(view[i, j])
            cols.append(j)
        ptr.append(len(tiles))
    if not tiles:                                              # fully zero
        tiles = [np.zeros((br, bc), x.dtype)]
        cols = [0]
        ptr = [0] * (nb + 1)
    return (np.stack(tiles).astype(np.float32),
            np.asarray(ptr, np.int32), np.asarray(cols, np.int32))


def _kernel(ptr_ref, col_ref, q_ref, tiles_ref, out_ref):
    nb_idx = pl.program_id(1)
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    t = ptr_ref[nb_idx] + step
    valid = t < ptr_ref[nb_idx + 1]

    @pl.when(valid)
    def _acc():
        tile = tiles_ref[0]                                   # (Br, Bc)
        qv = q_ref[...]                                       # (bq, Bc)
        out_ref[...] += jax.lax.dot_general(
            qv, tile, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bq", "max_steps", "interpret"))
def block_sparse_matmul_pallas(q: jax.Array, tiles: jax.Array,
                               tile_ptr: jax.Array, tile_col: jax.Array,
                               *, bq: int = 8, max_steps: int = 1,
                               interpret: bool = True) -> jax.Array:
    """q (Q, D) × BCSR head matrix -> (Q, N).  Q % bq == 0 (ops.py pads).

    ``max_steps`` bounds the per-row-block tile count (grid dim 2); pass the
    true max (host-computed from tile_ptr) for a tight grid — extra steps are
    masked out, zero tiles are never fetched either way."""
    qn, d = q.shape
    t_total, br, bc = tiles.shape
    nb = tile_ptr.shape[0] - 1
    n = nb * br
    assert d % bc == 0 and qn % bq == 0
    max_steps = max(int(max_steps), 1)

    grid = (qn // bq, nb, max_steps)

    def q_map(iq, jn, s, ptr, col):
        t = jnp.minimum(ptr[jn] + s, t_total - 1)
        return (iq, col[t])

    def tiles_map(iq, jn, s, ptr, col):
        t = jnp.minimum(ptr[jn] + s, t_total - 1)
        return (t, 0, 0)

    def out_map(iq, jn, s, ptr, col):
        return (iq, jn)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bq, bc), q_map),
                pl.BlockSpec((1, br, bc), tiles_map),
            ],
            out_specs=pl.BlockSpec((bq, br), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((qn, n), jnp.float32),
        interpret=interpret,
    )(tile_ptr, tile_col, q, tiles)
