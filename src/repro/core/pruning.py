"""Per-dimension magnitude pruning of the sparse component (paper §4.2, §6).

Two-level split (paper Eq. 6 / Eq. 7):
  data index      keeps entries with |x_j| >= eta_j   (hyper-sparse, fast scan)
  residual index  keeps entries with eta_j > |x_j| >= eps_j
  dropped         entries below eps_j (bounded error, Proposition 3)

eta_j is set so only the top ``keep_top`` magnitudes per dimension survive
(paper §6.1.2: "only top 100s of nonzero values in dimension j are kept"); eps_j
keeps "most" of the rest (we default to keeping everything: eps_j = 0).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

__all__ = ["PruneSplit", "per_dim_thresholds", "prune_split"]


@dataclasses.dataclass
class PruneSplit:
    index: sp.csr_matrix      # Prune(x; eta)        — first-pass data index
    residual: sp.csr_matrix   # Prune(R(x); eps)     — residual index
    dropped_mass: float       # fraction of L1 mass below eps (diagnostic)
    eta: np.ndarray           # (d,) thresholds
    eps: np.ndarray           # (d,)


def per_dim_thresholds(x_sparse, keep_top: int) -> np.ndarray:
    """eta_j = magnitude of the ``keep_top``-th largest |value| in dimension j
    (0 if the dimension has fewer nonzeros — everything kept)."""
    xc = x_sparse.tocsc()
    d = xc.shape[1]
    eta = np.zeros(d, dtype=np.float64)
    data = np.abs(xc.data)
    for j in range(d):
        lo, hi = xc.indptr[j], xc.indptr[j + 1]
        vals = data[lo:hi]
        if len(vals) > keep_top:
            # threshold = keep_top-th largest; strictly-greater entries survive
            # alongside ties at the threshold (>= in Eq. 6).
            eta[j] = np.partition(vals, len(vals) - keep_top)[len(vals) - keep_top]
    return eta


def prune_split(x_sparse, keep_top: int = 256,
                eps_quantile: float = 0.0) -> PruneSplit:
    """Split X^S into (data index, residual index) per paper §6 step (1)."""
    xr = x_sparse.tocsr().astype(np.float32)
    eta = per_dim_thresholds(xr, keep_top)

    coo = xr.tocoo()
    mag = np.abs(coo.data)
    in_index = mag >= eta[coo.col]

    if eps_quantile > 0.0 and (~in_index).any():
        rest = mag[~in_index]
        eps_val = np.quantile(rest, eps_quantile)
    else:
        eps_val = 0.0
    eps = np.full(xr.shape[1], eps_val, dtype=np.float64)
    in_resid = (~in_index) & (mag >= eps[coo.col])

    def pick(mask):
        return sp.csr_matrix(
            (coo.data[mask], (coo.row[mask], coo.col[mask])), shape=xr.shape
        )

    total = mag.sum() + 1e-30
    dropped = mag[(~in_index) & (~in_resid)].sum() / total
    return PruneSplit(index=pick(in_index), residual=pick(in_resid),
                      dropped_mass=float(dropped), eta=eta, eps=eps)
