"""HybridIndex — the paper's full indexing + search pipeline (paper §6).

Build:
  1. cache-sort datapoints (Algorithm 1) — all row-parallel structures below
     store rows in sorted order; search maps ids back at the end.
  2. sparse data index: eta-prune (top ``keep_top`` per dim), split into the
     tile-sorted head block (most-active dims) + padded inverted index (tail).
  3. sparse residual index: remaining entries as padded rows (eps = 0 default).
  4. dense data index: PQ, K_U = d^D/2 subspaces, l = 16 (LUT16 kernel path).
  5. dense residual index: int8 scalar quantization (K_V = d^D, l = 256).

Search (batch of hybrid queries) is delegated to core/engine.py's
ScoringEngine: the entire three-pass loop (pass 1 approx overfetch alpha*h,
pass 2 + dense residual keep beta*h, pass 3 + sparse residual return top h)
runs as one jitted device function; this class only converts queries to the
padded device layout and maps result positions back to original ids.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from .cache_sort import cache_sort, dimension_activity
from .engine import Backend, IndexArrays, ScoringEngine
from .pq import (PQCodebooks, ScalarQuant, pq_decode, pq_encode,
                 scalar_quantize, train_codebooks)
from .pruning import prune_split
from .sparse_index import (CompactColumns, PaddedInvertedIndex,
                           PaddedSparseRows, TileSparseHead,
                           build_compact_columns, build_padded_inverted_index,
                           build_padded_rows, build_tile_sparse_head,
                           sparse_queries_to_padded)

__all__ = ["HybridIndexParams", "HybridIndex", "SearchResult"]


@dataclasses.dataclass(frozen=True)
class HybridIndexParams:
    # sparse side
    keep_top: int = 256          # eta: entries kept per dim in the data index
    head_dims: int = 128         # most-active dims served by the tile block
    block_rows: int = 128        # tile height (the TPU "cache line", B)
    block_cols: int = 128
    nq_max: int = 256            # padded query nnz
    use_head_block: bool = True
    # dense side
    pq_subspaces: int | None = None   # default d^D // 2  (paper §6.1.1)
    pq_codes: int = 16
    kmeans_iters: int = 12
    seed: int = 0
    # search
    alpha: int = 20              # overfetch multiplier (pass 1)
    beta: int = 5                # keep multiplier (pass 2)
    use_lut16_kernel: bool = False  # legacy alias for backend="pallas"
    # engine backend: ref | onehot-mxu | pallas | pallas-packed
    backend: str | None = None
    # store PQ codes packed two-per-byte (half the HBM).  None => pack iff
    # the backend is pallas-packed; True also works with ref/onehot (they
    # unpack in-jit, bit-for-bit with unpacked storage).
    pack_codes: bool | None = None

    def resolve_backend(self) -> Backend:
        if self.backend is not None:
            return Backend.from_name(self.backend)
        return Backend.PALLAS if self.use_lut16_kernel else Backend.REF

    def resolve_pack(self) -> bool:
        if self.pack_codes is not None:
            return self.pack_codes
        return self.resolve_backend() is Backend.PALLAS_PACKED


@dataclasses.dataclass
class SearchResult:
    ids: np.ndarray        # (Q, h) original datapoint ids
    scores: np.ndarray     # (Q, h) refined inner products
    # diagnostics
    pass1_ids: np.ndarray | None = None


@dataclasses.dataclass
class HybridIndex:
    params: HybridIndexParams
    num_points: int
    pi: np.ndarray                     # sorted position -> original id
    cols: CompactColumns
    inv_index: PaddedInvertedIndex     # tail dims of the pruned data index
    head: TileSparseHead | None        # head dims of the pruned data index
    head_dim_ids: np.ndarray           # compact ids in the head block (pad -1)
    sparse_residual: PaddedSparseRows
    codebooks: PQCodebooks
    codes: jax.Array                   # (N, K) uint8; (N, ceil(K/2)) packed
                                       # when params.resolve_pack() — the
                                       # engine's array, not a second copy
    dense_residual: ScalarQuant
    d_dense: int
    engine: ScoringEngine              # device-resident three-pass scorer
    # streaming support (core/streaming.py, DESIGN.md §6): present iff the
    # index was built with mutable=True; owns the retained corpus, the delta
    # shard, and the tombstone bookkeeping behind insert()/delete()/compact()
    mutable_state: "object | None" = None

    # -- build -------------------------------------------------------------
    @classmethod
    def build(cls, x_sparse: sp.spmatrix, x_dense: np.ndarray,
              params: HybridIndexParams = HybridIndexParams(), *,
              mutable: bool = False,
              ext_ids: np.ndarray | None = None,
              delta_capacity: int = 64) -> "HybridIndex":
        x_sparse = x_sparse.tocsr()
        n = x_sparse.shape[0]
        x_dense = np.asarray(x_dense, np.float32)
        assert x_dense.shape[0] == n

        # 1. cache sort; permute every row-parallel structure once.
        pi = cache_sort(x_sparse)
        xs = x_sparse[pi]
        xd = x_dense[pi]

        # 2-3. prune + compact columns over the FULL sparse matrix so data
        # index and residual share one column space.
        split = prune_split(xs, keep_top=params.keep_top)
        cols, _ = build_compact_columns(xs)
        idx_compact = _remap(split.index, cols)
        res_compact = _remap(split.residual, cols)

        head = None
        head_dim_ids = np.empty(0, np.int32)
        tail_index = idx_compact
        if params.use_head_block and cols.num_active > 0:
            activity = dimension_activity(idx_compact)
            n_head = min(params.head_dims, cols.num_active)
            head_compact = np.sort(np.argsort(-activity)[:n_head]).astype(np.int32)
            head = build_tile_sparse_head(
                idx_compact, head_compact,
                block_rows=params.block_rows, block_cols=params.block_cols)
            head_dim_ids = np.asarray(head.head_dims)
            # zero head dims out of the tail inverted index
            tail_index = idx_compact.tolil()
            tail_index[:, head_compact] = 0
            tail_index = tail_index.tocsr()
            tail_index.eliminate_zeros()
        inv_index = build_padded_inverted_index(tail_index)
        sparse_residual = build_padded_rows(res_compact)

        # 4. dense PQ data index
        d_dense = xd.shape[1]
        k_u = params.pq_subspaces or max(d_dense // 2, 1)
        cb = train_codebooks(jnp.asarray(xd), k_u, params.pq_codes,
                             iters=params.kmeans_iters, seed=params.seed)
        codes = pq_encode(jnp.asarray(xd), cb)

        # 5. dense residual index (int8)
        recon = np.asarray(pq_decode(codes, cb))
        dres = scalar_quantize(jnp.asarray(xd - recon))

        backend = params.resolve_backend()
        arrays = IndexArrays.build(
            codebooks=cb, codes=codes, inv_index=inv_index, head=head,
            dense_residual=dres, sparse_residual=sparse_residual,
            num_points=n, d_active=cols.num_active,
            with_bcsr=backend in (Backend.PALLAS, Backend.PALLAS_PACKED),
            pack=params.resolve_pack())
        engine = ScoringEngine(arrays=arrays, backend=backend)
        # hold the ENGINE's codes (possibly packed): the unpacked (N, K)
        # build-time array must not stay resident or packing saves nothing.
        idx = cls(params=params, num_points=n, pi=pi, cols=cols,
                  inv_index=inv_index, head=head, head_dim_ids=head_dim_ids,
                  sparse_residual=sparse_residual, codebooks=cb,
                  codes=arrays.codes, dense_residual=dres, d_dense=d_dense,
                  engine=engine)
        if mutable:
            from .streaming import MutableState
            # delta_capacity pre-sizes the delta shard's device arrays
            # (amortized doubling still applies past it); a caller that
            # knows its insert rate avoids the growth re-materializations
            idx.mutable_state = MutableState(idx, x_sparse, x_dense,
                                             ext_ids=ext_ids,
                                             delta_capacity=delta_capacity)
        elif ext_ids is not None:
            raise ValueError("ext_ids only applies with mutable=True")
        return idx

    # -- persistence (thin wrappers over repro/persist, DESIGN.md §7) ------
    @classmethod
    def load(cls, root: str, *, backend=None) -> "HybridIndex":
        """Recover a mutable index from a durable store: committed snapshot
        (checksum-verified leaf blobs) + WAL-tail replay through the
        streaming machinery — bit-identical, ids and scores, to the index
        at its last durably-acked mutation.  ``backend`` overrides the
        recorded engine backend (any backend serves any snapshot)."""
        from repro.persist import recover
        rec = recover(root, backend=backend)
        rec.durability.close()       # load-only: no appends from here
        return rec.index

    def save(self, root: str) -> str:
        """Bootstrap a durable store for this freshly built mutable index
        (initial snapshot + empty WAL) without keeping a WAL handle open —
        the one-shot "write my index to disk" form.  Serving with
        durability goes through ``QueryService(persist_dir=…)`` instead."""
        from repro.persist import bootstrap
        d = bootstrap(root, self)
        d.close()
        return root

    # -- streaming mutation (thin wrappers over core/streaming.py) ---------
    def _mutable(self):
        if self.mutable_state is None:
            raise ValueError("index is immutable; build with "
                             "HybridIndex.build(..., mutable=True)")
        return self.mutable_state

    def insert(self, x_sparse, x_dense, ids=None) -> np.ndarray:
        """Insert (or upsert) rows into the delta shard (DESIGN.md §6),
        encoded against the frozen build artifacts.  Returns external ids."""
        return self._mutable().insert(x_sparse, x_dense, ids=ids)

    def delete(self, ids) -> int:
        """Tombstone rows by external id; returns how many were live."""
        return self._mutable().delete(ids)

    def compact(self, retrain: bool | None = None) -> "HybridIndex":
        """Fold the delta + tombstones down; returns the NEW mutable index
        (this one is untouched — swap at the call site, e.g.
        QueryService.refresh).  ``retrain=True`` re-runs the full batch
        build (new codebooks / column space / cache-sort); ``retrain=False``
        merge-compacts into the frozen artifacts; ``None`` (default) merges
        unless out-of-column-space sparse entries force a retrain
        (core/streaming.py, DESIGN.md §6.2)."""
        return self._mutable().compact(retrain=retrain)

    @property
    def delta_version(self) -> int:
        """Monotone mutation counter (0 for an untouched mutable index)."""
        return self._mutable().version

    # -- search ------------------------------------------------------------
    def search(self, q_sparse: sp.spmatrix, q_dense: np.ndarray, h: int = 20,
               alpha: int | None = None, beta: int | None = None,
               return_pass1: bool = False) -> SearchResult:
        """Thin wrapper: pad queries to the device layout, run the engine's
        single-jit three-pass search, map positions back to original ids.

        A mutable index (build(..., mutable=True)) routes through the
        delta-merging path instead and returns EXTERNAL ids (which default
        to build-row positions, so the two paths agree until the first
        mutation)."""
        if self.mutable_state is not None:
            if return_pass1:
                raise ValueError("return_pass1 is a diagnostic of the "
                                 "single-engine path; not available on a "
                                 "mutable index")
            from .streaming import search_mutable
            return search_mutable(self, q_sparse, q_dense, h=h,
                                  alpha=alpha, beta=beta)
        p = self.params
        alpha = p.alpha if alpha is None else alpha
        beta = p.beta if beta is None else beta

        q_dense = jnp.asarray(np.asarray(q_dense, np.float32))
        q_dims_np, q_vals_np = sparse_queries_to_padded(
            q_sparse, self.cols, nq_max=p.nq_max)
        s3, ids3, ids1 = self.engine.search(
            jnp.asarray(q_dims_np), jnp.asarray(q_vals_np), q_dense,
            h=h, alpha=alpha, beta=beta)

        orig = self.pi[np.asarray(ids3)]
        return SearchResult(
            ids=orig, scores=np.asarray(s3),
            pass1_ids=self.pi[np.asarray(ids1)] if return_pass1 else None)

    def exact_scores(self, q_sparse: sp.spmatrix, q_dense: np.ndarray,
                     x_sparse: sp.spmatrix, x_dense: np.ndarray) -> np.ndarray:
        """Brute-force q·x for validation (original row order)."""
        return (np.asarray((q_sparse @ x_sparse.T).todense())
                + np.asarray(q_dense, np.float32) @ np.asarray(x_dense, np.float32).T)


def _remap(x: sp.spmatrix, cols: CompactColumns) -> sp.csr_matrix:
    xc = x.tocsc()[:, cols.global_ids].tocsr()
    return xc
