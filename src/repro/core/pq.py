"""Product quantization for the dense component (paper §2.3, §4.1, §6).

Codebooks are learned with Lloyd's k-means per subspace (paper cites [17] QUIPS;
we use the reconstruction-MSE objective with optional whitening, which §4.1.3
notes is the QUIPS special case where query distribution == datapoint
distribution).

Two indices are built (paper §6):
  * data index   — K_U = d^D/2 subspaces, l = 16 codewords (4 bits / 2 dims),
                   scanned with the LUT16 kernel (kernels/lut16.py);
  * residual idx — K_V = d^D  subspaces, l = 256 ⇒ per-dimension scalar
                   quantization of the residual at 8 bits (§6.1.1).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lut16 import pack_codes, unpack_codes  # noqa: F401

__all__ = [
    "PQCodebooks", "train_codebooks", "pq_encode", "pq_decode",
    "adc_lut", "adc_scores_ref", "ScalarQuant", "scalar_quantize",
    "scalar_dequantize", "scalar_quantize_rows", "encode_rows",
    "whitening_transform", "pack_codes", "unpack_codes",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PQCodebooks:
    """K subspace codebooks, all subspaces the same width p = d^D / K.

    centers: (K, l, p) float32.
    """
    centers: jax.Array

    @property
    def num_subspaces(self) -> int:
        return self.centers.shape[0]

    @property
    def num_codes(self) -> int:
        return self.centers.shape[1]

    @property
    def sub_dim(self) -> int:
        return self.centers.shape[2]


def _split_subspaces(x: jax.Array, k: int) -> jax.Array:
    """(N, d) -> (N, K, p): contiguous subvector blocks (paper Eq. 2)."""
    n, d = x.shape
    assert d % k == 0, f"d={d} not divisible by K={k}"
    return x.reshape(n, k, d // k)


@partial(jax.jit, static_argnums=(1, 2, 3))
def _kmeans_one_subspace(x: jax.Array, l: int, iters: int, seed: int) -> jax.Array:
    """Lloyd's k-means on (N, p) -> (l, p) centers.  kmeans++-lite init:
    random distinct points, deterministic under `seed`."""
    n, p = x.shape
    key = jax.random.PRNGKey(seed)
    idx = jax.random.choice(key, n, shape=(l,), replace=False)
    centers = x[idx]

    def step(centers, _):
        # (N, l) squared distances via ||x||^2 - 2 x.c + ||c||^2 ; x-term constant.
        d2 = (
            jnp.sum(centers * centers, axis=1)[None, :]
            - 2.0 * x @ centers.T
        )
        assign = jnp.argmin(d2, axis=1)
        one_hot = jax.nn.one_hot(assign, l, dtype=x.dtype)        # (N, l)
        counts = one_hot.sum(axis=0)                              # (l,)
        sums = one_hot.T @ x                                      # (l, p)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # Keep old center for empty clusters.
        new = jnp.where((counts > 0)[:, None], new, centers)
        return new, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    return centers


def train_codebooks(x_dense: jax.Array, num_subspaces: int, num_codes: int = 16,
                    iters: int = 12, seed: int = 0,
                    sample: int | None = 65536) -> PQCodebooks:
    """Learn K codebooks by independent per-subspace k-means (paper §2.3)."""
    x = jnp.asarray(x_dense, jnp.float32)
    if sample is not None and x.shape[0] > sample:
        sel = jax.random.choice(jax.random.PRNGKey(seed + 101), x.shape[0],
                                shape=(sample,), replace=False)
        x = x[sel]
    subs = _split_subspaces(x, num_subspaces)                     # (N, K, p)
    centers = []
    for k in range(num_subspaces):
        centers.append(_kmeans_one_subspace(subs[:, k, :], num_codes, iters, seed + k))
    return PQCodebooks(centers=jnp.stack(centers))                # (K, l, p)


@jax.jit
def pq_encode(x_dense: jax.Array, codebooks: PQCodebooks) -> jax.Array:
    """phi_PQ: (N, d) -> (N, K) uint8 codes (argmin L2 per subspace)."""
    c = codebooks.centers                                         # (K, l, p)
    subs = _split_subspaces(jnp.asarray(x_dense, jnp.float32), c.shape[0])
    # (N, K, l) squared distance; x-term constant wrt argmin.
    d2 = (
        jnp.sum(c * c, axis=2)[None]                              # (1, K, l)
        - 2.0 * jnp.einsum("nkp,klp->nkl", subs, c)
    )
    return jnp.argmin(d2, axis=2).astype(jnp.uint8)


@jax.jit
def pq_decode(codes: jax.Array, codebooks: PQCodebooks) -> jax.Array:
    """Reconstruct (N, d) from (N, K) codes."""
    c = codebooks.centers
    k, l, p = c.shape
    recon = jnp.take_along_axis(
        c[None], codes[:, :, None, None].astype(jnp.int32), axis=2
    )                                                             # (N, K, 1, p)
    return recon[:, :, 0, :].reshape(codes.shape[0], k * p)


@jax.jit
def adc_lut(q_dense: jax.Array, codebooks: PQCodebooks) -> jax.Array:
    """Asymmetric LUT (paper §4.1.1): T[q][k][c] = q^(k) · U^(k)_c.

    q_dense: (Q, d) or (d,).  Returns (Q, K, l) (or (K, l)) float32.
    """
    c = codebooks.centers                                         # (K, l, p)
    single = q_dense.ndim == 1
    q = jnp.atleast_2d(jnp.asarray(q_dense, jnp.float32))
    qs = _split_subspaces(q, c.shape[0])                          # (Q, K, p)
    lut = jnp.einsum("qkp,klp->qkl", qs, c)
    return lut[0] if single else lut


@jax.jit
def adc_scores_ref(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """Reference ADC scan: (N, K) codes × (Q, K, l) LUT -> (Q, N) scores.

    Pure-jnp oracle for the LUT16 Pallas kernel (kernels/ref.py re-exports)."""
    single = lut.ndim == 2
    lut3 = lut[None] if single else lut                           # (Q, K, l)
    gathered = jnp.take_along_axis(
        lut3[:, None],                                            # (Q, 1, K, l)
        codes[None, :, :, None].astype(jnp.int32),                # (1, N, K, 1)
        axis=3,
    )[..., 0]                                                     # (Q, N, K)
    out = gathered.sum(axis=-1)
    return out[0] if single else out


# ---------------------------------------------------------------------------
# Scalar quantization — the dense residual index (K_V = d^D, l = 256, §6.1.1)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScalarQuant:
    """Per-dimension affine int8 quantization: x ≈ scale * q + zero."""
    q: jax.Array          # (N, d) int8
    scale: jax.Array      # (d,) float32
    zero: jax.Array       # (d,) float32


@jax.jit
def scalar_quantize(x: jax.Array) -> ScalarQuant:
    x = jnp.asarray(x, jnp.float32)
    lo = x.min(axis=0)
    hi = x.max(axis=0)
    scale = jnp.maximum(hi - lo, 1e-12) / 255.0
    zero = lo
    q = jnp.clip(jnp.round((x - zero) / scale), 0, 255) - 128
    return ScalarQuant(q=q.astype(jnp.int8), scale=scale, zero=zero)


@jax.jit
def scalar_dequantize(sq: ScalarQuant) -> jax.Array:
    return (sq.q.astype(jnp.float32) + 128.0) * sq.scale + sq.zero


def scalar_quantize_rows(x: np.ndarray, scale: np.ndarray,
                         zero: np.ndarray) -> np.ndarray:
    """Quantize NEW rows with FROZEN affine params (delta-shard insert path,
    DESIGN.md §6): the streaming index must keep serving the main
    generation's ``scale``/``zero``, so inserted residual rows are clamped
    into the existing grid instead of re-deriving it.  Same rounding as
    ``scalar_quantize`` (half-to-even), host-side numpy.  (M, d) -> int8."""
    x = np.asarray(x, np.float32)
    scale = np.asarray(scale, np.float32)
    zero = np.asarray(zero, np.float32)
    q = np.clip(np.round((x - zero) / scale), 0, 255) - 128
    return q.astype(np.int8)


def encode_rows(x_dense: np.ndarray, codebooks: PQCodebooks, *,
                pack: bool = False) -> np.ndarray:
    """Encode-on-insert: PQ-encode NEW dense rows against the FROZEN
    codebooks of the serving index (no retraining until compaction,
    DESIGN.md §6).  pack=True returns the rows packed two codes per byte —
    the delta shard's append unit — with pack_codes' odd-K phantom nibble.
    (M, d) -> (M, K) uint8, or (M, ceil(K/2)) packed."""
    codes = np.asarray(pq_encode(jnp.asarray(x_dense, jnp.float32),
                                 codebooks))
    return pack_codes(codes) if pack else codes


def whitening_transform(x_dense: jax.Array, eps: float = 1e-4):
    """P = Cov^{-1/2}(X^D) (paper §4.1.3).  Returns (P, P^{-T}) so that data is
    multiplied by P and queries by (P^{-1})^T, preserving inner products."""
    x = np.asarray(x_dense, np.float64)
    cov = np.cov(x, rowvar=False) + eps * np.eye(x.shape[1])
    evals, evecs = np.linalg.eigh(cov)
    p = evecs @ np.diag(evals ** -0.5) @ evecs.T
    p_inv_t = evecs @ np.diag(evals ** 0.5) @ evecs.T            # symmetric
    return jnp.asarray(p, jnp.float32), jnp.asarray(p_inv_t, jnp.float32)
