"""Distributed hybrid search (paper §7.2 "Online Search": 200 servers, one
shard each, merge results) mapped to JAX shard_map over the mesh 'data' axis.

Each device owns a row-shard of every row-parallel index structure (PQ codes,
inverted-index, head block, residuals).  A query batch is replicated; every
device scores its shard and keeps a local top-k; only (k × num_shards)
candidates cross the network (all_gather), never the index — the same
communication pattern as the paper's RPC fan-out.

The same function lowers at ShapeDtypeStruct scale (1e9 rows across 512
devices) in launch/dryrun.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["sharded_pass1_topk", "make_sharded_search_fn", "merge_topk"]


def merge_topk(scores: jax.Array, ids: jax.Array, k: int):
    """Merge per-shard candidates: (Q, S*k) -> (Q, k)."""
    vals, pos = jax.lax.top_k(scores, k)
    return vals, jnp.take_along_axis(ids, pos, axis=1)


def _pass1_local(codes, lut, inv_rows, inv_vals, q_dims, q_vals, row_offset,
                 *, k: int, axis: str, adc: str = "gather"):
    """Runs on one shard (inside shard_map): approximate hybrid scores for the
    local rows, local top-k, then all_gather the candidate sets."""
    n_local = codes.shape[0]
    if adc == "onehot":
        # MXU path (the LUT16 kernel's contraction, expressed in jnp): codes
        # expand to one-hot and contract against the LUT as a single matmul —
        # no (Q, N, K) gather intermediate, systolic-friendly on TPU.
        l = lut.shape[-1]
        onehot = (codes[:, :, None] ==
                  jnp.arange(l, dtype=codes.dtype)).astype(jnp.bfloat16)
        dense_scores = jax.lax.dot_general(
            lut.reshape(lut.shape[0], -1).astype(jnp.bfloat16),
            onehot.reshape(n_local, -1),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)         # (Q, n_local)
    else:
        # gather form (CPU-friendly reference path)
        gathered = jnp.take_along_axis(
            lut[:, None], codes[None, :, :, None].astype(jnp.int32), axis=3
        )[..., 0]                                       # (Q, n_local, K)
        dense_scores = gathered.sum(axis=-1)

    # sparse inverted-index accumulation on the local shard
    qn, nq = q_dims.shape
    rows_g = jnp.take(inv_rows, q_dims, axis=0, mode="fill", fill_value=n_local)
    vals_g = jnp.take(inv_vals, q_dims, axis=0, mode="fill", fill_value=0.0)
    acc = jnp.zeros((qn, n_local), jnp.float32)
    qidx = jnp.broadcast_to(jnp.arange(qn)[:, None, None], rows_g.shape)
    sparse_scores = acc.at[qidx, rows_g].add(vals_g * q_vals[:, :, None],
                                             mode="drop")

    scores = dense_scores + sparse_scores
    local_s, local_i = jax.lax.top_k(scores, k)
    local_i = local_i + row_offset[0]                  # globalize ids
    all_s = jax.lax.all_gather(local_s, axis, axis=1, tiled=True)  # (Q, S*k)
    all_i = jax.lax.all_gather(local_i, axis, axis=1, tiled=True)
    return merge_topk(all_s, all_i, k)


def make_sharded_search_fn(mesh: Mesh, *, k: int, axis: str = "data",
                           adc: str = "gather"):
    """Build the jit-able sharded pass-1 search.

    Index arrays are sharded on their row axis over `axis`; queries and LUTs
    are replicated.  Returns fn(codes, lut, inv_rows, inv_vals, q_dims,
    q_vals, row_offset) -> (scores (Q,k), global ids (Q,k)).

    row_offset: (num_shards,) int32 — global row id of each shard's first row.
    adc: "gather" (reference) or "onehot" (MXU contraction — the LUT16
    kernel's algorithm; the TPU-native fast path).
    """
    spec_rows = P(axis)        # row-sharded index structures
    spec_rep = P()             # replicated queries
    fn = jax.shard_map(
        functools.partial(_pass1_local, k=k, axis=axis, adc=adc),
        mesh=mesh,
        in_specs=(spec_rows, spec_rep, P(axis, None), P(axis, None),
                  spec_rep, spec_rep, P(axis)),
        out_specs=(spec_rep, spec_rep),
        check_vma=False,
    )
    return jax.jit(fn)


def sharded_pass1_topk(mesh: Mesh, codes, lut, inv_rows, inv_vals, q_dims,
                       q_vals, *, k: int, axis: str = "data"):
    """Convenience wrapper: shards the inputs, runs the search.

    NOTE inv_rows/inv_vals must be *per-shard stacked*: shape
    (num_shards * d_active_shard, L) where each shard's slice holds row ids
    local to that shard.  ``row_offset`` is derived from equal row sharding.
    """
    num_shards = mesh.shape[axis]
    n = codes.shape[0]
    assert n % num_shards == 0
    row_offset = jnp.arange(num_shards, dtype=jnp.int32) * (n // num_shards)
    fn = make_sharded_search_fn(mesh, k=k, axis=axis)
    return fn(codes, lut, inv_rows, inv_vals, q_dims, q_vals, row_offset)
