"""Distributed hybrid search (paper §7.2 "Online Search": 200 servers, one
shard each, merge results) mapped to JAX shard_map over the mesh 'data' axis.

Each device owns a row-shard of every row-parallel index structure (PQ codes,
inverted-index, residuals).  A query batch is replicated; every device scores
its shard and keeps a local top-k; only (k × num_shards) candidates cross the
network (all_gather), never the index — the same communication pattern as the
paper's RPC fan-out.

All scoring routes through core/engine.py (one implementation of the paper's
scorer); this module only adds the shard_map plumbing:

* ``make_sharded_search_fn``  — pass-1 only (approximate scores + merge);
* ``make_sharded_search3_fn`` — the FULL three-pass search per shard (pass 1
  approx → pass 2 dense residual → pass 3 sparse residual, each shard refining
  its own candidates against its local residual rows — the paper's per-server
  reordering) followed by one all_gather merge of the refined top-h.

The same functions lower at ShapeDtypeStruct scale (1e9 rows across 512
devices) in launch/dryrun.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

from . import engine as eng
from . import residual as res
from .pq import ScalarQuant
from .sparse_index import (PaddedInvertedIndex, PaddedSparseRows,
                           TileSparseHead, score_inverted)

__all__ = ["sharded_pass1_topk", "make_sharded_search_fn",
           "make_sharded_search3_fn", "sharded_three_pass_topk", "merge_topk",
           "merge_topk_host", "ceil16", "split_index_arrays"]


def merge_topk(scores: jax.Array, ids: jax.Array, k: int):
    """Merge per-shard candidates: (Q, S*k) -> (Q, k)."""
    vals, pos = jax.lax.top_k(scores, k)
    return vals, jnp.take_along_axis(ids, pos, axis=1)


def ceil16(n: int) -> int:
    """Round up to the 16 bucket — the tombstone-overfetch granularity
    (DESIGN.md §6.2): overfetch sizes are jit-static, so bucketing them
    keeps the compilation cache bounded while mutations accumulate."""
    return -(-n // 16) * 16


def merge_topk_host(parts, h: int, *, drop_ids=None, dedup_upserts=False):
    """Host-side top-h merge over per-engine candidate sets, the streaming
    generalization of the serving fan-out merge (DESIGN.md §5.4, §6.2) and
    the router-side merge of the cluster tier (DESIGN.md §8.2).

    parts: iterable of ``(scores (Q, k_i), ids (Q, k_i), filtered)`` — the
    per-engine top-k, already mapped to a COMMON (external) id space; the
    widths k_i may differ (the delta shard fetches its whole capacity).
    ``filtered=True`` parts drop candidates whose id is in ``drop_ids``
    (main-generation tombstones); the delta part passes False so an
    upserted row's new copy survives while its superseded main copy dies.
    ``filtered`` may also be an explicit array of ids to drop from THAT
    part only — the per-shard tombstone view the cluster router needs:
    ``drop_ids`` alone assumes every part shares one tombstone view, which
    a lagging replica does not (its own view is stale, so the MERGE must
    apply the caller's authoritative set, per part, DESIGN.md §8.4).

    ``dedup_upserts=True`` additionally drops, from every filtered part,
    any id that appears with a finite score in an unfiltered (delta) part:
    a live delta copy proves every main copy of that id is tombstoned
    (upsert kills before it appends), so the rule is exact — it only
    matters across a transport, where the main and delta parts cannot pin
    one atomic view the way the in-process fan-out does.

    Stable descending sort over parts concatenated in caller order, so ties
    break exactly like ``lax.top_k`` on the unsharded array when parts are
    shard slices in row order.  Entries with non-finite scores (tombstone
    masks, dropped ids) get id -1; callers overfetch (h + tombstone slack)
    so a full result always has h real rows.  Returns (scores, ids) (Q, h).
    """
    drop = np.asarray(sorted(drop_ids), np.int64) \
        if drop_ids else np.empty(0, np.int64)
    parts = [(np.asarray(s, np.float32), np.asarray(ids, np.int64), f)
             for s, ids, f in parts]
    delta_live = np.empty(0, np.int64)
    if dedup_upserts:
        live = [ids[np.isfinite(s)] for s, ids, f in parts
                if isinstance(f, bool) and not f]
        if live:
            delta_live = np.unique(np.concatenate([v.ravel() for v in live]))
    ss, ii = [], []
    for s, ids, filtered in parts:
        if isinstance(filtered, bool):
            part_drop = drop if filtered else np.empty(0, np.int64)
        else:                      # explicit per-part tombstone view
            part_drop = np.asarray(sorted(filtered), np.int64)
            filtered = True
        if filtered and delta_live.size:
            part_drop = np.union1d(part_drop, delta_live)
        if part_drop.size:
            s = np.where(np.isin(ids, part_drop), -np.inf, s)
        ss.append(s)
        ii.append(ids)
    ss = np.concatenate(ss, axis=1)
    ii = np.concatenate(ii, axis=1)
    if ss.shape[1] < h:                       # tiny pool: pad to (Q, h)
        pad = h - ss.shape[1]
        ss = np.pad(ss, ((0, 0), (0, pad)), constant_values=-np.inf)
        ii = np.pad(ii, ((0, 0), (0, pad)), constant_values=-1)
    order = np.argsort(-ss, axis=1, kind="stable")[:, :h]
    s_out = np.take_along_axis(ss, order, axis=1)
    i_out = np.take_along_axis(ii, order, axis=1)
    return s_out, np.where(np.isfinite(s_out), i_out, -1)


def split_index_arrays(arrays: eng.IndexArrays, num_shards: int, *,
                       ragged: bool = False
                       ) -> tuple[list[eng.IndexArrays], np.ndarray]:
    """Row-slice one ``IndexArrays`` into per-shard copies + row offsets.

    The host-side analogue of the shard_map row sharding above, and the
    fan-out entry point for ``serve/query_service.py`` (DESIGN.md §5): each
    shard gets its own complete ``IndexArrays`` over rows ``[s*n/S, (s+1)*n/S)``
    so a ``ScoringEngine`` per shard runs the FULL three-pass search on its
    rows; the service dispatches all shards back-to-back (JAX async dispatch
    overlaps them) and merges the per-shard top-k on host.

    Every row-parallel structure is sliced; the inverted index is localized
    (entries outside the shard re-padded to the ``n_local`` sentinel); the
    head block is re-padded to the tile grid and its BCSR form rebuilt when
    the parent carried one.  Column-space structures (codebooks, scales,
    ``head_pos``) are shared with the parent, not copied.

    Returns ``(shards, row_offsets)`` with ``row_offsets[s]`` the global row
    id of shard ``s``'s first row.  By default requires
    ``num_points % num_shards == 0`` (the same equal-rows contract as
    ``sharded_pass1_topk``); ``ragged=True`` instead ceil-splits — the first
    ``n % S`` shards get one extra row — which the cluster tier (DESIGN.md
    §8.2) needs because a compacted corpus has an arbitrary survivor count.
    Either way the shards are contiguous row slices in row order, so the
    stable ``merge_topk_host`` over them is bit-identical to the unsharded
    search.
    """
    n = arrays.num_points
    if num_shards < 1 or (n % num_shards and not ragged) or num_shards > n:
        raise ValueError(
            f"cannot split {n} rows into {num_shards} equal shards"
            + (" (pass ragged=True for a ceil-split)"
               if ragged is False and num_shards <= n else ""))
    base, rem = divmod(n, num_shards)
    sizes = np.full(num_shards, base, np.int64)
    sizes[:rem] += 1
    bounds = np.concatenate([[0], np.cumsum(sizes)])
    offsets = bounds[:-1].astype(np.int32)

    inv_rows = np.asarray(arrays.inv_index.rows)
    inv_vals = np.asarray(arrays.inv_index.vals)
    sres_cols = np.asarray(arrays.sparse_residual.cols)
    sres_vals = np.asarray(arrays.sparse_residual.vals)
    res_q = np.asarray(arrays.dense_residual.q)
    codes = np.asarray(arrays.codes)
    head_block = (np.asarray(arrays.head.block, np.float32)
                  if arrays.head is not None else None)
    vmask = (np.asarray(arrays.valid_mask)
             if arrays.valid_mask is not None else None)

    shards: list[eng.IndexArrays] = []
    for s in range(num_shards):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        n_local = hi - lo
        inside = (inv_rows >= lo) & (inv_rows < hi)
        inv_s = PaddedInvertedIndex(
            rows=jnp.asarray(
                np.where(inside, inv_rows - lo, n_local).astype(np.int32)),
            vals=jnp.asarray(
                np.where(inside, inv_vals, 0.0).astype(np.float32)),
            num_points=n_local)

        head_s = arrays.head
        tiles, ptr, col = arrays.head_tiles, arrays.head_ptr, arrays.head_col
        max_steps = arrays.head_max_steps
        if arrays.head is not None:
            br, bc = arrays.head.block_rows, arrays.head.block_cols
            n_pad = -(-n_local // br) * br
            blk = np.zeros((n_pad, head_block.shape[1]), np.float32)
            blk[:n_local] = head_block[lo:hi]
            occ = blk.reshape(n_pad // br, br,
                              blk.shape[1] // bc, bc).any(axis=(1, 3))
            head_s = TileSparseHead(
                block=jnp.asarray(blk, arrays.head.block.dtype),
                occupancy=jnp.asarray(occ), head_dims=arrays.head.head_dims,
                block_rows=br, block_cols=bc)
            if max_steps > 0:
                from repro.kernels.ops import bcsr_from_head
                tiles, ptr, col, max_steps = bcsr_from_head(head_s)

        shards.append(eng.IndexArrays(
            codebooks=arrays.codebooks,
            codes=jnp.asarray(codes[lo:hi]),
            inv_index=inv_s, head=head_s, head_pos=arrays.head_pos,
            head_tiles=tiles, head_ptr=ptr, head_col=col,
            dense_residual=ScalarQuant(q=jnp.asarray(res_q[lo:hi]),
                                       scale=arrays.dense_residual.scale,
                                       zero=arrays.dense_residual.zero),
            sparse_residual=PaddedSparseRows(
                cols=jnp.asarray(sres_cols[lo:hi]),
                vals=jnp.asarray(sres_vals[lo:hi])),
            num_points=n_local, d_active=arrays.d_active,
            head_max_steps=max_steps, codes_packed=arrays.codes_packed,
            valid_mask=(jnp.asarray(vmask[lo:hi])
                        if vmask is not None else None)))
    return shards, offsets


def _pass1_scores_local(codes, lut, inv_rows, inv_vals, q_dims, q_vals,
                        backend: eng.Backend):
    """Approximate hybrid scores for the local row-shard, via the engine.

    For backend PALLAS_PACKED, ``codes`` is the packed (N_local, ceil(K/2))
    form — packed codes row-shard exactly like unpacked ones, so each device
    streams (and stores) half the code bytes."""
    n_local = codes.shape[0]
    inv = PaddedInvertedIndex(rows=inv_rows, vals=inv_vals,
                              num_points=n_local)
    return (eng.adc_scores(codes, lut, backend)
            + score_inverted(inv, q_dims, q_vals))


def _pass1_topk_local(codes, lut, inv_rows, inv_vals, q_dims, q_vals, *,
                      k: int, backend: eng.Backend):
    """Per-shard pass-1 top-k: fused scan-and-select (DESIGN.md §2.5) on the
    Pallas backends when k fits the candidate buffer — the per-device
    (Q, N_local) score matrix never hits HBM — else materialize + top_k.
    Both routes are bit-identical (shared block partial sums; fp32 add is
    commutative), so the fan-out merge sees the same candidates either way."""
    from repro.kernels.ops import MAX_FUSED_CANDIDATES, lut16_adc_topk
    n_local = codes.shape[0]
    inv = PaddedInvertedIndex(rows=inv_rows, vals=inv_vals,
                              num_points=n_local)
    if (backend in (eng.Backend.PALLAS, eng.Backend.PALLAS_PACKED)
            and k <= MAX_FUSED_CANDIDATES):
        bias = score_inverted(inv, q_dims, q_vals)
        return lut16_adc_topk(
            codes, lut, k, bias=bias,
            packed=backend is eng.Backend.PALLAS_PACKED)
    scores = (eng.adc_scores(codes, lut, backend)
              + score_inverted(inv, q_dims, q_vals))
    return jax.lax.top_k(scores, k)


def _pass1_local(codes, lut, inv_rows, inv_vals, q_dims, q_vals, row_offset,
                 *, k: int, axis: str, backend: eng.Backend):
    """Runs on one shard (inside shard_map): engine pass-1 top-k for the
    local rows (fused on the Pallas backends), then all_gather the
    candidate sets."""
    local_s, local_i = _pass1_topk_local(codes, lut, inv_rows, inv_vals,
                                         q_dims, q_vals, k=k, backend=backend)
    local_i = local_i + row_offset[0]                  # globalize ids
    all_s = jax.lax.all_gather(local_s, axis, axis=1, tiled=True)  # (Q, S*k)
    all_i = jax.lax.all_gather(local_i, axis, axis=1, tiled=True)
    return merge_topk(all_s, all_i, k)


def make_sharded_search_fn(mesh: Mesh, *, k: int, axis: str = "data",
                           adc: str = "gather"):
    """Build the jit-able sharded pass-1 search.

    Index arrays are sharded on their row axis over `axis`; queries and LUTs
    are replicated.  Returns fn(codes, lut, inv_rows, inv_vals, q_dims,
    q_vals, row_offset) -> (scores (Q,k), global ids (Q,k)).

    row_offset: (num_shards,) int32 — global row id of each shard's first row.
    adc: an engine Backend name — "ref"/"gather" (reference), "onehot"/
    "onehot-mxu" (MXU contraction), "pallas" (LUT16 kernel), or
    "pallas-packed" (LUT16 over two-per-byte 4-bit codes: pass codes packed
    via kernels pack_codes; half the per-device HBM, same row sharding).
    """
    backend = eng.Backend.from_name(adc)
    spec_rows = P(axis)        # row-sharded index structures
    spec_rep = P()             # replicated queries
    fn = compat.shard_map(
        functools.partial(_pass1_local, k=k, axis=axis, backend=backend),
        mesh=mesh,
        in_specs=(spec_rows, spec_rep, P(axis, None), P(axis, None),
                  spec_rep, spec_rep, P(axis)),
        out_specs=(spec_rep, spec_rep),
        check_vma=False,
    )
    return jax.jit(fn)


def sharded_pass1_topk(mesh: Mesh, codes, lut, inv_rows, inv_vals, q_dims,
                       q_vals, *, k: int, axis: str = "data",
                       adc: str = "gather"):
    """Convenience wrapper: shards the inputs, runs the pass-1 search.

    NOTE inv_rows/inv_vals must be *per-shard stacked*: shape
    (num_shards * d_active, L) where each shard's slice holds row ids
    local to that shard.  ``row_offset`` is derived from equal row sharding.
    """
    num_shards = mesh.shape[axis]
    n = codes.shape[0]
    assert n % num_shards == 0
    row_offset = jnp.arange(num_shards, dtype=jnp.int32) * (n // num_shards)
    fn = make_sharded_search_fn(mesh, k=k, axis=axis, adc=adc)
    return fn(codes, lut, inv_rows, inv_vals, q_dims, q_vals, row_offset)


# ---------------------------------------------------------------------------
# Full three-pass sharded search (paper §7.2: every server refines locally,
# the coordinator merges refined top-h)
# ---------------------------------------------------------------------------

def _search3_local(codes, lut, inv_rows, inv_vals, res_q, res_scale, res_zero,
                   sres_cols, sres_vals, q_dims, q_vals, q_dense, q_cols,
                   row_offset, *, h: int, alpha: int, beta: int, axis: str,
                   backend: eng.Backend):
    """One shard's full three-pass search; candidate counts are per-shard so
    every server does the paper's reordering on its own rows."""
    n_local = codes.shape[0]
    c1 = min(max(alpha * h, h), n_local)
    c2 = min(max(beta * h, h), c1)

    # pass 1: local candidates, overfetch c1 (fused on Pallas backends)
    s1, ids1 = _pass1_topk_local(codes, lut, inv_rows, inv_vals,
                                 q_dims, q_vals, k=c1, backend=backend)

    # pass 2: + local dense residual rows, keep c2
    sq = ScalarQuant(q=res_q, scale=res_scale, zero=res_zero)
    extra_d = res.dense_residual_scores(sq, ids1, q_dense)
    s2, ids2 = res.reorder_pass(s1, ids1, extra_d, c2)

    # pass 3: + local sparse residual rows, local top-h
    rows = PaddedSparseRows(cols=sres_cols, vals=sres_vals)
    extra_s = res.sparse_residual_scores(rows, ids2, q_cols)
    s3, ids3 = res.reorder_pass(s2, ids2, extra_s, h)

    ids3 = ids3 + row_offset[0]                        # globalize ids
    all_s = jax.lax.all_gather(s3, axis, axis=1, tiled=True)   # (Q, S*h)
    all_i = jax.lax.all_gather(ids3, axis, axis=1, tiled=True)
    return merge_topk(all_s, all_i, h)


def make_sharded_search3_fn(mesh: Mesh, *, h: int, alpha: int = 20,
                            beta: int = 5, axis: str = "data",
                            adc: str = "gather"):
    """Build the jit-able sharded THREE-pass search.

    Row-sharded over `axis`: codes (N, K) — or (N, ceil(K/2)) packed
    two-per-byte when adc="pallas-packed" — inv_rows/inv_vals (per-shard
    stacked, see sharded_pass1_topk), res_q (N, d^D) int8 dense-residual rows,
    sres_cols/sres_vals (N, R) padded sparse-residual rows.  Replicated: lut,
    res_scale/res_zero, q_dims/q_vals, q_dense (Q, d^D), q_cols
    (Q, d_active + 1) — the padded sparse queries scattered into the compact
    column space (engine.scatter_queries_compact).  row_offset: (S,) int32.

    Returns fn(...) -> (scores (Q, h), global ids (Q, h)).
    """
    backend = eng.Backend.from_name(adc)
    rows = P(axis)
    rep = P()
    fn = compat.shard_map(
        functools.partial(_search3_local, h=h, alpha=alpha, beta=beta,
                          axis=axis, backend=backend),
        mesh=mesh,
        in_specs=(rows, rep, P(axis, None), P(axis, None),   # codes, lut, inv
                  rows, rep, rep,                            # dense residual
                  rows, rows,                                # sparse residual
                  rep, rep, rep, rep,                        # queries
                  P(axis)),                                  # row_offset
        out_specs=(rep, rep),
        check_vma=False,
    )
    return jax.jit(fn)


def sharded_three_pass_topk(mesh: Mesh, codes, lut, inv_rows, inv_vals,
                            res_q, res_scale, res_zero, sres_cols, sres_vals,
                            q_dims, q_vals, q_dense, q_cols, *, h: int,
                            alpha: int = 20, beta: int = 5,
                            axis: str = "data", adc: str = "gather"):
    """Convenience wrapper: derives row_offset from equal row sharding and
    runs the full three-pass fan-out search."""
    num_shards = mesh.shape[axis]
    n = codes.shape[0]
    assert n % num_shards == 0
    row_offset = jnp.arange(num_shards, dtype=jnp.int32) * (n // num_shards)
    fn = make_sharded_search3_fn(mesh, h=h, alpha=alpha, beta=beta, axis=axis,
                                 adc=adc)
    return fn(codes, lut, inv_rows, inv_vals, res_q, res_scale, res_zero,
              sres_cols, sres_vals, q_dims, q_vals, q_dense, q_cols,
              row_offset)
